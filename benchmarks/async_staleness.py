"""Staleness bound k vs. iteration throughput (ssp sweep) on the event
engine.

Under stragglers, BSP pays the max of n lognormals every iteration. SSP(k)
lets a worker run up to k iterations ahead of the slowest peer, so fast
workers amortize slow ones' bad draws; async removes the bound entirely.
This sweep quantifies the throughput side of that trade — the *numeric*
side (stale gradients still converge) is proven by ``LocalWorkerPool``'s
matching sync modes in tests/test_event_engine.py.

Run:  PYTHONPATH=src python -m benchmarks.async_staleness
"""
from __future__ import annotations

from repro.serverless import WORKLOADS, EventEngine, ObjectStore, ParamStore
from benchmarks.common import emit_json

W = WORKLOADS["bert-small"]
N_WORKERS = 32
MEMORY_MB = 4096
BATCH = 1024
SAMPLES = 40_000
SIGMA = 0.5
MODES = [("bsp", 0), ("ssp", 1), ("ssp", 2), ("ssp", 4), ("ssp", 8),
         ("async", None)]


def run() -> list:
    rows = []
    bsp_wall = None
    for mode, k in MODES:
        res = EventEngine(W, "hier", N_WORKERS, MEMORY_MB, BATCH,
                          ParamStore(), ObjectStore(), samples=SAMPLES,
                          sync_mode=mode, staleness=k or 0,
                          straggler_sigma=SIGMA, seed=0,
                          trace_enabled=False).run()
        if bsp_wall is None:
            bsp_wall = res.wall_s
        rows.append({
            "figure": "async_staleness", "sync_mode": mode,
            "staleness_k": k, "sigma": SIGMA,
            "wall_s": round(res.wall_s, 2),
            "iters_per_s": round(res.iters_done / res.wall_s, 4),
            "samples_per_s": round(res.samples_done / res.wall_s, 2),
            "cost_usd": round(res.cost_usd, 4),
            "speedup_vs_bsp": round(bsp_wall / res.wall_s, 3),
        })
    return rows


def summarize(rows) -> str:
    by = {(r["sync_mode"], r["staleness_k"]): r for r in rows}
    a = by[("async", None)]
    best_ssp = max((r for r in rows if r["sync_mode"] == "ssp"),
                   key=lambda r: r["speedup_vs_bsp"])
    return (f"sigma={SIGMA}: async {a['speedup_vs_bsp']:.2f}x bsp; "
            f"ssp(k={best_ssp['staleness_k']}) reaches "
            f"{best_ssp['speedup_vs_bsp'] / a['speedup_vs_bsp']:.0%} of "
            "async at bounded staleness")


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(summarize(rows))
    print("json:", emit_json("event_async_staleness", rows))
