"""Backend arbitrage: serverless vs VM vs GPU — where should a job run?

Multi-backend execution makes *where a job runs* a searched dimension
(``ConfigSpace(search_backend=True)``), and this benchmark demonstrates
the three claims that justify it:

1. **The flip.** The same Bayesian optimizer, pointed at two jobs on
   opposite sides of the scale/urgency threshold, picks opposite
   backends: a small job under a tight deadline lands on serverless
   (only instant elasticity fits inside the deadline — every VM-kind
   candidate pays a provisioning delay it cannot hide), while a large
   compute-dominated job under a budget lands on the GPU VM (7800
   Gflop/s amortizes its provisioning and hourly rate within a few
   iterations). Asserted on the BO winner's backend for both jobs.

2. **The workflow split.** Under ONE ``Goal(deadline_s, budget_usd)``
   and one shared ledger, an HPO sweep runs its rungs on serverless
   (cheap, elastic trial fleets) while the winner's fine-tune — pinned
   via ``TaskSpec(backend="gpu_vm")`` and warm-started from the sweep —
   runs on the GPU VM. Asserted: the rungs billed Lambda requests, the
   fine-tune billed ``backend:gpu_vm`` dollars, and the whole workflow
   stayed inside the budget.

3. **Hazard-aware checkpointing.** On a preemption-heavy spot
   ``PriceTrace``, the hazard-aware cadence (Young–Daly on the forward
   hazard + a progress-at-risk flush before each forecast crossing)
   beats *every* constant cadence on total dollars. Asserted against a
   two-decade grid of constant cadences.

Run:  PYTHONPATH=src python -m benchmarks.backend_arbitrage [--smoke]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import ConfigSpace, Goal
from repro.core.bayes_opt import Config
from repro.core.cost_model import epoch_estimate
from repro.core.scheduler import TaskScheduler
from repro.serverless import (BACKENDS, WORKLOADS, ObjectStore, ParamStore,
                              PriceTrace, ServerlessPlatform,
                              simulate_spot_epoch, spot_variant)
from repro.workflow import (HPOSweep, TaskSpec, WorkflowDAG,
                            WorkflowOrchestrator, expand_hpo,
                            sweep_final_tasks)
from benchmarks.common import emit_json

BATCH = 512
# the two sides of the threshold: a small interactive job that must
# finish inside a tight deadline, and a large fine-tune minimizing time
# under a budget
SMALL = ("resnet18", 8192, 1, Goal("min_cost_deadline", deadline_s=120.0))
LARGE = ("bert-small", 65536, 8, Goal("min_time_budget", budget_usd=50.0))

WF_DEADLINE_S = 7200.0
WF_BUDGET_USD = 2.0

# preemption-heavy spot market: ~$0.80/hr baseline with frequent spikes
# above the $1/hr bid (drawn once, seeded — the benchmark is deterministic)
SPOT_BID_USD_PER_HR = 1.0
SPOT_WORK_S = 1800.0
CADENCE_GRID_S = (5.0, 10.0, 20.0, 40.0, 80.0, 160.0, 320.0, 640.0)


def _spot_trace() -> PriceTrace:
    rng = np.random.RandomState(5)
    times, prices = [0.0], [0.8]
    t = 0.0
    for _ in range(30):
        t += float(rng.uniform(90.0, 260.0))
        times.append(t)
        prices.append(float(rng.uniform(1.5, 4.0)))
        t += float(rng.uniform(10.0, 40.0))
        times.append(t)
        prices.append(0.8)
    return PriceTrace(tuple(times), tuple(prices))


def _cheapest_feasible(workload, samples, epochs, goal):
    """Closed-form economics per backend over a worker grid: the
    cheapest config that satisfies the goal's constraint (None if the
    backend cannot satisfy it at all) — the ground truth the optimizer
    is expected to discover."""
    w = WORKLOADS[workload]
    out = {}
    for be in ("", "vm", "gpu_vm"):
        best = None
        for n in (1, 2, 4, 8, 16, 32):
            est = epoch_estimate(w, "hier", Config(n, 3072, backend=be),
                                 BATCH, ParamStore(), ObjectStore(),
                                 samples=samples)
            wall, cost = est.wall_s * epochs, est.cost_usd * epochs
            if goal.deadline_s is not None and wall > goal.deadline_s:
                continue
            if goal.budget_usd is not None and cost > goal.budget_usd:
                continue
            key = cost if goal.kind == "min_cost_deadline" else wall
            if best is None or key < best[0]:
                best = (key, n, wall, cost)
        out[be or "serverless"] = best
    return out


def _bo_pick(workload, samples, epochs, goal, seed=0):
    sched = TaskScheduler(
        ServerlessPlatform(seed=0), ObjectStore(), ParamStore(),
        space=ConfigSpace(max_workers=32, max_memory=4096,
                          search_backend=True),
        seed=seed, bo_max_iters=20, probe_cache=None)
    cfg, t_prof, usd_prof, _ = sched.optimize(
        WORKLOADS[workload], BATCH, goal, epochs, samples)
    return cfg, t_prof, usd_prof


def run_flip() -> list:
    rows = []
    for side, (workload, samples, epochs, goal) in (("small", SMALL),
                                                    ("large", LARGE)):
        cfg, _, probe_usd = _bo_pick(workload, samples, epochs, goal)
        picked = cfg.backend or "serverless"
        econ = _cheapest_feasible(workload, samples, epochs, goal)
        rows.append({
            "figure": "backend_arbitrage", "claim": "flip", "side": side,
            "workload": workload, "samples": samples, "epochs": epochs,
            "goal": goal.kind, "picked_backend": picked,
            "picked_workers": cfg.workers, "picked_memory_mb": cfg.memory_mb,
            "probe_usd": round(probe_usd, 4),
            "feasible_backends": sorted(b for b, v in econ.items()
                                        if v is not None),
        })
    small_row, large_row = rows
    assert small_row["picked_backend"] == "serverless", \
        "under a tight deadline only serverless elasticity is feasible"
    assert small_row["feasible_backends"] == ["serverless"], \
        "the VM provisioning delay must make VM-kind backends infeasible"
    assert large_row["picked_backend"] == "gpu_vm", \
        "a compute-dominated job must arbitrage onto the GPU VM"
    return rows


def run_workflow_split(quick: bool) -> list:
    w = WORKLOADS["resnet18"]
    scale = 2 if quick else 1
    sweep = HPOSweep("hpo", w, n_trials=4, rungs=2, eta=2,
                     epochs_per_rung=1, batch_size=BATCH,
                     samples=8192 // scale, seed=3)
    finetune = TaskSpec("finetune", w, epochs=2, batch_size=BATCH,
                        samples=16384 // scale,
                        deps=sweep_final_tasks(sweep),
                        warm_start_from="hpo", kind="finetune",
                        priority=4, backend="gpu_vm")
    dag = WorkflowDAG(expand_hpo(sweep) + [finetune])
    goal = Goal("deadline_budget", deadline_s=WF_DEADLINE_S,
                budget_usd=WF_BUDGET_USD)
    plat = ServerlessPlatform(seed=0)
    orch = WorkflowOrchestrator(
        dag, goal, plat, ObjectStore(), ParamStore(),
        space=ConfigSpace(max_workers=32, max_memory=4096),
        engine="event", sweeps=[sweep], seed=0)
    res = orch.run()
    gpu_usd = plat.ledger.extra.get("backend:gpu_vm", 0.0)
    row = {
        "figure": "backend_arbitrage", "claim": "workflow_split",
        "wall_s": round(res.wall_s, 2),
        "ledger_usd": round(res.ledger_usd, 4),
        "budget_usd": WF_BUDGET_USD,
        "gpu_vm_usd": round(gpu_usd, 4),
        "lambda_requests": plat.ledger.requests,
        "finetune_epochs": res.tasks["finetune"].epochs_done,
        "winner_trial": res.winners["hpo"][0],
        "dropped": len(res.dropped),
    }
    assert row["ledger_usd"] <= WF_BUDGET_USD, \
        "one goal, one ledger: the split workflow must stay in budget"
    assert row["lambda_requests"] > 0, \
        "the HPO rungs must have billed serverless requests"
    assert gpu_usd > 0.0, \
        "the fine-tune must have billed per-second GPU-VM dollars"
    assert row["finetune_epochs"] >= 1 and row["dropped"] == 0
    return [row]


def run_hazard_cadence() -> list:
    spot = spot_variant(BACKENDS["gpu_vm"], _spot_trace(),
                        bid_usd_per_hr=SPOT_BID_USD_PER_HR,
                        spot_policy="wait")
    hazard = simulate_spot_epoch(SPOT_WORK_S, spot)
    rows = [{
        "figure": "backend_arbitrage", "claim": "hazard_cadence",
        "cadence": "hazard-aware",
        "cost_usd": round(hazard["cost_usd"], 4),
        "wall_s": round(hazard["wall_s"], 1),
        "preemptions": int(hazard["preemptions"]),
        "checkpoints": int(hazard["checkpoints"]),
    }]
    for cadence_s in CADENCE_GRID_S:
        r = simulate_spot_epoch(SPOT_WORK_S, spot, cadence_s=cadence_s)
        rows.append({
            "figure": "backend_arbitrage", "claim": "hazard_cadence",
            "cadence": f"constant-{cadence_s:g}s",
            "cost_usd": round(r["cost_usd"], 4),
            "wall_s": round(r["wall_s"], 1),
            "preemptions": int(r["preemptions"]),
            "checkpoints": int(r["checkpoints"]),
        })
    best_constant = min(r["cost_usd"] for r in rows[1:])
    assert rows[0]["cost_usd"] < best_constant, \
        "hazard-aware cadence must beat every constant cadence on cost"
    return rows


def run(quick: bool = False) -> list:
    return (run_flip() + run_workflow_split(quick) + run_hazard_cadence())


def summarize(rows) -> str:
    flip = {r["side"]: r["picked_backend"] for r in rows
            if r["claim"] == "flip"}
    wf = next(r for r in rows if r["claim"] == "workflow_split")
    hz = next(r for r in rows if r["claim"] == "hazard_cadence"
              and r["cadence"] == "hazard-aware")
    best_const = min(r["cost_usd"] for r in rows
                     if r["claim"] == "hazard_cadence"
                     and r["cadence"] != "hazard-aware")
    return (f"flip: small->{flip['small']} large->{flip['large']}; "
            f"split: ${wf['gpu_vm_usd']:.2f} gpu + "
            f"{wf['lambda_requests']} requests <= ${wf['budget_usd']:.2f}; "
            f"hazard ckpt ${hz['cost_usd']:.3f} vs best-const "
            f"${best_const:.3f}")


if __name__ == "__main__":
    rows = run(quick="--smoke" in sys.argv)
    for r in rows:
        print(r)
    print(summarize(rows))
    print("json:", emit_json("backend_arbitrage", rows))
