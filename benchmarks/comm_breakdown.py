"""Paper Fig. 7: communication time breakdown per step of the hierarchical
synchronization (UL-Shard / DL-Shard / UL-aggr / DL-grad) vs the baselines'
UL-grad / DL-grad, for bert-medium and the RL (atari) workload."""
from __future__ import annotations

from repro.serverless import (WORKLOADS, ObjectStore, ParamStore,
                              comm_breakdown)

N_WORKERS = 50
SCHEMES = {"SMLT": "hier", "Cirrus": "ps", "Siren": "ps_s3"}


def run() -> list:
    ps, os_ = ParamStore(), ObjectStore()
    rows = []
    for wname in ("bert-medium", "atari-rl"):
        w = WORKLOADS[wname]
        for label, scheme in SCHEMES.items():
            bd = comm_breakdown(scheme, w.grad_bytes, N_WORKERS, 4096, ps,
                                os_, extra_upload_bytes=w.extra_upload_bytes)
            for step, t in bd.items():
                rows.append({"figure": "fig7", "workload": wname,
                             "system": label, "step": step,
                             "time_s": round(t, 3)})
    return rows


def summarize(rows) -> str:
    def total(sys_, wl):
        return sum(r["time_s"] for r in rows
                   if r["system"] == sys_ and r["workload"] == wl)

    dl_cirrus = [r["time_s"] for r in rows if r["system"] == "Cirrus"
                 and r["step"] == "DL-grad" and r["workload"] == "bert-medium"][0]
    dl_smlt = [r["time_s"] for r in rows if r["system"] == "SMLT"
               and r["step"] == "DL-grad" and r["workload"] == "bert-medium"][0]
    return (f"bert-medium DL-grad: Cirrus {dl_cirrus:.1f}s vs SMLT "
            f"{dl_smlt:.1f}s ({dl_cirrus/dl_smlt:.1f}x); totals SMLT "
            f"{total('SMLT','bert-medium'):.1f}s Cirrus "
            f"{total('Cirrus','bert-medium'):.1f}s Siren "
            f"{total('Siren','bert-medium'):.1f}s")


if __name__ == "__main__":
    for r in run():
        print(r)
    print(summarize(run()))
