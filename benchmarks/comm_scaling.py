"""Paper Figs. 1, 2, 8: per-iteration communication time vs #workers for
SMLT (hier) against Siren (ps_s3) and Cirrus (ps), all 5 paper workloads."""
from __future__ import annotations

from repro.serverless import (WORKLOADS, ObjectStore, ParamStore,
                              comm_breakdown)

WORKERS = [10, 25, 50, 100, 150, 200]
SCHEMES = {"SMLT": "hier", "Cirrus": "ps", "Siren": "ps_s3"}


def run() -> list:
    ps, os_ = ParamStore(), ObjectStore()
    rows = []
    for wname, w in WORKLOADS.items():
        for label, scheme in SCHEMES.items():
            for n in WORKERS:
                t = sum(comm_breakdown(
                    scheme, w.grad_bytes, n, 4096, ps, os_,
                    extra_upload_bytes=w.extra_upload_bytes).values())
                rows.append({"figure": "fig8", "workload": wname,
                             "system": label, "workers": n,
                             "comm_s": round(t, 3)})
    return rows


def summarize(rows) -> str:
    # headline: speedup of SMLT over the worst baseline at 200 workers
    worst = {}
    smlt = {}
    for r in rows:
        if r["workers"] != 200:
            continue
        if r["system"] == "SMLT":
            smlt[r["workload"]] = r["comm_s"]
        else:
            worst[r["workload"]] = max(worst.get(r["workload"], 0),
                                       r["comm_s"])
    sp = [worst[k] / smlt[k] for k in smlt]
    return ("comm speedup vs worst baseline @200 workers: "
            f"min {min(sp):.1f}x max {max(sp):.1f}x")


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(summarize(rows))
