"""CommPlan strategies: bytes-on-wire and wall-clock across aggregation
topologies and top-k compression ratios, on both execution paths.

The CommPlan IR prices {ps, scatter_reduce, hier(b)} x ratio in closed
form *and* executes them on the discrete-event engine, so this benchmark
can show — for the same workload and fleet — what the paper's Figs. 7/8
claim and what the seed repo could never choose:

  - the aggregation tree (``hier``) beats the central store (``ps``) on
    wall-clock from n=16 up (O(G) vs O(n*G) downloads), enforced here;
  - ScatterReduce beats both (parallel shard aggregators);
  - compression buys wire bytes on every topology, with the decompress
    CPU charge and index overhead visible in the engine wall-clock;
  - a Bayesian-optimizer scenario (``ConfigSpace(search_comm=True)``)
    under a deadline goal picks a non-trivial (strategy, ratio) — the
    scheduler can now *choose* the paper's hierarchy and a wire ratio,
    judged on compression-inflated time and dollars (enforced here).

Run:  PYTHONPATH=src python -m benchmarks.comm_strategies [--smoke]
"""
from __future__ import annotations

import sys

from repro.core import Config, ConfigSpace, Goal, TaskScheduler
from repro.core.comm import CommSpec, build_plan
from repro.core.cost_model import epoch_estimate
from repro.serverless import (WORKLOADS, EventEngine, ObjectStore, ParamStore,
                              ServerlessPlatform)

W = WORKLOADS["bert-small"]
N = 32
MEM = 4096
BATCH = 2048
SAMPLES = 16_384          # 8 iterations
SMOKE_SAMPLES = 4_096

STRATEGIES = {
    "ps": CommSpec("ps"),
    "scatter_reduce": CommSpec("scatter_reduce"),
    "hier-b4": CommSpec("hier", branching=4),
    "hier-b8": CommSpec("hier", branching=8),
}
RATIOS = (1.0, 0.1, 0.01)


def _row(name, spec, ratio, samples):
    spec = CommSpec(spec.strategy, ratio=ratio, branching=spec.branching,
                    store=spec.store)
    plan = build_plan(spec, W.grad_bytes, N)
    est = epoch_estimate(W, spec, Config(N, MEM), BATCH, ParamStore(),
                         ObjectStore(), samples=samples)
    r = EventEngine(W, spec, N, MEM, BATCH, ParamStore(), ObjectStore(),
                    samples=samples, seed=0, trace_enabled=False).run()
    return {"figure": "comm_strategies", "strategy": name, "ratio": ratio,
            "wire_mb_per_iter": round(plan.wire_bytes / 1e6, 1),
            "engine_wall_s": round(r.wall_s, 2),
            "analytic_wall_s": round(est.wall_s, 2),
            "analytic_err": round(r.wall_s / est.wall_s - 1, 4),
            "cost_usd": round(r.cost_usd, 4)}


def _optimizer_row(quick: bool):
    """The scheduler searches (strategy, ratio, branching) next to
    (workers, memory) under Scenario-1's deadline goal."""
    sched = TaskScheduler(ServerlessPlatform(seed=0), ObjectStore(),
                          ParamStore(), scheme="scatter_reduce",
                          space=ConfigSpace(max_workers=64,
                                            search_comm=True),
                          seed=0, bo_max_iters=6 if quick else 12)
    cfg, t_prof, usd_prof, _ = sched.optimize(
        WORKLOADS["bert-medium"], 1024,
        Goal("min_cost_deadline", deadline_s=3600.0),
        epochs_remaining=4, samples=25_000)
    nontrivial = (cfg.compress_ratio < 1.0
                  or cfg.comm not in ("", "scatter_reduce"))
    assert nontrivial, f"optimizer chose the trivial comm plan: {cfg}"
    return {"figure": "comm_strategies", "strategy": "BO-selected",
            "ratio": cfg.compress_ratio, "selected_comm": cfg.comm,
            "selected_branching": cfg.branching, "workers": cfg.workers,
            "memory_mb": cfg.memory_mb,
            "profile_s": round(t_prof, 1),
            "profile_usd": round(usd_prof, 2)}


def run(quick: bool = False) -> list:
    samples = SMOKE_SAMPLES if quick else SAMPLES
    ratios = (1.0, 0.01) if quick else RATIOS
    rows = []
    for name, spec in STRATEGIES.items():
        for ratio in ratios:
            rows.append(_row(name, spec, ratio, samples))
    dense = {r["strategy"]: r for r in rows if r["ratio"] == 1.0}
    # acceptance: the aggregation tree beats the central store at n>=16
    for hname in ("hier-b4", "hier-b8"):
        assert dense[hname]["engine_wall_s"] < dense["ps"]["engine_wall_s"], \
            (hname, dense[hname], dense["ps"])
    rows.append(_optimizer_row(quick))
    return rows


def summarize(rows) -> str:
    dense = {r["strategy"]: r for r in rows if r.get("ratio") == 1.0}
    comp = {r["strategy"]: r for r in rows
            if r.get("ratio") not in (1.0, None)
            and r["strategy"] != "BO-selected"}
    bo = [r for r in rows if r["strategy"] == "BO-selected"][0]
    speed = dense["ps"]["engine_wall_s"] / dense["hier-b4"]["engine_wall_s"]
    wire = (dense["scatter_reduce"]["wire_mb_per_iter"]
            / comp["scatter_reduce"]["wire_mb_per_iter"])
    return (f"hier-b4 {speed:.1f}x faster than ps @n={N}; top-k cuts "
            f"scatter_reduce wire {wire:.0f}x; BO picked "
            f"({bo['selected_comm']}, r={bo['ratio']}, "
            f"b={bo['selected_branching']})")


if __name__ == "__main__":
    rows = run(quick="--smoke" in sys.argv)
    for r in rows:
        print(r)
    print(summarize(rows))
    from benchmarks.common import emit_json
    print("json:", emit_json("comm_strategies", rows))
