"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from repro.core import ConfigSpace, TaskScheduler
from repro.serverless import ObjectStore, ParamStore, ServerlessPlatform

OUT_DIR = "experiments/bench"


def fresh_scheduler(scheme: str = "hier", seed: int = 0, max_workers: int = 200,
                    failure_rate: float = 0.0, search_fleet: bool = False,
                    search_comm: bool = False, **scheduler_kw):
    plat = ServerlessPlatform(failure_rate=failure_rate, seed=seed)
    os_, ps = ObjectStore(), ParamStore()
    sched = TaskScheduler(plat, os_, ps, scheme=scheme,
                          space=ConfigSpace(max_workers=max_workers,
                                            search_fleet=search_fleet,
                                            search_comm=search_comm),
                          seed=seed, **scheduler_kw)
    return sched, plat, os_, ps


def emit_json(name: str, rows: List[Dict]) -> str:
    """Write a benchmark's detailed rows to experiments/bench/<name>.json
    (the same location benchmarks.run uses) and return the path."""
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return path


def fmt_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def timed(fn, *args, reps: int = 3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6  # us
