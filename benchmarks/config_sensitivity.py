"""Paper Fig. 3: per-iteration time and cost distributions over deployment
configurations (workers x memory) for 4 models — shows why picking the
'right' config is non-trivial (high variance, no single safe default)."""
from __future__ import annotations

import numpy as np

from repro.serverless import (WORKLOADS, ObjectStore, ParamStore,
                              iteration_time)
from repro.serverless.platform import LAMBDA_GB_SECOND

MODELS = ["bert-medium", "bert-small", "resnet18", "resnet50"]
WORKERS = [10, 25, 50, 100, 200]
MEMORY = [3072, 6144, 10240]
GLOBAL_BATCH = 1024


def run() -> list:
    ps, os_ = ParamStore(), ObjectStore()
    rows = []
    for m in MODELS:
        w = WORKLOADS[m]
        times, costs = [], []
        for n in WORKERS:
            for mem in MEMORY:
                it = iteration_time(w, "hier", n, mem, GLOBAL_BATCH, ps, os_)
                cost = n * mem / 1024.0 * it["total"] * LAMBDA_GB_SECOND
                times.append(it["total"])
                costs.append(cost)
        rows.append({
            "figure": "fig3", "workload": m,
            "time_min_s": round(min(times), 3),
            "time_med_s": round(float(np.median(times)), 3),
            "time_max_s": round(max(times), 3),
            "cost_min_usd": round(min(costs), 6),
            "cost_med_usd": round(float(np.median(costs)), 6),
            "cost_max_usd": round(max(costs), 6),
        })
    return rows


def summarize(rows) -> str:
    spreads = [r["time_max_s"] / r["time_min_s"] for r in rows]
    cspreads = [r["cost_max_usd"] / r["cost_min_usd"] for r in rows]
    return (f"config choice spreads per-iter time by up to {max(spreads):.0f}x "
            f"and cost by up to {max(cspreads):.0f}x across models")


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(summarize(rows))
