"""Paper Figs. 11a & 12: dynamic batching.

 - Fig 11a: profiling + training cost for SMLT vs MLCD (VM-based MLaaS with
   one-shot expensive VM profiling), LambdaML (serverless, fixed allocation)
   and IaaS (fixed VM fleet), on resnet50 with a batch schedule.
 - Fig 12: throughput / workers / batch-size timeline for SMLT vs LambdaML
   when the batch size changes mid-training.
"""
from __future__ import annotations

from repro.core import Config, EpochPlan, Goal
from repro.core.cost_model import VM_TYPES, vm_epoch_estimate
from repro.optim.schedules import step_batch
from repro.serverless import WORKLOADS
from benchmarks.common import fresh_scheduler

W = WORKLOADS["resnet50"]
SAMPLES = 100_000
BATCHES = step_batch([256, 1024, 4096], epochs_per=2)


def run() -> list:
    rows = []
    plans = [EpochPlan(b, W, samples=SAMPLES) for b in BATCHES]

    # SMLT: adaptive, cheap serverless profiling at every change
    sched, *_ = fresh_scheduler("hier", seed=0)
    smlt = sched.run(plans, Goal("min_cost"))
    rows.append({"figure": "fig11a", "system": "SMLT",
                 "profile_usd": round(smlt.profile_usd, 3),
                 "train_usd": round(smlt.cost_usd, 2),
                 "total_usd": round(smlt.total_cost, 2)})

    # LambdaML: serverless + ScatterReduce but fixed allocation, no
    # profiling; sized by the user for the PEAK batch (over-provisioned
    # for the small-batch epochs, Section 2.2)
    sched, *_ = fresh_scheduler("hier", seed=0)
    lml = sched.run(plans, Goal("min_cost"), adaptive=False,
                    fixed_config=Config(workers=100, memory_mb=4096))
    rows.append({"figure": "fig11a", "system": "LambdaML",
                 "profile_usd": 0.0, "train_usd": round(lml.cost_usd, 2),
                 "total_usd": round(lml.total_cost, 2)})

    # MLCD: VM-based; Bayesian profiling ONCE on billed-by-the-hour VMs —
    # paper [59]: tuning can reach ~60% of total — probes are full short
    # runs on candidate fleet sizes, each paying VM spin-up minimums.
    vm = VM_TYPES["c5.4xlarge"]
    n_vms_peak = 16                      # provisioned for batch 4096
    probes = 20
    mlcd_profile = 0.0
    for i in range(probes):
        n = 2 + (i % 8) * 2
        wall, usd = vm_epoch_estimate(W, vm, n, 1024, samples=30_000)
        mlcd_profile += usd + n * vm.usd_hour * (120.0 / 3600.0)  # spin-up
    # +50% over-provisioning for OOM robustness (Section 2.2)
    mlcd_train = 1.5 * sum(
        vm_epoch_estimate(W, vm, n_vms_peak, b, samples=SAMPLES)[1]
        for b in BATCHES)
    rows.append({"figure": "fig11a", "system": "MLCD",
                 "profile_usd": round(mlcd_profile, 2),
                 "train_usd": round(mlcd_train, 2),
                 "total_usd": round(mlcd_profile + mlcd_train, 2)})

    # IaaS: fixed VM fleet provisioned for peak, billed wall-clock incl.
    # the inter-epoch setup gaps (20% duty overhead)
    iaas_wall = 1.2 * sum(
        vm_epoch_estimate(W, vm, n_vms_peak, b, samples=SAMPLES)[0]
        for b in BATCHES)
    iaas_usd = n_vms_peak * vm.usd_hour * iaas_wall / 3600.0
    rows.append({"figure": "fig11a", "system": "IaaS", "profile_usd": 0.0,
                 "train_usd": round(iaas_usd, 2),
                 "total_usd": round(iaas_usd, 2)})

    # Fig 12 timeline: throughput under a batch-size change; the goal here
    # is throughput (min_time); LambdaML is frozen at SMLT's initial config
    sched, *_ = fresh_scheduler("hier", seed=0)
    smlt_t = sched.run(plans, Goal("min_time"))
    sched, *_ = fresh_scheduler("hier", seed=0)
    lml_t = sched.run(plans, Goal("min_time"), adaptive=False,
                      fixed_config=smlt_t.config_history[0])
    for res, name in ((smlt_t, "SMLT"), (lml_t, "LambdaML")):
        for e in res.events:
            if e.kind != "epoch":
                continue
            rows.append({"figure": "fig12", "system": name,
                         "t_s": round(e.t, 1),
                         "throughput": round(e.throughput, 1),
                         "workers": e.workers, "batch": e.batch_size})
    return rows


def summarize(rows) -> str:
    f11 = {r["system"]: r for r in rows if r["figure"] == "fig11a"}
    smlt, lml = f11["SMLT"], f11["LambdaML"]
    mlcd = f11["MLCD"]
    tp = {}
    for r in rows:
        if r["figure"] == "fig12":
            tp.setdefault(r["system"], []).append(r["throughput"])
    adv = tp["SMLT"][-1] / tp["LambdaML"][-1]
    return (f"total cost: SMLT ${smlt['total_usd']} vs LambdaML "
            f"${lml['total_usd']} ({lml['total_usd']/smlt['total_usd']:.2f}x) "
            f"vs MLCD ${mlcd['total_usd']} "
            f"(profiling {mlcd['profile_usd']/mlcd['total_usd']*100:.0f}% of "
            f"MLCD total); final-epoch throughput advantage {adv:.2f}x")


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(summarize(rows))
