"""Event-engine throughput harness: the repo's perf trajectory anchor.

Measures the ``EventEngine`` hot path (calendar-queue dispatch, coalesced
cohorts, vectorized draws, class-based incremental ``SharedLink``
accounting) on a fixed scenario grid — fleet sizes {64, 512, 2048, 10000}
with and without stragglers, heterogeneous (mixed-memory) fleets, and
``ServingJob`` rows (alone and co-scheduled with training) — and reports
events/sec, worker-iterations/sec, and wall time per scenario. See
``docs/PERF.md`` for the regression policy.

    PYTHONPATH=src python -m benchmarks.engine_throughput            # full grid
    PYTHONPATH=src python -m benchmarks.engine_throughput --quick    # CI gate
    PYTHONPATH=src python -m benchmarks.engine_throughput --update-baseline

The checked-in baseline ``BENCH_engine_throughput.json`` (repo root)
records both the **pre-PR** engine (measured once from the git tree
before the overhaul, embedded below as ``PRE_PR_WALL_S``) and the current
engine. ``--quick`` runs the small rows only and exits non-zero if
events/sec regresses by more than ``REGRESSION_TOLERANCE`` against the
baseline — wall-clock noise on shared CI runners is why the gate is 25%,
not 5%; regenerate the baseline on a quiet machine when the engine
legitimately changes speed. Each row's wall is the best of ``REPEATS``
runs (the simulation is deterministic, so repeats differ only by host
noise; the minimum is the least-contended measurement).

"Events" are *logical simulation events* (``EngineResult.sim_events``:
invocations armed, transfers finished, compute segments, iterations,
worker completions — counted per member worker, so a coalesced cohort of
2048 workers scores 2048, keeping the metric machinery-independent). The
pre-PR engine simulated the identical logical schedule one worker at a
time, so its events/sec is the same event count over its measured wall.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

import numpy as np

from repro.serverless import (ContentionDomain, EventEngine, FleetSpec,
                              ObjectStore, ParamStore, ServingJob, WORKLOADS)
from repro.serving import ServePolicy

BASELINE_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_engine_throughput.json")

REGRESSION_TOLERANCE = 0.25      # --quick fails beyond this ev/s drop
REPEATS = 3                      # wall = best of N deterministic runs

# (n_workers, straggler_sigma, iterations): per-worker batch 512, memory
# 2048 MB, resnet18 over "hier". sigma=0 rows exercise the coalesced
# cohort path; sigma=0.3 rows force per-worker simulation (every worker
# draws its own straggler factor each iteration).
SCENARIOS = [
    (64, 0.0, 10),
    (512, 0.0, 10),
    (2048, 0.0, 10),
    (10000, 0.0, 2),
    (64, 0.3, 10),
    (512, 0.3, 10),
    (2048, 0.3, 10),
]

# Heterogeneous rows: half the fleet at 2048 MB, half at 3072 MB — two
# (cap, prio) link classes and a cohort cut at the memory boundary, the
# regime the class-based water-filling exists for.
HETERO_SCENARIOS = [
    (512, 0.0, 10),
    (512, 0.3, 10),
    (2048, 0.3, 10),
]

QUICK = {"n64_s0.0", "n512_s0.0", "n64_s0.3", "n512_s0.3",
         "n512_s0.0_hetero", "n512_s0.3_hetero",
         "serving_small", "trainserve_small"}

# Wall seconds of the pre-overhaul engine (commit f90646a lineage) on the
# identical scenario grid, measured on the same machine that produced the
# checked-in baseline. The old engine has no sim_events counter; its
# events/sec is the current engine's (deterministic) logical event count
# for the scenario divided by this wall. Hetero/serving rows postdate the
# old engine and have no pre-PR entry.
PRE_PR_WALL_S = {
    "n64_s0.0": 0.108,
    "n512_s0.0": 5.187,
    "n2048_s0.0": 89.513,
    "n10000_s0.0": 677.102,
    "n64_s0.3": 0.102,
    "n512_s0.3": 4.650,
    "n2048_s0.3": 82.332,
}


def key(n: int, sigma: float) -> str:
    return f"n{n}_s{sigma}"


def hetero_fleet(n: int) -> FleetSpec:
    return FleetSpec.mixed([(n - n // 2, 2048, "standard"),
                            (n // 2, 3072, "large")])


def _timed(fn):
    """Wall-time ``fn()`` with the cyclic GC paused (collected first):
    the collector's periodic scans over the simulation's own live object
    graph otherwise dominate run-to-run variance (up to ~2x on large
    fleets). Same discipline as pytest-benchmark's default."""
    was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        res = fn()
        wall = time.perf_counter() - t0
    finally:
        if was_enabled:
            gc.enable()
    return wall, res


def _row(k: str, wall: float, events: int, **extra) -> dict:
    r = {"key": k, "wall_s": round(wall, 4), "sim_events": events,
         "events_per_s": round(events / wall, 1)}
    r.update(extra)
    return r


def run_scenario(n: int, sigma: float, iters: int, *, hetero: bool = False,
                 repeats: int = 1) -> dict:
    gb = 512 * n
    best, res, eng = None, None, None
    for _ in range(max(repeats, 1)):
        eng = EventEngine(WORKLOADS["resnet18"], "hier", n, 2048, gb,
                          ParamStore(), ObjectStore(), samples=iters * gb,
                          fleet=hetero_fleet(n) if hetero else None,
                          straggler_sigma=sigma, seed=42, record_trace=False)
        wall, res = _timed(eng.run)
        if best is None or wall < best:
            best = wall
    return _row(key(n, sigma) + ("_hetero" if hetero else ""), best,
                res.sim_events, n=n, sigma=sigma, iters=res.iters_done,
                worker_iters_per_s=round(res.iters_done * n / best, 1),
                sim_wall_s=res.wall_s, coalesced=eng.coalesced)


def run_serving_scenario(n_requests: int, label: str, *,
                         repeats: int = 1) -> dict:
    """ServingJob alone: autoscaling fleet, cold-start fetches and periodic
    model refreshes on the store links, vectorized arrival slabs."""
    pol = ServePolicy(8, 0.1, 3072)
    rng = np.random.RandomState(42)
    arr = np.sort(rng.uniform(0.0, n_requests / 30.0, size=n_requests))
    best, res = None, None
    for _ in range(max(repeats, 1)):
        job = ServingJob(pol, arr, 2e9, ParamStore(), ObjectStore(),
                         model_bytes=200e6, code_bytes=20e6,
                         cold_start_s=1.0, keep_warm_s=30.0,
                         max_instances=32, refresh_every_s=5.0)
        wall, res = _timed(job.run)
        if best is None or wall < best:
            best = wall
    return _row(label, best, res.sim_events, requests=res.requests,
                batches=res.batches, peak_instances=res.peak_instances)


def run_trainserve_scenario(n: int, sigma: float, iters: int,
                            n_requests: int, label: str, *,
                            repeats: int = 1) -> dict:
    """Train + serve in one ContentionDomain on one ParamStore: the
    serving fetches carry link priority 4.0, so the shared param link
    water-fills over two (cap, prio) classes."""
    pol = ServePolicy(8, 0.1, 3072)
    rng = np.random.RandomState(42)
    gb = 512 * n
    best, events = None, None
    for _ in range(max(repeats, 1)):
        arr = np.sort(rng.uniform(0.0, n_requests / 30.0, size=n_requests))
        dom = ContentionDomain()
        ps = ParamStore()
        eng = EventEngine(WORKLOADS["resnet18"], "hier", n, 2048, gb,
                          ps, ObjectStore(), samples=iters * gb,
                          straggler_sigma=sigma, seed=42, domain=dom,
                          record_trace=False)
        job = ServingJob(pol, arr, 2e9, ps, ObjectStore(), domain=dom,
                         model_bytes=200e6, code_bytes=20e6,
                         cold_start_s=1.0, keep_warm_s=30.0,
                         max_instances=32, refresh_every_s=5.0,
                         link_priority=4.0)
        wall, _ = _timed(dom.run)
        events = eng.result().sim_events + job.result().sim_events
        if best is None or wall < best:
            best = wall
    return _row(label, best, events, n=n, sigma=sigma,
                requests=n_requests)


def full_grid(quick: bool, repeats: int = REPEATS) -> list:
    rows = []
    for n, sigma, iters in SCENARIOS:
        if quick and key(n, sigma) not in QUICK:
            continue
        rows.append(run_scenario(n, sigma, iters, repeats=repeats))
    for n, sigma, iters in HETERO_SCENARIOS:
        if quick and key(n, sigma) + "_hetero" not in QUICK:
            continue
        rows.append(run_scenario(n, sigma, iters, hetero=True,
                                 repeats=repeats))
    if quick:
        rows.append(run_serving_scenario(3000, "serving_small",
                                         repeats=repeats))
        rows.append(run_trainserve_scenario(64, 0.3, 10, 3000,
                                            "trainserve_small",
                                            repeats=repeats))
    else:
        for nr, label in ((3000, "serving_small"), (20000, "serving_20k")):
            rows.append(run_serving_scenario(nr, label, repeats=repeats))
        rows.append(run_trainserve_scenario(64, 0.3, 10, 3000,
                                            "trainserve_small",
                                            repeats=repeats))
        rows.append(run_trainserve_scenario(256, 0.3, 10, 10000,
                                            "trainserve_256",
                                            repeats=repeats))
    return rows


def build_report(rows: list) -> dict:
    current = {r["key"]: r for r in rows}
    pre = {}
    speedup = {}
    for k, r in current.items():
        old_wall = PRE_PR_WALL_S.get(k)
        if old_wall is None:
            continue
        pre[k] = {"wall_s": old_wall,
                  "events_per_s": round(r["sim_events"] / old_wall, 1)}
        speedup[k] = round(old_wall / r["wall_s"], 1)
    return {
        "scenario": "resnet18/hier, per-worker batch 512, 2048 MB, seed 42",
        "pre_pr": pre,
        "current": current,
        "speedup_wall": speedup,
    }


def check_regression(rows: list, baseline: dict) -> list:
    """Rows whose events/sec fell >REGRESSION_TOLERANCE below baseline."""
    failures = []
    base = baseline.get("current", {})
    for r in rows:
        k = r["key"]
        ref = base.get(k, {}).get("events_per_s")
        if not ref:
            continue
        floor = ref * (1.0 - REGRESSION_TOLERANCE)
        if r["events_per_s"] < floor:
            failures.append(
                f"{k}: {r['events_per_s']:.0f} ev/s < {floor:.0f} "
                f"(baseline {ref:.0f} - {REGRESSION_TOLERANCE:.0%})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small rows only; fail on ev/s regression vs "
                         "the checked-in baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help=f"rewrite {os.path.basename(BASELINE_PATH)}")
    ap.add_argument("--repeats", type=int, default=REPEATS,
                    help="wall = best of N runs (default %(default)s)")
    args = ap.parse_args(argv)

    print(f"{'key':>20} {'wall_s':>9} {'events':>9} {'ev/s':>12}")
    rows = []
    for r in full_grid(args.quick, repeats=args.repeats):
        rows.append(r)
        print(f"{r['key']:>20} {r['wall_s']:>9.3f} {r['sim_events']:>9} "
              f"{r['events_per_s']:>12.1f}")

    if args.quick and not args.update_baseline:
        try:
            with open(BASELINE_PATH) as f:
                baseline = json.load(f)
        except FileNotFoundError:
            print(f"no baseline at {BASELINE_PATH}; run --update-baseline",
                  file=sys.stderr)
            return 1
        failures = check_regression(rows, baseline)
        for line in failures:
            print(f"REGRESSION {line}", file=sys.stderr)
        if failures:
            return 1
        print(f"quick gate OK: all rows within {REGRESSION_TOLERANCE:.0%} "
              "of baseline events/sec")
        return 0

    report = build_report(rows)
    for k, s in sorted(report["speedup_wall"].items()):
        print(f"speedup {k}: {s}x wall vs pre-PR engine")
    if args.update_baseline or not os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH, "w") as f:
            json.dump(report, f, indent=1)
            f.write("\n")
        print(f"wrote {BASELINE_PATH}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
