"""Event-engine throughput harness: the repo's perf trajectory anchor.

Measures the ``EventEngine`` hot path (calendar-queue dispatch, coalesced
cohorts, vectorized draws, incremental ``SharedLink`` accounting) on a
fixed scenario grid — fleet sizes {64, 512, 2048, 10000} with and without
stragglers — and reports events/sec, worker-iterations/sec, and wall time
per scenario. See ``docs/PERF.md`` for the regression policy.

    PYTHONPATH=src python -m benchmarks.engine_throughput            # full grid
    PYTHONPATH=src python -m benchmarks.engine_throughput --quick    # CI gate
    PYTHONPATH=src python -m benchmarks.engine_throughput --update-baseline

The checked-in baseline ``BENCH_engine_throughput.json`` (repo root)
records both the **pre-PR** engine (measured once from the git tree
before the overhaul, embedded below as ``PRE_PR_WALL_S``) and the current
engine. ``--quick`` runs the small rows only and exits non-zero if
events/sec regresses by more than ``REGRESSION_TOLERANCE`` against the
baseline — wall-clock noise on shared CI runners is why the gate is 25%,
not 5%; regenerate the baseline on a quiet machine when the engine
legitimately changes speed.

"Events" are *logical simulation events* (``EngineResult.sim_events``:
invocations armed, transfers finished, compute segments, iterations,
worker completions — counted per member worker, so a coalesced cohort of
2048 workers scores 2048, keeping the metric machinery-independent). The
pre-PR engine simulated the identical logical schedule one worker at a
time, so its events/sec is the same event count over its measured wall.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.serverless import EventEngine, ObjectStore, ParamStore, WORKLOADS

BASELINE_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_engine_throughput.json")

REGRESSION_TOLERANCE = 0.25      # --quick fails beyond this ev/s drop

# (n_workers, straggler_sigma, iterations): per-worker batch 512, memory
# 2048 MB, resnet18 over "hier". sigma=0 rows exercise the coalesced
# cohort path; sigma=0.3 rows force per-worker simulation (every worker
# draws its own straggler factor each iteration).
SCENARIOS = [
    (64, 0.0, 10),
    (512, 0.0, 10),
    (2048, 0.0, 10),
    (10000, 0.0, 2),
    (64, 0.3, 10),
    (512, 0.3, 10),
    (2048, 0.3, 10),
]
QUICK = {(64, 0.0), (512, 0.0), (64, 0.3), (512, 0.3)}

# Wall seconds of the pre-overhaul engine (commit f90646a lineage) on the
# identical scenario grid, measured on the same machine that produced the
# checked-in baseline. The old engine has no sim_events counter; its
# events/sec is the current engine's (deterministic) logical event count
# for the scenario divided by this wall.
PRE_PR_WALL_S = {
    "n64_s0.0": 0.108,
    "n512_s0.0": 5.187,
    "n2048_s0.0": 89.513,
    "n10000_s0.0": 677.102,
    "n64_s0.3": 0.102,
    "n512_s0.3": 4.650,
    "n2048_s0.3": 82.332,
}


def key(n: int, sigma: float) -> str:
    return f"n{n}_s{sigma}"


def run_scenario(n: int, sigma: float, iters: int) -> dict:
    gb = 512 * n
    eng = EventEngine(WORKLOADS["resnet18"], "hier", n, 2048, gb,
                      ParamStore(), ObjectStore(), samples=iters * gb,
                      straggler_sigma=sigma, seed=42, record_trace=False)
    t0 = time.perf_counter()
    res = eng.run()
    wall = time.perf_counter() - t0
    return {
        "n": n, "sigma": sigma, "iters": res.iters_done,
        "wall_s": round(wall, 4),
        "sim_events": res.sim_events,
        "events_per_s": round(res.sim_events / wall, 1),
        "worker_iters_per_s": round(res.iters_done * n / wall, 1),
        "sim_wall_s": res.wall_s,
        "coalesced": eng.coalesced,
    }


def build_report(rows: list) -> dict:
    current = {key(r["n"], r["sigma"]): r for r in rows}
    pre = {}
    speedup = {}
    for k, r in current.items():
        old_wall = PRE_PR_WALL_S.get(k)
        if old_wall is None:
            continue
        pre[k] = {"wall_s": old_wall,
                  "events_per_s": round(r["sim_events"] / old_wall, 1)}
        speedup[k] = round(old_wall / r["wall_s"], 1)
    return {
        "scenario": "resnet18/hier, per-worker batch 512, 2048 MB, seed 42",
        "pre_pr": pre,
        "current": current,
        "speedup_wall": speedup,
    }


def check_regression(rows: list, baseline: dict) -> list:
    """Rows whose events/sec fell >REGRESSION_TOLERANCE below baseline."""
    failures = []
    base = baseline.get("current", {})
    for r in rows:
        k = key(r["n"], r["sigma"])
        ref = base.get(k, {}).get("events_per_s")
        if not ref:
            continue
        floor = ref * (1.0 - REGRESSION_TOLERANCE)
        if r["events_per_s"] < floor:
            failures.append(
                f"{k}: {r['events_per_s']:.0f} ev/s < {floor:.0f} "
                f"(baseline {ref:.0f} - {REGRESSION_TOLERANCE:.0%})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small rows only; fail on ev/s regression vs "
                         "the checked-in baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help=f"rewrite {os.path.basename(BASELINE_PATH)}")
    args = ap.parse_args(argv)

    grid = [(n, s, i) for n, s, i in SCENARIOS
            if not args.quick or (n, s) in QUICK]
    rows = []
    print(f"{'n':>6} {'sigma':>5} {'iters':>5} {'wall_s':>9} "
          f"{'events':>9} {'ev/s':>12} {'w-iters/s':>10} {'coalesced':>9}")
    for n, sigma, iters in grid:
        r = run_scenario(n, sigma, iters)
        rows.append(r)
        print(f"{n:>6} {sigma:>5} {r['iters']:>5} {r['wall_s']:>9.3f} "
              f"{r['sim_events']:>9} {r['events_per_s']:>12.1f} "
              f"{r['worker_iters_per_s']:>10.1f} {str(r['coalesced']):>9}")

    if args.quick and not args.update_baseline:
        try:
            with open(BASELINE_PATH) as f:
                baseline = json.load(f)
        except FileNotFoundError:
            print(f"no baseline at {BASELINE_PATH}; run --update-baseline",
                  file=sys.stderr)
            return 1
        failures = check_regression(rows, baseline)
        for line in failures:
            print(f"REGRESSION {line}", file=sys.stderr)
        if failures:
            return 1
        print(f"quick gate OK: all rows within {REGRESSION_TOLERANCE:.0%} "
              f"of baseline events/sec")
        return 0

    report = build_report(rows)
    for k, s in sorted(report["speedup_wall"].items()):
        print(f"speedup {k}: {s}x wall vs pre-PR engine")
    if args.update_baseline or not os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH, "w") as f:
            json.dump(report, f, indent=1)
            f.write("\n")
        print(f"wrote {BASELINE_PATH}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
