"""Analytic per-device FLOPs / HBM-bytes / collective-bytes model.

Why analytic: XLA's ``cost_analysis()`` counts a ``while`` (scan) body ONCE
regardless of trip count, so scanned-layer models under-report FLOPs and
in-loop collectives by ~n_layers x. The dry-run remains the source of truth
for *sharding coherence* and the *collective op mix*; the roofline terms are
computed here from first principles and cross-checked against the dry-run
numbers (see EXPERIMENTS.md §Roofline, "HLO vs analytic").

All quantities are PER DEVICE on the given mesh. Hardware: TPU v5e-like —
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses

from repro.models import registry
from repro.models.base import INPUT_SHAPES, ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

BYTES_P = 2          # bf16 params/activations
BYTES_OPT = 8        # f32 mu+nu per param
BYTES_ACT = 2


@dataclasses.dataclass
class Terms:
    flops: float
    hbm_bytes: float
    coll_bytes: float          # total on the bottleneck link class
    coll_cross_pod: float = 0.0

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    def dominant(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)


def _attn_flops(cfg: ModelConfig, b, s_q, s_kv, n_layers, causal=True):
    """Score + PV matmul flops (full, as lowered — masking is not skipped
    by the jnp blockwise path)."""
    if not cfg.n_heads:
        return 0.0
    hd = cfg.resolved_head_dim
    return 4.0 * b * s_q * s_kv * cfg.n_heads * hd * n_layers


def _ssd_flops(cfg: ModelConfig, b, s, n_layers):
    if not cfg.ssm_state:
        return 0.0
    Q = cfg.ssm_chunk
    h, p, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    per_tok = (2 * Q * n               # CB^T row (shared over heads)
               + h * (2 * Q * p        # score @ x
                      + 4 * n * p))    # state update + C@S
    return b * s * per_tok * n_layers


def _moe_dispatch_flops(cfg: ModelConfig, tokens, n_model: int = 1):
    """Dispatch+combine one-hot einsums as lowered: 2 * g * E * C * d each
    way per group of g tokens (E*C = cf*k*g slots). When the (padded)
    expert axis divides the model mesh axis the contraction is expert-
    parallel and the per-device cost divides by n_model."""
    if not cfg.n_experts:
        return 0.0
    E, k = max(cfg.n_experts, cfg.moe_pad_experts), cfg.top_k
    g = min(cfg.moe_group, tokens)
    cap = max(cfg.moe_capacity_factor * k * g / E, 1.0)
    per_dev = n_model if E % n_model == 0 else 1
    return (tokens / g) * 2 * 2.0 * g * E * cap * cfg.d_model / per_dev


def matmul_param_count(cfg: ModelConfig, active: bool = True) -> int:
    """Params participating in matmuls (excl. token-embedding lookup)."""
    total = registry.param_count(cfg, active_only=active)
    vocab_embed = cfg.vocab_padded * cfg.d_model  # lookup table
    return max(total - vocab_embed, 0)


def step_terms(cfg: ModelConfig, shape_name: str, *, n_data: int = 16,
               n_model: int = 16, n_pod: int = 1, strategy: str = "hier",
               fsdp: bool = True, remat: bool = True,
               flash_causal: bool = False) -> Terms:
    """Roofline terms for one step of (arch x shape) on a mesh."""
    shape = INPUT_SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    dp = n_data * n_pod if b % (n_data * n_pod) == 0 else 1
    b_dev = b // dp                           # per-device batch
    P_mm = matmul_param_count(cfg, active=True)
    P_all = registry.param_count(cfg)

    if shape.kind == "train":
        tokens_dev = b_dev * s
        # full remat recomputes the whole fwd (4x); "dots" policy saves
        # matmul outputs and only recomputes cheap elementwise work (3.1x)
        if remat:
            fwd_mults = 3.1 if cfg.remat_policy == "dots" else 4.0
        else:
            fwd_mults = 3.0
        dense = 2.0 * P_mm / n_model * tokens_dev * fwd_mults
        attn = _attn_flops(cfg, b_dev, s, s, L) / n_model * fwd_mults
        if flash_causal:
            attn *= 0.5
        ssd = _ssd_flops(cfg, b_dev, s, L) / n_model * fwd_mults
        moe = (_moe_dispatch_flops(cfg, tokens_dev, n_model)
               * L * fwd_mults)
        flops = dense + attn + ssd + moe

        p_shard = P_all / n_model / (n_data if fsdp else 1)
        # params read (fwd+bwd+remat) + grads written/read + opt state rw
        hbm = (P_all / n_model * BYTES_P * fwd_mults
               + p_shard * BYTES_P * 2
               + P_all / n_model / n_data * (BYTES_OPT * 2 + 4))
        # activations: boundaries under full remat; matmul outs under dots
        act_unit = tokens_dev * cfg.d_model * BYTES_ACT
        act_saved = 2 if (remat and cfg.remat_policy == "full") else 6
        hbm += act_unit * L * act_saved

        G = P_all / n_model * BYTES_P         # grad bytes per model shard
        # wire bytes are ~2G for ring-AR and for RS+AG alike; the strategies
        # differ in WHERE the bytes flow on a multi-pod mesh:
        #   flat (allreduce / hier1): the ring spans pods -> ~G crosses the
        #     pod-boundary link per device pair;
        #   2-level (hier): RS intra-pod first -> only the G/n_data shard
        #     is all-reduced across pods.
        coll = 2.0 * G
        if n_pod > 1:
            if strategy in ("allreduce", "hier1"):
                cross = G
            else:                              # hier == 2-level on multi-pod
                cross = 2.0 * G / n_data
        else:
            cross = 0.0
        if fsdp:
            coll += P_all / n_model * BYTES_P * (3 if remat else 2)  # param AG
        # TP activation all-reduces (fwd + bwd mirror), 2x bytes per ring
        # AR; sequence parallelism turns each AR into RS+AG = half the bytes
        tp_bytes = 2.0 * _ar_per_layer(cfg) * 2.0 * act_unit * L
        if cfg.seq_shard:
            tp_bytes *= 0.5
        coll += tp_bytes
        if cfg.n_experts and cfg.n_experts % n_model == 0:
            coll += 4.0 * tokens_dev * cfg.top_k * cfg.d_model * BYTES_ACT
        return Terms(flops, hbm, coll, cross)

    if shape.kind == "prefill":
        tokens_dev = b_dev * s
        dense = 2.0 * P_mm / n_model * tokens_dev
        attn = _attn_flops(cfg, b_dev, s, s, L) / n_model
        if flash_causal:
            attn *= 0.5
        ssd = _ssd_flops(cfg, b_dev, s, L) / n_model
        moe = _moe_dispatch_flops(cfg, tokens_dev, n_model) * L
        flops = dense + attn + ssd + moe
        hbm = (P_all / n_model * BYTES_P
               + tokens_dev * cfg.d_model * BYTES_ACT * L * 4)
        coll = (_ar_per_layer(cfg) * 2.0 * tokens_dev * cfg.d_model
                * BYTES_ACT * L)
        return Terms(flops, hbm, coll)

    # decode: one token against a seq_len cache
    tokens_dev = b_dev
    dense = 2.0 * P_mm / n_model * tokens_dev
    if cfg.n_heads:
        kv_len = min(s, cfg.sliding_window) if cfg.sliding_window else s
    else:
        kv_len = 0
    attn = _attn_flops(cfg, b_dev, 1, kv_len, _attn_layers(cfg))
    attn /= n_model
    ssd = 0.0
    if cfg.ssm_state:
        h, p, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
        ssd = b_dev * h * (4 * n * p) * L / n_model
    flops = dense + attn + ssd

    hbm = P_all / n_model * BYTES_P            # every param read per token
    if cfg.n_heads:
        hd = cfg.resolved_head_dim
        cache = (b_dev * kv_len * cfg.n_kv_heads * hd * 2 * BYTES_P
                 * _attn_layers(cfg) / n_model)
        hbm += cache
    if cfg.ssm_state:
        hbm += (b_dev * cfg.ssm_nheads * cfg.ssm_state * cfg.ssm_headdim
                * 4 * 2 * L / n_model)
    coll = (_ar_per_layer(cfg) * 2.0 * tokens_dev * cfg.d_model
            * BYTES_ACT * L)
    return Terms(flops, hbm, coll)


def _ar_per_layer(cfg: ModelConfig) -> float:
    """TP activation all-reduces per layer in the forward pass: one per
    row-parallel projection (attn out + mlp out for dense; the single
    out_proj for a mamba block; self+cross+mlp for enc-dec/vlm cross layers)."""
    if cfg.family == "ssm":
        return 1.0
    if cfg.family == "hybrid":
        return 1.0 + 2.0 / max(cfg.attn_every, 1)
    if cfg.family == "audio":
        return 3.0
    if cfg.family == "vlm":
        per = cfg.cross_attn_every
        return 2.0 + (2.0 / per if per else 0.0)
    return 2.0


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    if cfg.family == "vlm":
        return cfg.n_layers  # self (4/5) + cross (1/5) both attend
    return cfg.n_layers


def model_flops_per_step(cfg: ModelConfig, shape_name: str) -> float:
    """The 6·N·D (train) / 2·N·D (inference) 'useful FLOPs' yardstick —
    N = active matmul params, D = tokens in the step (whole cluster)."""
    shape = INPUT_SHAPES[shape_name]
    n = matmul_param_count(cfg, active=True)
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    return (6.0 if shape.kind == "train" else 2.0) * n * tokens
