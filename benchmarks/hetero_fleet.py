"""Heterogeneous fleets on the event engine: mixed memory, spot shocks.

The analytic model (and the paper) deploy n *identical* functions. Real
elastic fleets are mixed: leftover capacity comes in odd sizes, and cheap
"spot" slots die in correlated bursts. This benchmark measures what the
closed form cannot:

  - an **identical-per-worker fleet** must reproduce the homogeneous
    engine and ``epoch_estimate`` exactly (the zero-variance bsp anchor);
  - a **genuinely mixed fleet** (half memory on half the fleet) runs with
    **load-aware shard placement** — the batch splits in proportion to
    worker speed, so compute is balanced and the bsp cost comes only from
    the slow tier's *network cap* and the lower aggregate FLOP/s. The
    analytic fleet estimate is now tight (the old equal-split
    weighted-harmonic model priced the mean worker while bsp paid the
    max; the ``equal_split_model_err`` row quantifies the closed gap,
    asserted below);
  - relaxed sync (``ssp(2)``, ``async``) on the balanced mixed fleet has
    little left to recover — with compute equalized, barrier idle time
    comes only from contended transfers;
  - a **spot tier** under a correlated ``ShockModel`` shows burst failures
    costing real wall-clock and invocations.

Run:  PYTHONPATH=src python -m benchmarks.hetero_fleet [--smoke]
"""
from __future__ import annotations

import sys

from repro.core import Config
from repro.core.cost_model import epoch_estimate
from repro.serverless import (WORKLOADS, EventEngine, FleetSpec, ObjectStore,
                              ParamStore, ShockModel)
from benchmarks.common import emit_json

W = WORKLOADS["bert-small"]
N = 16
MEM = 4096
MEM_SMALL = 2048
MEM_EQUAL_AGG = (MEM + MEM_SMALL) // 2     # same total GB as the 50/50 mix
BATCH = 1024
SAMPLES = 16_000          # ~16 iterations
SMOKE_SAMPLES = 4_000


def _engine(fleet=None, mem=MEM, samples=SAMPLES, **kw):
    return EventEngine(W, "hier", N, mem, BATCH, ParamStore(), ObjectStore(),
                       samples=samples, fleet=fleet, seed=0,
                       trace_enabled=False, **kw).run()


def _row(name, res, base_wall=None):
    r = {"figure": "hetero_fleet", "config": name,
         "wall_s": round(res.wall_s, 2), "cost_usd": round(res.cost_usd, 4),
         "iters": res.iters_done, "failures": res.failures,
         "invocations": res.invocations}
    if base_wall:
        r["slowdown_vs_homog"] = round(res.wall_s / base_wall, 3)
    return r


def run(quick: bool = False) -> list:
    samples = SMOKE_SAMPLES if quick else SAMPLES
    mixed = FleetSpec.mixed([(N // 2, MEM, "standard"),
                             (N // 2, MEM_SMALL, "small")])
    spot = FleetSpec.mixed([(N // 2, MEM, "standard"),
                            (N // 2, MEM_SMALL, "spot")])

    homog = _engine(samples=samples)
    rows = [_row("homog-4096", homog)]

    ident = _engine(fleet=FleetSpec.homogeneous(N, MEM), samples=samples)
    r = _row("fleet-identical-4096", ident, homog.wall_s)
    est = epoch_estimate(W, "hier", Config(N, MEM), BATCH, ParamStore(),
                         ObjectStore(), samples=samples,
                         fleet=FleetSpec.homogeneous(N, MEM))
    r["analytic_wall_s"] = round(est.wall_s, 2)
    r["analytic_err"] = round(ident.wall_s / est.wall_s - 1, 4)
    rows.append(r)

    equal_agg = _engine(mem=MEM_EQUAL_AGG, samples=samples)
    rows.append(_row(f"homog-{MEM_EQUAL_AGG}-equal-aggregate", equal_agg,
                     homog.wall_s))

    mix = _engine(fleet=mixed, samples=samples)
    r = _row("mixed-50/50-bsp", mix, homog.wall_s)
    r["slowdown_vs_equal_agg"] = round(mix.wall_s / equal_agg.wall_s, 3)
    estm = epoch_estimate(W, "hier", Config(N, MEM), BATCH, ParamStore(),
                          ObjectStore(), samples=samples, fleet=mixed)
    r["analytic_wall_s"] = round(estm.wall_s, 2)
    # load-aware shard placement (batch split by worker speed) makes the
    # mixed-fleet compute estimate exact, closing the old equal-split
    # model's weighted-harmonic-vs-max gap (it priced the mean worker
    # while bsp paid the max)
    r["analytic_err"] = round(mix.wall_s / estm.wall_s - 1, 4)
    local = BATCH // N
    comp_harm = W.flops_per_sample * local / (mixed.gflops_harmonic() * 1e9)
    old_wall = estm.wall_s + estm.iters * (comp_harm
                                           - estm.it_breakdown["compute"])
    r["equal_split_model_err"] = round(mix.wall_s / old_wall - 1, 4)
    assert abs(r["analytic_err"]) < abs(r["equal_split_model_err"]), \
        "load-aware placement must tighten the fleet estimate"
    rows.append(r)

    for mode, kw in [("ssp(2)", {"sync_mode": "ssp", "staleness": 2}),
                     ("async", {"sync_mode": "async"})]:
        res = _engine(fleet=mixed, samples=samples, **kw)
        rr = _row(f"mixed-50/50-{mode}", res, homog.wall_s)
        rr["cost_saving_vs_bsp"] = round(1 - res.cost_usd / mix.cost_usd, 3)
        rows.append(rr)

    shocked = _engine(fleet=spot, samples=samples,
                      shocks=ShockModel(interval_s=120.0, kill_frac=0.5,
                                        tier="spot"))
    r = _row("mixed-50/50-spot-shocks", shocked, homog.wall_s)
    r["shock_events"] = shocked.shock_events
    rows.append(r)
    return rows


def summarize(rows) -> str:
    by = {r["config"]: r for r in rows}
    ident = by["fleet-identical-4096"]
    mix = by["mixed-50/50-bsp"]
    asy = by["mixed-50/50-async"]
    shock = by["mixed-50/50-spot-shocks"]
    return (f"identical-fleet engine==homog ({ident['slowdown_vs_homog']:.3f}x,"
            f" analytic err {ident['analytic_err']:+.1%}); mixed 50/50 "
            f"{mix['slowdown_vs_homog']:.2f}x vs homog-4096 and "
            f"{mix['slowdown_vs_equal_agg']:.2f}x vs equal-aggregate RAM; "
            f"async saves {asy['cost_saving_vs_bsp']:.0%} of the mixed "
            f"fleet's cost; spot shocks: {shock['failures']} kills in "
            f"{shock['shock_events']} bursts -> "
            f"{shock['slowdown_vs_homog']:.2f}x wall")


if __name__ == "__main__":
    rows = run(quick="--smoke" in sys.argv)
    for r in rows:
        print(r)
    print(summarize(rows))
    print("json:", emit_json("event_hetero_fleet", rows))
