"""Kernel micro-bench: us_per_call of each Pallas kernel (interpret mode —
CPU wall times are NOT TPU times; the roofline in benchmarks/roofline.py is
the performance source of truth. This bench proves the kernels execute and
tracks relative regressions)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops

RNG = np.random.RandomState(0)


def run() -> list:
    rows = []
    # hier_agg: 16 workers x 1M-element shard
    sh = jnp.array(RNG.randn(16, 1 << 20), jnp.float32)
    _, us = timed(lambda: jax.block_until_ready(
        ops.aggregate_shards(sh, block=8192)), reps=3)
    rows.append({"kernel": "hier_agg", "shape": "16x1Mi", "us_per_call": us})

    q = jnp.array(RNG.randn(1, 4, 1024, 64), jnp.float32)
    _, us = timed(lambda: jax.block_until_ready(
        ops.flash_attention(q, q, q, causal=True, block_q=256, block_k=256)),
        reps=2)
    rows.append({"kernel": "flash_attention", "shape": "b1h4s1024d64",
                 "us_per_call": us})

    b, s, h, p, n = 1, 512, 8, 64, 32
    x = jnp.array(RNG.randn(b, s, h, p), jnp.float32)
    dt = jnp.array(np.abs(RNG.randn(b, s, h)) * 0.5, jnp.float32)
    A = -jnp.ones(h, jnp.float32)
    B = jnp.array(RNG.randn(b, s, n), jnp.float32)
    C = jnp.array(RNG.randn(b, s, n), jnp.float32)
    D = jnp.ones(h, jnp.float32)
    _, us = timed(lambda: jax.block_until_ready(
        ops.ssd_scan(x, dt, A, B, C, D, chunk=128)[0]), reps=2)
    rows.append({"kernel": "ssd_scan", "shape": "b1s512h8p64n32",
                 "us_per_call": us})
    return rows


def summarize(rows) -> str:
    return "; ".join(f"{r['kernel']}={r['us_per_call']:.0f}us" for r in rows)


if __name__ == "__main__":
    for r in run():
        print(r)
