"""Multi-job contention on one shared ParamStore (event engine).

"Towards Demystifying Serverless ML Training" (arXiv 2105.07806) measures
storage contention dominating at scale: two training jobs synchronizing
through the same parameter-store node slow each other down by the *actual
overlap* of their transfers, which no per-job closed form can price.

Setup: two jobs (a hier job and a ps job — the latter's n*G downloads keep
the store link busy) run (a) each in its own isolated domain, then (b) in
one ``ContentionDomain`` sharing a single ParamStore — same seeds, so the
only difference is the shared link. A control (c) runs both jobs in one
domain but with *separate* stores: the slowdown must vanish, proving the
interference is the link, not the co-simulation.

The domain also tracks the keep-alive *union* (``sync_union_s``): the
shared container is alive once, not once per job, so each job is billed
its proportional share of the union (``store_billed_s``) — summing the
per-job windows would double-bill the overlap.

Run:  PYTHONPATH=src python -m benchmarks.multi_job [--smoke]
"""
from __future__ import annotations

import sys

from repro.serverless import (WORKLOADS, ContentionDomain, EventEngine,
                              ObjectStore, ParamStore)
from benchmarks.common import emit_json

JOBS = {
    # name: (workload, scheme, n, mem, batch). The ps job at n=32 moves
    # n*G per worker per iteration — the store link is its bottleneck, so
    # it is both the loudest neighbor and the most contention-sensitive;
    # hier's O(G) sync makes it comparatively quiet and robust.
    "jobA-hier": (WORKLOADS["bert-small"], "hier", 16, 4096, 1024),
    "jobB-ps": (WORKLOADS["bert-small"], "ps", 32, 3072, 1024),
}
SAMPLES = {"jobA-hier": 12_000, "jobB-ps": 8_000}
SMOKE_FRAC = 4


def _mk(name, param_store, domain, samples, seed):
    w, scheme, n, mem, batch = JOBS[name]
    return EventEngine(w, scheme, n, mem, batch, param_store, ObjectStore(),
                       samples=samples, seed=seed, domain=domain,
                       trace_enabled=False)


def run(quick: bool = False) -> list:
    samples = {k: v // (SMOKE_FRAC if quick else 1)
               for k, v in SAMPLES.items()}
    names = list(JOBS)

    isolated = {}
    for i, name in enumerate(names):
        isolated[name] = _mk(name, ParamStore(), None, samples[name],
                             seed=i).run()

    shared_ps = ParamStore()
    dom = ContentionDomain()
    engines = {name: _mk(name, shared_ps, dom, samples[name], seed=i)
               for i, name in enumerate(names)}
    dom.run()
    shared = {name: engines[name].result() for name in names}

    ctrl_dom = ContentionDomain()
    ctrl_engines = {name: _mk(name, ParamStore(), ctrl_dom, samples[name],
                              seed=i) for i, name in enumerate(names)}
    ctrl_dom.run()
    control = {name: ctrl_engines[name].result() for name in names}

    rows = []
    for name in names:
        iso, sh, ct = isolated[name], shared[name], control[name]
        rows.append({
            "figure": "multi_job", "job": name,
            "isolated_wall_s": round(iso.wall_s, 2),
            "shared_wall_s": round(sh.wall_s, 2),
            "control_wall_s": round(ct.wall_s, 2),
            "slowdown_shared": round(sh.wall_s / iso.wall_s, 3),
            "slowdown_control": round(ct.wall_s / iso.wall_s, 3),
            "isolated_cost_usd": round(iso.cost_usd, 4),
            "shared_cost_usd": round(sh.cost_usd, 4),
            "iters": sh.iters_done,
        })
    rows.append({
        "figure": "multi_job", "job": "store-keep-alive",
        "sync_sum_s": round(sum(shared[n].sync_s for n in names), 2),
        "sync_union_s": round(dom.sync_union_s, 2),
        "overlap_s": round(sum(shared[n].sync_s for n in names)
                           - dom.sync_union_s, 2),
        # what each job is actually billed: its share of the union
        "billed_s": {n: round(shared[n].store_billed_s, 2) for n in names},
    })
    return rows


def summarize(rows) -> str:
    jobs = [r for r in rows if "slowdown_shared" in r]
    ka = next(r for r in rows if r["job"] == "store-keep-alive")
    parts = [f"{r['job']} {r['slowdown_shared']:.2f}x shared "
             f"(control {r['slowdown_control']:.2f}x)" for r in jobs]
    return ("; ".join(parts)
            + f"; keep-alive union {ka['sync_union_s']}s vs per-job sum "
              f"{ka['sync_sum_s']}s ({ka['overlap_s']}s overlap)")


if __name__ == "__main__":
    rows = run(quick="--smoke" in sys.argv)
    for r in rows:
        print(r)
    print(summarize(rows))
    print("json:", emit_json("event_multi_job", rows))
