"""Multi-job contention on one shared ParamStore (event engine).

"Towards Demystifying Serverless ML Training" (arXiv 2105.07806) measures
storage contention dominating at scale: two training jobs synchronizing
through the same parameter-store node slow each other down by the *actual
overlap* of their transfers, which no per-job closed form can price.

Setup: two jobs (a hier job and a ps job — the latter's n*G downloads keep
the store link busy) run (a) each in its own isolated domain, then (b) in
one ``ContentionDomain`` sharing a single ParamStore — same seeds, so the
only difference is the shared link. A control (c) runs both jobs in one
domain but with *separate* stores: the slowdown must vanish, proving the
interference is the link, not the co-simulation.

The domain also tracks the keep-alive *union* (``sync_union_s``): the
shared container is alive once, not once per job, so each job is billed
its proportional share of the union (``store_billed_s``) — summing the
per-job windows would double-bill the overlap.

Run:  PYTHONPATH=src python -m benchmarks.multi_job [--smoke]
"""
from __future__ import annotations

import sys

from repro.core import ConfigSpace, Goal
from repro.serverless import (WORKLOADS, ContentionDomain, EventEngine,
                              ObjectStore, ParamStore)
from repro.workflow import BudgetAllocator, TaskSpec, WorkflowDAG
from benchmarks.common import emit_json

JOBS = {
    # name: (workload, scheme, n, mem, batch). The ps job at n=32 moves
    # n*G per worker per iteration — the store link is its bottleneck, so
    # it is both the loudest neighbor and the most contention-sensitive;
    # hier's O(G) sync makes it comparatively quiet and robust.
    "jobA-hier": (WORKLOADS["bert-small"], "hier", 16, 4096, 1024),
    "jobB-ps": (WORKLOADS["bert-small"], "ps", 32, 3072, 1024),
}
SAMPLES = {"jobA-hier": 12_000, "jobB-ps": 8_000}
SMOKE_FRAC = 4


def _mk(name, param_store, domain, samples, seed):
    w, scheme, n, mem, batch = JOBS[name]
    return EventEngine(w, scheme, n, mem, batch, param_store, ObjectStore(),
                       samples=samples, seed=seed, domain=domain,
                       trace_enabled=False)


def run(quick: bool = False) -> list:
    samples = {k: v // (SMOKE_FRAC if quick else 1)
               for k, v in SAMPLES.items()}
    names = list(JOBS)

    isolated = {}
    for i, name in enumerate(names):
        isolated[name] = _mk(name, ParamStore(), None, samples[name],
                             seed=i).run()

    shared_ps = ParamStore()
    dom = ContentionDomain()
    engines = {name: _mk(name, shared_ps, dom, samples[name], seed=i)
               for i, name in enumerate(names)}
    dom.run()
    shared = {name: engines[name].result() for name in names}

    ctrl_dom = ContentionDomain()
    ctrl_engines = {name: _mk(name, ParamStore(), ctrl_dom, samples[name],
                              seed=i) for i, name in enumerate(names)}
    ctrl_dom.run()
    control = {name: ctrl_engines[name].result() for name in names}

    rows = []
    for name in names:
        iso, sh, ct = isolated[name], shared[name], control[name]
        rows.append({
            "figure": "multi_job", "job": name,
            "isolated_wall_s": round(iso.wall_s, 2),
            "shared_wall_s": round(sh.wall_s, 2),
            "control_wall_s": round(ct.wall_s, 2),
            "slowdown_shared": round(sh.wall_s / iso.wall_s, 3),
            "slowdown_control": round(ct.wall_s / iso.wall_s, 3),
            "isolated_cost_usd": round(iso.cost_usd, 4),
            "shared_cost_usd": round(sh.cost_usd, 4),
            "iters": sh.iters_done,
        })
    rows.append({
        "figure": "multi_job", "job": "store-keep-alive",
        "sync_sum_s": round(sum(shared[n].sync_s for n in names), 2),
        "sync_union_s": round(dom.sync_union_s, 2),
        "overlap_s": round(sum(shared[n].sync_s for n in names)
                           - dom.sync_union_s, 2),
        # what each job is actually billed: its share of the union
        "billed_s": {n: round(shared[n].store_billed_s, 2) for n in names},
    })
    rows.append(_priority_share_row(samples))
    return rows


# priorities for the weighted-share scenario: jobA is the production job
PRIORITIES = {"jobA-hier": 3, "jobB-ps": 1}


def _priority_share_row(samples) -> dict:
    """Cross-job *fairness* (ROADMAP open item), first measurable
    scenario: the workflow layer's ``BudgetAllocator`` splits one shared
    budget across the two contending jobs by
    ``forecast-cost x priority`` weight, and converts each grant into a
    worker window — the priority knob visibly changes both the dollars
    and the fleet scale each job is entitled to."""
    specs = []
    for name, (w, _scheme, _n, _mem, batch) in JOBS.items():
        specs.append(TaskSpec(name, w, epochs=1, batch_size=batch,
                              samples=samples[name],
                              priority=PRIORITIES[name]))
    dag = WorkflowDAG(specs)
    goal = Goal("deadline_budget", deadline_s=7200.0, budget_usd=30.0)
    alloc = BudgetAllocator(dag, goal, ParamStore(), ObjectStore(),
                            space=ConfigSpace(max_workers=64))
    grants, _ = alloc.allocate(now_s=0.0, spent_usd=0.0, running={},
                               finished=set(), dropped=set(),
                               ready=list(JOBS))
    a, b = grants["jobA-hier"], grants["jobB-ps"]
    # the higher-priority job is entitled to the larger weighted share of
    # budget and fleet (its forecast is also the cheaper of the two, so
    # any inversion here would mean the priority knob is dead)
    assert a.budget_usd > b.budget_usd
    assert a.max_workers >= b.max_workers
    return {
        "figure": "multi_job", "job": "priority-weighted-share",
        "priorities": dict(PRIORITIES),
        "grant_usd": {n: round(grants[n].budget_usd, 4) for n in JOBS},
        "grant_share": {n: round(grants[n].budget_usd
                                 / sum(g.budget_usd
                                       for g in grants.values()), 3)
                        for n in JOBS},
        "workers": {n: [grants[n].min_workers, grants[n].max_workers]
                    for n in JOBS},
        "budget_usd": goal.budget_usd,
    }


def summarize(rows) -> str:
    jobs = [r for r in rows if "slowdown_shared" in r]
    ka = next(r for r in rows if r["job"] == "store-keep-alive")
    pr = next(r for r in rows if r["job"] == "priority-weighted-share")
    parts = [f"{r['job']} {r['slowdown_shared']:.2f}x shared "
             f"(control {r['slowdown_control']:.2f}x)" for r in jobs]
    shares = "/".join(f"{pr['grant_share'][n]:.2f}" for n in JOBS)
    return ("; ".join(parts)
            + f"; keep-alive union {ka['sync_union_s']}s vs per-job sum "
              f"{ka['sync_sum_s']}s ({ka['overlap_s']}s overlap)"
            + f"; priority shares {shares}")


if __name__ == "__main__":
    rows = run(quick="--smoke" in sys.argv)
    for r in rows:
        print(r)
    print(summarize(rows))
    print("json:", emit_json("event_multi_job", rows))
