"""Paper Fig. 13: neural architecture search (ENAS-style).

The search explores architectures of very different sizes; SMLT re-optimizes
the deployment per candidate while LambdaML keeps the allocation tuned for
the first model. Throughput and cost over the exploration timeline.
"""
from __future__ import annotations

import numpy as np

from repro.core import EpochPlan, Goal
from repro.serverless import Workload
from benchmarks.common import fresh_scheduler

SAMPLES = 150_000
BATCH = 512


def enas_candidates(n: int = 12, seed: int = 0):
    """Candidate child models in the ENAS range. Children differ both in
    parameter count AND in compute intensity (depth/width/sequence trade
    offs change FLOPs-per-parameter), so the optimal deployment moves:
    comm-heavy children want few workers, compute-heavy ones want many."""
    rng = np.random.RandomState(seed)
    sizes = rng.choice([5e6, 11e6, 23e6, 46e6, 80e6, 110e6], size=n)
    tokens = rng.choice([64, 256, 1024], size=n)
    sizes[0], tokens[0] = 110e6, 1024  # exploration starts from the largest
    # child: the fixed-allocation baseline provisions for THIS one and then
    # overpays on every smaller candidate (paper Fig 13)
    return [Workload(f"enas-{i}", int(s), 6.0 * s * t, 3_000, 10 ** 9)
            for i, (s, t) in enumerate(zip(sizes, tokens))]


N_SEEDS = 5  # candidate streams are random; report per-seed + median


def _one_stream(seed: int):
    plans = [EpochPlan(BATCH, w, samples=SAMPLES)
             for w in enas_candidates(seed=seed)]
    # NAS exploration is throughput-driven: evaluate candidates fast
    sched, *_ = fresh_scheduler("hier", seed=seed)
    smlt = sched.run(plans, Goal("min_time"))
    # LambdaML: allocation tuned for the FIRST child, then frozen
    sched, *_ = fresh_scheduler("hier", seed=seed)
    lml = sched.run(plans, Goal("min_time"), adaptive=False,
                    fixed_config=smlt.config_history[0])
    return smlt, lml


def run() -> list:
    rows = []
    for seed in range(N_SEEDS):
        smlt, lml = _one_stream(seed)
        if seed == 0:  # Fig-13-style timeline for one stream
            for res, name in ((smlt, "SMLT"), (lml, "LambdaML")):
                for e in res.events:
                    if e.kind != "epoch":
                        continue
                    rows.append({"figure": "fig13", "system": name,
                                 "t_s": round(e.t, 1),
                                 "throughput": round(e.throughput, 1),
                                 "workers": e.workers,
                                 "model_params": e.model_params})
        rows.append({"figure": "fig13_cost", "seed": seed,
                     "smlt_wall_s": round(smlt.wall_s, 0),
                     "smlt_usd": round(smlt.total_cost, 2),
                     "lml_wall_s": round(lml.wall_s, 0),
                     "lml_usd": round(lml.total_cost, 2),
                     "time_speedup": round(lml.wall_s / smlt.wall_s, 2),
                     "cost_saving": round(lml.total_cost / smlt.total_cost,
                                          2)})
    return rows


def summarize(rows) -> str:
    costs = [r for r in rows if r["figure"] == "fig13_cost"]
    ts = sorted(r["time_speedup"] for r in costs)
    cs = sorted(r["cost_saving"] for r in costs)
    med = len(ts) // 2
    return (f"ENAS exploration over {len(costs)} candidate streams: "
            f"median {ts[med]:.2f}x faster / {cs[med]:.2f}x cheaper than "
            f"frozen allocation (range {ts[0]:.2f}-{ts[-1]:.2f}x / "
            f"{cs[0]:.2f}-{cs[-1]:.2f}x; paper: 3x on their stream)")


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(summarize(rows))
