"""Paper Fig. 11b: 24-hour end-to-end online training cost.

Samples arrive continuously (diurnal Poisson stream); each hour the systems
train on what arrived. Serverless systems pay only while training; VM
systems pay around the clock (IaaS) or pay heavy profiling upfront (MLCD).
"""
from __future__ import annotations

from repro.core import Config, EpochPlan, Goal
from repro.core.cost_model import VM_TYPES, vm_epoch_estimate
from repro.data import OnlineStream
from repro.serverless import WORKLOADS
from benchmarks.common import fresh_scheduler

W = WORKLOADS["resnet50"]
HOURS = 24
BATCH = 512


def hourly_arrivals(seed: int = 0):
    stream = OnlineStream(base_rate=6.0, seed=seed)
    return [max(stream.arrivals(h * 3600.0, 3600.0), BATCH)
            for h in range(HOURS)]


def run() -> list:
    rows = []
    arr = hourly_arrivals()
    plans = [EpochPlan(BATCH, W, samples=a) for a in arr]

    sched, *_ = fresh_scheduler("hier", seed=0)
    smlt = sched.run(plans, Goal("min_cost"))
    rows.append({"figure": "fig11b", "system": "SMLT",
                 "total_usd": round(smlt.total_cost, 2),
                 "busy_s": round(smlt.wall_s, 0)})

    sched, *_ = fresh_scheduler("hier", seed=0)
    lml = sched.run(plans, Goal("min_cost"), adaptive=False,
                    fixed_config=Config(workers=50, memory_mb=4096))
    rows.append({"figure": "fig11b", "system": "LambdaML",
                 "total_usd": round(lml.total_cost, 2),
                 "busy_s": round(lml.wall_s, 0)})

    vm = VM_TYPES["c5.4xlarge"]
    n_vms = 4
    # IaaS: VMs up for the whole 24h regardless of utilization
    iaas_usd = n_vms * vm.usd_hour * HOURS
    rows.append({"figure": "fig11b", "system": "IaaS",
                 "total_usd": round(iaas_usd, 2), "busy_s": HOURS * 3600})
    # MLCD: VM fleet runs while training + upfront profiling
    busy = sum(vm_epoch_estimate(W, vm, n_vms, BATCH, samples=a)[0]
               for a in arr)
    train_usd = n_vms * vm.usd_hour * busy / 3600.0
    profile_usd = 15 * vm_epoch_estimate(W, vm, n_vms, BATCH,
                                         samples=2_000)[1]
    # continuous provisioning: MLCD keeps the fleet warm between bursts
    # (non-deterministic arrival times -> conservative 50% idle-on)
    idle_usd = 0.5 * n_vms * vm.usd_hour * (HOURS - busy / 3600.0)
    rows.append({"figure": "fig11b", "system": "MLCD",
                 "total_usd": round(train_usd + profile_usd + idle_usd, 2),
                 "busy_s": round(busy, 0)})
    return rows


def summarize(rows) -> str:
    d = {r["system"]: r["total_usd"] for r in rows}
    return (f"24h online training: SMLT ${d['SMLT']} vs LambdaML "
            f"${d['LambdaML']} vs MLCD ${d['MLCD']} vs IaaS ${d['IaaS']} "
            f"(SMLT {max(d.values())/d['SMLT']:.1f}x cheaper than worst)")


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(summarize(rows))
