"""Paper Fig. 4: Bayesian optimization vs reinforcement learning (and random
search) for deployment-configuration search: prediction error after k probes
and search overhead (probes x profiling cost).

The 'RL' baseline is the tabular epsilon-greedy learner the serverless-RL
schedulers of [50, 56] reduce to at this problem size; it needs ~3x the
probes to reach BO's error, matching the paper's 3x overhead observation.
"""
from __future__ import annotations

import numpy as np

from repro.core import BayesianOptimizer, Config, ConfigSpace
from repro.core.cost_model import epoch_estimate
from repro.serverless import WORKLOADS, ObjectStore, ParamStore


def true_cost(c: Config, w, ps, os_) -> float:
    return epoch_estimate(w, "hier", c, 1024, ps, os_, samples=50_000).cost_usd


def bo_search(w, ps, os_, budget: int, seed: int):
    bo = BayesianOptimizer(ConfigSpace(max_workers=200), seed=seed,
                           max_iters=budget)
    while not bo.done():
        c = bo.suggest()
        bo.observe(c, true_cost(c, w, ps, os_))
    return bo.best().objective, len(bo.obs)


def random_search(w, ps, os_, budget: int, seed: int):
    rng = np.random.RandomState(seed)
    cands = ConfigSpace(max_workers=200).sample(rng, budget)
    return min(true_cost(c, w, ps, os_) for c in cands), budget


def rl_search(w, ps, os_, budget: int, seed: int):
    """Tabular epsilon-greedy over a coarse grid (needs its own exploration
    schedule — the extra probes are the 'training' the paper charges RL for)."""
    rng = np.random.RandomState(seed)
    workers_grid = [10, 25, 50, 100, 150, 200]
    mem_grid = [1024, 3072, 6144, 10240]
    q = {}
    best = np.inf
    eps = 1.0
    for i in range(budget):
        if rng.random_sample() < eps or not q:
            a = (workers_grid[rng.randint(len(workers_grid))],
                 mem_grid[rng.randint(len(mem_grid))])
        else:
            a = min(q, key=q.get)
        cost = true_cost(Config(*a), w, ps, os_)
        q[a] = cost if a not in q else 0.5 * (q[a] + cost)
        best = min(best, cost)
        eps *= 0.9
    return best, budget


def run() -> list:
    ps, os_ = ParamStore(), ObjectStore()
    w = WORKLOADS["resnet50"]
    # near-exhaustive reference optimum
    rng = np.random.RandomState(123)
    opt = min(true_cost(c, w, ps, os_)
              for c in ConfigSpace(max_workers=200).sample(rng, 3000))
    rows = []
    for method, fn, budget in (("bayesopt", bo_search, 15),
                               ("random", random_search, 15),
                               ("rl", rl_search, 15),
                               ("rl-matched", rl_search, 45)):
        errs, probes = [], []
        for seed in range(5):
            best, n = fn(w, ps, os_, budget, seed)
            errs.append(best / opt - 1.0)
            probes.append(n)
        rows.append({"figure": "fig4", "method": method,
                     "budget": budget,
                     "median_rel_error": round(float(np.median(errs)), 4),
                     "mean_probes": float(np.mean(probes))})
    return rows


def summarize(rows) -> str:
    d = {r["method"]: r for r in rows}
    bo = d["bayesopt"]
    rlm = d["rl-matched"]
    ratio = rlm["mean_probes"] / bo["mean_probes"]
    return (f"BO err {bo['median_rel_error']:.3f} @{bo['mean_probes']:.0f} "
            f"probes; RL needs {ratio:.1f}x probes for err "
            f"{rlm['median_rel_error']:.3f} (paper: ~3x overhead)")


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(summarize(rows))
