"""Pipelined CommPlan overlap: compute ∥ communication across depths and
strategies, on both execution paths.

FuncPipe-style pipelining is the biggest remaining lever once the
dataflow itself is optimal: ``CommPlan.pipeline(depth)`` splits compute
into micro-batch segments and hides the pre-barrier uploads of segment
*i* under compute of segment *i+1*. This benchmark sweeps
depth × {ps, scatter_reduce, hier} with the hidden side — the
pre-barrier upload — sized near one compute segment (ul/compute ≈ 0.8,
the regime where overlap pays most; total comm, exposed downloads
included, is larger) and enforces the PR's acceptance criteria:

  - the event engine reproduces the overlap-aware closed form within 1%
    at zero variance for every (strategy, depth) — the two paths price
    the *same* schedule;
  - ``depth=1`` is exactly today's sequential plan, and any
    ``depth > 1`` strictly beats it on wall-clock whenever the plan has
    hidden-comm to work with (overlap wins when the overlappable upload
    is comparable to compute);
  - a ``ConfigSpace(search_comm=True)`` Bayesian-optimizer run on a
    comm-bound workload *selects* a ``pipeline_depth > 1`` plan — the
    scheduler can now choose overlap, not just execute it. (Overlap
    carries no convergence inflation: micro-batch gradient accumulation
    is numerically the full-batch gradient.)

Run:  PYTHONPATH=src python -m benchmarks.overlap_pipeline [--smoke]
"""
from __future__ import annotations

import dataclasses
import sys

from repro.core import Config, ConfigSpace, Goal, TaskScheduler
from repro.core.comm import CommSpec, build_plan
from repro.core.cost_model import epoch_estimate
from repro.serverless import (WORKLOADS, EventEngine, ObjectStore, ParamStore,
                              ServerlessPlatform)

W = WORKLOADS["bert-small"]
N = 64
MEM = 4096
BATCH = 512              # local batch 8: overlappable UL ≈ 0.8x compute
SAMPLES = 8_192          # 16 iterations
SMOKE_SAMPLES = 2_048

STRATEGIES = {
    "ps": CommSpec("ps"),
    "scatter_reduce": CommSpec("scatter_reduce"),
    "hier-b4": CommSpec("hier", branching=4),
}
DEPTHS = (1, 2, 4, 8)


def _row(name, spec, depth, samples):
    spec = dataclasses.replace(spec, pipeline_depth=depth)
    plan = build_plan(spec, W.grad_bytes, N)
    est = epoch_estimate(W, spec, Config(N, MEM), BATCH, ParamStore(),
                         ObjectStore(), samples=samples)
    r = EventEngine(W, spec, N, MEM, BATCH, ParamStore(), ObjectStore(),
                    samples=samples, seed=0, trace_enabled=False).run()
    err = r.wall_s / est.wall_s - 1
    assert abs(err) <= 0.01, (name, depth, err)
    assert abs(r.cost_usd / est.cost_usd - 1) <= 0.01, (name, depth)
    it = est.it_breakdown
    # the hidden-side size: the leading upload run's time (same phase
    # names at every depth, marked overlappable once depth > 1)
    hidden_names = [ph.name for ph in build_plan(
        dataclasses.replace(spec, pipeline_depth=2), W.grad_bytes,
        N).overlappable_phases]
    ul_s = sum(it[nm] for nm in hidden_names)
    return {"figure": "overlap_pipeline", "strategy": name, "depth": depth,
            "ul_compute_ratio": round(ul_s / it["compute"], 2),
            "comm_compute_ratio": round(it["comm"] / it["compute"], 2),
            "hidden_s_per_iter": round(it["comm_hidden"], 3),
            "bubble_s_per_iter": round(it["bubble"], 3),
            "engine_wall_s": round(r.wall_s, 2),
            "analytic_wall_s": round(est.wall_s, 2),
            "analytic_err": round(err, 4),
            "store_busy_s_per_iter": round(it["store_busy"], 3),
            "cost_usd": round(r.cost_usd, 4),
            "plan_wire_mb": round(plan.wire_bytes / 1e6, 1)}


def _optimizer_row(quick: bool):
    """With the fleet shape pinned, the only way the optimizer can buy
    wall-clock on this comm-bound workload is the comm plan itself — it
    must discover that a ``pipeline_depth > 1`` schedule dominates its
    sequential counterpart (same wire bytes, same numerics, less
    exposed time)."""
    space = ConfigSpace(min_workers=N, max_workers=N,
                        min_memory=MEM, max_memory=MEM, search_comm=True,
                        ratio_choices=(1.0,), depth_choices=(1, 2, 4, 8))
    sched = TaskScheduler(ServerlessPlatform(seed=0), ObjectStore(),
                          ParamStore(), scheme="scatter_reduce", space=space,
                          seed=0, bo_max_iters=6 if quick else 10)
    cfg, t_prof, usd_prof, _ = sched.optimize(
        W, BATCH, Goal("min_time"), epochs_remaining=4, samples=SAMPLES)
    assert cfg.pipeline_depth > 1, \
        f"optimizer failed to pick an overlapped plan: {cfg}"
    return {"figure": "overlap_pipeline", "strategy": "BO-selected",
            "depth": cfg.pipeline_depth, "selected_comm": cfg.comm,
            "workers": cfg.workers, "memory_mb": cfg.memory_mb,
            "profile_s": round(t_prof, 1), "profile_usd": round(usd_prof, 2)}


def run(quick: bool = False) -> list:
    samples = SMOKE_SAMPLES if quick else SAMPLES
    depths = (1, 4) if quick else DEPTHS
    rows = []
    for name, spec in STRATEGIES.items():
        for depth in depths:
            rows.append(_row(name, spec, depth, samples))
    # acceptance: overlap strictly wins over the sequential plan on both
    # paths for every strategy with hidden comm
    for name in STRATEGIES:
        by_depth = {r["depth"]: r for r in rows if r["strategy"] == name}
        base = by_depth[1]
        deepest = by_depth[max(by_depth)]
        assert deepest["engine_wall_s"] < base["engine_wall_s"], (name, by_depth)
        assert deepest["analytic_wall_s"] < base["analytic_wall_s"], name
        # overlap never changes the keep-alive billing basis
        assert deepest["store_busy_s_per_iter"] >= base["store_busy_s_per_iter"]
    rows.append(_optimizer_row(quick))
    return rows


def summarize(rows) -> str:
    sr = {r["depth"]: r for r in rows if r["strategy"] == "scatter_reduce"}
    base, best = sr[1], sr[max(sr)]
    speed = base["engine_wall_s"] / best["engine_wall_s"]
    bo = [r for r in rows if r["strategy"] == "BO-selected"][0]
    return (f"depth={max(sr)} hides {best['hidden_s_per_iter']:.2f}s/iter "
            f"(ul/compute={base['ul_compute_ratio']}): "
            f"{speed:.2f}x over sequential scatter_reduce @n={N}; "
            f"BO picked depth={bo['depth']} ({bo['selected_comm'] or 'default'})")


if __name__ == "__main__":
    rows = run(quick="--smoke" in sys.argv)
    for r in rows:
        print(r)
    print(summarize(rows))
    from benchmarks.common import emit_json
    print("json:", emit_json("overlap_pipeline", rows))
