"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Three pairs (selection rationale in EXPERIMENTS.md §Perf):
  A. qwen2-moe-a2.7b x train_4k   — worst useful-FLOPs ratio (0.05)
  B. mamba2-2.7b     x train_4k   — most collective-bound (coll/compute 3.8x)
  C. mistral-large-123b x train_4k — the paper's own technique (grad sync)

Each iteration re-computes the analytic roofline terms AND re-lowers the
production config in a fresh subprocess (dryrun sets XLA_FLAGS), recording
HLO collective stats. Results go to experiments/perf/.

Run:  PYTHONPATH=src python -m benchmarks.perf_hillclimb [A|B|C ...]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.configs import ARCHS
from benchmarks import flops_model as FM

OUT = "experiments/perf"


def analytic(arch, shape, *, n_data=16, n_model=16, n_pod=1,
             strategy="hier", **cfg_overrides):
    cfg = ARCHS[arch].replace(**cfg_overrides) if cfg_overrides else ARCHS[arch]
    t = FM.step_terms(cfg, shape, n_data=n_data, n_model=n_model,
                      n_pod=n_pod, strategy=strategy)
    return {"compute": round(t.t_compute, 4), "memory": round(t.t_memory, 4),
            "collective": round(t.t_collective, 4),
            "cross_pod_gb": round(t.coll_cross_pod / 1e9, 2),
            "dominant": t.dominant(),
            "bound_s": round(max(t.t_compute, t.t_memory,
                                 t.t_collective), 4)}


def lower(arch, shape, tag, *, mesh_shape=None, multi_pod=False,
          strategy="hier", sets=()):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--strategy", strategy, "--out", OUT,
           "--tag", tag, "--skip-existing"]
    if mesh_shape:
        cmd += ["--mesh-shape", mesh_shape]
    if multi_pod:
        cmd += ["--multi-pod"]
    for s in sets:
        cmd += ["--set", s]
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=3600)
    if r.returncode != 0:
        return {"error": (r.stdout + r.stderr)[-800:]}
    mesh = mesh_shape or ("2x16x16" if multi_pod else "16x16")
    path = os.path.join(OUT, f"{arch}__{shape}__{mesh}__{strategy}{tag}.json")
    with open(path) as f:
        d = json.load(f)
    return {"hlo_coll_gb": round(d["collective_bytes"] / 1e9, 2),
            "hlo_flops_T": round(d["flops"] / 1e12, 2),
            "hlo_ops": {k: v["count"] for k, v in d["collectives"].items()},
            "compile_s": d["compile_s"]}


def record(name, iters):
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"hillclimb_{name}.json"), "w") as f:
        json.dump(iters, f, indent=1)
    print(json.dumps(iters, indent=1))


def climb_A():
    """qwen2-moe: MoE dispatch waste."""
    arch, shape = "qwen2-moe-a2.7b", "train_4k"
    iters = []
    iters.append(dict(step="A0 baseline (paper-faithful, group=4096)",
                      analytic=analytic(arch, shape),
                      hlo=lower(arch, shape, "")))
    iters.append(dict(
        step="A1 dispatch group 4096->512",
        hypothesis="dispatch flops/token ~ 4*g*cf*k*d: 8x smaller group "
                   "=> dispatch term ~8x down; compute 5.8s -> ~1.1s",
        analytic=analytic(arch, shape, moe_group=512),
        hlo=lower(arch, shape, "__g512", sets=["moe_group=512"])))
    iters.append(dict(
        step="A2 + pad experts 60->64 (expert-parallel sharding)",
        hypothesis="E=64 divides model axis: expert FFN + dispatch einsums "
                   "shard 16-way => dispatch/16; compute -> ~0.45s",
        analytic=analytic(arch, shape, moe_group=512, moe_pad_experts=64),
        hlo=lower(arch, shape, "__g512_pad64",
                  sets=["moe_group=512", "moe_pad_experts=64"])))
    iters.append(dict(
        step="A3 + group 512->256 (check diminishing returns)",
        hypothesis="halving g again halves dispatch, but dense/attn now "
                   "dominate: expect <5% on the compute term",
        analytic=analytic(arch, shape, moe_group=256, moe_pad_experts=64),
        hlo=lower(arch, shape, "__g256_pad64",
                  sets=["moe_group=256", "moe_pad_experts=64"])))
    iters.append(dict(
        step="A4 + sequence parallelism (attack the new dominant term)",
        hypothesis="collective is now dominant (1.22s, mostly TP-AR): "
                   "SP halves TP bytes -> ~0.65s",
        analytic=analytic(arch, shape, moe_group=512, moe_pad_experts=64,
                          seq_shard=True),
        hlo=lower(arch, shape, "__g512_pad64_seq",
                  sets=["moe_group=512", "moe_pad_experts=64",
                        "seq_shard=True"])))
    iters.append(dict(
        step="A5 + mesh 32x8 (E=64 still divides 8)",
        hypothesis="2x more DP halves tokens/device -> TP bytes halve "
                   "again; expert einsums now /8 not /16 (compute +2x on "
                   "dispatch but it is small): expect bound ~0.55s compute",
        analytic=analytic(arch, shape, n_data=32, n_model=8, moe_group=512,
                          moe_pad_experts=64, seq_shard=True),
        hlo=lower(arch, shape, "__g512_pad64_seq", mesh_shape="32x8",
                  sets=["moe_group=512", "moe_pad_experts=64",
                        "seq_shard=True"])))
    record("A_qwen2moe_dispatch", iters)


def climb_B():
    """mamba2: TP right-sizing for a collective-bound small model."""
    arch, shape = "mamba2-2.7b", "train_4k"
    iters = []
    iters.append(dict(step="B0 baseline 16x16 mesh",
                      analytic=analytic(arch, shape),
                      hlo=lower(arch, shape, "")))
    iters.append(dict(
        step="B1 mesh 16x16 -> 64x4 (right-size TP)",
        hypothesis="TP-AR bytes ~ tokens/device: 4x more DP => 4x fewer "
                   "tokens/device => collective 1.75s -> ~0.5s; grad RS "
                   "grows (P/4 vs P/16) but stays <0.1s",
        analytic=analytic(arch, shape, n_data=64, n_model=4),
        hlo=lower(arch, shape, "", mesh_shape="64x4")))
    iters.append(dict(
        step="B2 mesh 128x2",
        hypothesis="again 2x fewer tokens/device but grad RS doubles: "
                   "expect net <10% further",
        analytic=analytic(arch, shape, n_data=128, n_model=2),
        hlo=lower(arch, shape, "", mesh_shape="128x2")))
    iters.append(dict(
        step="B3 64x4 + sequence parallelism",
        hypothesis="each TP all-reduce becomes RS+AG: TP bytes halve; "
                   "collective ~0.57 -> ~0.35s, now compute-bound",
        analytic=analytic(arch, shape, n_data=64, n_model=4, seq_shard=True),
        hlo=lower(arch, shape, "__seqshard", mesh_shape="64x4",
                  sets=["seq_shard=True"])))
    record("B_mamba2_mesh", iters)


def climb_C():
    """mistral-large: the paper's gradient-sync technique at 123B scale."""
    arch, shape = "mistral-large-123b", "train_4k"
    iters = []
    iters.append(dict(
        step="C0 naive baseline: flat all-reduce, replicated opt state",
        analytic=analytic(arch, shape, strategy="allreduce"),
        hlo=lower(arch, shape, "", strategy="allreduce")))
    iters.append(dict(
        step="C1 PAPER-FAITHFUL: hierarchical ScatterReduce (RS+AG, "
             "sharded optimizer)",
        hypothesis="same wire bytes as ring-AR but opt-state memory /16 "
                   "and the update runs on shards (SMLT Fig. 5 dataflow)",
        analytic=analytic(arch, shape, strategy="hier"),
        hlo=lower(arch, shape, "", strategy="hier")))
    iters.append(dict(
        step="C2 multi-pod: flat 1-level sync over (pod,data)",
        hypothesis="gradient RS crosses the pod link at full |G|/16 bytes",
        analytic=analytic(arch, shape, strategy="hier1", n_pod=2),
        hlo=lower(arch, shape, "", strategy="hier1", multi_pod=True)))
    iters.append(dict(
        step="C3 multi-pod: 2-level pod-aware hierarchy (beyond-paper)",
        hypothesis="RS intra-pod first => cross-pod bytes drop 16x "
                   "(|G|/16/16 per device)",
        analytic=analytic(arch, shape, strategy="hier2", n_pod=2),
        hlo=lower(arch, shape, "", strategy="hier", multi_pod=True)))
    iters.append(dict(
        step="C4 + sequence parallelism (beyond-paper)",
        hypothesis="TP-AR is the largest single-pod term (22.6s of 24.2s): "
                   "SP halves it -> collective ~13s, compute-bound",
        analytic=analytic(arch, shape, strategy="hier", seq_shard=True),
        hlo=lower(arch, shape, "__seqshard", sets=["seq_shard=True"])))
    iters.append(dict(
        step="C5 + remat policy full->dots (beyond-paper)",
        hypothesis="fwd_mults 4.0->3.1: compute 21.8 -> ~16.9s at the cost "
                   "of ~3x activation HBM (fits: 0.4s memory term)",
        analytic=analytic(arch, shape, strategy="hier", seq_shard=True,
                          remat_policy="dots"),
        hlo=lower(arch, shape, "__seqshard_dots",
                  sets=["seq_shard=True", "remat_policy='dots'"])))
    iters.append(dict(
        step="C6 + mesh 32x8 (right-size TP at 123B)",
        hypothesis="tokens/device halve => TP bytes halve again; grad RS "
                   "doubles (P/8) but is ~1s; expect collective ~7s",
        analytic=analytic(arch, shape, strategy="hier", seq_shard=True,
                          remat_policy="dots", n_data=32, n_model=8),
        hlo=lower(arch, shape, "__seqshard_dots", mesh_shape="32x8",
                  sets=["seq_shard=True", "remat_policy='dots'"])))
    record("C_mistral_sync", iters)


def climb_D():
    """Bonus (beyond the required three): llama-3.2-vision-90b train —
    2nd-most collective-heavy pair; checks the B/C levers generalize."""
    arch, shape = "llama-3.2-vision-90b", "train_4k"
    iters = []
    iters.append(dict(step="D0 baseline 16x16",
                      analytic=analytic(arch, shape),
                      hlo=lower(arch, shape, "")))
    iters.append(dict(
        step="D1 + sequence parallelism",
        hypothesis="TP-AR bytes halve: collective 21.7 -> ~11.5s",
        analytic=analytic(arch, shape, seq_shard=True),
        hlo=lower(arch, shape, "__seqshard", sets=["seq_shard=True"])))
    iters.append(dict(
        step="D2 + remat dots",
        hypothesis="compute 15.5 -> ~12s (fwd_mults 4->3.1)",
        analytic=analytic(arch, shape, seq_shard=True, remat_policy="dots"),
        hlo=lower(arch, shape, "__seqshard_dots",
                  sets=["seq_shard=True", "remat_policy='dots'"])))
    iters.append(dict(
        step="D3 + mesh 32x8",
        hypothesis="TP bytes halve again; 90B params at TP=8 with FSDP/32 "
                   "still fit (params 5.6GB + opt 22GB/32)",
        analytic=analytic(arch, shape, seq_shard=True, remat_policy="dots",
                          n_data=32, n_model=8),
        hlo=lower(arch, shape, "__seqshard_dots", mesh_shape="32x8",
                  sets=["seq_shard=True", "remat_policy='dots'"])))
    record("D_llamavision", iters)


if __name__ == "__main__":
    which = sys.argv[1:] or ["A", "B", "C"]
    for w in which:
        {"A": climb_A, "B": climb_B, "C": climb_C, "D": climb_D}[w]()
