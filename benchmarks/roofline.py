"""§Roofline: per (arch x shape) three-term roofline on the single-pod mesh.

 compute    = FLOPs / (chip peak 197 TF/s bf16)
 memory     = HBM bytes / (819 GB/s)
 collective = collective bytes / (50 GB/s/link ICI)

Primary terms come from the analytic per-device model (flops_model.py);
the dry-run JSONs supply the HLO cross-check (XLA cost_analysis counts scan
bodies once — see flops_model docstring), the collective op mix, and the
per-device argument sizes. MODEL_FLOPS = 6·N_active·D (train) or 2·N·D
(inference); the ratio MODEL_FLOPS/step_FLOPs shows remat/dispatch waste.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.configs import ARCHS, pairs
from repro.models.base import INPUT_SHAPES
from benchmarks import flops_model as FM

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")
N_CHIPS = 256


def load_dryrun(arch: str, shape: str, mesh: str = "16x16",
                strategy: str = "hier") -> Optional[Dict]:
    path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}__{strategy}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def row(arch: str, shape: str, **kw) -> Dict:
    cfg = ARCHS[arch]
    t = FM.step_terms(cfg, shape, **kw)
    model_fl = FM.model_flops_per_step(cfg, shape)
    step_fl_cluster = t.flops * N_CHIPS
    d = load_dryrun(arch, shape)
    out = {
        "arch": arch, "shape": shape,
        "t_compute_s": t.t_compute,
        "t_memory_s": t.t_memory,
        "t_collective_s": t.t_collective,
        "dominant": t.dominant(),
        "model_flops": model_fl,
        "useful_ratio": model_fl / step_fl_cluster,
        "bound_s": max(t.t_compute, t.t_memory, t.t_collective),
    }
    if d:
        out["hlo_flops"] = d["flops"]
        out["hlo_coll_bytes"] = d["collective_bytes"]
        out["hlo_arg_gb"] = d["memory"].get("argument_size_in_bytes", 0) / 1e9
        out["hlo_ops"] = {k: v["count"] for k, v in d["collectives"].items()}
    return out


def what_would_help(r: Dict) -> str:
    d = r["dominant"]
    if d == "compute":
        return ("flash-attention causal skip / lower remat multiplier"
                if r["useful_ratio"] < 0.5 else "near compute roofline")
    if d == "memory":
        return "keep weights resident: raise batch/device or quantize cache"
    return "2-level (pod-aware) sync + TP-activation overlap"


def optimized_knobs(arch: str, shape: str):
    """Beyond-paper defaults from the §Perf hillclimbs: right-sized TP
    (bounded by the shape's batch divisibility — a 32-sample prefill can't
    use 64-way DP), sequence parallelism, dots remat, small MoE dispatch
    groups (+ expert padding where E doesn't divide the TP degree)."""
    cfg = ARCHS[arch]
    p = cfg.param_count()
    n_model = 16 if p > 50e9 else (8 if p > 8e9 else 4)
    batch = INPUT_SHAPES[shape].global_batch
    kind = INPUT_SHAPES[shape].kind
    if kind == "decode":
        # decode is weight-read bound: keep maximum TP
        n_model = 16
    while 256 // n_model > max(batch, 1) or batch % (256 // n_model):
        n_model *= 2
        if n_model >= 16:
            # >16-way TP would stop dividing the zoo's head counts
            # (mamba2 80 heads, zamba2 112) — stay at the baseline mesh
            n_model = 16
            break
    over = {"seq_shard": True, "remat_policy": "dots"}
    if cfg.n_experts:
        over["moe_group"] = 512
        if cfg.n_experts % n_model:
            over["moe_pad_experts"] = (
                (cfg.n_experts + n_model - 1) // n_model * n_model)
    return over, {"n_data": 256 // n_model, "n_model": n_model}


def row_optimized(arch: str, shape: str) -> Dict:
    over, mesh = optimized_knobs(arch, shape)
    cfg = ARCHS[arch].replace(**over)
    t = FM.step_terms(cfg, shape, **mesh)
    base = FM.step_terms(ARCHS[arch], shape)
    b_bound = max(base.t_compute, base.t_memory, base.t_collective)
    o_bound = max(t.t_compute, t.t_memory, t.t_collective)
    return {"arch": arch, "shape": shape, "mesh": f"{mesh['n_data']}x{mesh['n_model']}",
            "t_compute_s": t.t_compute, "t_memory_s": t.t_memory,
            "t_collective_s": t.t_collective, "dominant": t.dominant(),
            "useful_ratio": FM.model_flops_per_step(cfg, shape)
            / (t.flops * N_CHIPS),
            "baseline_bound_s": b_bound, "bound_s": o_bound,
            "speedup": b_bound / o_bound if o_bound else 1.0}


def run() -> list:
    rows = []
    for arch, shape in pairs():
        r = row(arch, shape)
        r["hint"] = what_would_help(r)
        r["optimized"] = row_optimized(arch, shape)
        rows.append(r)
    return rows


def table(rows) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collect':>10s} {'dominant':>10s} {'useful':>7s}")
    lines = ["PAPER-FAITHFUL BASELINE (16x16, hier, full remat):",
             hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['t_compute_s']:10.4f} "
            f"{r['t_memory_s']:10.4f} {r['t_collective_s']:10.4f} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.2f}")
    lines += ["", "BEYOND-PAPER OPTIMIZED (right-sized mesh, seq-parallel, "
                  "dots remat, MoE dispatch fixes):",
              f"{'arch':24s} {'shape':12s} {'mesh':>7s} {'bound_s':>9s} "
              f"{'baseline':>9s} {'speedup':>8s} {'dominant':>10s}",
              "-" * len(hdr)]
    for r in rows:
        o = r["optimized"]
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {o['mesh']:>7s} "
            f"{o['bound_s']:9.4f} {o['baseline_bound_s']:9.4f} "
            f"{o['speedup']:8.2f} {o['dominant']:>10s}")
    return "\n".join(lines)


def summarize(rows) -> str:
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return f"dominant terms across {len(rows)} pairs: {doms}"


if __name__ == "__main__":
    rows = run()
    print(table(rows))
    print(summarize(rows))
