"""Benchmark harness: one module per paper figure/table + roofline + kernels.

    PYTHONPATH=src python -m benchmarks.run            # all benchmarks
    PYTHONPATH=src python -m benchmarks.run fig7 fig8  # subset
    PYTHONPATH=src python -m benchmarks.run --quick    # CI smoke subset

``--quick`` runs the event-path benchmarks at reduced scale (modules whose
``run`` accepts ``quick=True``) — a smoke check that every registered
module still imports, runs, and emits rows, cheap enough for CI.

Prints a ``name,us_per_call,derived`` CSV line per benchmark (us_per_call is
the harness wall time for that benchmark; `derived` is its headline result)
followed by the §Roofline table. Detailed rows go to
experiments/bench/<name>.json.
"""
from __future__ import annotations

import inspect
import sys
import time

from benchmarks.common import emit_json
from benchmarks import (async_staleness, backend_arbitrage, comm_breakdown,
                        comm_scaling, comm_strategies, config_sensitivity,
                        dynamic_batching, hetero_fleet, kernels_bench,
                        multi_job, nas_adaptation, online_learning,
                        optimizer_compare, overlap_pipeline, roofline,
                        scenarios, serving_contention, serving_slo,
                        shard_ablation, straggler_tail, workflow_hpo)

BENCHES = {
    "fig1_2_8_comm_scaling": comm_scaling,
    "fig3_config_sensitivity": config_sensitivity,
    "fig4_optimizer_compare": optimizer_compare,
    "fig7_comm_breakdown": comm_breakdown,
    "comm_strategies": comm_strategies,
    "overlap_pipeline": overlap_pipeline,
    "fig9_10_scenarios": scenarios,
    "fig11a_12_dynamic_batching": dynamic_batching,
    "fig11b_online_learning": online_learning,
    "fig13_nas": nas_adaptation,
    "footnote4_shard_ablation": shard_ablation,
    "serving_slo_batching": serving_slo,
    "serving_contention": serving_contention,
    "event_straggler_tail": straggler_tail,
    "event_async_staleness": async_staleness,
    "event_hetero_fleet": hetero_fleet,
    "event_multi_job": multi_job,
    "workflow_hpo": workflow_hpo,
    "backend_arbitrage": backend_arbitrage,
    "kernels": kernels_bench,
    "roofline": roofline,
}

# the CI smoke set: the event-path benchmarks (cheap, no BO search inside)
# plus one analytic module, all at reduced scale where supported;
# workflow_hpo runs the orchestrator end to end (successive halving vs
# uniform HPO under one deadline+budget) with reduced rung samples, and
# backend_arbitrage asserts the serverless/gpu_vm flip, the in-budget
# HPO-on-serverless + finetune-on-gpu_vm split, and the hazard-aware
# checkpoint-cadence win over every constant cadence
QUICK = ["fig7_comm_breakdown", "comm_strategies", "overlap_pipeline",
         "event_straggler_tail", "event_async_staleness",
         "event_hetero_fleet", "event_multi_job", "serving_contention",
         "workflow_hpo", "backend_arbitrage"]


def _run_mod(mod, quick: bool):
    if quick and "quick" in inspect.signature(mod.run).parameters:
        return mod.run(quick=True)
    return mod.run()


def main() -> None:
    args = sys.argv[1:]
    quick = "--quick" in args
    which = [a for a in args if not a.startswith("--")]
    which = which or (QUICK if quick else list(BENCHES))
    print("name,us_per_call,derived")
    roofline_rows = None
    for name in which:
        mod = BENCHES[[k for k in BENCHES if name in k][0]] \
            if name not in BENCHES else BENCHES[name]
        t0 = time.perf_counter()
        rows = _run_mod(mod, quick)
        us = (time.perf_counter() - t0) * 1e6
        derived = mod.summarize(rows) if hasattr(mod, "summarize") else ""
        print(f"{name},{us:.0f},\"{derived}\"", flush=True)
        emit_json(name, rows)
        if mod is roofline:
            roofline_rows = rows
    if roofline_rows is not None:
        print()
        print(roofline.table(roofline_rows))


if __name__ == "__main__":
    main()
