"""Paper Figs. 9 & 10: user-centric deployment scenarios on bert-medium.

Scenario 1: minimize cost s.t. training time <= 1 hour.
Scenario 2: minimize time s.t. cost <= $50.

SMLT optimizes for the goal (profiling time/cost charged, as in the paper's
'for a fair comparison'); Siren and Cirrus are goal-oblivious: they run
their fixed deployments and meet limits only by coincidence.
"""
from __future__ import annotations

from repro.core import Config, EpochPlan, Goal
from repro.serverless import WORKLOADS
from benchmarks.common import fresh_scheduler

W = WORKLOADS["bert-medium"]
EPOCH_SAMPLES = 25_000
EPOCHS = 8
BATCH = 1024

BASELINES = {
    # goal-oblivious fixed deployments (replicated systems, Section 2.2)
    "Siren": ("ps_s3", Config(workers=40, memory_mb=3072)),
    "Cirrus": ("ps", Config(workers=60, memory_mb=6144)),
}


def _run(goal: Goal, stop_at_deadline: bool):
    rows = []
    sched, *_ = fresh_scheduler("hier", seed=0)
    plans = [EpochPlan(BATCH, W, samples=EPOCH_SAMPLES) for _ in range(EPOCHS)]
    res = sched.run(plans, goal, stop_at_deadline=stop_at_deadline)
    rows.append({"system": "SMLT", "wall_s": round(res.wall_s, 1),
                 "cost_usd": round(res.cost_usd, 2),
                 "profile_s": round(res.profile_s, 1),
                 "profile_usd": round(res.profile_usd, 2),
                 "total_usd": round(res.total_cost, 2),
                 "epochs": res.epochs_done})
    for name, (scheme, cfgc) in BASELINES.items():
        sched, *_ = fresh_scheduler(scheme, seed=0)
        res = sched.run(plans, goal, adaptive=False, fixed_config=cfgc,
                        stop_at_deadline=stop_at_deadline)
        rows.append({"system": name, "wall_s": round(res.wall_s, 1),
                     "cost_usd": round(res.cost_usd, 2), "profile_s": 0.0,
                     "profile_usd": 0.0,
                     "total_usd": round(res.total_cost, 2),
                     "epochs": res.epochs_done})
    return rows


def _run_event(goal: Goal, stop_at_deadline: bool, sigma: float = 0.3,
               system: str = None, search_fleet: bool = False,
               search_comm: bool = False, engine_opts: dict = None):
    """The same scenario executed on the discrete-event engine: the epochs
    actually unfold (lognormal stragglers, per-iteration monitoring with
    mid-epoch re-optimization) instead of being costed in closed form."""
    opts = {"straggler_sigma": sigma, **(engine_opts or {})}
    sched, *_ = fresh_scheduler("hier", seed=0, engine="event",
                                search_fleet=search_fleet,
                                search_comm=search_comm, engine_opts=opts)
    plans = [EpochPlan(BATCH, W, samples=EPOCH_SAMPLES) for _ in range(EPOCHS)]
    res = sched.run(plans, goal, stop_at_deadline=stop_at_deadline)
    return {"system": system or f"SMLT-event(s={sigma})",
            "wall_s": round(res.wall_s, 1),
            "cost_usd": round(res.cost_usd, 2),
            "profile_s": round(res.profile_s, 1),
            "profile_usd": round(res.profile_usd, 2),
            "total_usd": round(res.total_cost, 2),
            "epochs": res.epochs_done}


def run() -> list:
    rows = []
    s1 = _run(Goal("min_cost_deadline", deadline_s=3600.0),
              stop_at_deadline=True)
    for r in s1:
        r.update(figure="fig9", scenario="deadline_1h",
                 meets=(r["wall_s"] <= 3600.0))
        rows.append(r)
    s2 = _run(Goal("min_time_budget", budget_usd=50.0),
              stop_at_deadline=False)
    for r in s2:
        r.update(figure="fig10", scenario="budget_50usd",
                 meets=(r["total_usd"] <= 50.0))
        rows.append(r)
    # event-engine replay of Scenario 1: the analytic rows above assume
    # zero variance; this row shows the deadline scenario surviving
    # stragglers (same goal, discrete-event execution path)
    r = _run_event(Goal("min_cost_deadline", deadline_s=3600.0),
                   stop_at_deadline=True)
    r.update(figure="fig9_event", scenario="deadline_1h_stragglers",
             meets=(r["wall_s"] <= 3600.0))
    rows.append(r)
    # fleet-composition search: the optimizer may deploy a mixed fleet
    # (Config.small_frac) when the cheaper small tier wins the goal
    r = _run_event(Goal("min_cost_deadline", deadline_s=3600.0),
                   stop_at_deadline=True, system="SMLT-event-fleet",
                   search_fleet=True)
    r.update(figure="fig9_event_fleet", scenario="deadline_1h_fleet_search",
             meets=(r["wall_s"] <= 3600.0))
    rows.append(r)
    # comm-plan search: the optimizer also searches (strategy, ratio,
    # branching) — the CommPlan IR lets it deploy the paper's hierarchy
    # or a compressed schedule when that wins the goal, and the event
    # engine executes whatever plan it picked
    r = _run_event(Goal("min_cost_deadline", deadline_s=3600.0),
                   stop_at_deadline=True, system="SMLT-event-comm",
                   search_comm=True)
    r.update(figure="fig9_event_comm", scenario="deadline_1h_comm_search",
             meets=(r["wall_s"] <= 3600.0))
    rows.append(r)
    # correlated spot shocks on top of stragglers: bursts kill half the
    # fleet at once; the deadline must survive the redone work
    from repro.serverless import ShockModel
    r = _run_event(Goal("min_cost_deadline", deadline_s=3600.0),
                   stop_at_deadline=True, system="SMLT-event-shocks",
                   engine_opts={"shocks": ShockModel(interval_s=600.0,
                                                     kill_frac=0.5)})
    r.update(figure="fig9_event_shocks", scenario="deadline_1h_spot_shocks",
             meets=(r["wall_s"] <= 3600.0))
    rows.append(r)
    return rows


def summarize(rows) -> str:
    s1 = {r["system"]: r for r in rows if r["figure"] == "fig9"}
    s2 = {r["system"]: r for r in rows if r["figure"] == "fig10"}
    out = []
    out.append(
        f"scenario1(1h): SMLT meets={s1['SMLT']['meets']} "
        f"epochs={s1['SMLT']['epochs']} ${s1['SMLT']['total_usd']}"
        f" | Siren meets={s1['Siren']['meets']} epochs={s1['Siren']['epochs']}"
        f" | Cirrus meets={s1['Cirrus']['meets']} epochs={s1['Cirrus']['epochs']}")
    best_base_t = min(s2["Siren"]["wall_s"], s2["Cirrus"]["wall_s"])
    out.append(
        f"scenario2($50): SMLT {s2['SMLT']['wall_s']:.0f}s vs best baseline "
        f"{best_base_t:.0f}s ({best_base_t / s2['SMLT']['wall_s']:.1f}x faster)")
    return "; ".join(out)


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(summarize(rows))
