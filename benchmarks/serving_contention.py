"""Train/serve contention on one shared store fleet (event engine).

PAPER.md's loop is continuous: the model being *served* contends with the
training that produces its successor. This benchmark co-schedules one
``ServingJob`` (diurnal + bursty arrivals, heavy model, 1 Hz model
refresh — continuous deployment) with one ps-scheme training job whose
n*G downloads keep the ParamStore link busy, and measures the
interference in *both* directions:

  (a) isolated   — each job alone in its own domain (own stores);
  (b) shared     — one ``ContentionDomain``, one ParamStore/ObjectStore:
                   serving latency inflates AND training wall grows;
  (c) shared+prio — same, but the serving fetches carry water-filling
                   priority 8 on the shared links: serving p99 inflation
                   is bounded (back to the isolated tail) at a small
                   training cost;
  (d) control    — both jobs in one domain but *separate* stores: the
                   interference must vanish, proving it is the link, not
                   the co-simulation.

Same seeds everywhere, so the only difference between scenarios is what
is shared. Both jobs bill one platform ledger with per-job attribution.

Run:  PYTHONPATH=src python -m benchmarks.serving_contention [--smoke]
"""
from __future__ import annotations

import sys

from repro.serverless import (WORKLOADS, ArrivalSpec, ContentionDomain,
                              EventEngine, ObjectStore, ParamStore,
                              RequestStream, ServerlessPlatform, ServingJob)
from repro.serving import ServePolicy
from benchmarks.common import emit_json

# the trainer: ps at n=32 moves n*G per worker per iteration — the store
# link is its bottleneck, so it is serving's loudest possible neighbor
TRAIN_W = WORKLOADS["bert-medium"]
TRAIN = dict(scheme="ps", n=32, mem=3072, batch=1024)
SAMPLES = 12_000

# the server: heavy model (re-pulled every second — continuous
# deployment), diurnal + bursty traffic, SLO-driven batching
POLICY = ServePolicy(max_batch=8, timeout_s=0.1, memory_mb=3072)
ARRIVALS = ArrivalSpec(base_rps=30.0, bursts_per_hour=12.0, burst_s=30.0,
                       burst_multiplier=3.0)
MODEL_BYTES = TRAIN_W.param_count * 4.0
FLOPS_PER_REQUEST = 2e9
DURATION_S = 300.0
SLO_S = 0.5
PRIO = 8.0
SMOKE_FRAC = 2


def _mk_train(param_store, domain, samples, platform=None):
    return EventEngine(TRAIN_W, TRAIN["scheme"], TRAIN["n"], TRAIN["mem"],
                       TRAIN["batch"], param_store, ObjectStore(),
                       samples=samples, seed=1, domain=domain,
                       platform=platform, trace_enabled=False)


def _mk_serve(param_store, object_store, domain, arrivals, *, prio=1.0,
              platform=None):
    return ServingJob(POLICY, arrivals, FLOPS_PER_REQUEST, param_store,
                      object_store, domain=domain, platform=platform,
                      model_bytes=MODEL_BYTES, code_bytes=20e6,
                      cold_start_s=1.0, keep_warm_s=30.0, max_instances=16,
                      refresh_every_s=1.0, link_priority=prio, slo_s=SLO_S,
                      job="serve")


def _scenario(arrivals, samples, *, share_stores, prio=1.0, platform=None):
    """One co-run: (train EngineResult, ServingResult)."""
    dom = ContentionDomain()
    ps = ParamStore()
    train = _mk_train(ps, dom, samples, platform=platform)
    serve = _mk_serve(ps if share_stores else ParamStore(),
                      ObjectStore(), dom, arrivals, prio=prio,
                      platform=platform)
    dom.run()
    return train.result(), serve.result()


def run(quick: bool = False) -> list:
    frac = SMOKE_FRAC if quick else 1
    samples = SAMPLES // frac
    duration = DURATION_S / frac
    arrivals = RequestStream(ARRIVALS, seed=7).arrivals(0.0, duration)

    # (a) isolated: each job alone
    rt_iso = _mk_train(ParamStore(), None, samples).run()
    dom = ContentionDomain()
    sj = _mk_serve(ParamStore(), ObjectStore(), dom, arrivals)
    dom.run()
    rs_iso = sj.result()

    # (b) shared stores — one ledger, per-job attribution
    plat = ServerlessPlatform(seed=0)
    rt_sh, rs_sh = _scenario(arrivals, samples, share_stores=True,
                             platform=plat)
    # (c) shared stores, serving fetches at priority PRIO
    rt_pr, rs_pr = _scenario(arrivals, samples, share_stores=True,
                             prio=PRIO)
    # (d) control: same domain, separate stores
    rt_ct, rs_ct = _scenario(arrivals, samples, share_stores=False)

    # contention must be visible in BOTH directions on the shared store...
    assert rs_sh.p99_s > rs_iso.p99_s * 1.05, \
        f"serving p99 did not inflate: {rs_sh.p99_s} vs {rs_iso.p99_s}"
    assert rt_sh.wall_s > rt_iso.wall_s * 1.003, \
        f"training wall did not inflate: {rt_sh.wall_s} vs {rt_iso.wall_s}"
    # ...vanish in the separate-store control...
    assert abs(rs_ct.p99_s - rs_iso.p99_s) < 0.01 * rs_iso.p99_s
    assert abs(rt_ct.wall_s - rt_iso.wall_s) < 0.005 * rt_iso.wall_s
    # ...and be bounded by the serving fetches' link priority
    assert rs_pr.p99_s < rs_sh.p99_s, \
        f"priority did not bound p99: {rs_pr.p99_s} vs {rs_sh.p99_s}"
    # the co-run billed one ledger: ServingJob self-attributes in
    # result(); training attribution is the scheduler layer's job, so
    # mirror it here (as repro.workflow does per task)
    plat.ledger.attribute("train-ps", rt_sh.cost_usd)
    assert abs(plat.ledger.job_usd["serve"] - rs_sh.cost_usd) \
        < 1e-9 * max(rs_sh.cost_usd, 1e-12)
    assert plat.ledger.total_cost > 0.0
    assert set(plat.ledger.job_usd) == {"serve", "train-ps"}

    rows = []
    for tag, rt, rs in [("isolated", rt_iso, rs_iso),
                        ("shared", rt_sh, rs_sh),
                        (f"shared-prio{PRIO:g}", rt_pr, rs_pr),
                        ("control-sep-stores", rt_ct, rs_ct)]:
        rows.append({
            "figure": "serving_contention", "scenario": tag,
            "train_wall_s": round(rt.wall_s, 2),
            "train_slowdown": round(rt.wall_s / rt_iso.wall_s, 4),
            "serve_p50_s": round(rs.p50_s, 4),
            "serve_p99_s": round(rs.p99_s, 4),
            "p99_inflation": round(rs.p99_s / rs_iso.p99_s, 3),
            "slo_violations": rs.slo_violations,
            "requests": rs.requests,
            "peak_instances": rs.peak_instances,
            "cold_starts": rs.cold_starts,
            "serve_cost_usd": round(rs.cost_usd, 6),
        })
    rows.append({
        "figure": "serving_contention", "scenario": "shared-ledger",
        "ledger_usd": round(plat.ledger.total_cost, 6),
        "job_usd": {k: round(v, 6)
                    for k, v in sorted(plat.ledger.job_usd.items())},
    })
    return rows


def summarize(rows) -> str:
    by = {r["scenario"]: r for r in rows if "train_wall_s" in r}
    sh = by["shared"]
    pr = next(v for k, v in by.items() if k.startswith("shared-prio"))
    ct = by["control-sep-stores"]
    return (f"shared: serve p99 {sh['p99_inflation']:.2f}x, train "
            f"{sh['train_slowdown']:.3f}x; prio{PRIO:g}: p99 "
            f"{pr['p99_inflation']:.2f}x; control: p99 "
            f"{ct['p99_inflation']:.2f}x")


if __name__ == "__main__":
    rows = run(quick="--smoke" in sys.argv)
    for r in rows:
        print(r)
    print(summarize(rows))
    print("json:", emit_json("serving_contention", rows))
