"""Serving extension (BATCH [17], the SMLT authors' companion system):
SLO-aware adaptive batching for serverless inference.

Sweeps arrival rates; for each, the policy optimizer picks the cheapest
(batch size, timeout, memory) meeting a 1 s p99 SLO. Compared against the
naive B=1 deployment — the serving twin of the paper's Scenario 1.
Also reports the hier_topk compressed-training comm saving.
"""
from __future__ import annotations

from repro.serving import ServePolicy, optimize_policy, simulate
from repro.serverless import WORKLOADS, ObjectStore, ParamStore, comm_breakdown

FLOPS = 2e9
SLO = 1.0


def policy_row(rate: float, slo_s: float, *,
               flops: float = FLOPS) -> dict:
    """One optimizer sweep at ``rate``; an infeasible SLO is reported as
    a row (policy 'infeasible'), not a crash."""
    pol, st, log = optimize_policy(arrival_rate=rate,
                                   flops_per_request=flops, slo_s=slo_s)
    if pol is None:
        return {"figure": "serving_slo", "rate_rps": rate, "slo_s": slo_s,
                "policy": "infeasible", "evaluated": log["evaluated"],
                "feasible": log["feasible"]}
    naive = simulate(ServePolicy(1, 0.01, pol.memory_mb),
                     arrival_rate=rate, flops_per_request=flops)
    return {"figure": "serving_slo", "rate_rps": rate, "slo_s": slo_s,
            "policy": f"B={pol.max_batch},tau={pol.timeout_s}s,"
                      f"{pol.memory_mb}MB",
            "p99_s": round(st.p99_s, 3),
            "cost_per_1k": round(st.cost_per_1k, 5),
            "naive_cost_per_1k": round(naive.cost_per_1k, 5),
            "naive_p99_s": round(naive.p99_s, 3),
            "saving": round(naive.cost_per_1k / st.cost_per_1k, 2)}


def run() -> list:
    rows = [policy_row(rate, SLO) for rate in (1.0, 5.0, 20.0, 40.0)]
    # a deliberately infeasible point (high rate, SLO below the bare
    # execution time): exercised so the sweep reports instead of crashing
    rows.append(policy_row(40.0, 0.05))
    # compressed-sync comm saving (training-side beyond-paper extension)
    ps, os_ = ParamStore(), ObjectStore()
    W = WORKLOADS["bert-medium"]
    dense = sum(comm_breakdown("hier", W.grad_bytes, 64, 4096, ps,
                               os_).values())
    sparse = sum(comm_breakdown("hier_topk", W.grad_bytes, 64, 4096, ps,
                                os_, topk_ratio=0.05).values())
    rows.append({"figure": "topk_comm", "dense_s": round(dense, 2),
                 "topk5pct_s": round(sparse, 2),
                 "speedup": round(dense / sparse, 2)})
    return rows


def summarize(rows) -> str:
    sv = [r for r in rows if r["figure"] == "serving_slo"
          and r["policy"] != "infeasible"]
    skipped = sum(1 for r in rows if r.get("policy") == "infeasible")
    tk = [r for r in rows if r["figure"] == "topk_comm"][0]
    best = max(r["saving"] for r in sv)
    return (f"adaptive batching: up to {best:.1f}x cheaper than B=1 at the "
            f"same 1s SLO ({skipped} infeasible SLO point(s) skipped); "
            f"top-k 5% sync cuts hier comm {tk['speedup']}x "
            f"({tk['dense_s']}s -> {tk['topk5pct_s']}s @64 workers)")


if __name__ == "__main__":
    for r in run():
        print(r)
    print(summarize(run()))
