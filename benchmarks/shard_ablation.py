"""Paper footnote 4 ablation: the number of shards m vs workers n.

"If m is greater than n, each worker aggregates multiple shards. Choosing
m less than n will cause some workers to be idle during aggregation."
We sweep m around n=64 for bert-medium and confirm m = n is the sweet
spot: m < n leaves aggregators idle (DL-Shard inflates on the busy ones),
m > n adds per-request latency for no bandwidth gain.
"""
from __future__ import annotations

from repro.serverless import WORKLOADS, ObjectStore, ParamStore
from repro.serverless.worker import comm_breakdown

N = 64
MS = [8, 16, 32, 64, 128, 256]
W = WORKLOADS["bert-medium"]


def run() -> list:
    ps, os_ = ParamStore(), ObjectStore()
    rows = []
    for m in MS:
        bd = comm_breakdown("hier", W.grad_bytes, N, 4096, ps, os_,
                            n_shards=m)
        rows.append({"figure": "footnote4", "m_shards": m, "n_workers": N,
                     "comm_s": round(sum(bd.values()), 3),
                     "dl_shard_s": round(bd["DL-Shard"], 3)})
    return rows


def summarize(rows) -> str:
    best = min(rows, key=lambda r: r["comm_s"])
    return (f"m=n={N} optimal at {dict((r['m_shards'], r['comm_s']) for r in rows)}"
            if best["m_shards"] == N else
            f"UNEXPECTED optimum m={best['m_shards']}")


if __name__ == "__main__":
    for r in run():
        print(r)
    print(summarize(run()))
