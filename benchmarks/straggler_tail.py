"""Straggler tail latency: hier vs ps on the event engine.

The analytic model cannot express stragglers at all — every worker takes
exactly the mean iteration time. The event engine samples a per-(worker,
iteration) lognormal compute multiplier (mean 1) and lets the barriers do
their damage: a BSP iteration ends when the *slowest* worker's DL-grad
lands, so the per-iteration distribution grows a tail as sigma grows.

The comparison the paper's Fig. 7/8 implies but can't show: hier's
per-iteration communication is O(G) vs ps's O(n*G) download, so the same
compute straggler costs ps strictly more wall-clock — its barrier sits at
the end of a longer critical path.

Run:  PYTHONPATH=src python -m benchmarks.straggler_tail
"""
from __future__ import annotations

import numpy as np

from repro.serverless import WORKLOADS, EventEngine, ObjectStore, ParamStore
from benchmarks.common import emit_json

W = WORKLOADS["bert-small"]
N_WORKERS = 32
MEMORY_MB = 4096
BATCH = 1024
SAMPLES = 40_000          # ~40 iterations
SIGMAS = (0.0, 0.2, 0.4, 0.6)
SCHEMES = ("hier", "ps")


def _iteration_durations(iter_times):
    # drop the first completion: it includes cold start + data fetch
    return np.diff(np.asarray(iter_times))


def run() -> list:
    rows = []
    for sigma in SIGMAS:
        for scheme in SCHEMES:
            res = EventEngine(W, scheme, N_WORKERS, MEMORY_MB, BATCH,
                              ParamStore(), ObjectStore(), samples=SAMPLES,
                              straggler_sigma=sigma, seed=0,
                              trace_enabled=False).run()
            d = _iteration_durations(res.iter_times)
            rows.append({
                "figure": "straggler_tail", "scheme": scheme, "sigma": sigma,
                "wall_s": round(res.wall_s, 2),
                "cost_usd": round(res.cost_usd, 4),
                "iters": res.iters_done,
                "it_p50_s": round(float(np.percentile(d, 50)), 3),
                "it_p95_s": round(float(np.percentile(d, 95)), 3),
                "it_p99_s": round(float(np.percentile(d, 99)), 3),
                "tail_amplification": round(
                    float(np.percentile(d, 99) / np.percentile(d, 50)), 3),
            })
    return rows


def summarize(rows) -> str:
    hi = max(SIGMAS)
    at = {r["scheme"]: r for r in rows if r["sigma"] == hi}
    base = {r["scheme"]: r for r in rows if r["sigma"] == 0.0}
    h, p = at["hier"], at["ps"]
    return (f"sigma={hi}: hier p99 {h['it_p99_s']}s vs ps {p['it_p99_s']}s "
            f"({p['it_p99_s'] / h['it_p99_s']:.1f}x); wall {h['wall_s']:.0f}s"
            f" vs {p['wall_s']:.0f}s; straggler cost vs sigma=0: hier "
            f"+{h['wall_s'] / base['hier']['wall_s'] - 1:.0%}, ps "
            f"+{p['wall_s'] / base['ps']['wall_s'] - 1:.0%}")


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(summarize(rows))
    print("json:", emit_json("event_straggler_tail", rows))
