"""Workflow HPO: successive halving vs uniform-budget search, one Goal.

The workflow layer's headline claim: under a single global
``Goal(deadline_s, budget_usd)`` on a shared serverless fleet, a
rung-structured successive-halving sweep (losers early-stopped, their
budget reclaimed by the allocator and re-granted to the surviving rungs)
reaches the same best configuration *sooner and cheaper* than the
uniform-budget baseline that trains every trial to full depth.

Both variants run the *same* trials (identical synthetic loss curves,
seeded), the same event-engine fleet, and the same allocator mechanics —
the only difference is the DAG shape. Asserted here (and in CI smoke), on
the anytime-performance framing: taking the best loss either strategy
achieved inside the shared budget as the target, successive halving
reaches the target **sooner** (time-to-target) and on **fewer dollars**
(cost-to-target) — under a binding budget the uniform split typically
cannot afford full depth on any trial, so it never reaches the target at
all, while both stay inside the global ledger budget.

Run:  PYTHONPATH=src python -m benchmarks.workflow_hpo [--smoke]
"""
from __future__ import annotations

import sys

from repro.core import ConfigSpace, Goal
from repro.serverless import (WORKLOADS, ObjectStore, ParamStore,
                              ServerlessPlatform)
from repro.workflow import (HPOSweep, TaskSpec, WorkflowDAG,
                            WorkflowOrchestrator, expand_hpo,
                            sweep_final_tasks, trial_loss)
from benchmarks.common import emit_json

W = WORKLOADS["resnet18"]
BATCH = 512
SAMPLES = 16_384
DEADLINE_S = 3600.0
BUDGET_USD = 3.0
# quick/CI mode halves the per-rung samples and scales the budget with
# them, keeping it *binding*: the even uniform split must not afford full
# depth (that starvation is the successive-halving win being measured)
QUICK_BUDGET_USD = 2.0


def _budget(quick: bool) -> float:
    return QUICK_BUDGET_USD if quick else BUDGET_USD


def _sweep(quick: bool) -> HPOSweep:
    return HPOSweep("hpo", W, n_trials=8, rungs=2, eta=2,
                    epochs_per_rung=1, batch_size=BATCH,
                    samples=SAMPLES // (2 if quick else 1), seed=3)


def _orchestrate(dag, sweeps, budget):
    goal = Goal("deadline_budget", deadline_s=DEADLINE_S, budget_usd=budget)
    orch = WorkflowOrchestrator(
        dag, goal, ServerlessPlatform(seed=0), ObjectStore(), ParamStore(),
        space=ConfigSpace(max_workers=32, max_memory=4096),
        engine="event", sweeps=sweeps, seed=0)
    return orch.run()


def run_successive_halving(quick: bool):
    sweep = _sweep(quick)
    res = _orchestrate(WorkflowDAG(expand_hpo(sweep)), [sweep],
                       _budget(quick))
    winner, best_loss = res.winners["hpo"]
    final = next(n for n, t in res.assignments.items()
                 if t == winner and f":r{sweep.rungs - 1}:" in n)
    return res, {"winner": winner, "best_loss": best_loss,
                 "time_to_best_s": res.finish_s[final]}


def run_uniform(quick: bool):
    """Every trial trains to full depth (rungs * epochs_per_rung epochs),
    no early stopping — the grid-search shape of spending one budget."""
    sweep = _sweep(quick)
    depth = sweep.rungs * sweep.epochs_per_rung
    dag = WorkflowDAG([
        TaskSpec(f"uni:t{i}", W, epochs=depth, batch_size=sweep.batch_size,
                 samples=sweep.samples, kind="hpo")
        for i in range(sweep.n_trials)])
    res = _orchestrate(dag, [], _budget(quick))
    losses = {i: trial_loss(sweep, i, res.tasks[f"uni:t{i}"].epochs_done)
              for i in range(sweep.n_trials)}
    winner = min(losses, key=lambda i: (losses[i], i))
    return res, {"winner": winner, "best_loss": losses[winner],
                 "time_to_best_s": res.finish_s[f"uni:t{winner}"]}


def run(quick: bool = False) -> list:
    rows = []
    sh_res, sh = run_successive_halving(quick)
    un_res, un = run_uniform(quick)
    # anytime comparison at equal global dollars: the target is the best
    # loss either strategy reached inside the one shared budget;
    # time/cost-to-target are when a strategy's own timeline first
    # achieved it and how many dollars it had sunk by then (None = never
    # reached — under a binding budget, uniform's even split often cannot
    # afford full depth on any trial)
    target = min(sh["best_loss"], un["best_loss"])
    for name, res, info in (("successive-halving", sh_res, sh),
                            ("uniform-budget", un_res, un)):
        reached = info["best_loss"] <= target + 1e-9
        t_target = info["time_to_best_s"] if reached else None
        c_target = (sum(r.total_cost for n, r in res.tasks.items()
                        if res.finish_s[n] <= t_target + 1e-9)
                    if reached else None)
        rows.append({
            "figure": "workflow_hpo", "strategy": name,
            "wall_s": round(res.wall_s, 2),
            "cost_usd": round(res.ledger_usd, 4),
            "best_loss": round(info["best_loss"], 4),
            "target_loss": round(target, 4),
            "time_to_target_s": (round(t_target, 2)
                                 if t_target is not None else None),
            "cost_to_target_usd": (round(c_target, 4)
                                   if c_target is not None else None),
            "winner_trial": info["winner"],
            "budget_usd": _budget(quick), "deadline_s": DEADLINE_S,
            "epochs_total": sum(r.epochs_done for r in res.tasks.values()),
        })
    sh_row, un_row = rows
    # the workflow-layer contract, enforced at benchmark time
    budget = _budget(quick)
    assert sh_row["cost_usd"] <= budget and sh_row["wall_s"] <= DEADLINE_S
    assert un_row["cost_usd"] <= budget, \
        "the allocator must hold the uniform variant inside the budget too"
    assert sh_row["best_loss"] <= un_row["best_loss"] + 1e-9, \
        "early stopping must not lose the winner"
    assert sh_row["time_to_target_s"] is not None, \
        "successive halving must reach the target loss"
    assert (un_row["time_to_target_s"] is None
            or sh_row["time_to_target_s"] < un_row["time_to_target_s"]), \
        "successive halving must reach the target loss sooner"
    assert (un_row["cost_to_target_usd"] is None
            or sh_row["cost_to_target_usd"] < un_row["cost_to_target_usd"]), \
        "successive halving must reach the target loss on fewer dollars"
    return rows


def summarize(rows) -> str:
    sh = next(r for r in rows if r["strategy"] == "successive-halving")
    un = next(r for r in rows if r["strategy"] == "uniform-budget")
    un_t = (f"{un['time_to_target_s']:.0f}s"
            f"/${un['cost_to_target_usd']:.2f}"
            if un["time_to_target_s"] is not None else "never")
    return (f"target loss {sh['target_loss']:.3f}: halving"
            f" {sh['time_to_target_s']:.0f}s/${sh['cost_to_target_usd']:.2f}"
            f" vs uniform {un_t}"
            f" (final loss {sh['best_loss']:.3f} vs {un['best_loss']:.3f})")


if __name__ == "__main__":
    rows = run(quick="--smoke" in sys.argv)
    for r in rows:
        print(r)
    print(summarize(rows))
    print("json:", emit_json("workflow_hpo", rows))
