"""User-centric deployment scenarios (paper Figs. 9-10) as a runnable demo.

Scenario 1: "finish within --deadline seconds, as cheap as possible."
Scenario 2: "spend at most --budget dollars, as fast as possible."
Scenario 3: a whole *workflow* — train -> fine-tune -> eval — under ONE
            deadline and ONE budget, split and re-split across the tasks
            by the workflow layer's BudgetAllocator.

Run:  PYTHONPATH=src python examples/deadline_budget.py --deadline 3600 --budget 50
"""
import argparse

from repro.core import EpochPlan, Goal
from repro.serverless import WORKLOADS


def fresh_scheduler(scheme="hier", seed=0, max_workers=200):
    from repro.core import ConfigSpace, TaskScheduler
    from repro.serverless import ObjectStore, ParamStore, ServerlessPlatform
    plat = ServerlessPlatform(seed=seed)
    sched = TaskScheduler(plat, ObjectStore(), ParamStore(), scheme=scheme,
                          space=ConfigSpace(max_workers=max_workers),
                          seed=seed)
    return (sched, plat)



def show(title, res, goal):
    cfgs = [(c.workers, c.memory_mb) for c in res.config_history]
    print(f"\n{title}")
    print(f"  deployments: {cfgs[0]} (x{len(cfgs)} epochs)")
    print(f"  wall time:   {res.wall_s:,.0f} s "
          f"(profiling {res.profile_s:,.0f} s)")
    print(f"  cost:        ${res.total_cost:.2f} "
          f"(profiling ${res.profile_usd:.2f})")
    if goal.deadline_s:
        print(f"  deadline:    {goal.deadline_s:,.0f} s -> "
              f"{'MET' if res.wall_s <= goal.deadline_s else 'MISSED'} "
              f"({res.epochs_done} epochs trained)")
    if goal.budget_usd:
        print(f"  budget:      ${goal.budget_usd:.2f} -> "
              f"{'MET' if res.total_cost <= goal.budget_usd else 'MISSED'}")


def show_workflow(title, res, goal):
    print(f"\n{title}")
    for name in res.tasks:
        r = res.tasks[name]
        cfg = res.config_of(name)
        grant = res.allocations[name].budget_usd
        print(f"  {name:<10} [{res.start_s[name]:7.0f}s ->"
              f" {res.finish_s[name]:7.0f}s]  epochs={r.epochs_done}"
              f"  workers={cfg.workers if cfg else 0:>3}"
              f"  ${r.total_cost:6.3f} of ${grant:6.3f} granted")
    print(f"  workflow:    {res.wall_s:,.0f} s, ledger ${res.ledger_usd:.2f}"
          f" (deadline {goal.deadline_s:,.0f} s ->"
          f" {'MET' if res.wall_s <= goal.deadline_s else 'MISSED'};"
          f" budget ${goal.budget_usd:.2f} ->"
          f" {'MET' if res.ledger_usd <= goal.budget_usd else 'MISSED'})")


def run_workflow(args):
    from repro.core import ConfigSpace
    from repro.serverless import ObjectStore, ParamStore, ServerlessPlatform
    from repro.workflow import TaskSpec, WorkflowDAG, WorkflowOrchestrator
    w = WORKLOADS[args.model]
    small = max(args.samples // 4, 1024)
    dag = WorkflowDAG([
        TaskSpec("train", w, epochs=max(args.epochs - 2, 1),
                 batch_size=1024, samples=args.samples),
        TaskSpec("finetune", w, epochs=1, batch_size=1024, samples=small,
                 deps=("train",), kind="finetune", warm_start_from="train",
                 priority=2),
        TaskSpec("eval", w, epochs=1, batch_size=1024, samples=small,
                 deps=("finetune",), kind="eval"),
    ])
    goal = Goal("deadline_budget", deadline_s=args.deadline,
                budget_usd=args.budget)
    orch = WorkflowOrchestrator(
        dag, goal, ServerlessPlatform(seed=0), ObjectStore(), ParamStore(),
        space=ConfigSpace(max_workers=200), engine="analytic", seed=0)
    return orch.run(), goal


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--deadline", type=float, default=3600.0)
    ap.add_argument("--budget", type=float, default=50.0)
    ap.add_argument("--model", default="bert-medium",
                    choices=sorted(WORKLOADS))
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--samples", type=int, default=25_000)
    args = ap.parse_args()

    w = WORKLOADS[args.model]
    plans = [EpochPlan(1024, w, samples=args.samples)
             for _ in range(args.epochs)]

    goal1 = Goal("min_cost_deadline", deadline_s=args.deadline)
    sched, *_ = fresh_scheduler("hier")
    res1 = sched.run(plans, goal1, stop_at_deadline=True)
    show(f"Scenario 1 — min cost s.t. T <= {args.deadline:.0f}s "
         f"({args.model})", res1, goal1)

    goal2 = Goal("min_time_budget", budget_usd=args.budget)
    sched, *_ = fresh_scheduler("hier")
    res2 = sched.run(plans, goal2, stop_at_budget=True)
    show(f"Scenario 2 — min time s.t. $ <= {args.budget:.0f} "
         f"({args.model})", res2, goal2)

    res3, goal3 = run_workflow(args)
    show_workflow("Scenario 3 — train -> fine-tune -> eval workflow under "
                  f"one goal (T <= {goal3.deadline_s:.0f}s, "
                  f"$ <= {goal3.budget_usd:.0f})", res3, goal3)


if __name__ == "__main__":
    main()
