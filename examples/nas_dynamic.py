"""Adaptive workflows demo: dynamic batching + NAS (paper Figs. 12-13).

Shows the task scheduler's training-dynamics monitoring: when the batch
size or candidate model changes, SMLT re-runs the Bayesian optimizer and
redeploys; the fixed-allocation baseline (LambdaML-style) cannot.

Run:  PYTHONPATH=src python examples/nas_dynamic.py
"""
from repro.core import EpochPlan, Goal
from repro.optim.schedules import doubling_batch
from repro.serverless import WORKLOADS


def fresh_scheduler(scheme="hier", seed=0, max_workers=200):
    from repro.core import ConfigSpace, TaskScheduler
    from repro.serverless import ObjectStore, ParamStore, ServerlessPlatform
    plat = ServerlessPlatform(seed=seed)
    sched = TaskScheduler(plat, ObjectStore(), ParamStore(), scheme=scheme,
                          space=ConfigSpace(max_workers=max_workers),
                          seed=seed)
    return (sched, plat)



def timeline(res, label):
    print(f"\n  {label}:")
    print(f"  {'t(s)':>8s} {'batch':>6s} {'params':>8s} {'workers':>7s} "
          f"{'mem(MB)':>8s} {'samples/s':>10s}")
    for e in res.events:
        if e.kind != "epoch":
            continue
        print(f"  {e.t:8.0f} {e.batch_size:6d} "
              f"{e.model_params/1e6:7.0f}M {e.workers:7d} "
              f"{e.memory_mb:8d} {e.throughput:10.1f}")
    print(f"  -> wall {res.wall_s:,.0f}s, total ${res.total_cost:.2f}")


def main():
    w = WORKLOADS["resnet50"]
    print("== dynamic batching (batch doubles every 2 epochs) ==")
    batches = doubling_batch(256, 6, every=2)
    plans = [EpochPlan(b, w, samples=50_000) for b in batches]
    sched, *_ = fresh_scheduler("hier", seed=0)
    adaptive = sched.run(plans, Goal("min_time"))
    timeline(adaptive, "SMLT (adaptive)")
    sched, *_ = fresh_scheduler("hier", seed=0)
    fixed = sched.run(plans, Goal("min_time"), adaptive=False,
                      fixed_config=adaptive.config_history[0])
    timeline(fixed, "fixed allocation (LambdaML-style)")

    print("\n== NAS / ENAS exploration (12 candidate child models) ==")
    import numpy as np
    from repro.serverless import Workload
    rng = np.random.RandomState(0)
    sizes = rng.choice([5e6, 11e6, 23e6, 46e6, 80e6, 110e6], size=12)
    tokens = rng.choice([64, 256, 1024], size=12)
    cands = [Workload(f"enas-{i}", int(s), 6.0 * s * t, 3_000, 10 ** 9)
             for i, (s, t) in enumerate(zip(sizes, tokens))]
    plans = [EpochPlan(512, c, samples=50_000) for c in cands]
    sched, *_ = fresh_scheduler("hier", seed=0)
    nas = sched.run(plans, Goal("min_time"))
    timeline(nas, "SMLT (adaptive)")


if __name__ == "__main__":
    main()
