"""Quickstart: the three layers of SMLT in one minute.

  1. the REAL training path — hierarchical sync on a model from the zoo;
  2. the SCHEDULER — user-centric deadline goal on the serverless simulator;
  3. the KERNELS — Pallas shard aggregation vs its oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core import EpochPlan, Goal
from repro.kernels import ops, ref
from repro.launch.train import train
from repro.models import registry
from repro.serverless import WORKLOADS


def fresh_scheduler(scheme="hier", seed=0, max_workers=200):
    from repro.core import ConfigSpace, TaskScheduler
    from repro.serverless import ObjectStore, ParamStore, ServerlessPlatform
    plat = ServerlessPlatform(seed=seed)
    sched = TaskScheduler(plat, ObjectStore(), ParamStore(), scheme=scheme,
                          space=ConfigSpace(max_workers=max_workers),
                          seed=seed)
    return (sched, plat)


# 1. real training: reduced olmo-1b, hierarchical (RS+AG) gradient sync
cfg = reduced(ARCHS["olmo-1b"])
print(f"[1/3] training reduced {cfg.arch_id} "
      f"({registry.param_count(cfg)/1e6:.1f}M params)")
_, losses = train(cfg, steps=40, batch=8, seq=64, strategy="hier",
                  lr=1e-3, log_every=20)
assert losses[-1] < losses[0]

# 2. scheduler: minimize cost under a 1-hour deadline (paper Scenario 1)
print("[2/3] SMLT scheduler, Scenario 1 (min cost s.t. T <= 1h)")
sched, *_ = fresh_scheduler("hier")
res = sched.run([EpochPlan(1024, WORKLOADS["bert-small"], samples=30_000)
                 for _ in range(3)],
                Goal("min_cost_deadline", deadline_s=3600.0),
                stop_at_deadline=True)
cfgs = {(c.workers, c.memory_mb) for c in res.config_history}
print(f"      deployed {cfgs}; wall {res.wall_s:.0f}s <= 3600s; "
      f"cost ${res.total_cost:.2f} (profiling ${res.profile_usd:.2f})")

# 3. Pallas kernel == oracle
print("[3/3] Pallas hier_agg kernel vs jnp oracle")
shards = jnp.array(np.random.RandomState(0).randn(8, 4096), jnp.float32)
np.testing.assert_allclose(ops.aggregate_shards(shards),
                           ref.ref_aggregate(shards), rtol=1e-6, atol=1e-6)
print("      allclose OK")
print("quickstart done.")
