"""Batched serving demo: prefill + KV/SSM-cache decode on zoo models.

Serves a batch of requests on reduced configs of one attention model and
one attention-free (SSM) model — the two cache disciplines the decode
dry-run shapes exercise.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.configs import ARCHS, reduced
from repro.launch.serve import serve


def main():
    for arch in ("qwen2.5-3b", "mamba2-2.7b"):
        cfg = reduced(ARCHS[arch])
        toks, tp, td = serve(cfg, n_requests=4, prompt_len=32, gen=12)
        per = td / 11 / 4 * 1e3
        print(f"{arch:14s} (reduced): prefill {tp*1e3:6.0f} ms, "
              f"decode {per:5.1f} ms/token/request, "
              f"sample: {toks[0, :8].tolist()}")


if __name__ == "__main__":
    main()
