"""End-to-end SMLT training driver.

Trains a decoder LM for a few hundred steps with:
 - the hierarchical (reduce-scatter + all-gather) gradient sync strategy,
 - a dynamic batch schedule (doubles mid-run, as in the paper's dynamic
   batching workflows) — the step is re-built when the batch grows,
 - a mid-run checkpoint/restore cycle (the serverless duration-cap path),
 - markov-structured synthetic data so the loss visibly decreases.

Default is a ~28M-param model sized for a CPU container; ``--model-dim`` /
``--layers`` scale it up (a 100M run is ~d_model 768 x 12L; on TPU use
``repro.launch.train`` with a full config).

After training, the run is projected onto the serverless platform: the
trained model becomes a calibrated ``Workload`` and one epoch executes on
the discrete-event engine (``repro.serverless.events``) under bsp and
async sync, with lognormal stragglers — what this exact job would cost
and how long it would take on Lambda. ``--skip-serverless-sim`` disables.

Run:  PYTHONPATH=src python examples/train_e2e.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import CheckpointMeta, DiskCheckpointer
from repro.data import DataConfig, IteratorState, ShardedLoader, TokenDataset
from repro.launch.steps import make_train_step
from repro.models import registry
from repro.models.base import ModelConfig
from repro.optim import AdamW, warmup_cosine
from repro.serverless import EventEngine, ObjectStore, ParamStore, Workload


def serverless_projection(cfg, seq_len: int, batch: int, steps: int):
    """Replay this training job on the event engine: hier sync, 16 Lambda
    workers, bsp vs async under mild stragglers."""
    params = registry.param_count(cfg)
    w = Workload(name=cfg.arch_id, param_count=params,
                 flops_per_sample=6.0 * params * seq_len,   # fwd+bwd decoder
                 sample_bytes=4.0 * seq_len,
                 dataset_samples=batch * steps)
    n, mem = 16, 4096
    print(f"serverless projection ({n} workers x {mem}MB, hier):")
    for mode in ("bsp", "async"):
        res = EventEngine(w, "hier", n, mem, batch * n, ParamStore(),
                          ObjectStore(), sync_mode=mode,
                          straggler_sigma=0.3, seed=0,
                          trace_enabled=False).run()
        print(f"  {mode:5s}: {res.iters_done} iters, wall {res.wall_s:.0f}s, "
              f"${res.cost_usd:.3f}, {res.invocations} invocations, "
              f"{res.restarts} cap-restarts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--model-dim", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/smlt_e2e_ckpt")
    ap.add_argument("--skip-serverless-sim", action="store_true")
    args = ap.parse_args()

    cfg = ModelConfig(arch_id="e2e-lm", family="dense",
                      n_layers=args.layers, d_model=args.model_dim,
                      n_heads=max(args.model_dim // 128, 4),
                      n_kv_heads=max(args.model_dim // 256, 2),
                      d_ff=args.model_dim * 4, vocab_size=args.vocab)
    print(f"model: {registry.param_count(cfg)/1e6:.1f}M params")

    devs = np.array(jax.devices())
    mesh = Mesh(devs.reshape(len(devs), 1), ("data", "model"))
    opt = AdamW(lr=args.lr, schedule=warmup_cosine(30, args.steps))
    step_fn, pshard, oshard, _ = make_train_step(cfg, mesh, strategy="hier",
                                                 optimizer=opt)
    params = jax.device_put(registry.init(jax.random.key(0), cfg), pshard)
    opt_state = jax.device_put(opt.init(params), oshard)

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq)
    loader = ShardedLoader(TokenDataset(data))
    ck = DiskCheckpointer(args.ckpt_dir)

    batch_size = args.batch
    t0 = time.perf_counter()
    losses = []
    for i in range(args.steps):
        if i == args.steps // 3:
            batch_size *= 2  # dynamic batching: schedule doubles the batch
            print(f"step {i}: batch {args.batch} -> {batch_size} "
                  "(step re-lowered)")
        if i == args.steps // 2:
            # duration-cap simulation: checkpoint, drop state, restore
            ck.save("mid", {"params": params, "opt": opt_state},
                    CheckpointMeta(step=i, epoch=loader.state.epoch,
                                   index=loader.state.index))
            restored, meta = ck.restore("mid", {"params": params,
                                                "opt": opt_state})
            params, opt_state = restored["params"], restored["opt"]
            loader = ShardedLoader(TokenDataset(data),
                                   IteratorState(meta.epoch, meta.index))
            print(f"step {i}: checkpoint/restart cycle OK "
                  f"(resumed at epoch {meta.epoch}, index {meta.index})")
        b = loader.next_batch(batch_size)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, loss = step_fn(params, opt_state, b)
        losses.append(float(loss))
        if i % 25 == 0 or i == args.steps - 1:
            tput = sum([args.batch] * min(i + 1, 25)) * args.seq / max(
                time.perf_counter() - t0, 1e-9)
            print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                  f"{tput:,.0f} tok/s")
    print(f"loss: {losses[0]:.3f} -> {min(losses):.3f} "
          f"({time.perf_counter()-t0:.0f}s total)")
    assert min(losses) < losses[0] - 0.5, "training must clearly progress"
    if not args.skip_serverless_sim:
        serverless_projection(cfg, args.seq, batch_size, args.steps)


if __name__ == "__main__":
    main()
