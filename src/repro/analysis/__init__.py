"""simlint: AST-based invariant linting for this repository.

Every correctness guarantee the reproduction rests on — same-seed
bit-identity, golden-trace byte-stability, engine-vs-ledger billing
parity — is enforced *after the fact* by runtime tests. This package
moves the recurring failure classes to review time with four static
passes over the source tree:

- **determinism** (:mod:`repro.analysis.determinism`): global-state
  ``random``/``np.random`` draws, wall-clock reads (``time.time``,
  ``datetime.now``), raw ``np.random.RandomState`` construction outside
  ``repro.core.rng``, and iteration over ``set``/``dict.keys()`` in the
  event-scheduling layers (``serverless``/``workflow``), where ordering
  feeds event schedules, traces, and hashes.
- **billing units** (:mod:`repro.analysis.units`): suffix-based
  dimension inference (``_s``, ``_gbps``, ``_mb``/``_gb``, ``_usd``,
  ``_ev``) flagging arithmetic that mixes incompatible units and
  unconverted cross-unit assignments — the static version of the PR 4
  keep-alive parity bugs.
- **trace/event coverage** (:mod:`repro.analysis.coverage`): every
  literal kind passed to ``TraceEvent(...)`` must be declared in
  ``TraceEvent.KINDS`` and every declared kind must be emitted
  somewhere (the PR 5 typo class, both directions), and every event
  pushed at a ``CalendarQueue``/``ContentionDomain`` must name a
  handler that resolves to a function defined in the module.
- **API misuse** (:mod:`repro.analysis.api`): ``seed``-taking code that
  constructs fresh *unseeded* RNGs, and mutation of frozen-dataclass
  fields outside ``dataclasses.replace`` /  the owning class.

Run it exactly as CI does::

    python -m repro.analysis.lint src/ benchmarks/ examples/ --fail-on warning

Findings carry ``file:line``, a rule id, and a message. A finding is
suppressed with an inline comment carrying a written reason::

    t0 = time.time()  # simlint: ok(det-wallclock, operator-facing log stamp)

A suppression without a reason is itself an error. See
docs/STATIC_ANALYSIS.md for the rule catalogue and policy.
"""
from repro.analysis.core import Finding, Linter, RULES, lint_paths

__all__ = ["Finding", "Linter", "RULES", "lint_paths"]
