"""API-misuse pass (project-wide).

- ``api-unseeded-rng`` (error): a zero-argument
  ``np.random.RandomState()`` / ``np.random.default_rng()`` /
  ``random.Random()`` seeds itself from the OS — inside a function that
  *takes* a ``seed`` parameter this silently discards the caller's
  seed, which is the exact failure mode ``repro.core.rng`` exists to
  prevent; anywhere else it is still hidden nondeterminism.

- ``api-frozen-mutation`` (error): the repo's configs are frozen
  dataclasses so a sweep can share one instance across engines. The two
  escape hatches that defeat that are ``object.__setattr__(cfg, ...)``
  used outside the owning class (``__post_init__`` normalisation is the
  one legitimate site) and plain attribute assignment to a value whose
  annotation names a frozen class (which raises ``FrozenInstanceError``
  at runtime — but only on the code path that runs). The fix is
  ``dataclasses.replace(cfg, field=...)``.

The pass is project-wide because the frozen-class registry must be
built from every file before any single file can be judged.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis.core import (FileContext, Finding, dotted_name,
                                 register_rule)

register_rule("api-unseeded-rng", "error",
              "a fresh RNG constructed with no seed (OS-seeded); thread "
              "the caller's seed through repro.core.rng instead")
register_rule("api-frozen-mutation", "error",
              "mutation of a frozen-dataclass field outside the owning "
              "class; use dataclasses.replace")

_RNG_CONSTRUCTORS = {
    "RandomState": "np.random.RandomState",
    "default_rng": "np.random.default_rng",
    "Random": "random.Random",
}


def _frozen_classes(contexts: Sequence[FileContext]) -> Set[str]:
    """Names of classes decorated ``@dataclass(frozen=True)`` anywhere."""
    out: Set[str] = set()
    for ctx in contexts:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                name = dotted_name(dec.func)
                if name not in ("dataclass", "dataclasses.dataclass"):
                    continue
                for kw in dec.keywords:
                    if kw.arg == "frozen" \
                            and isinstance(kw.value, ast.Constant) \
                            and kw.value.value is True:
                        out.add(node.name)
    return out


def _takes_seed(fn: ast.AST) -> bool:
    args = fn.args
    names = [a.arg for a in (args.posonlyargs + args.args
                             + args.kwonlyargs)]
    return any(n == "seed" or n.endswith("_seed") for n in names)


def _annotation_name(node: Optional[ast.AST]) -> Optional[str]:
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.split(".")[-1].strip()
    name = dotted_name(node)
    if name is not None:
        return name.split(".")[-1]
    if isinstance(node, ast.Subscript):   # Optional[Cfg] / list[Cfg]: outer
        return None
    return None


def _check_unseeded(ctx: FileContext) -> List[Finding]:
    out: List[Finding] = []
    funcs = [n for n in ast.walk(ctx.tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    seeded_spans = [(f.lineno, max(f.lineno, getattr(f, "end_lineno",
                                                    f.lineno)))
                    for f in funcs if _takes_seed(f)]
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call) or node.args or node.keywords:
            continue
        name = dotted_name(node.func)
        if name is None:
            continue
        tail = name.split(".")[-1]
        if tail not in _RNG_CONSTRUCTORS:
            continue
        # `Random` is a common identifier; require the module prefix.
        # `RandomState`/`default_rng` are distinctive enough bare.
        if tail == "Random" and "." not in name:
            continue
        in_seeded = any(lo <= node.lineno <= hi for lo, hi in seeded_spans)
        where = ("inside a seed-taking function, discarding the caller's "
                 "seed" if in_seeded else "OS-seeded, so every run differs")
        out.append(ctx.finding(
            node, "api-unseeded-rng",
            f"{_RNG_CONSTRUCTORS[tail]}() with no seed is {where}; use "
            "repro.core.rng streams"))
    return out


def _check_frozen(ctx: FileContext, frozen: Set[str]) -> List[Finding]:
    out: List[Finding] = []
    # classes defined in this file; object.__setattr__ inside their own
    # method bodies (i.e. __post_init__ normalisation) is legitimate
    own_spans = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ClassDef) and node.name in frozen:
            own_spans.append((node.lineno,
                              getattr(node, "end_lineno", node.lineno)))
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name == "object.__setattr__":
                inside_owner = any(lo <= node.lineno <= hi
                                   for lo, hi in own_spans)
                if not inside_owner:
                    out.append(ctx.finding(
                        node, "api-frozen-mutation",
                        "object.__setattr__ outside the owning frozen "
                        "class bypasses immutability; build a new "
                        "instance with dataclasses.replace"))
    # attribute assignment to names annotated with a frozen class:
    # parameters and AnnAssign locals give us the annotation
    for fn in [n for n in ast.walk(ctx.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        typed: Dict[str, str] = {}
        args = fn.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            ann = _annotation_name(a.annotation)
            if ann in frozen:
                typed[a.arg] = ann
        for node in ast.walk(fn):
            if isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name):
                ann = _annotation_name(node.annotation)
                if ann in frozen:
                    typed[node.target.id] = ann
        if not typed:
            continue
        for node in ast.walk(fn):
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AugAssign):
                targets = [node.target]
            for tgt in targets:
                if isinstance(tgt, ast.Attribute) \
                        and isinstance(tgt.value, ast.Name) \
                        and tgt.value.id in typed:
                    cls = typed[tgt.value.id]
                    out.append(ctx.finding(
                        tgt, "api-frozen-mutation",
                        f"{tgt.value.id}.{tgt.attr} = ... mutates frozen "
                        f"dataclass {cls} (FrozenInstanceError at "
                        f"runtime); use dataclasses.replace({tgt.value.id}"
                        f", {tgt.attr}=...)"))
    return out


def check_project(contexts: Sequence[FileContext]) -> List[Finding]:
    frozen = _frozen_classes(contexts)
    out: List[Finding] = []
    for ctx in contexts:
        out.extend(_check_unseeded(ctx))
        out.extend(_check_frozen(ctx, frozen))
    return out
