"""simlint driver: file contexts, the rule registry, suppressions.

A *rule* is an id + severity + one-line description, registered in
``RULES`` so the CLI, the docs, and the suppression checker share one
catalogue. A *pass* is a callable producing :class:`Finding`s — either
per-file (``(FileContext) -> findings``) or project-wide
(``(list[FileContext]) -> findings`` — the trace-kind cross-check needs
to see the declaration and every emission site at once).

Suppressions are inline comments::

    expr  # simlint: ok(rule-id, why this specific site is fine)

matching findings on the same line, or — for a comment-only line — on
the next source line. The reason is mandatory: a reasonless ``ok(...)``
does not suppress and is reported as ``suppression-needs-reason``.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence

__all__ = ["Finding", "FileContext", "Linter", "Rule", "RULES",
           "lint_paths", "register_rule", "dotted_name"]

SEVERITIES = ("warning", "error")


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    severity: str
    description: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity: {self.severity!r}")


# one catalogue shared by every pass, the CLI, and the docs
RULES: Dict[str, Rule] = {}


def register_rule(rule_id: str, severity: str, description: str) -> Rule:
    if rule_id in RULES:
        raise ValueError(f"duplicate rule id: {rule_id!r}")
    rule = Rule(rule_id, severity, description)
    RULES[rule_id] = rule
    return rule


register_rule("suppression-needs-reason", "error",
              "a `# simlint: ok(rule)` comment must carry a written "
              "reason: `# simlint: ok(rule, reason)`")
register_rule("suppression-unknown-rule", "error",
              "a suppression names a rule id that does not exist")
register_rule("parse-error", "error",
              "a linted file does not parse as Python")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str                  # posix-style, as given to the linter
    line: int                  # 1-indexed
    rule: str
    message: str

    @property
    def severity(self) -> str:
        return RULES[self.rule].severity

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.severity} "
                f"[{self.rule}] {self.message}")


class FileContext:
    """One parsed source file plus the helpers every pass needs."""

    def __init__(self, path: str, source: str):
        self.path = Path(path).as_posix()
        self.source = source
        self.tree = ast.parse(source, filename=self.path)
        self.lines = source.splitlines()

    def in_dir(self, *parts: str) -> bool:
        """True when any of ``parts`` appears as a path component
        sequence, e.g. ``in_dir("repro/serverless")``."""
        p = "/" + self.path.strip("/") + "/"
        return any(f"/{part.strip('/')}/" in p for part in parts)

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        return Finding(self.path, getattr(node, "lineno", 1), rule, message)


def dotted_name(node: ast.AST) -> Optional[str]:
    """``np.random.RandomState`` for the matching Attribute chain, or
    None when the chain does not bottom out at a plain Name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# -- suppressions ------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*simlint:\s*ok\(\s*(?P<rule>[\w-]+)\s*(?:,\s*(?P<reason>[^)]*?)\s*)?\)")


@dataclasses.dataclass(frozen=True)
class _Suppression:
    line: int
    rule: str
    reason: str
    comment_only: bool         # a bare-comment line also covers line+1


def _parse_suppressions(ctx: FileContext) -> List[_Suppression]:
    # real COMMENT tokens only — a `# simlint: ok(...)` shown inside a
    # docstring or string literal is documentation, not a suppression
    out = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(ctx.source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if m is None:
                continue
            line = tok.start[0]
            comment_only = ctx.lines[line - 1].lstrip().startswith("#")
            out.append(_Suppression(line, m.group("rule"),
                                    (m.group("reason") or "").strip(),
                                    comment_only))
    except tokenize.TokenizeError:
        pass                    # file parsed, so this should not happen
    return out


def _apply_suppressions(ctx: FileContext,
                        findings: List[Finding]) -> List[Finding]:
    sups = _parse_suppressions(ctx)
    if not sups:
        return findings
    out = []
    active: Dict[tuple, _Suppression] = {}
    for s in sups:
        if not s.reason:
            out.append(Finding(ctx.path, s.line, "suppression-needs-reason",
                               f"suppression of {s.rule!r} has no reason; "
                               f"write `# simlint: ok({s.rule}, <why>)`"))
            continue
        if s.rule not in RULES:
            out.append(Finding(ctx.path, s.line, "suppression-unknown-rule",
                               f"no such rule: {s.rule!r}"))
            continue
        active[(s.line, s.rule)] = s
        if s.comment_only:
            active[(s.line + 1, s.rule)] = s
    for f in findings:
        if (f.line, f.rule) in active:
            continue
        out.append(f)
    return out


# -- driver ------------------------------------------------------------------

FilePass = Callable[[FileContext], Iterable[Finding]]
ProjectPass = Callable[[Sequence[FileContext]], Iterable[Finding]]


class Linter:
    """Collect ``.py`` files, run every pass, filter suppressions."""

    def __init__(self, file_passes: Optional[Sequence[FilePass]] = None,
                 project_passes: Optional[Sequence[ProjectPass]] = None):
        if file_passes is None or project_passes is None:
            # deferred: the pass modules import this one
            from repro.analysis import api, coverage, determinism, units
            file_passes = [determinism.check_file, units.check_file]
            project_passes = [coverage.check_project, api.check_project]
        self.file_passes = list(file_passes)
        self.project_passes = list(project_passes)

    def collect(self, paths: Sequence[str]) -> List[str]:
        files: List[str] = []
        for p in paths:
            path = Path(p)
            if path.is_dir():
                files.extend(sorted(str(f) for f in path.rglob("*.py")))
            elif path.suffix == ".py":
                files.append(str(path))
        return files

    def lint_files(self, files: Sequence[str]) -> List[Finding]:
        contexts = []
        findings: List[Finding] = []
        for f in files:
            src = Path(f).read_text()
            try:
                contexts.append(FileContext(f, src))
            except SyntaxError as e:
                findings.append(Finding(Path(f).as_posix(), e.lineno or 1,
                                        "parse-error",
                                        f"file does not parse: {e.msg}"))
        per_file: Dict[str, List[Finding]] = {c.path: [] for c in contexts}
        for ctx in contexts:
            for fp in self.file_passes:
                per_file[ctx.path].extend(fp(ctx))
        for pp in self.project_passes:
            for f in pp(contexts):
                per_file.setdefault(f.path, []).append(f)
        by_path = {c.path: c for c in contexts}
        for path, fs in per_file.items():
            ctx = by_path.get(path)
            findings.extend(_apply_suppressions(ctx, fs) if ctx else fs)
        findings.sort(key=lambda f: (f.path, f.line, f.rule))
        return findings

    def lint_paths(self, paths: Sequence[str]) -> List[Finding]:
        return self.lint_files(self.collect(paths))


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    return Linter().lint_paths(paths)
