"""Trace/event coverage pass (project-wide).

Two invariants that runtime checks only half-enforce:

- **Trace kinds, both directions.** ``TraceEvent.__post_init__``
  rejects an *emitted* kind missing from ``KINDS`` — but only when that
  code path actually runs, and it can never notice the converse: a kind
  declared in ``KINDS`` that nothing emits any more (PR 5 added the
  runtime check precisely because a typo'd kind silently vanished from
  traces; a dead declared kind is the same bug seen from the other
  side, keeping ``events if e.kind == ...`` filters looking alive).
  This pass collects every ``KINDS`` declaration and every literal kind
  passed to a ``TraceEvent(...)`` construction across the whole tree
  and reports both mismatch directions.

- **Event push targets.** ``CalendarQueue``/``ContentionDomain``
  records are ``(t, seq, fn, payload)`` tuples holding a *bound method*
  — there is no registry to validate against at runtime, so a renamed
  handler only fails when the event fires (possibly hours into a
  sweep). In any module that uses those classes, every ``at``/``at2``/
  ``at2_bulk``/``push``/``push_bulk`` call whose handler is written as
  an attribute (``self._compute_done``) must name a function defined
  somewhere in that module. Handlers passed through variables or
  parameters are skipped — the pass only proves what it can see.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (FileContext, Finding, dotted_name,
                                 register_rule)

register_rule("trace-kind-undeclared", "error",
              "TraceEvent(...) constructed with a literal kind missing "
              "from TraceEvent.KINDS")
register_rule("trace-kind-dead", "warning",
              "a kind declared in TraceEvent.KINDS is never emitted by "
              "any TraceEvent(...) construction in the tree")
register_rule("event-unbound-handler", "error",
              "an event pushed at a CalendarQueue/ContentionDomain names "
              "a handler attribute with no matching function definition "
              "in the module")

_TRACE_CLASSES = ("TraceEvent",)
_KIND_ARG_INDEX = 2                     # TraceEvent(t, epoch, kind, ...)


def _literal_strings(node: ast.AST) -> Optional[Set[str]]:
    """The set of strings a KINDS declaration holds, if it is a literal
    frozenset/set of string constants (possibly ``frozenset({...})``)."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("frozenset", "set") and len(node.args) == 1:
        return _literal_strings(node.args[0])
    if isinstance(node, (ast.Set, ast.Tuple, ast.List)):
        out: Set[str] = set()
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, str)):
                return None
            out.add(elt.value)
        return out
    return None


def _kind_of_call(call: ast.Call) -> Optional[Tuple[str, ast.AST]]:
    """(kind string, node-to-blame) for a literal-kind TraceEvent call."""
    for kw in call.keywords:
        if kw.arg == "kind":
            if isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, str):
                return kw.value.value, kw.value
            return None
    if len(call.args) > _KIND_ARG_INDEX:
        arg = call.args[_KIND_ARG_INDEX]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            return arg.value, arg
    return None


def _check_trace_kinds(contexts: Sequence[FileContext]) -> List[Finding]:
    declared: Dict[str, Set[str]] = {}          # class -> kinds
    decl_site: Dict[str, Tuple[FileContext, ast.AST]] = {}
    emissions: List[Tuple[FileContext, ast.AST, str, str]] = []
    for ctx in contexts:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef) and node.name in _TRACE_CLASSES:
                for stmt in node.body:
                    tgt = None
                    if isinstance(stmt, ast.AnnAssign) \
                            and isinstance(stmt.target, ast.Name):
                        tgt, val = stmt.target.id, stmt.value
                    elif isinstance(stmt, ast.Assign) \
                            and len(stmt.targets) == 1 \
                            and isinstance(stmt.targets[0], ast.Name):
                        tgt, val = stmt.targets[0].id, stmt.value
                    if tgt == "KINDS" and val is not None:
                        kinds = _literal_strings(val)
                        if kinds is not None:
                            declared[node.name] = kinds
                            decl_site[node.name] = (ctx, stmt)
            elif isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name is None:
                    continue
                cls = name.split(".")[-1]
                if cls in _TRACE_CLASSES:
                    got = _kind_of_call(node)
                    if got is not None:
                        emissions.append((ctx, got[1], cls, got[0]))

    out: List[Finding] = []
    emitted: Dict[str, Set[str]] = {c: set() for c in declared}
    for ctx, node, cls, kind in emissions:
        if cls not in declared:
            continue
        emitted[cls].add(kind)
        if kind not in declared[cls]:
            out.append(ctx.finding(
                node, "trace-kind-undeclared",
                f"{cls}(kind={kind!r}) is not declared in {cls}.KINDS — "
                "this raises at runtime; register the kind (or fix the "
                "typo)"))
    for cls, kinds in declared.items():
        ctx, site = decl_site[cls]
        # fixture trees may declare a class nothing emits; only judge
        # deadness when the class is constructed somewhere in this run
        if not emitted[cls]:
            continue
        for kind in sorted(kinds - emitted[cls]):
            out.append(ctx.finding(
                site, "trace-kind-dead",
                f"{cls}.KINDS declares {kind!r} but no {cls}(...) in the "
                f"tree emits it; drop it so `kind == {kind!r}` filters "
                "can't silently match nothing"))
    return out


# -- event handler binding ---------------------------------------------------

_QUEUE_MARKERS = ("CalendarQueue", "ContentionDomain")
# method -> index of the handler inside the call's argument list, or,
# for the tuple/bulk forms, inside each record tuple
_DIRECT = {"at": 1, "at2": 1}
_RECORD = {"push": 2}                    # (t, seq, fn, payload)
_BULK = {"at2_bulk": 1, "push_bulk": 2}  # list of tuples, fn at index


def _handler_exprs(call: ast.Call, method: str) -> List[ast.AST]:
    if method in _DIRECT:
        idx = _DIRECT[method]
        return [call.args[idx]] if len(call.args) > idx else []
    if method in _RECORD:
        idx = _RECORD[method]
        if call.args and isinstance(call.args[0], (ast.Tuple, ast.List)) \
                and len(call.args[0].elts) > idx:
            return [call.args[0].elts[idx]]
        return []
    if method in _BULK:
        idx = _BULK[method]
        out = []
        if not call.args:
            return out
        seq = call.args[0]
        elts: List[ast.AST] = []
        if isinstance(seq, (ast.List, ast.Tuple, ast.Set)):
            elts = list(seq.elts)
        elif isinstance(seq, (ast.ListComp, ast.GeneratorExp)):
            elts = [seq.elt]
        for e in elts:
            if isinstance(e, (ast.Tuple, ast.List)) and len(e.elts) > idx:
                out.append(e.elts[idx])
        return out
    return []


def _check_handlers(contexts: Sequence[FileContext]) -> List[Finding]:
    out: List[Finding] = []
    for ctx in contexts:
        if not any(m in ctx.source for m in _QUEUE_MARKERS):
            continue
        defined = {n.name for n in ast.walk(ctx.tree)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            method = node.func.attr
            if method not in {**_DIRECT, **_RECORD, **_BULK}:
                continue
            # attribute handlers are provable; bare names may be
            # parameters or loop variables (unresolvable statically)
            # and lambdas/calls are accepted as-is
            for h in _handler_exprs(node, method):
                if isinstance(h, ast.Attribute) and h.attr not in defined:
                    out.append(ctx.finding(
                        h, "event-unbound-handler",
                        f"handler .{h.attr} pushed at the event queue "
                        f"but no function named {h.attr!r} is defined "
                        "in this module — the event would raise (or "
                        "call the wrong thing) when it fires"))
    return out


def check_project(contexts: Sequence[FileContext]) -> List[Finding]:
    return _check_trace_kinds(contexts) + _check_handlers(contexts)
