"""Determinism pass: every draw through named streams, no wall clocks.

The engine's same-seed bit-identity guarantee (tests/golden_engine_trace
and the invariants suite) holds only because every stochastic draw
routes through ``repro.core.rng`` named streams and every timestamp is
simulation time. The four rules here catch the ways that discipline has
historically eroded:

- ``det-global-rng``: ``np.random.rand(...)``, ``random.random()`` and
  friends mutate interpreter-global generator state — two call sites
  silently couple, and import order changes results.
- ``det-wallclock``: ``time.time()`` / ``datetime.now()`` reads make
  output depend on when (and on which machine) the run happened.
  ``time.perf_counter`` / ``time.monotonic`` are allowed: they are
  duration timers for explicitly-timed bench regions, not wall clocks.
- ``det-raw-randomstate``: inside ``src/repro`` (except
  ``repro.core.rng`` itself, which is the one place seed formulas may
  live) a direct ``np.random.RandomState(...)`` bypasses the named
  streams — adjacent integer seeds produce correlated streams, and the
  seed-formula sprawl is how the pre-PR-6 ad-hoc seeding bugs happened.
- ``det-set-iter``: in the event-scheduling layers (``serverless/``,
  ``workflow/``) iteration order feeds event schedules, trace lines,
  and hashes; ``set`` iteration order depends on PYTHONHASHSEED, so an
  unsorted walk is a cross-process nondeterminism bug. ``sorted(s)`` is
  the fix (and is not flagged). ``dict.keys()`` iteration is flagged in
  the same scope: it is insertion-ordered today, but the insertion
  order of these registries is itself schedule-dependent.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.core import (FileContext, Finding, dotted_name,
                                 register_rule)

register_rule("det-global-rng", "error",
              "global-state RNG call (np.random.<draw> / random.<draw>); "
              "use a repro.core.rng named stream")
register_rule("det-wallclock", "warning",
              "wall-clock read (time.time / datetime.now); use simulation "
              "time, or time.perf_counter for timed bench regions")
register_rule("det-raw-randomstate", "warning",
              "direct np.random.RandomState construction inside src/repro; "
              "route through repro.core.rng named streams")
register_rule("det-set-iter", "warning",
              "iteration over a set (or dict.keys()) in an "
              "event-scheduling layer; wrap in sorted() for a "
              "hash-seed-independent order")

# np.random attributes that are constructors/types, not global-state draws
_NP_RANDOM_OK = {
    "RandomState", "default_rng", "Generator", "SeedSequence",
    "BitGenerator", "MT19937", "PCG64", "PCG64DXSM", "Philox", "SFC64",
}

# stdlib random module functions that read/mutate the global generator
_PY_RANDOM_GLOBAL = {
    "random", "seed", "randint", "randrange", "choice", "choices",
    "shuffle", "sample", "uniform", "gauss", "normalvariate",
    "lognormvariate", "expovariate", "betavariate", "gammavariate",
    "triangular", "vonmisesvariate", "paretovariate", "weibullvariate",
    "getrandbits", "randbytes", "binomialvariate",
}

_WALLCLOCK = {
    "time.time", "time.time_ns", "time.localtime", "time.gmtime",
    "time.ctime", "time.asctime",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}

# order-insensitive consumers: iterating a set inside these is fine
_ORDER_SAFE_CALLS = {
    "sorted", "len", "min", "max", "sum", "any", "all", "set", "frozenset",
}


class _Aliases:
    """Import-derived aliasing: which local names mean numpy, the stdlib
    random module, time, and datetime members."""

    def __init__(self, tree: ast.AST):
        self.numpy: Set[str] = set()        # import numpy as np -> {"np"}
        self.np_random: Set[str] = set()    # import numpy.random as npr
        self.py_random: Set[str] = set()    # import random [as r]
        self.time_mod: Set[str] = set()     # import time [as t]
        self.dt_mod: Set[str] = set()       # import datetime [as dt]
        self.dt_class: Set[str] = set()     # from datetime import datetime
        self.date_class: Set[str] = set()   # from datetime import date
        self.from_time: Set[str] = set()    # from time import time -> {"time"}
        self.from_random: Set[str] = set()  # from random import random, ...
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    name = a.asname or a.name.split(".")[0]
                    if a.name == "numpy":
                        self.numpy.add(name)
                    elif a.name == "numpy.random" and a.asname:
                        self.np_random.add(a.asname)
                    elif a.name == "random":
                        self.py_random.add(name)
                    elif a.name == "time":
                        self.time_mod.add(name)
                    elif a.name == "datetime":
                        self.dt_mod.add(name)
            elif isinstance(node, ast.ImportFrom):
                if node.module == "datetime":
                    for a in node.names:
                        tgt = a.asname or a.name
                        if a.name == "datetime":
                            self.dt_class.add(tgt)
                        elif a.name == "date":
                            self.date_class.add(tgt)
                elif node.module == "time":
                    for a in node.names:
                        if a.name in ("time", "time_ns"):
                            self.from_time.add(a.asname or a.name)
                elif node.module == "random":
                    for a in node.names:
                        if a.name in _PY_RANDOM_GLOBAL:
                            self.from_random.add(a.asname or a.name)


def _rng_violation(dotted: str, al: _Aliases) -> Optional[str]:
    """Why a dotted call name is a global-state RNG call, or None."""
    parts = dotted.split(".")
    if len(parts) == 3 and parts[0] in al.numpy and parts[1] == "random":
        if parts[2] not in _NP_RANDOM_OK:
            return (f"np.random.{parts[2]} draws from numpy's global "
                    "generator")
    if len(parts) == 2:
        if parts[0] in al.np_random and parts[1] not in _NP_RANDOM_OK:
            return (f"numpy.random.{parts[1]} draws from numpy's global "
                    "generator")
        if parts[0] in al.py_random and parts[1] in _PY_RANDOM_GLOBAL:
            return (f"random.{parts[1]} draws from the interpreter-global "
                    "generator")
    return None


def _wallclock_violation(dotted: str, al: _Aliases) -> bool:
    parts = dotted.split(".")
    if len(parts) == 1:
        return parts[0] in al.from_time
    if len(parts) == 2:
        mod, fn = parts
        if mod in al.time_mod and f"time.{fn}" in _WALLCLOCK:
            return True
        if mod in al.dt_class and fn in ("now", "utcnow", "today"):
            return True
        if mod in al.date_class and fn == "today":
            return True
    if len(parts) == 3:
        mod, cls, fn = parts
        if mod in al.dt_mod and f"datetime.{cls}.{fn}" in _WALLCLOCK:
            return True
    return False


# -- set-iteration detection -------------------------------------------------

def _is_set_expr(node: ast.AST, local_sets: Set[str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in ("set", "frozenset"):
            return True
        if isinstance(fn, ast.Attribute) and fn.attr == "keys":
            return True                 # dict.keys(): see module docstring
        if isinstance(fn, ast.Attribute) and fn.attr in (
                "union", "intersection", "difference",
                "symmetric_difference"):
            return _is_set_expr(fn.value, local_sets)
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return (_is_set_expr(node.left, local_sets)
                or _is_set_expr(node.right, local_sets))
    if isinstance(node, ast.Name):
        return node.id in local_sets
    return False


def _local_set_names(scope: ast.AST) -> Set[str]:
    """Names assigned a provably-set value (and never a non-set value)
    anywhere in ``scope`` — a function body, or the module."""
    is_set: Dict[str, bool] = {}
    for node in ast.walk(scope):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            val = _is_set_expr(node.value, set())
            is_set[name] = val and is_set.get(name, True)
        elif isinstance(node, ast.AnnAssign) and node.value is not None \
                and isinstance(node.target, ast.Name):
            name = node.target.id
            val = _is_set_expr(node.value, set())
            is_set[name] = val and is_set.get(name, True)
    return {n for n, ok in is_set.items() if ok}


def _iter_sites(scope: ast.AST) -> Iterable[ast.AST]:
    """(site, iterated-expression) pairs inside one scope."""
    for node in ast.walk(scope):
        if isinstance(node, ast.For):
            yield node, node.iter
        elif isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.DictComp,
                               ast.SetComp)):
            for gen in node.generators:
                yield node, gen.iter
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("list", "tuple", "enumerate", "iter",
                                     "reversed") and node.args:
            yield node, node.args[0]


def check_file(ctx: FileContext) -> List[Finding]:
    al = _Aliases(ctx.tree)
    out: List[Finding] = []
    in_repro = ctx.in_dir("repro") and not ctx.path.endswith(
        "repro/core/rng.py")
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = dotted_name(node.func)
        if dotted is None:
            continue
        why = _rng_violation(dotted, al)
        if why is not None:
            out.append(ctx.finding(node, "det-global-rng", why))
        elif _wallclock_violation(dotted, al):
            out.append(ctx.finding(
                node, "det-wallclock",
                f"{dotted}() reads the wall clock; results now depend on "
                "when the run happened (use time.perf_counter for "
                "durations, simulation time for schedules)"))
        elif in_repro and dotted.split(".")[-1] == "RandomState" and (
                len(dotted.split(".")) == 3
                and dotted.split(".")[0] in al.numpy
                or len(dotted.split(".")) == 2
                and dotted.split(".")[0] in al.np_random):
            out.append(ctx.finding(
                node, "det-raw-randomstate",
                "construct streams via repro.core.rng (stream/base_stream/"
                "worker_stream/...) so seed formulas live in one place"))

    if ctx.in_dir("repro/serverless", "repro/workflow"):
        scopes = [n for n in ast.walk(ctx.tree)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        module_sets = _local_set_names(ctx.tree)
        for scope in scopes or [ctx.tree]:
            local = module_sets | _local_set_names(scope)
            for site, it in _iter_sites(scope):
                if _is_set_expr(it, local):
                    out.append(ctx.finding(
                        site, "det-set-iter",
                        "iteration order over a set depends on "
                        "PYTHONHASHSEED and feeds the event schedule/"
                        "trace; iterate sorted(...) instead"))
    return out
