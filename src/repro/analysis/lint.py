"""CLI: ``python -m repro.analysis.lint <paths...> --fail-on warning``.

Exit status: 0 when no finding meets the ``--fail-on`` threshold,
1 otherwise. ``--fail-on never`` always exits 0 (report-only mode).
``--list-rules`` prints the registered catalogue and exits.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.analysis.core import RULES, Linter


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="AST invariant linter: determinism, billing units, "
                    "trace/event coverage, API misuse.")
    parser.add_argument("paths", nargs="*", default=[],
                        help="files or directories to lint "
                             "(default: src benchmarks examples)")
    parser.add_argument("--fail-on", choices=("warning", "error", "never"),
                        default="warning",
                        help="lowest severity that fails the run "
                             "(default: warning)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalogue and exit")
    args = parser.parse_args(argv)

    # importing Linter's default passes registers every rule
    linter = Linter()

    if args.list_rules:
        for rule in sorted(RULES.values(), key=lambda r: r.id):
            print(f"{rule.id:28s} {rule.severity:8s} {rule.description}")
        return 0

    paths = args.paths or ["src", "benchmarks", "examples"]
    findings = linter.lint_paths(paths)
    for f in findings:
        print(f.render())

    n_err = sum(1 for f in findings if f.severity == "error")
    n_warn = len(findings) - n_err
    if findings:
        print(f"simlint: {n_err} error(s), {n_warn} warning(s)")
    else:
        print("simlint: clean")

    if args.fail_on == "never":
        return 0
    if args.fail_on == "error":
        return 1 if n_err else 0
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
