"""Billing-units pass: suffix-inferred dimensions on names.

The repo's naming convention carries dimensions in identifier suffixes
(``wall_s``, ``bw_gbps``, ``state_mb``, ``cost_usd``, ``n_ev``). PR 4's
latent keep-alive billing bug was exactly a cross-unit slip — seconds
billed against the wrong store's rate — that type checkers cannot see
because everything is ``float``. Two rules:

- ``unit-mix`` (error): ``a_s + b_usd``, ``a_mb - b_gb``, or a
  comparison between two differently-dimensioned operands. Addition,
  subtraction, and comparison require like dimensions; multiplication
  and division are how conversions happen and are never flagged.
- ``unit-assign`` (warning): ``x_s = y_mb`` style assignments (and
  keyword arguments, ``f(wall_s=item.cost_usd)``) where both sides
  carry a known dimension and they differ, with no arithmetic in
  between to perform the conversion.

Inference is deliberately shallow: only bare names and attribute
accesses whose final component carries a known suffix get a dimension.
Any expression containing arithmetic is treated as dimensionless (a
conversion may have happened inside it).
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.core import FileContext, Finding, register_rule

register_rule("unit-mix", "error",
              "arithmetic or comparison mixing incompatible unit "
              "dimensions (suffix-inferred: _s, _gbps, _mb, _gb, _usd, "
              "_usd_per_s, _usd_per_hr, _ev)")
register_rule("unit-assign", "warning",
              "assignment (or keyword argument) carries a value of one "
              "unit dimension into a name of another without conversion")

# endswith-matched, longest suffix first so `_gbps` is not read as `_s`
# and `_mbps`-style names never alias `_s`. `_mb` and `_gb` are distinct
# dimensions on purpose: adding megabytes to gigabytes without a /1024
# is exactly the class of bug this pass exists for. The billing *rates*
# (`_usd_per_s`, `_usd_per_hr`) come first for the same reason: a
# per-second rate is neither seconds nor dollars, and adding an hourly
# rate to a per-second one without the /3600 is the exact spot-market
# slip the multi-backend billing paths are exposed to.
_SUFFIXES = (
    ("_usd_per_hr", "dollars per hour"),
    ("_usd_per_s", "dollars per second"),
    ("_gbps", "bandwidth (Gbit/s)"),
    ("_usd", "dollars"),
    ("_mb", "megabytes"),
    ("_gb", "gigabytes"),
    ("_ev", "events"),
    ("_ns", "nanoseconds"),
    ("_ms", "milliseconds"),
    ("_s", "seconds"),
)

# plural/indexed forms: `times_s`, `sizes_mb` — same dimension per element
_ZERO_LIKE = (0, 0.0, -1, -1.0, 1, 1.0)


def _dim_of_name(name: str) -> Optional[str]:
    for suffix, dim in _SUFFIXES:
        if name.endswith(suffix) or name.endswith(suffix + "s"):
            return dim
    return None


def _dim(node: ast.AST) -> Optional[str]:
    """Dimension of an expression, or None when unknown/dimensionless.

    Only bare names, attributes, and subscripts of those are inferred;
    calls and arithmetic are opaque (conversion may occur inside).
    """
    if isinstance(node, ast.Name):
        return _dim_of_name(node.id)
    if isinstance(node, ast.Attribute):
        return _dim_of_name(node.attr)
    if isinstance(node, ast.Subscript):
        return _dim(node.value)
    if isinstance(node, ast.UnaryOp):
        return _dim(node.operand)
    return None


def _is_zero_like(node: ast.AST) -> bool:
    if isinstance(node, ast.UnaryOp):
        node = node.operand
    return isinstance(node, ast.Constant) and node.value in _ZERO_LIKE


def check_file(ctx: FileContext) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.Add, ast.Sub)):
            ld, rd = _dim(node.left), _dim(node.right)
            if ld is not None and rd is not None and ld != rd:
                out.append(ctx.finding(
                    node, "unit-mix",
                    f"adding/subtracting {ld} and {rd}; convert one side "
                    "explicitly first"))
        elif isinstance(node, ast.Compare) and len(node.ops) == 1:
            ld, rd = _dim(node.left), _dim(node.comparators[0])
            if ld is not None and rd is not None and ld != rd:
                out.append(ctx.finding(
                    node, "unit-mix",
                    f"comparing {ld} against {rd}; the comparison is "
                    "meaningless without a conversion"))
        elif isinstance(node, ast.Assign):
            rd = _dim(node.value)
            if rd is None or _is_zero_like(node.value):
                continue
            for tgt in node.targets:
                td = _dim(tgt)
                if td is not None and td != rd:
                    out.append(ctx.finding(
                        node, "unit-assign",
                        f"{ast.unparse(tgt)} ({td}) assigned a {rd} value "
                        "with no conversion"))
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            rd, td = _dim(node.value), _dim(node.target)
            if td is not None and rd is not None and td != rd:
                out.append(ctx.finding(
                    node, "unit-assign",
                    f"{ast.unparse(node.target)} ({td}) assigned a {rd} "
                    "value with no conversion"))
        elif isinstance(node, ast.AugAssign) and isinstance(
                node.op, (ast.Add, ast.Sub)):
            td, rd = _dim(node.target), _dim(node.value)
            if td is not None and rd is not None and td != rd:
                out.append(ctx.finding(
                    node, "unit-mix",
                    f"accumulating {rd} into {ast.unparse(node.target)} "
                    f"({td}); convert first"))
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg is None:
                    continue
                td, rd = _dim_of_name(kw.arg), _dim(kw.value)
                if td is not None and rd is not None and td != rd:
                    out.append(ctx.finding(
                        kw.value, "unit-assign",
                        f"keyword {kw.arg} ({td}) passed a {rd} value "
                        "with no conversion"))
    return out
