from repro.checkpoint.checkpointer import (  # noqa: F401
    CheckpointMeta, DiskCheckpointer, StoreCheckpointer)
