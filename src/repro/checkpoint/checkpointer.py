"""Checkpointing: the mechanism behind SMLT's duration-cap restarts and
fault tolerance (paper Section 4.1).

Two backends share one format:
 - ``DiskCheckpointer``: npz files on local disk (real training runs);
 - ``StoreCheckpointer``: blobs in the simulated object store (so the
   serverless scheduler's restart path moves the same bytes the paper's
   workers would).

A checkpoint = flat {path: array} + metadata (step, epoch, iterator state),
so restore works across fleet sizes (elastic rescaling re-shards on load).
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf) if leaf.dtype != jax.numpy.bfloat16 \
            else np.asarray(leaf, np.float32)  # npz has no bf16; restore casts
        out[key] = arr
    return out


def _unflatten(flat: Dict[str, np.ndarray], tree_like):
    paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree.structure(tree_like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = flat[key]
        leaves.append(np.asarray(arr).astype(leaf.dtype).reshape(leaf.shape))
    return jax.tree.unflatten(treedef, leaves)


@dataclasses.dataclass
class CheckpointMeta:
    step: int = 0
    epoch: int = 0
    index: int = 0       # data-iterator position
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


class DiskCheckpointer:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def save(self, name: str, tree, meta: CheckpointMeta):
        flat = _flatten(tree)
        np.savez(os.path.join(self.dir, f"{name}.npz"), **flat)
        with open(os.path.join(self.dir, f"{name}.json"), "w") as f:
            json.dump(dataclasses.asdict(meta), f)

    def restore(self, name: str, tree_like) -> Tuple[Any, CheckpointMeta]:
        data = np.load(os.path.join(self.dir, f"{name}.npz"))
        with open(os.path.join(self.dir, f"{name}.json")) as f:
            meta = CheckpointMeta(**json.load(f))
        return _unflatten(dict(data), tree_like), meta

    def exists(self, name: str) -> bool:
        return os.path.exists(os.path.join(self.dir, f"{name}.npz"))


class StoreCheckpointer:
    """Checkpoints through the (simulated) object store — bytes are
    accounted so restart overheads show up in time and cost."""

    def __init__(self, object_store):
        self.store = object_store

    def save(self, name: str, tree, meta: CheckpointMeta) -> float:
        flat = _flatten(tree)
        buf = io.BytesIO()
        np.savez(buf, **flat)
        nbytes = buf.getbuffer().nbytes
        self.store.put(f"ckpt/{name}", buf.getvalue(), nbytes=nbytes)
        self.store.put(f"ckpt/{name}.meta", dataclasses.asdict(meta))
        return self.store.put_time(nbytes)

    def restore(self, name: str, tree_like) -> Tuple[Any, CheckpointMeta, float]:
        raw = self.store.get(f"ckpt/{name}")
        t = self.store.get_time(len(raw))
        data = np.load(io.BytesIO(raw))
        meta = CheckpointMeta(**self.store.get(f"ckpt/{name}.meta"))
        return _unflatten(dict(data), tree_like), meta, t
