"""Architecture registry: the 10 assigned architectures, the 4 input
shapes, the reduced (smoke-test) variants, and ``input_specs()`` —
ShapeDtypeStruct stand-ins for every model input (no device allocation).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.base import INPUT_SHAPES, InputShape, ModelConfig

from repro.configs import (arctic_480b, llama3p2_vision_90b, mamba2_2p7b,
                           mistral_large_123b, olmo_1b, phi4_mini_3p8b,
                           qwen2_moe_a2p7b, qwen2p5_3b, seamless_m4t_medium,
                           zamba2_7b)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.arch_id: m.CONFIG
    for m in (mamba2_2p7b, seamless_m4t_medium, qwen2_moe_a2p7b, arctic_480b,
              olmo_1b, qwen2p5_3b, phi4_mini_3p8b, llama3p2_vision_90b,
              zamba2_7b, mistral_large_123b)
}

# long_500k (524,288-token KV) runs only for sub-quadratic decode paths:
# pure SSM and the hybrid's sliding-window attention. Pure full-attention
# archs are skipped per the brief (see DESIGN.md §4).
LONG_CONTEXT_ARCHS = ("mamba2-2.7b", "zamba2-7b")


def supports(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True


def pairs():
    """All (arch, shape) combinations that must lower (10x4 minus skips)."""
    for a in ARCHS:
        for s in INPUT_SHAPES:
            if supports(a, s):
                yield a, s


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, weak-type-correct, no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def n_frames_for(cfg: ModelConfig, seq_len: int) -> int:
    return max(seq_len // 4, 16)


def batch_extras(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    """Modality-frontend stubs (the one sanctioned carve-out)."""
    out = {}
    if cfg.family == "vlm":
        out["image_embeds"] = _sds((batch, cfg.n_image_tokens, cfg.d_vision),
                                   cfg.dtype)
    if cfg.family == "audio":
        out["audio_frames"] = _sds((batch, n_frames_for(cfg, seq_len),
                                    cfg.d_audio), cfg.dtype)
    return out


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """Model inputs for one workload shape.

    train:    {tokens, labels, (extras)}                     -> train_step
    prefill:  {tokens, (extras)}                             -> prefill_step
    decode:   {tokens: (B,1), pos: scalar, (extras)}         -> serve_step
              (the KV/SSM cache spec is derived separately; see dryrun)
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32)}
        specs.update(batch_extras(cfg, b, s))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _sds((b, s), jnp.int32)}
        specs.update(batch_extras(cfg, b, s))
        return specs
    if shape.kind == "decode":
        specs = {"tokens": _sds((b, 1), jnp.int32),
                 "pos": _sds((), jnp.int32)}
        specs.update(batch_extras(cfg, b, s))
        return specs
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# reduced variants for CPU smoke tests (2 layers, d_model<=512, <=4 experts)
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    kw = dict(
        n_layers=2,
        d_model=128,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 997),
        dtype=jnp.float32,
        remat=False,
    )
    if cfg.n_heads:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = max(1, 4 * cfg.n_kv_heads // cfg.n_heads)
        kw["head_dim"] = 32
    if cfg.n_experts:
        kw["n_experts"] = 4
        kw["top_k"] = min(cfg.top_k, 2)
        kw["n_shared_experts"] = min(cfg.n_shared_experts, 2)
        # dropless at smoke scale so decode == prefill numerically
        kw["moe_capacity_factor"] = float(4 // min(cfg.top_k, 2))
    if cfg.ssm_state:
        kw["ssm_state"] = 16
        kw["ssm_headdim"] = 16
        kw["ssm_chunk"] = 16
    if cfg.attn_every:
        kw["attn_every"] = 2
        kw["n_layers"] = 5           # 2 groups of 2 + 1 remainder layer
        kw["sliding_window"] = 32
    if cfg.cross_attn_every:
        kw["cross_attn_every"] = 2
        kw["n_layers"] = 4
        kw["n_image_tokens"] = 16
        kw["d_vision"] = 64
    if cfg.is_encdec:
        kw["n_encoder_layers"] = 2
        kw["n_audio_frames"] = 32
        kw["d_audio"] = 64
    return cfg.replace(**kw)


def reduced_batch(cfg: ModelConfig, batch: int = 2, seq: int = 32,
                  seed: int = 0) -> Dict:
    """Concrete small batch for the reduced config (smoke tests/examples)."""
    rng = jax.random.key(seed)
    toks = jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size)
    out = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        out["image_embeds"] = jax.random.normal(
            rng, (batch, cfg.n_image_tokens, cfg.d_vision), cfg.dtype)
    if cfg.family == "audio":
        out["audio_frames"] = jax.random.normal(
            rng, (batch, cfg.n_audio_frames, cfg.d_audio), cfg.dtype)
    return out
