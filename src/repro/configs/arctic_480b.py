"""arctic-480b — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) per-expert d_ff=4864 vocab=32000.
"""
import jax.numpy as jnp

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    dtype=jnp.bfloat16,
)
