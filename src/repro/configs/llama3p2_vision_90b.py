"""llama-3.2-vision-90b — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision, scaled per the 90B card].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; every 5th layer
cross-attends to image tokens. Vision encoder (ViT-H) is a stub supplying
patch embeddings (1600 tokens, d_vision=1280).
"""
import jax.numpy as jnp

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    cross_attn_every=5,
    n_image_tokens=1600,
    d_vision=1280,
    rope_theta=500_000.0,
    dtype=jnp.bfloat16,
)
