"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

64L d_model=2560, attention-free, d_ff=0, vocab=50280, ssm_state=128.
"""
import jax.numpy as jnp

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    norm="rmsnorm",
    dtype=jnp.bfloat16,
)
