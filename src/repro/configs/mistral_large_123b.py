"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
import jax.numpy as jnp

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mistral-large-123b",
    family="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    n_layers=88,
    d_model=12_288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28_672,
    vocab_size=32_768,
    rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
)
