"""olmo-1b — non-parametric LayerNorm [arXiv:2402.00838].

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.
"""
import jax.numpy as jnp

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="olmo-1b",
    family="dense",
    source="arXiv:2402.00838",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50_304,
    norm="nonparametric_ln",
    dtype=jnp.bfloat16,
)
