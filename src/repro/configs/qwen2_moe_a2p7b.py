"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) per-expert d_ff=1408 vocab=151936.
"""
import jax.numpy as jnp

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151_936,
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    qkv_bias=True,
    dtype=jnp.bfloat16,
)
