"""qwen2.5-3b — GQA (kv=2), QKV bias [hf:Qwen/Qwen2.5-0.5B family].

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
"""
import jax.numpy as jnp

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-3b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11_008,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
)
