"""seamless-m4t-medium — multimodal enc-dec [arXiv:2308.11596].

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206. Transformer
backbone only: the mel-spectrogram/conv codec frontend is a stub that
supplies precomputed frame embeddings (d_audio=1024).
"""
import jax.numpy as jnp

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-medium",
    family="audio",
    source="arXiv:2308.11596",
    n_layers=12,
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    norm="layernorm",
    mlp="gelu",
    n_audio_frames=1024,     # default; input_specs scales with seq_len
    d_audio=1024,
    dtype=jnp.bfloat16,
)
