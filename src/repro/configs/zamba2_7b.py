"""zamba2-7b — Mamba2 backbone + shared attention block [arXiv:2411.15242].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
The shared attention block is applied every 6 SSM layers; it uses a 4k
sliding window so long_500k decode stays sub-quadratic (see DESIGN.md §4).
"""
import jax.numpy as jnp

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14_336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    attn_every=6,
    sliding_window=4096,
    dtype=jnp.bfloat16,
)
