"""SMLT's primary contribution: adaptive serverless ML training.

 - comm:        CommPlan IR — the communication schedule as a typed,
                transformable phase DAG shared by every cost layer
 - hier_sync:   hierarchical model synchronization on JAX collectives
 - bayes_opt:   GP + Expected Improvement deployment optimizer
 - scheduler:   training-dynamics-aware task scheduler
 - cost_model:  serverless + VM cost/time models
 - elastic:     on-the-fly worker-fleet rescaling for the real-JAX path
 - constraints: user-centric goals (deadline / budget)
 - probe_cache: memoized epoch_estimate/profile_cost probes for the BO
 - rng:         named deterministic RandomState streams
"""
from repro.core.bayes_opt import (  # noqa: F401
    GP, BayesianOptimizer, Config, ConfigSpace, expected_improvement)
from repro.core.comm import (  # noqa: F401
    CommPhase, CommPlan, CommSpec, build_plan)
from repro.core.constraints import Goal  # noqa: F401
from repro.core.hier_sync import (  # noqa: F401
    STRATEGIES, allreduce_mean, make_sync_grad_fn, ps_mean,
    scatter_reduce_mean, sync_grads, two_level_mean)
from repro.core.probe_cache import DEFAULT_CACHE, ProbeCache  # noqa: F401
from repro.core.rng import stream, stream_seed  # noqa: F401
from repro.core.scheduler import (  # noqa: F401
    EpochPlan, RunResult, TaskScheduler, TraceEvent)
