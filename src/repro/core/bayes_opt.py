"""Bayesian optimizer for deployment configuration search (paper Section 3.2).

Gaussian-Process regression posterior + Expected Improvement acquisition,
exactly as the paper specifies:

    EI(C_i) = (y_best - mu(C_i)) * Phi(gamma) + sigma(C_i) * phi(gamma)

(the paper's beta/theta are the standard normal CDF/PDF; y_max is "the
current lowest value from all explored tuples", i.e. minimization). The
search space is 2-D: number of workers (scale-out) x per-worker memory in MB
(scale-up, 128MB..10GB at 1MB granularity per AWS Lambda quotas).

Constrained goals (deadline / budget) use feasibility-weighted EI: a second
GP models the constraint metric and EI is multiplied by P(feasible).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np


def _norm_cdf(x):
    return 0.5 * (1.0 + np.vectorize(math.erf)(x / math.sqrt(2.0)))


def _norm_pdf(x):
    return np.exp(-0.5 * x * x) / math.sqrt(2.0 * math.pi)


class GP:
    """RBF-kernel GP regression with input scaling + output standardization."""

    def __init__(self, length_scale: float = 0.2, noise: float = 1e-4,
                 signal: float = 1.0):
        self.ls = length_scale
        self.noise = noise
        self.signal = signal
        self._fit = None

    def _k(self, A, B):
        d2 = ((A[:, None, :] - B[None, :, :]) ** 2).sum(-1)
        return self.signal * np.exp(-0.5 * d2 / self.ls ** 2)

    def fit(self, X: np.ndarray, y: np.ndarray):
        X = np.atleast_2d(np.asarray(X, float))
        y = np.asarray(y, float)
        self.ymu, self.ystd = y.mean(), max(y.std(), 1e-12)
        yn = (y - self.ymu) / self.ystd
        K = self._k(X, X) + self.noise * np.eye(len(X))
        self.L = np.linalg.cholesky(K)
        self.alpha = np.linalg.solve(self.L.T, np.linalg.solve(self.L, yn))
        self.X = X
        self._fit = True
        return self

    def predict(self, Xs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        Xs = np.atleast_2d(np.asarray(Xs, float))
        Ks = self._k(self.X, Xs)
        mu = Ks.T @ self.alpha
        v = np.linalg.solve(self.L, Ks)
        var = np.maximum(self._k(Xs, Xs).diagonal() - (v * v).sum(0), 1e-12)
        return mu * self.ystd + self.ymu, np.sqrt(var) * self.ystd


def expected_improvement(mu, sigma, y_best):
    """EI for minimization (paper's formula with y_best = lowest observed)."""
    gamma = (y_best - mu) / np.maximum(sigma, 1e-12)
    return (y_best - mu) * _norm_cdf(gamma) + sigma * _norm_pdf(gamma)


@dataclasses.dataclass(frozen=True)
class Config:
    """One deployment configuration
    C_i = <workers, memory[, fleet mix][, comm plan]>.

    ``small_frac`` is the searchable fleet-composition dimension: the
    fraction of the fleet deployed as a cheaper half-memory "small" tier
    (see ``repro.serverless.platform.fleet_from_config``). 0.0 keeps the
    paper's homogeneous 2-D space.

    ``comm``/``compress_ratio``/``branching``/``pipeline_depth`` are the
    searchable communication-plan dimensions
    (``repro.core.comm.CommSpec``): the aggregation strategy ("" keeps
    the scheduler's default scheme), the top-k wire ratio (1.0 = dense),
    the hier tree fan-in (0 = n/a), and the compute∥comm overlap depth
    (micro-batch segments; 1 = sequential).

    ``backend`` is the searchable execution target
    (``repro.serverless.backends.BACKENDS``): "" (or "serverless") keeps
    the native serverless path; "vm"/"gpu_vm" swap in provisioning
    delays, flat compute/NIC rates, and per-second billing."""
    workers: int
    memory_mb: int
    small_frac: float = 0.0
    comm: str = ""                     # "" | "ps" | "scatter_reduce" | "hier"
    compress_ratio: float = 1.0
    branching: int = 0
    pipeline_depth: int = 1
    backend: str = ""                  # "" | "serverless" | "vm" | "gpu_vm"

    _COMM_IDX = ("", "ps", "scatter_reduce", "hier")
    _BACKEND_IDX = ("serverless", "vm", "gpu_vm")

    def as_unit(self, space: "ConfigSpace") -> np.ndarray:
        return np.array([
            (self.workers - space.min_workers)
            / max(space.max_workers - space.min_workers, 1),
            (self.memory_mb - space.min_memory)
            / max(space.max_memory - space.min_memory, 1),
            self.small_frac,
            self._COMM_IDX.index(self.comm)
            / (len(self._COMM_IDX) - 1),
            # ratio on a log scale: 1.0 -> 0, 0.01 -> 1
            min(math.log10(1.0 / max(self.compress_ratio, 1e-4)) / 2.0, 1.0),
            0.0 if self.branching <= 0 else min(
                math.log2(self.branching) / 4.0, 1.0),
            # overlap depth on a log scale: 1 -> 0, 8 -> 1
            0.0 if self.pipeline_depth <= 1 else min(
                math.log2(self.pipeline_depth) / 3.0, 1.0),
            # backend as an ordinal ("" == serverless == 0)
            0.0 if self.backend == "" else (
                self._BACKEND_IDX.index(self.backend)
                / (len(self._BACKEND_IDX) - 1)),
        ])


@dataclasses.dataclass
class ConfigSpace:
    min_workers: int = 1
    max_workers: int = 200
    min_memory: int = 128
    max_memory: int = 10_240
    memory_step: int = 1           # 1 MB granularity (paper / Lambda quotas)
    # fleet composition: when True, candidates also draw a small-tier
    # fraction, letting the optimizer trade a cheaper mixed fleet against
    # the bsp barrier cost of its slowest workers
    search_fleet: bool = False
    small_frac_choices: Tuple[float, ...] = (0.0, 0.25, 0.5)
    # communication plan: when True, candidates also draw an aggregation
    # strategy, a top-k compression ratio, a hier-tree branching, and a
    # compute∥comm overlap depth — the optimizer trades wire bytes
    # against the convergence cost of sparsification
    # (constraints.compression_inflation) and hides pre-barrier uploads
    # under segmented compute (CommPlan.pipeline)
    search_comm: bool = False
    comm_choices: Tuple[str, ...] = ("scatter_reduce", "hier", "ps")
    ratio_choices: Tuple[float, ...] = (1.0, 0.1, 0.05, 0.01)
    branching_choices: Tuple[int, ...] = (2, 4, 8)
    depth_choices: Tuple[int, ...] = (1, 2, 4)
    # execution target: when True, candidates also draw a backend, so the
    # optimizer can arbitrage serverless elasticity against flat-rate
    # VM/GPU compute (the scheduler migrates on a backend change)
    search_backend: bool = False
    backend_choices: Tuple[str, ...] = ("serverless", "vm", "gpu_vm")

    def sample(self, rng: np.random.RandomState, n: int) -> List[Config]:
        ws = rng.randint(self.min_workers, self.max_workers + 1, size=n)
        ms = rng.randint(0, (self.max_memory - self.min_memory)
                         // self.memory_step + 1, size=n)
        if self.search_fleet:
            fr = [self.small_frac_choices[i] for i in
                  rng.randint(len(self.small_frac_choices), size=n)]
        else:
            fr = [0.0] * n
        if self.search_comm:
            cm = [self.comm_choices[i] for i in
                  rng.randint(len(self.comm_choices), size=n)]
            ra = [self.ratio_choices[i] for i in
                  rng.randint(len(self.ratio_choices), size=n)]
            br = [self.branching_choices[i] for i in
                  rng.randint(len(self.branching_choices), size=n)]
            dp = [self.depth_choices[i] for i in
                  rng.randint(len(self.depth_choices), size=n)]
        else:
            cm, ra, br, dp = [""] * n, [1.0] * n, [0] * n, [1] * n
        # drawn *after* every earlier dimension so existing search
        # configurations consume the rng stream identically (bit-identity)
        if self.search_backend:
            be = [self.backend_choices[i] for i in
                  rng.randint(len(self.backend_choices), size=n)]
        else:
            be = [""] * n
        return [Config(int(w), int(self.min_memory + m * self.memory_step),
                       float(f), c, float(r), int(b) if c == "hier" else 0,
                       int(d), e)
                for w, m, f, c, r, b, d, e in zip(ws, ms, fr, cm, ra, br,
                                                  dp, be)]


@dataclasses.dataclass
class Observation:
    config: Config
    objective: float
    constraint: Optional[float] = None  # metric compared against a threshold


class BayesianOptimizer:
    """Iterative GP+EI search; optionally constraint-aware."""

    def __init__(self, space: ConfigSpace, *,
                 constraint_limit: Optional[float] = None,
                 n_init: int = 3, n_candidates: int = 512, seed: int = 0,
                 ei_tolerance: float = 1e-3, max_iters: int = 20):
        self.space = space
        self.constraint_limit = constraint_limit
        self.n_init = n_init
        self.n_candidates = n_candidates
        from repro.core.rng import base_stream
        self.rng = base_stream(seed)
        self.ei_tolerance = ei_tolerance
        self.max_iters = max_iters
        self.obs: List[Observation] = []
        # unit-cube embedding per observation, computed once at observe
        # time: suggest() refits the GP on every call, and re-embedding
        # the whole history each time was the dominant non-GP cost
        self._X: List[np.ndarray] = []

    # -- bookkeeping ---------------------------------------------------------
    def observe(self, config: Config, objective: float,
                constraint: Optional[float] = None):
        self.obs.append(Observation(config, float(objective),
                                    None if constraint is None
                                    else float(constraint)))
        self._X.append(config.as_unit(self.space))

    def _feasible(self, o: Observation) -> bool:
        return (self.constraint_limit is None or o.constraint is None
                or o.constraint <= self.constraint_limit)

    def best(self) -> Optional[Observation]:
        feas = [o for o in self.obs if self._feasible(o)]
        pool = feas or self.obs
        return min(pool, key=lambda o: o.objective) if pool else None

    # -- acquisition ---------------------------------------------------------
    def suggest(self) -> Config:
        if len(self.obs) < self.n_init:
            return self.space.sample(self.rng, 1)[0]
        X = np.stack(self._X)
        y = np.array([o.objective for o in self.obs])
        gp = GP().fit(X, y)
        cands = self.space.sample(self.rng, self.n_candidates)
        Xc = np.stack([c.as_unit(self.space) for c in cands])
        best = self.best()
        mu, sig = gp.predict(Xc)
        acq = expected_improvement(mu, sig, best.objective)
        if (self.constraint_limit is not None
                and any(o.constraint is not None for o in self.obs)):
            yc = np.array([o.constraint for o in self.obs])
            gpc = GP().fit(X, yc)
            mc, sc = gpc.predict(Xc)
            p_feas = _norm_cdf((self.constraint_limit - mc)
                               / np.maximum(sc, 1e-12))
            acq = acq * p_feas
        return cands[int(np.argmax(acq))]

    def done(self) -> bool:
        if len(self.obs) >= self.max_iters:
            return True
        if len(self.obs) <= self.n_init + 1:
            return False
        recent = [o.objective for o in self.obs[-3:] if self._feasible(o)]
        best = self.best()
        if best is None or len(recent) < 3:
            return False
        span = max(recent) - min(recent)
        return span < self.ei_tolerance * max(abs(best.objective), 1e-9)
