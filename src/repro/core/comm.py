"""CommPlan: the communication schedule as a first-class IR.

The paper names communication as *the* serverless bottleneck and answers
it with a hierarchical ScatterReduce dataflow (Section 3.3, Fig. 5).
This module makes that schedule a typed, transformable object — one plan
that every cost-bearing layer consumes:

 - the **analytic model** (``repro.serverless.worker.iteration_time`` /
   ``repro.core.cost_model.epoch_estimate``) prices a plan in closed form
   with per-phase fan-in contention;
 - the **event engine** (``repro.serverless.events.EventEngine``)
   executes the same phases generically on contended ``SharedLink``s;
 - the **semantic path** (``LocalWorkerPool``) maps the plan's strategy
   to matching real-gradient numerics (shard aggregation, tree means,
   top-k + error-feedback sparse sync).

Phase DAG contract
------------------
A ``CommPlan`` is a linear per-iteration sequence of ``CommPhase``s; the
DAG edges are implicit: phase *i+1* depends on phase *i* for each worker,
and a ``barrier_after`` phase additionally joins **all** workers before
anyone proceeds (bsp only; ssp/async drop the joins). Each phase names:

 - ``store``: which store link it contends on ("param" | "object");
 - ``nbytes``: bytes moved by one (busiest) *participating* worker;
 - ``fan_in``: how many workers participate concurrently — both the
   closed-form contention divisor and the engine's participant count
   (workers ``0..fan_in-1`` execute the phase, the rest skip straight to
   its barrier — aggregators are relabeled to the lowest ids);
 - ``requests``: store round-trips (latency multiplier);
 - ``cpu_s``: post-transfer local work (e.g. densifying a sparse payload).

The symbolic payload shape (``units`` items of ``item_frac``·G each, each
aggregating ``item_inputs`` worker gradients) is what ``compress`` uses
to rewrite wire bytes without re-deriving the topology.

Strategies
----------
 - ``ps(G, n)``            — Cirrus-style central store: upload G,
                             download n·G (``store="object"`` is the
                             Siren-style S3 variant).
 - ``scatter_reduce(G, n)``— the paper's ScatterReduce (Fig. 5): shard →
                             aggregate → re-upload → gather; O(G) per
                             worker. Legacy scheme name: ``"hier"``.
 - ``hier(G, n, branching, levels)`` — a multi-level aggregation tree:
                             groups of ``branching`` reduce level by
                             level to one root, which re-uploads the
                             global aggregate; cuts the central store's
                             O(n·G) download to O(G) without sharding.

``compress(ratio)`` applies the top-k(+error-feedback) wire model of
``repro.core.compression``: a single worker's contribution costs
``2·ratio`` of dense (4B value + 4B index per kept entry); an aggregate
of j contributions densifies to ``min(1, j·ratio)``; every download of a
compressed payload pays a decompress (sparse scatter-add) CPU charge.

Overlap contract (``pipeline(depth)``)
--------------------------------------
``pipeline(depth)`` makes a plan *overlap-aware*: compute splits into
``depth`` micro-batch segments (gradient accumulation — the numerics are
unchanged), and the plan's **leading upload run** — the UL phases before
the first barrier or download, which move the worker's *own* gradient and
therefore exist per segment — is marked ``overlappable``. Every consumer
executes the same schedule:

 - segment *i*'s share (``nbytes / depth``, full ``requests`` round-trips)
   of each overlappable UL may hide under compute of segment *i+1*;
 - barrier semantics are preserved: a ``barrier_after`` on an overlappable
   phase joins all workers only after the **last** segment's upload —
   never per segment — and every post-barrier/download phase stays
   strictly sequential (its input is aggregated data, not local compute);
 - the closed form prices the iteration as
   ``max(compute, hidden comm) + exposed comm + bubble`` with
   ``bubble = min(compute, hidden comm) / depth`` — ``depth=1`` is
   byte-identical to the unpipelined plan, ``depth→∞`` hides
   ``min(compute, hidden comm)`` entirely;
 - store-busy (keep-alive billing) is *unchanged by overlap*: a hidden
   transfer still holds the store while it runs, so the billing basis is
   the transfer time itself, hidden or not — and it accrues **only for
   ``store == "param"`` phases** (an S3-path plan never bills the Redis
   container; see ``plan_times``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple, Union

# (4B value + 4B index) / 4B dense — the top-k wire overhead per kept entry
INDEX_OVERHEAD = 2.0
# sparse scatter-add rate when densifying a received compressed payload
DECOMPRESS_GBPS = 2.0


@dataclasses.dataclass(frozen=True)
class CommPhase:
    """One step of the per-iteration communication schedule.

    ``nbytes`` is always derivable as ``units * item_frac * G *
    wire_factor`` — constructors precompute it so consumers never touch
    the symbolic fields, while ``CommPlan.compress`` rewrites it."""
    name: str
    store: str                   # "param" | "object"
    nbytes: float                # bytes moved by one busiest participant
    requests: int = 1            # store round-trips -> latency multiplier
    barrier_after: bool = False  # bsp join of ALL workers (engine)
    fan_in: int = 1              # concurrently participating workers
    direction: str = "ul"        # "ul" (worker->store) | "dl" (store->worker)
    level: int = 0               # hierarchy level (0 = flat)
    cpu_s: float = 0.0           # post-transfer local work (decompress)
    # overlap (set by CommPlan.pipeline): this phase moves the worker's own
    # per-segment gradient, so segment i's share may hide under compute of
    # segment i+1. overlap_group records the phase's position within the
    # upload run (informational — consumers execute overlappable phases
    # in plan order)
    overlappable: bool = False
    overlap_group: int = 0
    # symbolic payload shape (used by compress):
    units: int = 1               # payload items moved by the busiest worker
    item_frac: float = 1.0       # dense size of one item, fraction of G
    item_inputs: int = 1         # worker gradients aggregated per item


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """A size-independent description of a communication schedule — what
    the Bayesian optimizer searches over and the scheduler deploys. Bind
    it to a workload/fleet with ``build_plan(spec, grad_bytes, n)``."""
    strategy: str = "scatter_reduce"   # "ps" | "scatter_reduce" | "hier"
    ratio: float = 1.0                 # top-k keep ratio; 1.0 = dense
    branching: int = 0                 # hier fan-in per node; 0 = default 4
    levels: int = 0                    # hier depth; 0 = full depth
    store: str = "param"               # ps only: "object" = S3 (Siren)
    pipeline_depth: int = 1            # micro-batch overlap segments; 1 = off

    def __post_init__(self):
        if self.strategy not in ("ps", "scatter_reduce", "hier"):
            raise ValueError(f"unknown comm strategy {self.strategy!r}")
        if not 0.0 < self.ratio <= 1.0:
            raise ValueError("compress ratio must be in (0, 1], "
                             f"got {self.ratio}")
        if self.pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1, "
                             f"got {self.pipeline_depth}")


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """A bound communication schedule for one (workload, fleet size)."""
    strategy: str
    n_workers: int
    grad_bytes: float            # G, including any extra upload bytes
    phases: Tuple[CommPhase, ...]
    ratio: float = 1.0
    branching: int = 0
    levels: int = 0
    pipeline_depth: int = 1      # micro-batch segments (1 = no overlap)

    @property
    def wire_bytes(self) -> float:
        """Fleet-wide bytes on the wire per iteration (all participants)."""
        return sum(ph.fan_in * ph.nbytes for ph in self.phases)

    @property
    def cpu_s(self) -> float:
        """Busiest worker's per-iteration post-transfer CPU time."""
        return sum(ph.cpu_s for ph in self.phases)

    @property
    def overlappable_phases(self) -> Tuple[CommPhase, ...]:
        """The leading upload run that may hide under segmented compute
        (empty unless ``pipeline_depth > 1``)."""
        return tuple(ph for ph in self.phases if ph.overlappable)

    def pipeline(self, depth: int) -> "CommPlan":
        """Overlap transform: split compute into ``depth`` micro-batch
        segments and mark the plan's leading upload run — the UL phases
        before the first barrier or download, which move the worker's own
        gradient — as overlappable with the *next* segment's compute.

        Barrier semantics are preserved: a ``barrier_after`` on an
        overlappable phase still joins all workers, but only once, after
        the last segment's upload; post-barrier phases (aggregate
        downloads, re-uploads) never overlap. ``depth=1`` rebuilds the
        sequential plan exactly (idempotent round-trip)."""
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        phases = []
        blocked = depth == 1
        group = 0
        for ph in self.phases:
            ov = (not blocked) and ph.direction == "ul"
            if ph.barrier_after or ph.direction == "dl":
                blocked = True
            phases.append(dataclasses.replace(
                ph, overlappable=ov, overlap_group=group if ov else 0))
            if ov:
                group += 1
        return dataclasses.replace(self, phases=tuple(phases),
                                   pipeline_depth=depth)

    def compress(self, ratio: float,
                 decompress_gbps: float = DECOMPRESS_GBPS) -> "CommPlan":
        """Top-k wire model: a raw contribution (``item_inputs == 1``)
        shrinks to ``INDEX_OVERHEAD * ratio`` of dense; an aggregate of j
        contributions densifies to ``min(1, j*ratio)``. Either factor is
        capped at dense — a sender whose sparse encoding would exceed the
        dense payload falls back to dense, so wire bytes are monotone in
        the keep ratio. Downloads of still-sparse payloads pay a
        decompress CPU charge. ``ratio=1.0`` rebuilds the dense plan
        (idempotent round-trip from any compressed plan)."""
        if not 0.0 < ratio <= 1.0:
            raise ValueError(f"compress ratio must be in (0, 1], got {ratio}")
        phases = []
        for ph in self.phases:
            factor = min(1.0, (INDEX_OVERHEAD * ratio if ph.item_inputs <= 1
                               else ph.item_inputs * ratio))
            nbytes = ph.units * ph.item_frac * self.grad_bytes * factor
            cpu = (nbytes / 1e9 / decompress_gbps
                   if ph.direction == "dl" and factor < 1.0 else 0.0)
            phases.append(dataclasses.replace(ph, nbytes=nbytes, cpu_s=cpu))
        return dataclasses.replace(self, phases=tuple(phases), ratio=ratio)


# ---------------------------------------------------------------------------
# strategy constructors
# ---------------------------------------------------------------------------


def ps(grad_bytes: float, n_workers: int, *,
       store: str = "param") -> CommPlan:
    """Central parameter store (Cirrus; ``store="object"`` = Siren/S3):
    every worker uploads its gradient, then downloads everyone's."""
    n, G = n_workers, grad_bytes
    phases = (
        CommPhase("UL-grad", store, G, 1, barrier_after=True, fan_in=n,
                  direction="ul", units=1, item_frac=1.0, item_inputs=1),
        CommPhase("DL-grad", store, n * G, 1, fan_in=n, direction="dl",
                  units=n, item_frac=1.0, item_inputs=1),
    )
    return CommPlan("ps", n, G, phases)


def scatter_reduce(grad_bytes: float, n_workers: int,
                   n_shards: Optional[int] = None) -> CommPlan:
    """The paper's ScatterReduce (Fig. 5): every worker uploads m shards,
    worker j aggregates shard j from all workers and re-uploads it, then
    everyone gathers the m aggregated shards — O(G) per worker."""
    n, G = n_workers, grad_bytes
    m = n_shards or n
    # each of the busiest aggregators owns ceil(m/n) shards; with m < n
    # the n-m idle workers don't help and the busy ones pull n*G/m
    # (paper footnote 4: "m less than n will cause some workers to be
    # idle during aggregation, which will affect performance")
    spa = max(math.ceil(m / n), 1)
    phases = (
        CommPhase("UL-Shard", "param", G, m, barrier_after=True, fan_in=n,
                  direction="ul", units=m, item_frac=1.0 / m, item_inputs=1),
        CommPhase("DL-Shard", "param", spa * n * (G / m), spa * n, fan_in=n,
                  direction="dl", units=spa * n, item_frac=1.0 / m,
                  item_inputs=1),
        CommPhase("UL-aggr", "param", spa * G / m, spa, barrier_after=True,
                  fan_in=n, direction="ul", units=spa, item_frac=1.0 / m,
                  item_inputs=n),
        CommPhase("DL-grad", "param", m * (G / m), m, fan_in=n,
                  direction="dl", units=m, item_frac=1.0 / m, item_inputs=n),
    )
    return CommPlan("scatter_reduce", n, G, phases)


def hier(grad_bytes: float, n_workers: int, *, branching: int = 4,
         levels: int = 0) -> CommPlan:
    """Multi-level aggregation tree: at level l, the surviving partial
    aggregates upload and groups of ``branching`` of them are pulled and
    reduced by one aggregator each, until a single root holds the global
    aggregate; the root re-uploads it and everyone downloads O(G).

    ``levels`` caps the explicit depth (0 = full ``ceil(log_b n)``); a
    shallower tree makes the last level's aggregator pull everything
    that is left — levels=1 degenerates to a single reducing root."""
    n, G = n_workers, grad_bytes
    b = max(branching, 2)
    full = max(math.ceil(math.log(n, b)), 1) if n > 1 else 0
    L = min(levels, full) if levels > 0 else full
    phases: List[CommPhase] = []
    m_prev = n
    for lvl in range(1, L + 1):
        m = 1 if lvl == L else max(math.ceil(m_prev / b), 1)
        per_agg = math.ceil(m_prev / m)
        inputs = max(math.ceil(n / m_prev), 1)   # grads per uploaded partial
        phases.append(CommPhase(
            f"UL-l{lvl}", "param", G, 1, barrier_after=True, fan_in=m_prev,
            direction="ul", level=lvl, units=1, item_frac=1.0,
            item_inputs=inputs))
        phases.append(CommPhase(
            f"DL-l{lvl}", "param", per_agg * G, per_agg, fan_in=m,
            direction="dl", level=lvl, units=per_agg, item_frac=1.0,
            item_inputs=inputs))
        m_prev = m
    phases.append(CommPhase(
        "UL-root", "param", G, 1, barrier_after=True, fan_in=1,
        direction="ul", level=L + 1, units=1, item_frac=1.0, item_inputs=n))
    phases.append(CommPhase(
        "DL-grad", "param", G, 1, fan_in=n, direction="dl", level=L + 1,
        units=1, item_frac=1.0, item_inputs=n))
    return CommPlan("hier", n, G, tuple(phases), branching=b, levels=L)


_BUILDERS = {"ps": ps, "scatter_reduce": scatter_reduce, "hier": hier}

# legacy scheme strings (the paper called its ScatterReduce dataflow
# "hierarchical", hence the historical "hier" alias for scatter_reduce)
_SCHEME_ALIASES = {
    "hier": CommSpec("scatter_reduce"),
    "scatter_reduce": CommSpec("scatter_reduce"),
    "ps": CommSpec("ps"),
    "ps_s3": CommSpec("ps", store="object"),
}


def parse_scheme(scheme: str, topk_ratio: float = 0.05) -> CommSpec:
    """Map a legacy scheme string to its ``CommSpec``."""
    if scheme in _SCHEME_ALIASES:
        return _SCHEME_ALIASES[scheme]
    if scheme == "hier_topk":
        return CommSpec("scatter_reduce", ratio=topk_ratio)
    raise ValueError(f"unknown comm scheme {scheme!r}")


CommLike = Union[str, CommSpec, CommPlan]


def build_plan(comm: CommLike, grad_bytes: float, n_workers: int,
               n_shards: Optional[int] = None,
               extra_upload_bytes: float = 0.0,
               topk_ratio: float = 0.05) -> CommPlan:
    """Resolve a scheme string / ``CommSpec`` / prebuilt ``CommPlan`` into
    the bound plan for this (workload, fleet size)."""
    G = grad_bytes + extra_upload_bytes
    if isinstance(comm, CommPlan):
        if comm.n_workers != n_workers:
            raise ValueError(f"plan built for n={comm.n_workers}, "
                             f"deployment has n={n_workers}")
        if not math.isclose(comm.grad_bytes, G, rel_tol=1e-9):
            raise ValueError(f"plan built for G={comm.grad_bytes:.0f} bytes,"
                             f" workload moves {G:.0f} (incl. extra upload)")
        return comm
    if isinstance(comm, str):
        comm = parse_scheme(comm, topk_ratio)
    if comm.strategy == "ps":
        plan = ps(G, n_workers, store=comm.store)
    elif comm.strategy == "scatter_reduce":
        plan = scatter_reduce(G, n_workers, n_shards=n_shards)
    else:
        plan = hier(G, n_workers, branching=comm.branching or 4,
                    levels=comm.levels)
    if comm.ratio < 1.0:
        plan = plan.compress(comm.ratio)
    if comm.pipeline_depth > 1:
        plan = plan.pipeline(comm.pipeline_depth)
    return plan


# ---------------------------------------------------------------------------
# closed-form pricing (the analytic path's view of a plan)
# ---------------------------------------------------------------------------


def phase_time(ph: CommPhase, param_store, object_store,
               fn_bw_gbps: float, segments: int = 1) -> float:
    """One phase's closed-form seconds: per-request latency plus bytes at
    ``min(function pipe, store aggregate / fan_in)`` — the fan-in is the
    static contention divisor (the event engine relaxes it to *actual*
    overlap on the ``SharedLink``). With ``segments > 1`` (a pipelined
    overlappable phase) the payload moves as that many sub-transfers: the
    bytes term is unchanged, the per-request latency is paid once per
    segment."""
    s = max(segments, 1)
    if ph.store == "param":
        one = (param_store.xfer_time(ph.nbytes / s, concurrent=ph.fan_in,
                                     per_fn_gbps=fn_bw_gbps)
               + param_store.latency_s * max(ph.requests - 1, 0))
    else:
        one = (object_store.put_time(ph.nbytes / s, concurrent=ph.fan_in)
               + object_store.latency_s * max(ph.requests - 1, 0))
    return one * s


def plan_times(plan: CommPlan, param_store, object_store,
               fn_bw_gbps: float) -> Tuple[Dict[str, float], float]:
    """-> (per-phase seconds incl. decompress CPU, store-busy seconds).

    The second value is the time the **param store** is actually held by
    transfers — the keep-alive billing basis. Only ``store == "param"``
    phases accrue it: an object-store phase (the Siren-style ``ps_s3``
    plan) never holds the Redis container, so billing it there would
    charge for a store the plan does not touch. Decompress CPU runs on
    the worker with no store outstanding, so it is in the phase times
    (wall clock) but **not** in store-busy. Overlappable phases of a
    pipelined plan are priced as ``pipeline_depth`` sub-transfers;
    hiding them under compute changes the *iteration* wall-clock (see
    ``overlap_iteration_time``), never the store-busy seconds — a hidden
    transfer still holds the store while it runs."""
    out: Dict[str, float] = {}
    busy = 0.0
    for ph in plan.phases:
        t = phase_time(ph, param_store, object_store, fn_bw_gbps,
                       segments=plan.pipeline_depth if ph.overlappable else 1)
        if ph.store == "param":
            busy += t
        out[ph.name] = t + ph.cpu_s
    return out, busy


def overlap_iteration_time(compute_s: float, hidden_comm_s: float,
                           exposed_comm_s: float,
                           depth: int) -> Dict[str, float]:
    """Closed-form pipelined iteration: compute runs as ``depth``
    back-to-back segments of ``compute_s / depth``; segment *i*'s share
    of the overlappable uploads starts once segment *i* lands and queues
    behind segment *i-1*'s share. The last upload therefore completes at

        ``max(compute, hidden) + min(compute, hidden) / depth``

    (a fill/drain bubble of one segment of the shorter side), after
    which the exposed phases run sequentially. ``depth=1`` degenerates
    to the fully sequential ``compute + hidden + exposed``."""
    c, u = compute_s, hidden_comm_s
    d = max(depth, 1)
    window = max(c, u) + min(c, u) / d
    return {"total": window + exposed_comm_s,
            "bubble": min(c, u) / d if d > 1 else 0.0,
            "comm_hidden": (c + u) - window,
            "comm_exposed": exposed_comm_s + (window - c)}
