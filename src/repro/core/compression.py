"""Gradient compression for the serverless synchronization path
(beyond-paper: the paper identifies communication as THE serverless
bottleneck; top-k sparsification with error feedback attacks the bytes
directly, on top of the hierarchical schedule).

Top-k + error feedback (Stich et al., "Sparsified SGD with memory"):
each worker uploads only the k largest-magnitude gradient entries and
keeps the residual locally; the residual is added to the next step's
gradient, preserving convergence. Wire bytes per worker drop from 4·|G|
to ~8·k (value + index), i.e. ratio/2 of dense for k = ratio·|G|.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.comm import CommSpec
from repro.serverless.worker import LocalWorkerPool


def topk_compress(flat: np.ndarray, ratio: float) -> Tuple[np.ndarray,
                                                           np.ndarray]:
    """-> (indices int32, values f32) of the k = ratio*len largest-|.|."""
    k = max(int(len(flat) * ratio), 1)
    idx = np.argpartition(np.abs(flat), -k)[-k:].astype(np.int32)
    return idx, flat[idx]


def topk_decompress(idx: np.ndarray, vals: np.ndarray,
                    size: int) -> np.ndarray:
    out = np.zeros(size, np.float32)
    out[idx] = vals
    return out


def compressed_bytes(size: int, ratio: float) -> float:
    k = max(int(size * ratio), 1)
    return 8.0 * k  # 4B value + 4B index


@dataclasses.dataclass
class ErrorFeedback:
    """Per-worker residual memory."""
    residual: np.ndarray

    @classmethod
    def init(cls, size: int) -> "ErrorFeedback":
        return cls(np.zeros(size, np.float32))

    def compress(self, flat: np.ndarray, ratio: float):
        corrected = flat + self.residual
        idx, vals = topk_compress(corrected, ratio)
        sent = topk_decompress(idx, vals, len(flat))
        self.residual = corrected - sent
        return idx, vals


class CompressedWorkerPool(LocalWorkerPool):
    """Back-compat shim, folded into ``LocalWorkerPool(plan=...)``: a
    pool whose plan is a compressed central-store schedule — workers
    upload top-k sparse gradients with error feedback and the aggregator
    sums the sparse contributions (``LocalWorkerPool._step_compressed``).
    At ``ratio=1.0`` the plan is dense and the pool degenerates to the
    exact ps mean. Same param-store interfaces, so bytes are accounted."""

    def __init__(self, grad_fn, n_workers: int, param_store, *,
                 ratio: float = 0.05):
        super().__init__(grad_fn, n_workers, param_store,
                         plan=CommSpec("ps", ratio=ratio))
        self.ratio = ratio
