"""Gradient compression for the serverless synchronization path
(beyond-paper: the paper identifies communication as THE serverless
bottleneck; top-k sparsification with error feedback attacks the bytes
directly, on top of the hierarchical schedule).

Top-k + error feedback (Stich et al., "Sparsified SGD with memory"):
each worker uploads only the k largest-magnitude gradient entries and
keeps the residual locally; the residual is added to the next step's
gradient, preserving convergence. Wire bytes per worker drop from 4·|G|
to ~8·k (value + index), i.e. ratio/2 of dense for k = ratio·|G|.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def topk_compress(flat: np.ndarray, ratio: float) -> Tuple[np.ndarray,
                                                           np.ndarray]:
    """-> (indices int32, values f32) of the k = ratio*len largest-|.|."""
    k = max(int(len(flat) * ratio), 1)
    idx = np.argpartition(np.abs(flat), -k)[-k:].astype(np.int32)
    return idx, flat[idx]


def topk_decompress(idx: np.ndarray, vals: np.ndarray,
                    size: int) -> np.ndarray:
    out = np.zeros(size, np.float32)
    out[idx] = vals
    return out


def compressed_bytes(size: int, ratio: float) -> float:
    k = max(int(size * ratio), 1)
    return 8.0 * k  # 4B value + 4B index


@dataclasses.dataclass
class ErrorFeedback:
    """Per-worker residual memory."""
    residual: np.ndarray

    @classmethod
    def init(cls, size: int) -> "ErrorFeedback":
        return cls(np.zeros(size, np.float32))

    def compress(self, flat: np.ndarray, ratio: float):
        corrected = flat + self.residual
        idx, vals = topk_compress(corrected, ratio)
        sent = topk_decompress(idx, vals, len(flat))
        self.residual = corrected - sent
        return idx, vals


class CompressedWorkerPool:
    """LocalWorkerPool variant: workers upload top-k sparse gradients with
    error feedback; the aggregator sums sparse contributions. Uses the same
    param store interfaces so bytes are accounted."""

    def __init__(self, grad_fn, n_workers: int, param_store, *,
                 ratio: float = 0.05):
        from repro.serverless.worker import flatten_grads, unflatten_grads
        self._flatten = flatten_grads
        self._unflatten = unflatten_grads
        self.grad_fn = grad_fn
        self.n = n_workers
        self.store = param_store
        self.ratio = ratio
        self._ef: Dict[int, ErrorFeedback] = {}

    def step(self, params, global_batch):
        n = self.n
        size = None
        g_like = None
        for w in range(n):
            sl = jax.tree.map(
                lambda x: x[w * (x.shape[0] // n):(w + 1) * (x.shape[0] // n)],
                global_batch)
            g = self.grad_fn(params, sl)
            flat = self._flatten(g)
            size, g_like = len(flat), g
            if w not in self._ef:
                self._ef[w] = ErrorFeedback.init(size)
            idx, vals = self._ef[w].compress(flat, self.ratio)
            nbytes = compressed_bytes(size, self.ratio)
            self.store.put(f"sparse/{w}", (idx, vals), nbytes=nbytes)
        acc = np.zeros(size, np.float32)
        for w in range(n):
            idx, vals = self.store.get(
                f"sparse/{w}", nbytes=compressed_bytes(size, self.ratio))
            acc[idx] += vals
        return self._unflatten(acc / n, g_like)
