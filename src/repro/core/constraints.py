"""User-centric deployment goals (paper Section 3.2, Scenarios 1 & 2)."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

# Convergence cost of stale gradients, as a fractional increase in the
# iterations needed to reach the same loss per unit of staleness bound
# (SSP analyses bound the error term linearly in the staleness; MLLess-style
# significance filters eat some of it, hence a small default slope).
SSP_PENALTY_PER_STEP = 0.02

# Convergence cost of top-k sparsification, as a fractional increase in
# iterations per decade of compression (error feedback keeps top-k SGD at
# the dense rate up to a residual term that grows as the keep ratio
# shrinks — Stich et al.; a 100x compression pays ~2 decades).
COMPRESSION_PENALTY_PER_DECADE = 0.08


def compression_inflation(ratio: float,
                          per_decade: float = COMPRESSION_PENALTY_PER_DECADE
                          ) -> float:
    """Multiplicative iteration-count inflation of a top-k keep ratio:
    dense (ratio >= 1) pays none; smaller ratios pay per decade of
    dropped mass. The Bayesian optimizer multiplies a candidate's
    predicted time *and* cost by this (exactly as
    ``staleness_inflation``), so a searched ``compress_ratio`` is judged
    on convergence-inflated totals, not just its cheaper wire bytes."""
    if ratio >= 1.0:
        return 1.0
    return 1.0 + per_decade * math.log10(1.0 / max(ratio, 1e-6))


def staleness_inflation(sync_mode: str, staleness: int = 0,
                        n_workers: int = 1,
                        per_step: float = SSP_PENALTY_PER_STEP) -> float:
    """Multiplicative iteration-count inflation of a sync mode: bsp pays
    none; ssp(k) pays ``1 + per_step * k``; async has no bound, so its
    expected staleness is taken as the worst-case n-1 peers in flight.

    The Bayesian optimizer multiplies a candidate's predicted time *and*
    cost by this factor, so a ``Goal`` trade-off reflects convergence cost
    (more iterations to the same loss), not just the cheaper barrier-free
    wall-clock of one epoch."""
    from repro.serverless.worker import parse_sync_mode
    mode, k = parse_sync_mode(sync_mode, staleness)
    if mode == "bsp":
        return 1.0
    if mode == "ssp":
        return 1.0 + per_step * max(k, 0)
    return 1.0 + per_step * max(n_workers - 1, 0)      # async


@dataclasses.dataclass(frozen=True)
class Goal:
    """What the user asked SMLT to optimize.

    kinds:
      "min_cost_deadline" — minimize $ s.t. training time <= deadline_s  (Scenario 1)
      "min_time_budget"   — minimize time s.t. $ <= budget_usd            (Scenario 2)
      "min_time"          — as fast as possible
      "min_cost"          — as cheap as possible
    """
    kind: str
    deadline_s: Optional[float] = None
    budget_usd: Optional[float] = None

    def objective_and_constraint(self, time_s: float, cost_usd: float,
                                 inflation: float = 1.0):
        """-> (objective value, constraint value or None, limit or None).

        ``inflation`` is the ssp-aware staleness penalty
        (``staleness_inflation``): the predicted epochs-to-converge scale
        by it, so both the time and the dollars a candidate config is
        judged on grow with its staleness bound."""
        time_s = time_s * inflation
        cost_usd = cost_usd * inflation
        if self.kind == "min_cost_deadline":
            return cost_usd, time_s, self.deadline_s
        if self.kind == "min_time_budget":
            return time_s, cost_usd, self.budget_usd
        if self.kind == "min_time":
            return time_s, None, None
        if self.kind == "min_cost":
            return cost_usd, None, None
        raise ValueError(self.kind)
