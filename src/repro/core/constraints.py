"""User-centric deployment goals (paper Section 3.2, Scenarios 1 & 2)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Goal:
    """What the user asked SMLT to optimize.

    kinds:
      "min_cost_deadline" — minimize $ s.t. training time <= deadline_s  (Scenario 1)
      "min_time_budget"   — minimize time s.t. $ <= budget_usd            (Scenario 2)
      "min_time"          — as fast as possible
      "min_cost"          — as cheap as possible
    """
    kind: str
    deadline_s: Optional[float] = None
    budget_usd: Optional[float] = None

    def objective_and_constraint(self, time_s: float, cost_usd: float):
        """-> (objective value, constraint value or None, limit or None)."""
        if self.kind == "min_cost_deadline":
            return cost_usd, time_s, self.deadline_s
        if self.kind == "min_time_budget":
            return time_s, cost_usd, self.budget_usd
        if self.kind == "min_time":
            return time_s, None, None
        if self.kind == "min_cost":
            return cost_usd, None, None
        raise ValueError(self.kind)
