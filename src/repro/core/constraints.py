"""User-centric deployment goals (paper Section 3.2, Scenarios 1 & 2)."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

# Convergence cost of stale gradients, as a fractional increase in the
# iterations needed to reach the same loss per unit of staleness bound
# (SSP analyses bound the error term linearly in the staleness; MLLess-style
# significance filters eat some of it, hence a small default slope).
SSP_PENALTY_PER_STEP = 0.02

# Convergence cost of top-k sparsification, as a fractional increase in
# iterations per decade of compression (error feedback keeps top-k SGD at
# the dense rate up to a residual term that grows as the keep ratio
# shrinks — Stich et al.; a 100x compression pays ~2 decades).
COMPRESSION_PENALTY_PER_DECADE = 0.08


def compression_inflation(ratio: float,
                          per_decade: float = COMPRESSION_PENALTY_PER_DECADE
                          ) -> float:
    """Multiplicative iteration-count inflation of a top-k keep ratio:
    dense (ratio >= 1) pays none; smaller ratios pay per decade of
    dropped mass. The Bayesian optimizer multiplies a candidate's
    predicted time *and* cost by this (exactly as
    ``staleness_inflation``), so a searched ``compress_ratio`` is judged
    on convergence-inflated totals, not just its cheaper wire bytes."""
    if ratio >= 1.0:
        return 1.0
    return 1.0 + per_decade * math.log10(1.0 / max(ratio, 1e-6))


def preemption_inflation(hazard_per_s: float,
                         ckpt_write_s: float = 2.0) -> float:
    """Multiplicative wall/cost inflation of running on a preemptible
    (spot) backend, at the hazard-aware Young–Daly checkpoint cadence
    ``tau* = sqrt(2 * ckpt_write_s / hazard)``: the checkpoint overhead
    ``ckpt/tau*`` plus the expected rework ``hazard * tau* / 2`` sum to
    ``sqrt(2 * hazard * ckpt_write_s)``. The Bayesian optimizer
    multiplies a spot candidate's predicted time and dollars by this, so
    the discount race against on-demand is judged net of preemptions."""
    if hazard_per_s <= 0.0 or ckpt_write_s <= 0.0:
        return 1.0
    return 1.0 + math.sqrt(2.0 * hazard_per_s * ckpt_write_s)


def staleness_inflation(sync_mode: str, staleness: int = 0,
                        n_workers: int = 1,
                        per_step: float = SSP_PENALTY_PER_STEP) -> float:
    """Multiplicative iteration-count inflation of a sync mode: bsp pays
    none; ssp(k) pays ``1 + per_step * k``; async has no bound, so its
    expected staleness is taken as the worst-case n-1 peers in flight.

    The Bayesian optimizer multiplies a candidate's predicted time *and*
    cost by this factor, so a ``Goal`` trade-off reflects convergence cost
    (more iterations to the same loss), not just the cheaper barrier-free
    wall-clock of one epoch."""
    from repro.serverless.worker import parse_sync_mode
    mode, k = parse_sync_mode(sync_mode, staleness)
    if mode == "bsp":
        return 1.0
    if mode == "ssp":
        return 1.0 + per_step * max(k, 0)
    return 1.0 + per_step * max(n_workers - 1, 0)      # async


@dataclasses.dataclass(frozen=True)
class Goal:
    """What the user asked SMLT to optimize.

    kinds:
      "min_cost_deadline" — minimize $ s.t. training time <= deadline_s  (Scenario 1)
      "min_time_budget"   — minimize time s.t. $ <= budget_usd            (Scenario 2)
      "min_time"          — as fast as possible
      "min_cost"          — as cheap as possible
      "deadline_budget"   — minimize time s.t. time <= deadline_s AND
                            $ <= budget_usd — the workflow-level goal one
                            ``BudgetAllocator`` splits across a task DAG,
                            and the per-task grant it hands each task

    Constrained kinds validate their limit at construction: a
    "min_cost_deadline" without a deadline (or "deadline_budget" missing
    either limit) is a configuration bug, not a free-running goal.
    """
    kind: str
    deadline_s: Optional[float] = None
    budget_usd: Optional[float] = None

    _REQUIRED = {"min_cost_deadline": ("deadline_s",),
                 "min_time_budget": ("budget_usd",),
                 "deadline_budget": ("deadline_s", "budget_usd"),
                 "min_time": (), "min_cost": ()}

    def __post_init__(self):
        if self.kind not in self._REQUIRED:
            raise ValueError(f"unknown goal kind: {self.kind!r}")
        for field in self._REQUIRED[self.kind]:
            limit = getattr(self, field)
            if limit is None:
                raise ValueError(f"goal kind {self.kind!r} requires {field}")
            if limit <= 0:
                raise ValueError(f"goal {field} must be positive, "
                                 f"got {limit}")

    def objective_and_constraint(self, time_s: float, cost_usd: float,
                                 inflation: float = 1.0):
        """-> (objective value, constraint value or None, limit or None).

        ``inflation`` is the ssp-aware staleness penalty
        (``staleness_inflation``): the predicted epochs-to-converge scale
        by it, so both the time and the dollars a candidate config is
        judged on grow with its staleness bound.

        "deadline_budget" carries two limits; its constraint is the
        *binding* one, normalized — ``max(time/deadline, cost/budget)``
        against a limit of 1.0 — so the constrained-EI machinery needs no
        second constraint GP."""
        time_s = time_s * inflation
        cost_usd = cost_usd * inflation
        if self.kind == "min_cost_deadline":
            return cost_usd, time_s, self.deadline_s
        if self.kind == "min_time_budget":
            return time_s, cost_usd, self.budget_usd
        if self.kind == "deadline_budget":
            return time_s, max(time_s / self.deadline_s,
                               cost_usd / self.budget_usd), 1.0
        if self.kind == "min_time":
            return time_s, None, None
        if self.kind == "min_cost":
            return cost_usd, None, None
        raise ValueError(self.kind)
