"""Monetary cost + wall-time estimation (the S_B(C) / T_B(C) of Section 3.2).

Covers the serverless deployment (Lambda + S3 + Redis-on-ECS), the
profiling runs the Bayesian optimizer pays for, and the VM baselines the
paper compares against (IaaS and MLCD-style VM platforms).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core.bayes_opt import Config
from repro.serverless.backends import BackendLike, BackendSpec, resolve_backend
from repro.serverless.platform import (  # noqa: F401  (re-exported names)
    CHECKPOINT_RESTORE_S, DATA_OBJECT_BYTES, LAMBDA_GB_SECOND,
    LAMBDA_MAX_DURATION_S, LAMBDA_PER_REQUEST, FleetSpec, fleet_from_config)
from repro.serverless.stores import ObjectStore, ParamStore
from repro.core.comm import CommLike
from repro.serverless.worker import Workload, iteration_time


def _config_fleet(config: Config,
                  fleet: Optional[FleetSpec]) -> Optional[FleetSpec]:
    """Resolve the deployment's fleet: an explicit ``fleet`` wins; a config
    with a searched fleet composition (``small_frac > 0``) expands to its
    mixed fleet; a plain homogeneous config stays on the exact closed form
    (fleet=None)."""
    if fleet is not None:
        return fleet
    if getattr(config, "small_frac", 0.0) > 0.0:
        return fleet_from_config(config.workers, config.memory_mb,
                                 config.small_frac)
    return None


def _config_backend(config: Config,
                    backend: BackendLike) -> Optional[BackendSpec]:
    """Resolve the deployment's backend: an explicit ``backend`` wins; a
    config with a searched backend (``config.backend``) resolves through
    the registry; plain serverless stays on the exact legacy closed form
    (None)."""
    if backend is not None:
        return resolve_backend(backend)
    return resolve_backend(getattr(config, "backend", ""))


@dataclasses.dataclass
class EpochEstimate:
    wall_s: float
    lambda_usd: float
    store_usd: float
    iters: int
    it_breakdown: Dict[str, float]
    restarts_per_worker: int
    global_batch: int = 0        # samples per iteration (throughput basis)
    backend_usd: float = 0.0     # per-second VM/GPU compute dollars

    @property
    def cost_usd(self) -> float:
        return self.lambda_usd + self.store_usd + self.backend_usd

    @property
    def throughput(self) -> float:  # samples / s
        return 0.0 if self.wall_s == 0 else (
            self.iters * self.global_batch / self.wall_s)


def epoch_estimate(w: Workload, scheme: CommLike, config: Config,
                   global_batch: int, param_store: ParamStore,
                   object_store: ObjectStore, *,
                   framework_init_s: float = 4.0,
                   cold_start_s: float = 2.0,
                   max_duration_s: float = LAMBDA_MAX_DURATION_S,
                   samples: Optional[int] = None,
                   fleet: Optional[FleetSpec] = None,
                   backend: BackendLike = None) -> EpochEstimate:
    """Analytic time+cost of one epoch under deployment ``config``.

    A heterogeneous ``fleet`` (explicit, or implied by
    ``config.small_frac``) switches iteration costing to the mixed-memory
    approximation (weighted-harmonic compute, min-bandwidth sync; see
    ``iteration_time``) and bills GB-seconds at each worker's own memory —
    cheap enough for the Bayesian optimizer to probe fleet compositions.

    A VM-kind ``backend`` (explicit, or implied by ``config.backend``)
    swaps the execution semantics: provisioning delay replaces the cold
    start, there is no duration cap (so no cap restarts), and billing is
    per-second per worker from the end of provisioning (no GB-second or
    per-request fee); spot tiers bill at the price trace's time-average
    rate. Store billing is unchanged — VM workers synchronize through
    the same stores."""
    spec = _config_backend(config, backend)
    fleet = _config_fleet(config, fleet)
    n, mem = config.workers, config.memory_mb
    if fleet is not None:
        # an explicit fleet wins over the config shape: n (and total_mem
        # below) come from it; iteration_time resolves per-worker memory
        # from the fleet itself
        n = len(fleet)
    samples = samples or w.dataset_samples
    iters = max(math.ceil(samples / global_batch), 1)
    it = iteration_time(w, scheme, n, mem, global_batch, param_store,
                        object_store, fleet=fleet, backend=spec)

    # duration-cap restarts (Section 4.1): amortize init across a full
    # window. The per-epoch data fetch runs inside the *first*
    # invocation's usable window (the engine arms the cap before the
    # fetch), so it counts against the first window's budget — a
    # compute load that alone fits one window can still restart once
    # the fetch is folded in. Uncapped VM backends never restart.
    if spec is None:
        init_s = cold_start_s + framework_init_s
        usable = max_duration_s - init_s - CHECKPOINT_RESTORE_S
    else:
        init_s = spec.provision_s + framework_init_s
        usable = math.inf
    epoch_compute_s = iters * it["total"]

    # per-epoch data fetch from the object store (data iterator, Section 4.2)
    shard_bytes = w.sample_bytes * samples / n
    data_fetch_s = object_store.get_time(shard_bytes, concurrent=n)
    n_objects = max(math.ceil(w.sample_bytes * samples / DATA_OBJECT_BYTES), 1)

    invocations_per_worker = max(
        math.ceil((epoch_compute_s + data_fetch_s) / usable), 1)
    restart_overhead = (invocations_per_worker - 1) * (init_s + CHECKPOINT_RESTORE_S)

    wall = epoch_compute_s + restart_overhead + init_s + data_fetch_s

    total_mem = fleet.total_memory_mb if fleet is not None else n * mem
    if spec is None:
        lambda_usd = (total_mem / 1024.0 * wall * LAMBDA_GB_SECOND
                      + n * invocations_per_worker * LAMBDA_PER_REQUEST)
        backend_usd = 0.0
    else:
        # per-second billing arms when provisioning+init completes (the
        # engine's billing anchor), so the billed window is wall - init_s
        lambda_usd = 0.0
        backend_usd = n * (wall - init_s) * spec.expected_usd_per_s
    # param store billed only while synchronization is actually holding
    # it (Section 4.3): the plan's per-phase store-busy time — re-upload
    # fan-in levels included, decompress CPU excluded — so billing stays
    # in parity with the event engine's keep-alive window for every
    # strategy
    sync_s = iters * it["store_busy"]
    store_hourly = (param_store.vcpus * 0.04048
                    + param_store.memory_gb * 0.004445)
    store_usd = sync_s / 3600.0 * store_hourly
    s3_usd = (n_objects * 0.0004 / 1000.0) * n  # GETs per epoch
    return EpochEstimate(wall_s=wall, lambda_usd=lambda_usd,
                         store_usd=store_usd + s3_usd, iters=iters,
                         it_breakdown=it,
                         restarts_per_worker=invocations_per_worker - 1,
                         global_batch=global_batch,
                         backend_usd=backend_usd)


def profile_cost(w: Workload, scheme: CommLike, config: Config,
                 global_batch: int,
                 param_store: ParamStore, object_store: ObjectStore,
                 profile_iters: int = 3, *, framework_init_s: float = 4.0,
                 cold_start_s: float = 2.0,
                 fleet: Optional[FleetSpec] = None,
                 backend: BackendLike = None):
    """Time+cost of one Bayesian-optimizer profiling probe (k iterations).

    The deployment an explicit ``fleet=`` describes *wins* over the
    config's ``(workers, memory_mb)``: n, per-iteration times, and the
    billed memory all resolve from the fleet, so a probe of a fleet
    whose shape differs from the config never mixes the two. A VM-kind
    ``backend`` prices the probe at its per-second rate (provisioning
    replaces the cold start, no request fee)."""
    spec = _config_backend(config, backend)
    fleet = _config_fleet(config, fleet)
    n = len(fleet) if fleet is not None else config.workers
    mem = (fleet.memories[0] if fleet is not None and fleet.is_homogeneous
           else config.memory_mb)
    it = iteration_time(w, scheme, n, mem, global_batch, param_store,
                        object_store, fleet=fleet, backend=spec)
    total_mem = (fleet.total_memory_mb if fleet is not None
                 else n * config.memory_mb)
    if spec is None:
        wall = cold_start_s + framework_init_s + profile_iters * it["total"]
        usd = (total_mem / 1024.0 * wall * LAMBDA_GB_SECOND
               + n * LAMBDA_PER_REQUEST)
    else:
        wall = spec.provision_s + framework_init_s + profile_iters * it["total"]
        usd = n * profile_iters * it["total"] * spec.expected_usd_per_s
    return wall, usd, it


# ---------------------------------------------------------------------------
# VM baselines (IaaS / MLCD) for Figs. 9-11
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VmType:
    name: str
    vcpus: int
    usd_hour: float
    gflops: float
    net_gbps: float


VM_TYPES = {
    "c5.2xlarge": VmType("c5.2xlarge", 8, 0.34, 8 * 45.0, 1.25),
    "c5.4xlarge": VmType("c5.4xlarge", 16, 0.68, 16 * 45.0, 1.25),
    "c5.9xlarge": VmType("c5.9xlarge", 36, 1.53, 36 * 45.0, 1.5),
}


def vm_epoch_estimate(w: Workload, vm: VmType, n_vms: int, global_batch: int,
                      samples: Optional[int] = None):
    """Ring-allreduce data-parallel training on VMs (the IaaS baseline)."""
    samples = samples or w.dataset_samples
    iters = max(math.ceil(samples / global_batch), 1)
    local = max(global_batch // n_vms, 1)
    comp = w.flops_per_sample * local / (vm.gflops * 1e9)
    # ring allreduce: 2*(n-1)/n * G bytes over the NIC
    comm = 2 * (n_vms - 1) / max(n_vms, 1) * w.grad_bytes / (vm.net_gbps / 8 * 1e9)
    wall = iters * (comp + comm)
    usd = n_vms * vm.usd_hour * wall / 3600.0
    return wall, usd
