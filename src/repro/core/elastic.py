"""Elastic rescaling (paper Section 3.1: scale adaptation on the fly).

On a serverless platform SMLT changes the worker fleet between epochs; the
TPU analogue is re-instantiating the train step on a different sub-mesh and
moving the checkpointed state onto it. State transfer is a device_put with
the new NamedSharding — the JAX runtime performs the minimal resharding
collective, which is exactly the "checkpoint -> redeploy -> restore" path of
the paper with the object store replaced by ICI.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def make_data_mesh(n_workers: int, devices: Optional[Sequence] = None) -> Mesh:
    """A 1-D `data` mesh over the first n_workers devices."""
    devices = list(devices or jax.devices())[:n_workers]
    return Mesh(np.array(devices), ("data",))


def reshard(tree, mesh: Mesh, spec_fn: Callable = None):
    """Move a pytree onto ``mesh``. spec_fn(path, leaf) -> PartitionSpec;
    default replicates everything (parameters / optimizer state)."""
    spec_fn = spec_fn or (lambda path, leaf: P())

    def put(path, leaf):
        return jax.device_put(leaf, NamedSharding(mesh, spec_fn(path, leaf)))

    return jax.tree_util.tree_map_with_path(put, tree)


def shard_batch(batch, mesh: Mesh, axes=("data",)):
    """Shard a host batch along dim 0 over the data(-like) mesh axes."""
    sh = NamedSharding(mesh, P(axes))
    return jax.tree.map(lambda x: jax.device_put(x, sh), batch)


class ElasticRunner:
    """Owns (params, opt_state) and can rescale the worker fleet between
    epochs while training continues — the semantic core of SMLT adaptation."""

    def __init__(self, step_builder: Callable[[Mesh], Callable], params,
                 opt_state, n_workers: int):
        self._builder = step_builder
        self.mesh = make_data_mesh(n_workers)
        self.params = reshard(params, self.mesh)
        self.opt_state = reshard(opt_state, self.mesh)
        self.step = step_builder(self.mesh)
        self.n_workers = n_workers
        self.rescale_events = []

    def rescale(self, n_workers: int):
        if n_workers == self.n_workers:
            return
        self.mesh = make_data_mesh(n_workers)
        self.params = reshard(self.params, self.mesh)
        self.opt_state = reshard(self.opt_state, self.mesh)
        self.step = self._builder(self.mesh)
        self.rescale_events.append((self.n_workers, n_workers))
        self.n_workers = n_workers

    def train_step(self, batch):
        batch = shard_batch(batch, self.mesh)
        self.params, self.opt_state, loss = self.step(
            self.params, self.opt_state, batch)
        return loss
