"""Hierarchical model synchronization on JAX collectives (paper Section 3.3).

The paper's ScatterReduce dataflow (Fig. 5) maps 1:1 onto TPU collectives:

  shard generator  + upload     ->  reduce-scatter  (lax.psum_scatter)
  shard aggregator (mean)       ->  (the reduction inside psum_scatter) / n
  re-upload + global aggregator ->  all-gather      (lax.all_gather)

The centralized-PS pattern of Siren/Cirrus — every worker downloads every
other worker's full gradient — maps to all-gather of *unreduced* gradients
followed by a local mean: O(n*|G|) bytes per worker instead of O(|G|).

A 2-level variant maps SMLT's hierarchy onto a multi-pod mesh: reduce-scatter
intra-pod (fast ICI), all-reduce of the small shards across pods (slow DCI),
all-gather intra-pod. All functions run inside ``shard_map``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

STRATEGIES = ("allreduce", "hier", "hier2", "hier2_q", "ps")


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` (new API, ``check_vma``) with a fallback to
    ``jax.experimental.shard_map`` (``check_rep``) for older jaxlibs."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _flat_pad(g, n: int):
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def allreduce_mean(grads, axis: str, n: int):
    """Baseline: plain all-reduce mean (what XLA would emit for DP)."""
    return jax.tree.map(lambda g: jax.lax.psum(g, axis) / n, grads)


def ps_mean(grads, axis: str, n: int):
    """Siren/Cirrus centralized-store pattern: every worker gathers all
    n full gradients, then averages locally. O(n*|G|) ingress per worker."""

    def one(g):
        allg = jax.lax.all_gather(g, axis)          # (n, ...) on every worker
        return jnp.mean(allg, axis=0)

    return jax.tree.map(one, grads)


def scatter_reduce_mean(grads, axis: str, n: int):
    """SMLT hierarchical synchronization == reduce-scatter + all-gather."""

    def one(g):
        flat, pad = _flat_pad(g, n)
        shard = jax.lax.psum_scatter(flat, axis, scatter_dimension=0,
                                     tiled=True) / n
        full = jax.lax.all_gather(shard, axis, axis=0, tiled=True)
        if pad:
            full = full[:flat.shape[0] - pad]
        return full.reshape(g.shape)

    return jax.tree.map(one, grads)


def two_level_mean(grads, inner_axis: str, outer_axis: str, n_inner: int,
                   n_outer: int, *, compress_cross_pod: bool = False):
    """Pod-aware SMLT hierarchy: RS intra-pod, AR of shards across pods,
    AG intra-pod. Cross-pod traffic shrinks from |G| to |G|/n_inner per
    device pair — the TPU analogue of SMLT's shard-aggregator tree.

    ``compress_cross_pod`` additionally casts the (already intra-pod
    reduced) shard to bf16 for the slow cross-pod hop — a beyond-paper
    optimization halving DCI bytes; the intra-pod math stays full
    precision (see EXPERIMENTS.md §Perf C7 for the error analysis)."""

    def one(g):
        flat, pad = _flat_pad(g, n_inner)
        shard = jax.lax.psum_scatter(flat, inner_axis, scatter_dimension=0,
                                     tiled=True)
        if compress_cross_pod and shard.dtype == jnp.float32:
            shard = jax.lax.psum(shard.astype(jnp.bfloat16), outer_axis)
            shard = shard.astype(jnp.float32) / (n_inner * n_outer)
        else:
            shard = jax.lax.psum(shard, outer_axis) / (n_inner * n_outer)
        full = jax.lax.all_gather(shard, inner_axis, axis=0, tiled=True)
        if pad:
            full = full[:flat.shape[0] - pad]
        return full.reshape(g.shape)

    return jax.tree.map(one, grads)


def sync_grads(grads, strategy: str, *, data_axis: str = "data",
               pod_axis: str = "pod", n_data: int = 1, n_pod: int = 1):
    """Dispatch on strategy name (inside shard_map over the data/pod axes)."""
    if strategy == "allreduce":
        if n_pod > 1:
            grads = jax.tree.map(lambda g: jax.lax.psum(g, pod_axis), grads)
            return allreduce_mean(grads, data_axis, n_data * n_pod)
        return allreduce_mean(grads, data_axis, n_data)
    if strategy == "hier":
        if n_pod > 1:
            return two_level_mean(grads, data_axis, pod_axis, n_data, n_pod)
        return scatter_reduce_mean(grads, data_axis, n_data)
    if strategy == "hier2":
        assert n_pod > 1, "hier2 needs a pod axis"
        return two_level_mean(grads, data_axis, pod_axis, n_data, n_pod)
    if strategy == "hier2_q":
        assert n_pod > 1, "hier2_q needs a pod axis"
        return two_level_mean(grads, data_axis, pod_axis, n_data, n_pod,
                              compress_cross_pod=True)
    if strategy == "ps":
        if n_pod > 1:
            grads = jax.tree.map(lambda g: jax.lax.psum(g, pod_axis) / n_pod,
                                 grads)
        return ps_mean(grads, data_axis, n_data)
    raise ValueError(f"unknown strategy {strategy!r}; one of {STRATEGIES}")


def make_sync_grad_fn(loss_fn: Callable, mesh: Mesh, strategy: str,
                      *, data_axis: str = "data", pod_axis: str = "pod"):
    """Build f(params, batch) -> (loss, synced_grads) where per-worker grads
    are computed on the local batch slice and synchronized with ``strategy``.
    Params replicated; batch sharded on axis 0 over data (x pod) axes.
    """
    axes = dict(mesh.shape)
    n_data = axes.get(data_axis, 1)
    n_pod = axes.get(pod_axis, 1)
    batch_axes = ((pod_axis, data_axis) if n_pod > 1 else (data_axis,))

    def local_step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = sync_grads(grads, strategy, data_axis=data_axis,
                           pod_axis=pod_axis, n_data=n_data, n_pod=n_pod)
        loss = jax.lax.pmean(loss, data_axis)
        if n_pod > 1:
            loss = jax.lax.pmean(loss, pod_axis)
        return loss, grads

    return shard_map_compat(
        local_step, mesh=mesh,
        in_specs=(P(), P(batch_axes)),
        out_specs=(P(), P()))
