"""Training-dynamics monitor (paper Section 3.1: "the task scheduler
continuously monitors for changes in training information, and upon
detecting change, activates an optimizer").

The plan-signature detection in ``scheduler.py`` covers declared changes
(batch schedule, NAS candidates); this monitor detects *undeclared* shifts
from noisy per-iteration observations — e.g. a data-dependent slowdown or
a platform regression — with an EWMA + CUSUM change detector, and tells
the scheduler to re-optimize.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class ThroughputMonitor:
    """EWMA-normalized CUSUM on per-iteration throughput.

    z-scores are winsorized at ±z_clip so a single outlier iteration can
    add at most (z_clip - drift) to the CUSUM — alarms need *sustained*
    evidence; the slow EWMA keeps the baseline from chasing the shift
    before the CUSUM can accumulate it."""
    alpha: float = 0.05         # EWMA smoothing (slow baseline)
    # CUSUM slack = delta/2 for a target detectable shift of delta ~ 3
    # stddevs; under pure noise E[max(|z|-1.5, 0)] ~ 0.03/step, giving an
    # in-control ARL of ~250 iterations at threshold 8
    drift: float = 1.5
    threshold: float = 8.0      # CUSUM alarm level (in stddevs)
    z_clip: float = 4.0
    warmup: int = 5

    _mean: float = 0.0
    _var: float = 1.0
    _cusum_pos: float = 0.0
    _cusum_neg: float = 0.0
    _n: int = 0

    def observe(self, throughput: float) -> bool:
        """Feed one observation; returns True when a sustained shift is
        detected (and resets the detector)."""
        self._n += 1
        if self._n == 1:
            self._mean = throughput
            return False
        prev_mean = self._mean
        prev_std = max(self._var ** 0.5, 1e-9)
        # winsorize the update too: the baseline stats must not chase a
        # suspected shift while the CUSUM is still accumulating evidence
        dev = float(np.clip(throughput - prev_mean,
                            -self.z_clip * prev_std,
                            self.z_clip * prev_std))
        self._mean = (1 - self.alpha) * self._mean + self.alpha * (
            prev_mean + dev)
        self._var = (1 - self.alpha) * self._var + self.alpha * dev ** 2
        if self._n <= self.warmup:
            return False
        std = max(self._var ** 0.5, 1e-9)
        z = float(np.clip((throughput - prev_mean) / std,
                          -self.z_clip, self.z_clip))
        self._cusum_pos = max(0.0, self._cusum_pos + z - self.drift)
        self._cusum_neg = max(0.0, self._cusum_neg - z - self.drift)
        if max(self._cusum_pos, self._cusum_neg) > self.threshold:
            self.reset(keep_mean=throughput)
            return True
        return False

    def reset(self, keep_mean: Optional[float] = None):
        self._cusum_pos = self._cusum_neg = 0.0
        self._n = 1
        if keep_mean is not None:
            self._mean = keep_mean
            self._var = max(self._var, 1e-9)

    @property
    def mean(self) -> float:
        return self._mean
