"""Memoizing probe cache for the analytic cost model.

``epoch_estimate``/``profile_cost``/``iteration_time`` are pure
functions of their inputs: the workload's calibration numbers, the comm
scheme, the deployment config, the batch, and the stores' *parameters*
(latency/bandwidth/pricing — never their mutable blob/stat state). The
Bayesian optimizer re-evaluates the same closed forms hundreds of times
per training run — every re-optimization sweeps overlapping candidate
sets, Hyperband rungs re-probe surviving configs, and the workflow
allocator forecasts each task repeatedly under one deadline.

``ProbeCache`` memoizes those calls on the hashable
``(workload, scheme, config, batch, store-params, fleet, kwargs)``
tuple. Results are returned as defensive copies (``EpochEstimate`` and
the iteration-breakdown dict are mutable), so a caller that annotates
its estimate cannot poison the cache.

A process-wide ``DEFAULT_CACHE`` is shared by every ``TaskScheduler``
(and the workflow orchestrator's whole fleet of them) — safe because
keys capture *all* inputs, and profitable because concurrent tasks
probe overlapping config spaces. Pass ``probe_cache=None`` to a
scheduler to opt out, or a private instance to isolate hit/miss
accounting (as the unit tests do).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.core import cost_model as _cm
from repro.serverless.backends import resolve_backend
from repro.serverless.platform import FleetSpec
from repro.serverless.stores import ObjectStore, ParamStore
from repro.serverless.worker import Workload


def _store_key(store) -> Tuple:
    """A store's *parameters* — the only state the cost model reads."""
    if isinstance(store, ParamStore):
        return ("param", store.latency_s, store.node_gbps, store.vcpus,
                store.memory_gb)
    if isinstance(store, ObjectStore):
        return ("object", store.latency_s, store.per_stream_gbps,
                store.aggregate_gbps)
    # unknown store type: fall back to identity (correct, never shared)
    return ("id", id(store))


def probe_key(w: Workload, scheme, config, global_batch: int,
              param_store, object_store,
              fleet: Optional[FleetSpec] = None, **kwargs) -> Tuple:
    """The full-input hash key one cost-model probe is memoized under.
    ``scheme`` (str/CommSpec/CommPlan), ``config`` (frozen Config), and
    ``fleet.workers`` (frozen WorkerSpecs) are hashable as-is.

    Kwargs are normalized so equivalent calls share one entry and
    distinct ones never collide: ``None``-valued kwargs (the defaults)
    are dropped, and ``backend`` is canonicalized through
    ``resolve_backend`` — ``None``/``""``/``"serverless"`` all key as
    absent, while a name and its resolved ``BackendSpec`` (frozen, its
    ``PriceTrace`` tuple-backed — spot price and bid included) key
    identically, so cached estimates never leak across backends."""
    if "backend" in kwargs:
        spec = resolve_backend(kwargs["backend"])
        if spec is None:
            del kwargs["backend"]
        else:
            kwargs["backend"] = spec
    return (dataclasses.astuple(w), scheme, config, global_batch,
            _store_key(param_store), _store_key(object_store),
            None if fleet is None else fleet.workers,
            tuple(sorted((k, v) for k, v in kwargs.items()
                         if v is not None)))


class ProbeCache:
    """Bounded memo table over the analytic cost-model entry points."""

    def __init__(self, maxsize: int = 8192):
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._d: Dict[Tuple, Any] = {}

    def __len__(self) -> int:
        return len(self._d)

    def clear(self):
        self._d.clear()
        self.hits = 0
        self.misses = 0

    def _put(self, key: Tuple, value):
        if len(self._d) >= self.maxsize:
            # drop the oldest half (dict preserves insertion order) —
            # cheap, and BO probe streams are strongly front-loaded
            for k in list(self._d)[:self.maxsize // 2]:
                del self._d[k]
        self._d[key] = value

    # -- cached entry points -------------------------------------------------
    def epoch_estimate(self, w: Workload, scheme, config, global_batch: int,
                       param_store, object_store, **kwargs):
        key = ("epoch", probe_key(w, scheme, config, global_batch,
                                  param_store, object_store, **kwargs))
        est = self._d.get(key)
        if est is None:
            self.misses += 1
            est = _cm.epoch_estimate(w, scheme, config, global_batch,
                                     param_store, object_store, **kwargs)
            self._put(key, est)
        else:
            self.hits += 1
        # defensive copy: EpochEstimate (and its breakdown dict) is mutable
        return dataclasses.replace(est, it_breakdown=dict(est.it_breakdown))

    def profile_cost(self, w: Workload, scheme, config, global_batch: int,
                     param_store, object_store, profile_iters: int = 3,
                     **kwargs):
        key = ("profile", probe_key(w, scheme, config, global_batch,
                                    param_store, object_store,
                                    profile_iters=profile_iters, **kwargs))
        hit = self._d.get(key)
        if hit is None:
            self.misses += 1
            hit = _cm.profile_cost(w, scheme, config, global_batch,
                                   param_store, object_store, profile_iters,
                                   **kwargs)
            self._put(key, hit)
        else:
            self.hits += 1
        wall, usd, it = hit
        return wall, usd, dict(it)


# One shared table per process: every scheduler benefits from every
# other's probes (keys capture all inputs, so sharing is always sound).
DEFAULT_CACHE = ProbeCache()
