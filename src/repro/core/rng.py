"""Named, independent random streams for every stochastic subsystem.

The repo draws randomness in several places — the event engine's
per-fleet straggler/failure draws, the correlated-shock process, the
platform's independent failure coin, the scheduler's BO loop, the
tuner's synthetic learning curves. Historically each site rolled its
own ``np.random.RandomState(<ad-hoc formula>)``; this module is the
one place those formulas live, with two families of constructors:

**Legacy streams** (``shock_stream``, ``worker_stream``,
``curve_stream``, ``base_stream``) reproduce the exact seed formulas
the engine/tuner/scheduler have always used, bit-for-bit — moving the
seeding here is a pure relocation, so golden traces and seeded tests
are unchanged.

**Hashed streams** (``stream``) derive a well-mixed 31-bit seed from a
``(seed, name, *keys)`` tuple via a splitmix64-style mixer. New code
(e.g. the engine's vectorized per-epoch draw blocks) uses these: the
string name documents what the stream feeds, and distinct names give
statistically independent streams even for adjacent integer seeds.

All constructors return the legacy ``np.random.RandomState`` (MT19937)
so draw-for-draw reproducibility is well-defined across numpy versions.
"""
from __future__ import annotations

import numpy as np

__all__ = ["stream", "stream_seed", "worker_stream", "shock_stream",
           "curve_stream", "base_stream"]

_MASK64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    """splitmix64 finalizer: a cheap, well-distributed 64-bit mixer."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def stream_seed(seed: int, name: str, *keys: int) -> int:
    """A 31-bit seed derived from ``(seed, name, *keys)``.

    Deterministic across processes and platforms (no use of ``hash``),
    and well-mixed: streams for adjacent seeds or key values do not
    overlap in any detectable way. ``name`` labels the consumer
    ("straggler", "failure", ...), extra integer ``keys`` split it
    further (e.g. per job index).
    """
    h = _mix64(seed & _MASK64)
    for ch in name.encode("utf-8"):
        h = _mix64(h ^ ch)
    for k in keys:
        h = _mix64(h ^ (k & _MASK64))
    return h % (2 ** 31)


def stream(seed: int, name: str, *keys: int) -> np.random.RandomState:
    """An independent named stream: ``stream(seed, "straggler", job)``."""
    return np.random.RandomState(stream_seed(seed, name, *keys))


# -- legacy formulas (bit-exact relocations; do not change) ------------------

def worker_stream(seed: int, wid: int, job_idx: int = 0) \
        -> np.random.RandomState:
    """The event engine's historical per-worker stream (scalar straggler
    z / failure-u / failure-fraction draws, interleaved per attempt)."""
    return np.random.RandomState(
        (seed * 1_000_003 + wid + 611_953 * job_idx) % 2 ** 31)


def shock_stream(seed: int, job_idx: int = 0) -> np.random.RandomState:
    """The correlated-shock process (inter-arrival + kill coins)."""
    return np.random.RandomState(
        (seed * 2_147_483_029 + 97 + job_idx) % 2 ** 31)


def curve_stream(sweep_seed: int) -> np.random.RandomState:
    """The tuner's synthetic learning-curve generator."""
    return np.random.RandomState(sweep_seed * 9176 + 13)


def base_stream(seed: int) -> np.random.RandomState:
    """A plain ``RandomState(seed)`` — the scheduler's BO loop, the
    platform's failure coin. Kept as a named constructor so every
    seeding site routes through this module."""
    return np.random.RandomState(seed)
