"""SMLT task scheduler (paper Sections 3.1 and 4.1).

Maintains the *overarching view* of the training workflow across stateless
function invocations: monitors per-iteration training dynamics, detects
configuration changes (batch size for dynamic batching, model size for NAS),
re-runs the Bayesian optimizer when they change, redeploys workers at the
new <n_workers, memory> configuration, enforces the function duration cap
with checkpoint/restart, and restarts failed workers.

Runs are *resumable*: ``run(max_epochs=...)`` executes a bounded slice and
returns a ``RunResult`` whose ``.state`` continues the same run when passed
back as ``resume=`` — totals, trace, and the adaptation RNG stream carry
over, so a sliced run is equivalent to one uninterrupted call. The epoch
loop itself is a generator (``drive``) that yields an ``EngineRequest``
for every event-engine execution it needs: the default ``run`` wrapper
builds and runs each engine standalone, while the workflow orchestrator
(``repro.workflow``) builds them into a *shared* ``ContentionDomain`` at
the task's workflow-clock offset, co-scheduling many TaskScheduler jobs on
one simulated fleet.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, ClassVar, Dict, FrozenSet, List, Optional, Tuple


from repro.core.bayes_opt import BayesianOptimizer, Config, ConfigSpace
from repro.core.comm import CommSpec, parse_scheme
from repro.core.constraints import (Goal, compression_inflation,
                                    preemption_inflation,
                                    staleness_inflation)
from repro.core.cost_model import epoch_estimate, profile_cost
from repro.core.monitor import ThroughputMonitor
from repro.core.probe_cache import DEFAULT_CACHE, ProbeCache
from repro.core.rng import base_stream
from repro.serverless.backends import resolve_backend
from repro.serverless.platform import ServerlessPlatform, fleet_from_config
from repro.serverless.stores import ObjectStore, ParamStore
from repro.serverless.worker import Workload


@dataclasses.dataclass
class EpochPlan:
    """One epoch of the (possibly dynamic) workflow."""
    batch_size: int
    workload: Workload                 # may differ per epoch (NAS)
    samples: Optional[int] = None      # online learning: samples that arrived


@dataclasses.dataclass
class TraceEvent:
    t: float
    epoch: int
    kind: str                          # one of KINDS (validated below)
    throughput: float = 0.0            # samples / s
    workers: int = 0
    memory_mb: int = 0
    batch_size: int = 0
    model_params: int = 0
    cost_cum: float = 0.0
    restarts: int = 0                  # duration-cap restarts, per worker
    failures: int = 0

    # every kind the scheduler emits; a new kind must be registered here
    # before it can appear in a trace, so typos fail loudly instead of
    # silently slipping past `events if e.kind == ...` filters
    KINDS: ClassVar[FrozenSet[str]] = frozenset(
        {"epoch", "reoptimize", "reoptimize_mid", "migrate"})

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown TraceEvent kind: {self.kind!r} "
                             "(register it in TraceEvent.KINDS)")


@dataclasses.dataclass
class SchedulerState:
    """Resumable cursor of a (possibly partial) ``TaskScheduler.run``.

    ``run(max_epochs=k)`` returns after k epoch plans with ``done=False``;
    passing the state back via ``resume=`` continues where it left off.
    ``stop_reason`` records why a finished run ended: "completed" (all
    plans executed), "deadline", or "budget"."""
    next_epoch: int = 0
    config: Optional[Config] = None
    last_sig: Optional[Tuple] = None
    t: float = 0.0
    cost: float = 0.0
    t_prof: float = 0.0
    usd_prof: float = 0.0
    epochs_done: int = 0
    events: List[TraceEvent] = dataclasses.field(default_factory=list)
    history: List[Config] = dataclasses.field(default_factory=list)
    rng_state: Optional[Tuple] = None
    done: bool = False
    stop_reason: str = ""              # "" | "completed" | "deadline" | "budget"
    # observed actual/forecast ratios (event path): the stop gates forecast
    # with epoch_estimate, which knows nothing of cross-job contention on a
    # shared domain — each completed epoch teaches the gate how much slower
    # and dearer this task actually runs than its isolated estimate
    cost_infl: float = 1.0
    time_infl: float = 1.0


@dataclasses.dataclass
class RunResult:
    events: List[TraceEvent]
    wall_s: float
    cost_usd: float
    profile_s: float
    profile_usd: float
    epochs_done: int
    config_history: List[Config]
    state: Optional[SchedulerState] = None

    @property
    def total_cost(self):
        return self.cost_usd + self.profile_usd

    @property
    def stop_reason(self) -> str:
        return self.state.stop_reason if self.state is not None else ""


@dataclasses.dataclass
class EngineRequest:
    """One event-engine execution the epoch loop needs.

    Yielded by ``TaskScheduler.drive``; the driver builds the engine —
    optionally into a shared ``ContentionDomain`` via
    ``build(domain=..., start_at=..., on_complete=...)`` — and sends the
    ``EngineResult`` back into the generator. ``at_t`` is the task-local
    clock (profiling + prior epochs) when the chunk starts, i.e. the
    offset at which a workflow orchestrator should admit the engine."""
    at_t: float
    build: Callable[..., object]


class TaskScheduler:
    def __init__(self, platform: ServerlessPlatform,
                 object_store: ObjectStore, param_store: ParamStore, *,
                 space: Optional[ConfigSpace] = None, scheme: str = "hier",
                 profile_iters: int = 3, framework_init_s: float = 4.0,
                 cold_start_s: float = 2.0, seed: int = 0,
                 probe_cap_s: float = 180.0, bo_max_iters: int = 12,
                 engine: str = "analytic",
                 engine_opts: Optional[Dict] = None,
                 mid_epoch_adapt: bool = True,
                 probe_cache: Optional[ProbeCache] = DEFAULT_CACHE,
                 job: str = ""):
        self.platform = platform
        self.object_store = object_store
        self.param_store = param_store
        self.space = space or ConfigSpace()
        self.scheme = scheme
        self.profile_iters = profile_iters
        self.framework_init_s = framework_init_s
        self.cold_start_s = cold_start_s
        self.seed = seed
        # probes longer than this are aborted and recorded as censored —
        # the resource manager never lets a bad config burn real money
        self.probe_cap_s = probe_cap_s
        self.bo_max_iters = bo_max_iters
        # "analytic": closed-form epoch_estimate (fast path; BO probes
        # always use it). "event": epochs execute on the discrete-event
        # engine (stragglers, failures, sync modes via ``engine_opts``),
        # and per-iteration completions feed a ThroughputMonitor that can
        # abort + re-optimize *mid-epoch* when throughput drifts.
        if engine not in ("analytic", "event"):
            raise ValueError(engine)
        self.engine = engine
        self.engine_opts = dict(engine_opts or {})
        self.mid_epoch_adapt = mid_epoch_adapt
        # memo table for the analytic probes (epoch_estimate/profile_cost):
        # shared process-wide by default so every scheduler reuses every
        # other's probes; pass None to recompute every closed form
        self.probe_cache = probe_cache
        # ledger attribution label: several workflow tasks billing one
        # shared platform stay separable in ``ledger.job_usd``
        self.job = job

    def _space_for(self, w: Workload) -> ConfigSpace:
        """Resource-manager floor: the function must hold model + grads +
        framework (Section 4.1) — prunes configs that could never run.
        The fleet-composition and comm-plan search dimensions carry over
        from the scheduler's space."""
        model_mb = int(3 * 4 * w.param_count / 1e6) + 512
        lo = min(max(self.space.min_memory, model_mb),
                 self.space.max_memory - 1)
        return dataclasses.replace(self.space, min_memory=lo)

    def _comm_for(self, config: Config):
        """The communication schedule a config deploys: the scheduler's
        default scheme unless the optimizer searched the comm dimensions
        (``Config.comm``/``compress_ratio``/``branching``/
        ``pipeline_depth``)."""
        if (not config.comm and config.compress_ratio >= 1.0
                and config.branching <= 0 and config.pipeline_depth <= 1):
            return self.scheme
        base = (parse_scheme(self.scheme) if not config.comm
                else CommSpec(config.comm))
        return dataclasses.replace(base, ratio=config.compress_ratio,
                                   branching=(config.branching
                                              if base.strategy == "hier"
                                              else 0),
                                   pipeline_depth=max(config.pipeline_depth,
                                                      1))

    def _epoch_estimate(self, *args, **kwargs):
        if self.probe_cache is not None:
            return self.probe_cache.epoch_estimate(*args, **kwargs)
        return epoch_estimate(*args, **kwargs)

    def _profile_cost(self, *args, **kwargs):
        if self.probe_cache is not None:
            return self.probe_cache.profile_cost(*args, **kwargs)
        return profile_cost(*args, **kwargs)

    # -- Bayesian re-optimization (triggered on training-dynamics change) ----
    def optimize(self, w: Workload, batch: int, goal: Goal,
                 epochs_remaining: int, samples: Optional[int],
                 warm_start: Optional[Config] = None):
        """``warm_start`` (beyond-paper): seed the GP with the previous
        deployment's config — good configs correlate across similar
        workloads, so a warm re-optimization needs ~half the probes."""
        limit = None
        if goal.kind == "min_cost_deadline":
            limit = goal.deadline_s
        elif goal.kind == "min_time_budget":
            limit = goal.budget_usd
        elif goal.kind == "deadline_budget":
            limit = 1.0                # normalized max(time, cost) constraint
        space = self._space_for(w)
        max_iters = self.bo_max_iters
        if warm_start is not None:
            max_iters = max(self.bo_max_iters // 2, 4)
        bo = BayesianOptimizer(space, constraint_limit=limit,
                               seed=self.seed, max_iters=max_iters)
        seeds = []
        if warm_start is not None:
            # keep the fleet-composition dimension: the warm-start probe
            # must profile the deployment that was actually running
            seeds = [Config(min(max(warm_start.workers, space.min_workers),
                                space.max_workers),
                            min(max(warm_start.memory_mb, space.min_memory),
                                space.max_memory),
                            warm_start.small_frac, warm_start.comm,
                            warm_start.compress_ratio, warm_start.branching,
                            warm_start.pipeline_depth, warm_start.backend)]
        t_prof = usd_prof = 0.0
        while not bo.done():
            c = seeds.pop(0) if seeds else bo.suggest()
            comm = self._comm_for(c)
            pt, pu, _ = self._profile_cost(
                w, comm, c, batch, self.param_store, self.object_store,
                self.profile_iters, framework_init_s=self.framework_init_s,
                cold_start_s=self.cold_start_s,
                backend=self.engine_opts.get("backend"))
            # the probe cap targets runaway *compute*, not the known fixed
            # provisioning delay a VM-kind candidate always pays
            cap = self.probe_cap_s
            spec = resolve_backend(self.engine_opts.get("backend")
                                   or c.backend)
            if spec is not None:
                cap += spec.provision_s
            if pt > cap:
                # censored probe: abort at the cap, record a pessimistic
                # objective so the GP steers away without full payment
                frac = cap / pt
                t_prof += cap
                usd_prof += pu * frac
                worst = max((o.objective for o in bo.obs), default=1.0)
                bo.observe(c, worst * 10.0,
                           None if limit is None else limit * 10.0)
                continue
            t_prof += pt
            usd_prof += pu
            est = self._epoch_estimate(
                w, comm, c, batch, self.param_store, self.object_store,
                framework_init_s=self.framework_init_s,
                cold_start_s=self.cold_start_s, samples=samples,
                backend=self.engine_opts.get("backend"))
            total_t = est.wall_s * epochs_remaining
            total_c = est.cost_usd * epochs_remaining
            # convergence-aware objective: a relaxed sync mode buys
            # wall-clock per epoch, a top-k ratio buys wire bytes — both
            # pay iterations-to-converge, so judge the candidate on
            # inflated time and dollars
            infl = staleness_inflation(
                self.engine_opts.get("sync_mode", "bsp"),
                self.engine_opts.get("staleness", 0), c.workers)
            infl *= compression_inflation(c.compress_ratio)
            # a spot deployment (engine_opts backend spec, or the
            # candidate's own) pays expected preemption overhead at the
            # hazard-aware Young–Daly cadence
            be = resolve_backend(self.engine_opts.get("backend")
                                 or c.backend)
            if be is not None and be.spot:
                infl *= preemption_inflation(
                    be.price_trace.hazard_per_s(be.bid_usd_per_hr))
            obj, cons, _ = goal.objective_and_constraint(total_t, total_c,
                                                         inflation=infl)
            bo.observe(c, obj, cons)
        if usd_prof > 0.0:
            # profiling probes are real invocations: they belong on the
            # shared bill, attributed to this job
            self.platform.ledger.charge("profile", usd_prof)
            self.platform.ledger.attribute(self.job, usd_prof)
        # probes run real training iterations (the paper profiles live
        # throughput) — those samples count toward the epoch
        useful = sum(1 for o in bo.obs) * self.profile_iters * batch
        return bo.best().config, t_prof, usd_prof, useful

    # -- cross-backend migration ---------------------------------------------
    def _migrate(self, old: Optional[Config], new: Config,
                 w: Workload) -> float:
        """Migration protocol at re-optimization: when the optimizer moves
        the job to a different backend, the model + optimizer state
        (params + Adam m,v) checkpoints out through the ObjectStore under
        the old deployment and restores under the new one. Returns the
        wall overhead of the two transfers; the new backend's
        provisioning delay is paid by the next deployment's own init.
        No-op when the backend is unchanged."""
        if old is None or old.backend == new.backend:
            return 0.0
        ckpt_bytes = 12.0 * w.param_count
        key = f"migrate/{self.job or 'job'}"
        self.object_store.put(key, {"params": w.param_count},
                              nbytes=ckpt_bytes)
        dt = self.object_store.put_time(ckpt_bytes)
        self.object_store.get(key, nbytes=ckpt_bytes)
        dt += self.object_store.get_time(ckpt_bytes)
        return dt

    # -- event-engine epoch execution ----------------------------------------
    def _run_epoch_event(self, plan: EpochPlan, goal: Goal, config: Config,
                         samples_left: int, epoch_i: int, n_plans: int,
                         adaptive: bool, events: List[TraceEvent],
                         t_base: float, cost_base: float):
        """Execute one epoch on the discrete-event engine, in chunks: when
        the per-iteration ThroughputMonitor detects a sustained drift, the
        engine checkpoints and stops, we re-optimize *mid-epoch*, and the
        remaining samples run under the new deployment.

        This is a generator: every engine execution is a yielded
        ``EngineRequest`` whose ``EngineResult`` is sent back in, so a
        workflow orchestrator can run the chunk on a shared domain."""
        # deferred: events consumes the CommPlan IR from repro.core, so a
        # top-level import here would close an import cycle
        from repro.serverless.events import EventEngine
        wall = cost = 0.0
        restarts = failures = 0
        t_prof = usd_prof = 0.0
        configs: List[Config] = []
        remaining = samples_left
        attempt = 0
        iters_epoch = 0
        while remaining > 0:
            monitor = ThroughputMonitor()

            def on_it(g, t_now, dt, _m=monitor, _b=plan.batch_size):
                if dt <= 0 or not (adaptive and self.mid_epoch_adapt):
                    return False
                return _m.observe(_b / dt)

            opts = {"failure_rate": self.platform.failure_rate,
                    **self.engine_opts}
            # a slowdown injection is an epoch-level regression: keep its
            # onset fixed in epoch-iteration space across restarted chunks
            if opts.get("slowdown_at_iter") is not None:
                opts["slowdown_at_iter"] = max(
                    opts["slowdown_at_iter"] - iters_epoch, 0)
            # a searched fleet composition deploys as its mixed fleet
            # (an explicit engine_opts fleet overrides the config's)
            if config.small_frac > 0.0 and "fleet" not in opts:
                opts["fleet"] = fleet_from_config(
                    config.workers, config.memory_mb, config.small_frac)
            # a searched backend deploys on its engine semantics (an
            # explicit engine_opts backend — e.g. a spot variant — wins)
            if config.backend and "backend" not in opts:
                opts["backend"] = config.backend
            args = (plan.workload, self._comm_for(config), config.workers,
                    config.memory_mb, plan.batch_size, self.param_store,
                    self.object_store)
            kwargs = dict(platform=self.platform,
                          framework_init_s=self.framework_init_s,
                          cold_start_s=self.cold_start_s,
                          max_duration_s=self.platform.max_duration_s,
                          samples=remaining,
                          seed=self.seed + 7919 * epoch_i + attempt,
                          on_iteration=on_it, **opts)
            # perf default: engine epochs skip trace accumulation unless
            # the caller's engine_opts asked for it (either spelling)
            if "trace_enabled" not in kwargs:
                kwargs.setdefault("record_trace", False)
            r = yield EngineRequest(
                at_t=t_base + wall + t_prof,
                build=lambda args=args, kwargs=kwargs, **extra: EventEngine(
                    *args, **{**kwargs, **extra}))
            wall += r.wall_s
            cost += r.cost_usd
            # the engine's lambda dollars reached the shared ledger through
            # platform.finish; the store-side dollars did not — put them on
            # the bill too, and attribute the whole chunk to this job
            self.platform.ledger.charge("store", r.store_usd)
            self.platform.ledger.attribute(self.job, r.cost_usd)
            # EngineResult.restarts is fleet-wide; TraceEvent.restarts is
            # per worker (matching the analytic path's restarts_per_worker)
            restarts += round(r.restarts / config.workers)
            failures += r.failures
            remaining -= max(r.samples_done, plan.batch_size)
            iters_epoch += r.iters_done
            attempt += 1
            if r.stopped_early and remaining > 0 and adaptive:
                prev = config
                config, pt, pu, profiled = self.optimize(
                    plan.workload, plan.batch_size, goal,
                    epochs_remaining=n_plans - epoch_i, samples=remaining,
                    warm_start=config)
                t_prof += pt
                usd_prof += pu
                remaining = max(remaining - profiled, 0)
                configs.append(config)
                events.append(TraceEvent(
                    t_base + wall + t_prof, epoch_i, "reoptimize_mid",
                    workers=config.workers, memory_mb=config.memory_mb,
                    batch_size=plan.batch_size,
                    model_params=plan.workload.param_count,
                    cost_cum=cost_base + cost + usd_prof))
                mig = self._migrate(prev, config, plan.workload)
                if mig > 0.0:
                    wall += mig
                    events.append(TraceEvent(
                        t_base + wall + t_prof, epoch_i, "migrate",
                        workers=config.workers, memory_mb=config.memory_mb,
                        batch_size=plan.batch_size,
                        model_params=plan.workload.param_count,
                        cost_cum=cost_base + cost + usd_prof))
            elif not r.stopped_early:
                break
        meta = {"t_prof": t_prof, "usd_prof": usd_prof, "configs": configs}
        return wall, cost, restarts, failures, config, meta

    # -- main loop ------------------------------------------------------------
    def run(self, plans: List[EpochPlan], goal: Goal, *, adaptive: bool = True,
            fixed_config: Optional[Config] = None,
            stop_at_deadline: bool = False,
            stop_at_budget: bool = False,
            max_epochs: Optional[int] = None,
            resume: Optional[SchedulerState] = None,
            warm_start: Optional[Config] = None) -> RunResult:
        """Execute the epoch plans under ``goal``.

        ``stop_at_deadline`` / ``stop_at_budget`` break before an epoch
        that would push wall time past ``goal.deadline_s`` / total cost
        past ``goal.budget_usd``. ``max_epochs`` bounds this call to a
        slice; pass the returned ``RunResult.state`` back as ``resume=``
        to continue. ``warm_start`` seeds the first optimization with a
        config from another run (cross-task reuse)."""
        gen = self.drive(plans, goal, adaptive=adaptive,
                         fixed_config=fixed_config,
                         stop_at_deadline=stop_at_deadline,
                         stop_at_budget=stop_at_budget,
                         max_epochs=max_epochs, resume=resume,
                         warm_start=warm_start)
        try:
            req = next(gen)
            while True:
                req = gen.send(req.build().run())
        except StopIteration as stop:
            return stop.value

    def drive(self, plans: List[EpochPlan], goal: Goal, *,
              adaptive: bool = True, fixed_config: Optional[Config] = None,
              stop_at_deadline: bool = False, stop_at_budget: bool = False,
              max_epochs: Optional[int] = None,
              resume: Optional[SchedulerState] = None,
              warm_start: Optional[Config] = None):
        """Generator form of ``run``: yields an ``EngineRequest`` for
        every event-engine execution, expects its ``EngineResult`` sent
        back, and returns the ``RunResult`` via ``StopIteration.value``.
        The workflow orchestrator drives many of these concurrently on
        one shared ``ContentionDomain``."""
        st = resume if resume is not None else SchedulerState(
            config=fixed_config)
        if st.done:
            raise ValueError("cannot resume a finished run "
                             f"(stop_reason={st.stop_reason!r})")
        events, history = st.events, st.history
        config = st.config
        last_sig = st.last_sig
        t, cost = st.t, st.cost
        t_prof, usd_prof = st.t_prof, st.usd_prof
        epochs_done = st.epochs_done
        rng = base_stream(self.seed)
        if st.rng_state is not None:
            rng.set_state(st.rng_state)
        executed = 0
        i = st.next_epoch
        paused = False

        while i < len(plans):
            if max_epochs is not None and executed >= max_epochs:
                paused = True
                break
            plan = plans[i]
            sig = (plan.batch_size, plan.workload.param_count,
                   plan.workload.flops_per_sample)
            profiled_samples = 0
            if config is None or (adaptive and sig != last_sig):
                prev = config
                config, pt, pu, profiled_samples = self.optimize(
                    plan.workload, plan.batch_size, goal,
                    epochs_remaining=len(plans) - i, samples=plan.samples,
                    warm_start=config if config is not None else warm_start)
                t += pt
                cost += pu
                t_prof += pt
                usd_prof += pu
                events.append(TraceEvent(t, i, "reoptimize",
                                         workers=config.workers,
                                         memory_mb=config.memory_mb,
                                         batch_size=plan.batch_size,
                                         model_params=plan.workload.param_count,
                                         cost_cum=cost))
                mig = self._migrate(prev, config, plan.workload)
                if mig > 0.0:
                    # the job changes execution target: checkpoint out,
                    # restore under the new backend, resume
                    t += mig
                    events.append(TraceEvent(
                        t, i, "migrate", workers=config.workers,
                        memory_mb=config.memory_mb,
                        batch_size=plan.batch_size,
                        model_params=plan.workload.param_count,
                        cost_cum=cost))
            last_sig = sig

            samples_plan = plan.samples or plan.workload.dataset_samples
            samples_left = max(samples_plan - profiled_samples,
                               plan.batch_size)

            # forecast gate: never *start* an epoch whose estimate busts
            # the budget (both paths) or — on the event path, where the
            # epoch's ledger/store/shared-clock side effects are
            # irreversible once it runs — the deadline
            est_pre = None
            if ((stop_at_budget and goal.budget_usd is not None)
                    or (self.engine == "event" and stop_at_deadline
                        and goal.deadline_s is not None)):
                est_pre = self._epoch_estimate(
                    plan.workload, self._comm_for(config), config,
                    plan.batch_size, self.param_store, self.object_store,
                    framework_init_s=self.framework_init_s,
                    cold_start_s=self.cold_start_s, samples=samples_left,
                    backend=self.engine_opts.get("backend"))
            if (stop_at_budget and goal.budget_usd is not None
                    and cost + est_pre.cost_usd * st.cost_infl
                    > goal.budget_usd):
                st.stop_reason = "budget"
                break
            if (self.engine == "event" and stop_at_deadline
                    and goal.deadline_s is not None
                    and t + est_pre.wall_s * st.time_infl > goal.deadline_s):
                st.stop_reason = "deadline"
                break

            history.append(config)

            if self.engine == "event":
                # the epoch actually executed (stores + ledger already
                # carry its side effects); a later deadline break only
                # drops it from the result totals
                wall, epoch_cost, restarts, failures, config, meta = \
                    yield from self._run_epoch_event(
                        plan, goal, config, samples_left, i, len(plans),
                        adaptive, events, t, cost)
                t_prof += meta["t_prof"]
                usd_prof += meta["usd_prof"]
                t += meta["t_prof"]
                cost += meta["usd_prof"]
                history.extend(meta["configs"])
                commit = None
                if est_pre is not None:
                    # calibrate the stop gates on what this epoch really
                    # cost vs its isolated forecast (shared-domain
                    # contention, stragglers, failures)
                    if est_pre.cost_usd > 0:
                        st.cost_infl = max(1.0, epoch_cost
                                           / est_pre.cost_usd)
                    if est_pre.wall_s > 0:
                        st.time_infl = max(1.0, wall / est_pre.wall_s)
            else:
                est = est_pre if est_pre is not None else self._epoch_estimate(
                    plan.workload, self._comm_for(config), config,
                    plan.batch_size, self.param_store, self.object_store,
                    framework_init_s=self.framework_init_s,
                    cold_start_s=self.cold_start_s, samples=samples_left,
                    backend=self.engine_opts.get("backend"))
                # fault injection: failed iterations are redone (Section 4.1)
                failures = int(rng.binomial(est.iters,
                                            self.platform.failure_rate))
                redo_s = failures * est.it_breakdown["total"]
                wall = est.wall_s + redo_s
                epoch_cost = est.cost_usd * (wall / est.wall_s)
                restarts = est.restarts_per_worker

                def commit(est=est, wall=wall, config=config,
                           epoch_cost=epoch_cost):
                    # per-phase store-busy time from the plan (re-upload
                    # fan-in included, decompress CPU excluded) — the
                    # same basis epoch_estimate bills store_usd on
                    self.param_store.keep_alive(
                        est.iters * est.it_breakdown["store_busy"])
                    scale = wall / est.wall_s
                    spec = resolve_backend(
                        self.engine_opts.get("backend") or config.backend)
                    if spec is None:
                        # Lambda semantics: every worker is a request, and
                        # every duration-cap restart re-invokes the fleet
                        self.platform.ledger.charge_fleet(
                            config.memory_mb, config.workers, wall,
                            invocations_per_worker=est.restarts_per_worker
                            + 1)
                    else:
                        # per-second VM billing: no GB-seconds, no requests
                        self.platform.ledger.charge(
                            f"backend:{spec.name}",
                            est.backend_usd * scale)
                    self.platform.ledger.charge("store",
                                                est.store_usd * scale)
                    self.platform.ledger.attribute(self.job, epoch_cost)

            if (stop_at_deadline and goal.deadline_s is not None
                    and t + wall > goal.deadline_s):
                st.stop_reason = "deadline"
                if commit is None:
                    # event-path epochs bill as they run: the overshooting
                    # epoch's dollars are already on the shared ledger, so
                    # they stay in this run's cost even though its samples
                    # are discarded from the result — a budget layer above
                    # (the workflow allocator) must see money that is gone
                    cost += epoch_cost
                break
            if (commit is not None and stop_at_budget
                    and goal.budget_usd is not None
                    and cost + epoch_cost > goal.budget_usd):
                # the symmetric budget stop: break *before* committing the
                # epoch, so a min_time_budget goal never overspends (the
                # event path gates on the forecast above instead — its
                # epochs bill as they run)
                st.stop_reason = "budget"
                break
            if commit is not None:
                commit()      # deadline-skipped epochs are never billed
            t += wall
            cost += epoch_cost
            epochs_done += 1
            executed += 1
            events.append(TraceEvent(
                t, i, "epoch", throughput=samples_left / wall,
                workers=config.workers, memory_mb=config.memory_mb,
                batch_size=plan.batch_size,
                model_params=plan.workload.param_count, cost_cum=cost,
                restarts=restarts, failures=failures))
            i += 1

        st.next_epoch = i
        st.config = config
        st.last_sig = last_sig
        st.t, st.cost = t, cost
        st.t_prof, st.usd_prof = t_prof, usd_prof
        st.epochs_done = epochs_done
        st.rng_state = rng.get_state()
        if not paused and not st.stop_reason:
            st.stop_reason = "completed"
        st.done = not paused
        # snapshot the live lists: a later resumed slice keeps appending
        # to st.events/st.history, and must not retroactively mutate the
        # RunResult this slice returned
        return RunResult(events=list(events), wall_s=t,
                         cost_usd=cost - usd_prof,
                         profile_s=t_prof, profile_usd=usd_prof,
                         epochs_done=epochs_done,
                         config_history=list(history), state=st)
