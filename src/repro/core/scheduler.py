"""SMLT task scheduler (paper Sections 3.1 and 4.1).

Maintains the *overarching view* of the training workflow across stateless
function invocations: monitors per-iteration training dynamics, detects
configuration changes (batch size for dynamic batching, model size for NAS),
re-runs the Bayesian optimizer when they change, redeploys workers at the
new <n_workers, memory> configuration, enforces the function duration cap
with checkpoint/restart, and restarts failed workers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.bayes_opt import BayesianOptimizer, Config, ConfigSpace
from repro.core.constraints import Goal
from repro.core.cost_model import epoch_estimate, profile_cost
from repro.serverless.platform import ServerlessPlatform
from repro.serverless.stores import ObjectStore, ParamStore
from repro.serverless.worker import Workload


@dataclasses.dataclass
class EpochPlan:
    """One epoch of the (possibly dynamic) workflow."""
    batch_size: int
    workload: Workload                 # may differ per epoch (NAS)
    samples: Optional[int] = None      # online learning: samples that arrived


@dataclasses.dataclass
class TraceEvent:
    t: float
    epoch: int
    kind: str                          # "epoch" | "profile" | "reoptimize"
    throughput: float = 0.0            # samples / s
    workers: int = 0
    memory_mb: int = 0
    batch_size: int = 0
    model_params: int = 0
    cost_cum: float = 0.0
    restarts: int = 0
    failures: int = 0


@dataclasses.dataclass
class RunResult:
    events: List[TraceEvent]
    wall_s: float
    cost_usd: float
    profile_s: float
    profile_usd: float
    epochs_done: int
    config_history: List[Config]

    @property
    def total_cost(self):
        return self.cost_usd + self.profile_usd


class TaskScheduler:
    def __init__(self, platform: ServerlessPlatform,
                 object_store: ObjectStore, param_store: ParamStore, *,
                 space: Optional[ConfigSpace] = None, scheme: str = "hier",
                 profile_iters: int = 3, framework_init_s: float = 4.0,
                 cold_start_s: float = 2.0, seed: int = 0,
                 probe_cap_s: float = 180.0, bo_max_iters: int = 12):
        self.platform = platform
        self.object_store = object_store
        self.param_store = param_store
        self.space = space or ConfigSpace()
        self.scheme = scheme
        self.profile_iters = profile_iters
        self.framework_init_s = framework_init_s
        self.cold_start_s = cold_start_s
        self.seed = seed
        # probes longer than this are aborted and recorded as censored —
        # the resource manager never lets a bad config burn real money
        self.probe_cap_s = probe_cap_s
        self.bo_max_iters = bo_max_iters

    def _space_for(self, w: Workload) -> ConfigSpace:
        """Resource-manager floor: the function must hold model + grads +
        framework (Section 4.1) — prunes configs that could never run."""
        model_mb = int(3 * 4 * w.param_count / 1e6) + 512
        lo = min(max(self.space.min_memory, model_mb),
                 self.space.max_memory - 1)
        return ConfigSpace(min_workers=self.space.min_workers,
                           max_workers=self.space.max_workers,
                           min_memory=lo, max_memory=self.space.max_memory,
                           memory_step=self.space.memory_step)

    # -- Bayesian re-optimization (triggered on training-dynamics change) ----
    def optimize(self, w: Workload, batch: int, goal: Goal,
                 epochs_remaining: int, samples: Optional[int],
                 warm_start: Optional[Config] = None):
        """``warm_start`` (beyond-paper): seed the GP with the previous
        deployment's config — good configs correlate across similar
        workloads, so a warm re-optimization needs ~half the probes."""
        limit = None
        if goal.kind == "min_cost_deadline":
            limit = goal.deadline_s
        elif goal.kind == "min_time_budget":
            limit = goal.budget_usd
        space = self._space_for(w)
        max_iters = self.bo_max_iters
        if warm_start is not None:
            max_iters = max(self.bo_max_iters // 2, 4)
        bo = BayesianOptimizer(space, constraint_limit=limit,
                               seed=self.seed, max_iters=max_iters)
        seeds = []
        if warm_start is not None:
            seeds = [Config(min(max(warm_start.workers, space.min_workers),
                                space.max_workers),
                            min(max(warm_start.memory_mb, space.min_memory),
                                space.max_memory))]
        t_prof = usd_prof = 0.0
        while not bo.done():
            c = seeds.pop(0) if seeds else bo.suggest()
            pt, pu, _ = profile_cost(
                w, self.scheme, c, batch, self.param_store, self.object_store,
                self.profile_iters, framework_init_s=self.framework_init_s,
                cold_start_s=self.cold_start_s)
            if pt > self.probe_cap_s:
                # censored probe: abort at the cap, record a pessimistic
                # objective so the GP steers away without full payment
                frac = self.probe_cap_s / pt
                t_prof += self.probe_cap_s
                usd_prof += pu * frac
                worst = max((o.objective for o in bo.obs), default=1.0)
                bo.observe(c, worst * 10.0,
                           None if limit is None else limit * 10.0)
                continue
            t_prof += pt
            usd_prof += pu
            est = epoch_estimate(
                w, self.scheme, c, batch, self.param_store, self.object_store,
                framework_init_s=self.framework_init_s,
                cold_start_s=self.cold_start_s, samples=samples)
            total_t = est.wall_s * epochs_remaining
            total_c = est.cost_usd * epochs_remaining
            obj, cons, _ = goal.objective_and_constraint(total_t, total_c)
            bo.observe(c, obj, cons)
        # probes run real training iterations (the paper profiles live
        # throughput) — those samples count toward the epoch
        useful = sum(1 for o in bo.obs) * self.profile_iters * batch
        return bo.best().config, t_prof, usd_prof, useful

    # -- main loop ------------------------------------------------------------
    def run(self, plans: List[EpochPlan], goal: Goal, *, adaptive: bool = True,
            fixed_config: Optional[Config] = None,
            stop_at_deadline: bool = False) -> RunResult:
        events: List[TraceEvent] = []
        t = 0.0
        cost = 0.0
        t_prof = usd_prof = 0.0
        config: Optional[Config] = fixed_config
        last_sig = None
        history: List[Config] = []
        epochs_done = 0
        rng = np.random.RandomState(self.seed)

        for i, plan in enumerate(plans):
            sig = (plan.batch_size, plan.workload.param_count,
                   plan.workload.flops_per_sample)
            profiled_samples = 0
            if config is None or (adaptive and sig != last_sig):
                config, pt, pu, profiled_samples = self.optimize(
                    plan.workload, plan.batch_size, goal,
                    epochs_remaining=len(plans) - i, samples=plan.samples,
                    warm_start=config)
                t += pt
                cost += pu
                t_prof += pt
                usd_prof += pu
                events.append(TraceEvent(t, i, "reoptimize",
                                         workers=config.workers,
                                         memory_mb=config.memory_mb,
                                         batch_size=plan.batch_size,
                                         model_params=plan.workload.param_count,
                                         cost_cum=cost))
            last_sig = sig
            history.append(config)

            samples_plan = plan.samples or plan.workload.dataset_samples
            samples_left = max(samples_plan - profiled_samples,
                               plan.batch_size)
            est = epoch_estimate(
                plan.workload, self.scheme, config, plan.batch_size,
                self.param_store, self.object_store,
                framework_init_s=self.framework_init_s,
                cold_start_s=self.cold_start_s, samples=samples_left)
            # fault injection: failed iterations are redone (Section 4.1)
            failures = int(rng.binomial(est.iters,
                                        self.platform.failure_rate))
            redo_s = failures * est.it_breakdown["total"]
            wall = est.wall_s + redo_s
            epoch_cost = est.cost_usd * (wall / est.wall_s)

            if (stop_at_deadline and goal.deadline_s is not None
                    and t + wall > goal.deadline_s):
                break
            t += wall
            cost += epoch_cost
            self.param_store.keep_alive(est.iters
                                        * est.it_breakdown["comm"])
            self.platform.ledger.charge_fn(
                config.memory_mb * config.workers, wall)
            epochs_done += 1
            events.append(TraceEvent(
                t, i, "epoch", throughput=samples_left / wall,
                workers=config.workers, memory_mb=config.memory_mb,
                batch_size=plan.batch_size,
                model_params=plan.workload.param_count, cost_cum=cost,
                restarts=est.restarts_per_worker, failures=failures))

        return RunResult(events=events, wall_s=t, cost_usd=cost - usd_prof,
                         profile_s=t_prof, profile_usd=usd_prof,
                         epochs_done=epochs_done, config_history=history)
