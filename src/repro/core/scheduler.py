"""SMLT task scheduler (paper Sections 3.1 and 4.1).

Maintains the *overarching view* of the training workflow across stateless
function invocations: monitors per-iteration training dynamics, detects
configuration changes (batch size for dynamic batching, model size for NAS),
re-runs the Bayesian optimizer when they change, redeploys workers at the
new <n_workers, memory> configuration, enforces the function duration cap
with checkpoint/restart, and restarts failed workers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.bayes_opt import BayesianOptimizer, Config, ConfigSpace
from repro.core.comm import CommSpec, parse_scheme
from repro.core.constraints import (Goal, compression_inflation,
                                    staleness_inflation)
from repro.core.cost_model import epoch_estimate, profile_cost
from repro.core.monitor import ThroughputMonitor
from repro.serverless.platform import ServerlessPlatform, fleet_from_config
from repro.serverless.stores import ObjectStore, ParamStore
from repro.serverless.worker import Workload


@dataclasses.dataclass
class EpochPlan:
    """One epoch of the (possibly dynamic) workflow."""
    batch_size: int
    workload: Workload                 # may differ per epoch (NAS)
    samples: Optional[int] = None      # online learning: samples that arrived


@dataclasses.dataclass
class TraceEvent:
    t: float
    epoch: int
    kind: str                          # "epoch" | "profile" | "reoptimize"
    throughput: float = 0.0            # samples / s
    workers: int = 0
    memory_mb: int = 0
    batch_size: int = 0
    model_params: int = 0
    cost_cum: float = 0.0
    restarts: int = 0                  # duration-cap restarts, per worker
    failures: int = 0


@dataclasses.dataclass
class RunResult:
    events: List[TraceEvent]
    wall_s: float
    cost_usd: float
    profile_s: float
    profile_usd: float
    epochs_done: int
    config_history: List[Config]

    @property
    def total_cost(self):
        return self.cost_usd + self.profile_usd


class TaskScheduler:
    def __init__(self, platform: ServerlessPlatform,
                 object_store: ObjectStore, param_store: ParamStore, *,
                 space: Optional[ConfigSpace] = None, scheme: str = "hier",
                 profile_iters: int = 3, framework_init_s: float = 4.0,
                 cold_start_s: float = 2.0, seed: int = 0,
                 probe_cap_s: float = 180.0, bo_max_iters: int = 12,
                 engine: str = "analytic",
                 engine_opts: Optional[Dict] = None,
                 mid_epoch_adapt: bool = True):
        self.platform = platform
        self.object_store = object_store
        self.param_store = param_store
        self.space = space or ConfigSpace()
        self.scheme = scheme
        self.profile_iters = profile_iters
        self.framework_init_s = framework_init_s
        self.cold_start_s = cold_start_s
        self.seed = seed
        # probes longer than this are aborted and recorded as censored —
        # the resource manager never lets a bad config burn real money
        self.probe_cap_s = probe_cap_s
        self.bo_max_iters = bo_max_iters
        # "analytic": closed-form epoch_estimate (fast path; BO probes
        # always use it). "event": epochs execute on the discrete-event
        # engine (stragglers, failures, sync modes via ``engine_opts``),
        # and per-iteration completions feed a ThroughputMonitor that can
        # abort + re-optimize *mid-epoch* when throughput drifts.
        if engine not in ("analytic", "event"):
            raise ValueError(engine)
        self.engine = engine
        self.engine_opts = dict(engine_opts or {})
        self.mid_epoch_adapt = mid_epoch_adapt

    def _space_for(self, w: Workload) -> ConfigSpace:
        """Resource-manager floor: the function must hold model + grads +
        framework (Section 4.1) — prunes configs that could never run.
        The fleet-composition and comm-plan search dimensions carry over
        from the scheduler's space."""
        model_mb = int(3 * 4 * w.param_count / 1e6) + 512
        lo = min(max(self.space.min_memory, model_mb),
                 self.space.max_memory - 1)
        return dataclasses.replace(self.space, min_memory=lo)

    def _comm_for(self, config: Config):
        """The communication schedule a config deploys: the scheduler's
        default scheme unless the optimizer searched the comm dimensions
        (``Config.comm``/``compress_ratio``/``branching``/
        ``pipeline_depth``)."""
        if (not config.comm and config.compress_ratio >= 1.0
                and config.branching <= 0 and config.pipeline_depth <= 1):
            return self.scheme
        base = (parse_scheme(self.scheme) if not config.comm
                else CommSpec(config.comm))
        return dataclasses.replace(base, ratio=config.compress_ratio,
                                   branching=(config.branching
                                              if base.strategy == "hier"
                                              else 0),
                                   pipeline_depth=max(config.pipeline_depth,
                                                      1))

    # -- Bayesian re-optimization (triggered on training-dynamics change) ----
    def optimize(self, w: Workload, batch: int, goal: Goal,
                 epochs_remaining: int, samples: Optional[int],
                 warm_start: Optional[Config] = None):
        """``warm_start`` (beyond-paper): seed the GP with the previous
        deployment's config — good configs correlate across similar
        workloads, so a warm re-optimization needs ~half the probes."""
        limit = None
        if goal.kind == "min_cost_deadline":
            limit = goal.deadline_s
        elif goal.kind == "min_time_budget":
            limit = goal.budget_usd
        space = self._space_for(w)
        max_iters = self.bo_max_iters
        if warm_start is not None:
            max_iters = max(self.bo_max_iters // 2, 4)
        bo = BayesianOptimizer(space, constraint_limit=limit,
                               seed=self.seed, max_iters=max_iters)
        seeds = []
        if warm_start is not None:
            # keep the fleet-composition dimension: the warm-start probe
            # must profile the deployment that was actually running
            seeds = [Config(min(max(warm_start.workers, space.min_workers),
                                space.max_workers),
                            min(max(warm_start.memory_mb, space.min_memory),
                                space.max_memory),
                            warm_start.small_frac, warm_start.comm,
                            warm_start.compress_ratio, warm_start.branching,
                            warm_start.pipeline_depth)]
        t_prof = usd_prof = 0.0
        while not bo.done():
            c = seeds.pop(0) if seeds else bo.suggest()
            comm = self._comm_for(c)
            pt, pu, _ = profile_cost(
                w, comm, c, batch, self.param_store, self.object_store,
                self.profile_iters, framework_init_s=self.framework_init_s,
                cold_start_s=self.cold_start_s)
            if pt > self.probe_cap_s:
                # censored probe: abort at the cap, record a pessimistic
                # objective so the GP steers away without full payment
                frac = self.probe_cap_s / pt
                t_prof += self.probe_cap_s
                usd_prof += pu * frac
                worst = max((o.objective for o in bo.obs), default=1.0)
                bo.observe(c, worst * 10.0,
                           None if limit is None else limit * 10.0)
                continue
            t_prof += pt
            usd_prof += pu
            est = epoch_estimate(
                w, comm, c, batch, self.param_store, self.object_store,
                framework_init_s=self.framework_init_s,
                cold_start_s=self.cold_start_s, samples=samples)
            total_t = est.wall_s * epochs_remaining
            total_c = est.cost_usd * epochs_remaining
            # convergence-aware objective: a relaxed sync mode buys
            # wall-clock per epoch, a top-k ratio buys wire bytes — both
            # pay iterations-to-converge, so judge the candidate on
            # inflated time and dollars
            infl = staleness_inflation(
                self.engine_opts.get("sync_mode", "bsp"),
                self.engine_opts.get("staleness", 0), c.workers)
            infl *= compression_inflation(c.compress_ratio)
            obj, cons, _ = goal.objective_and_constraint(total_t, total_c,
                                                         inflation=infl)
            bo.observe(c, obj, cons)
        # probes run real training iterations (the paper profiles live
        # throughput) — those samples count toward the epoch
        useful = sum(1 for o in bo.obs) * self.profile_iters * batch
        return bo.best().config, t_prof, usd_prof, useful

    # -- event-engine epoch execution ----------------------------------------
    def _run_epoch_event(self, plan: EpochPlan, goal: Goal, config: Config,
                         samples_left: int, epoch_i: int, n_plans: int,
                         adaptive: bool, events: List[TraceEvent],
                         t_base: float, cost_base: float):
        """Execute one epoch on the discrete-event engine, in chunks: when
        the per-iteration ThroughputMonitor detects a sustained drift, the
        engine checkpoints and stops, we re-optimize *mid-epoch*, and the
        remaining samples run under the new deployment."""
        # deferred: events consumes the CommPlan IR from repro.core, so a
        # top-level import here would close an import cycle
        from repro.serverless.events import EventEngine
        wall = cost = 0.0
        restarts = failures = 0
        t_prof = usd_prof = 0.0
        configs: List[Config] = []
        remaining = samples_left
        attempt = 0
        iters_epoch = 0
        while remaining > 0:
            monitor = ThroughputMonitor()

            def on_it(g, t_now, dt, _m=monitor, _b=plan.batch_size):
                if dt <= 0 or not (adaptive and self.mid_epoch_adapt):
                    return False
                return _m.observe(_b / dt)

            opts = {"failure_rate": self.platform.failure_rate,
                    **self.engine_opts}
            # a slowdown injection is an epoch-level regression: keep its
            # onset fixed in epoch-iteration space across restarted chunks
            if opts.get("slowdown_at_iter") is not None:
                opts["slowdown_at_iter"] = max(
                    opts["slowdown_at_iter"] - iters_epoch, 0)
            # a searched fleet composition deploys as its mixed fleet
            # (an explicit engine_opts fleet overrides the config's)
            if config.small_frac > 0.0 and "fleet" not in opts:
                opts["fleet"] = fleet_from_config(
                    config.workers, config.memory_mb, config.small_frac)
            r = EventEngine(
                plan.workload, self._comm_for(config), config.workers,
                config.memory_mb,
                plan.batch_size, self.param_store, self.object_store,
                platform=self.platform,
                framework_init_s=self.framework_init_s,
                cold_start_s=self.cold_start_s,
                max_duration_s=self.platform.max_duration_s,
                samples=remaining, seed=self.seed + 7919 * epoch_i + attempt,
                on_iteration=on_it, trace_enabled=False, **opts).run()
            wall += r.wall_s
            cost += r.cost_usd
            # EngineResult.restarts is fleet-wide; TraceEvent.restarts is
            # per worker (matching the analytic path's restarts_per_worker)
            restarts += round(r.restarts / config.workers)
            failures += r.failures
            remaining -= max(r.samples_done, plan.batch_size)
            iters_epoch += r.iters_done
            attempt += 1
            if r.stopped_early and remaining > 0 and adaptive:
                config, pt, pu, profiled = self.optimize(
                    plan.workload, plan.batch_size, goal,
                    epochs_remaining=n_plans - epoch_i, samples=remaining,
                    warm_start=config)
                t_prof += pt
                usd_prof += pu
                remaining = max(remaining - profiled, 0)
                configs.append(config)
                events.append(TraceEvent(
                    t_base + wall + t_prof, epoch_i, "reoptimize_mid",
                    workers=config.workers, memory_mb=config.memory_mb,
                    batch_size=plan.batch_size,
                    model_params=plan.workload.param_count,
                    cost_cum=cost_base + cost + usd_prof))
            elif not r.stopped_early:
                break
        meta = {"t_prof": t_prof, "usd_prof": usd_prof, "configs": configs}
        return wall, cost, restarts, failures, config, meta

    # -- main loop ------------------------------------------------------------
    def run(self, plans: List[EpochPlan], goal: Goal, *, adaptive: bool = True,
            fixed_config: Optional[Config] = None,
            stop_at_deadline: bool = False) -> RunResult:
        events: List[TraceEvent] = []
        t = 0.0
        cost = 0.0
        t_prof = usd_prof = 0.0
        config: Optional[Config] = fixed_config
        last_sig = None
        history: List[Config] = []
        epochs_done = 0
        rng = np.random.RandomState(self.seed)

        for i, plan in enumerate(plans):
            sig = (plan.batch_size, plan.workload.param_count,
                   plan.workload.flops_per_sample)
            profiled_samples = 0
            if config is None or (adaptive and sig != last_sig):
                config, pt, pu, profiled_samples = self.optimize(
                    plan.workload, plan.batch_size, goal,
                    epochs_remaining=len(plans) - i, samples=plan.samples,
                    warm_start=config)
                t += pt
                cost += pu
                t_prof += pt
                usd_prof += pu
                events.append(TraceEvent(t, i, "reoptimize",
                                         workers=config.workers,
                                         memory_mb=config.memory_mb,
                                         batch_size=plan.batch_size,
                                         model_params=plan.workload.param_count,
                                         cost_cum=cost))
            last_sig = sig
            history.append(config)

            samples_plan = plan.samples or plan.workload.dataset_samples
            samples_left = max(samples_plan - profiled_samples,
                               plan.batch_size)

            if self.engine == "event":
                # the epoch actually executed (stores + ledger already
                # carry its side effects); a later deadline break only
                # drops it from the result totals
                wall, epoch_cost, restarts, failures, config, meta = \
                    self._run_epoch_event(plan, goal, config, samples_left,
                                          i, len(plans), adaptive, events,
                                          t, cost)
                t_prof += meta["t_prof"]
                usd_prof += meta["usd_prof"]
                t += meta["t_prof"]
                cost += meta["usd_prof"]
                history.extend(meta["configs"])
                commit = None
            else:
                est = epoch_estimate(
                    plan.workload, self._comm_for(config), config,
                    plan.batch_size, self.param_store, self.object_store,
                    framework_init_s=self.framework_init_s,
                    cold_start_s=self.cold_start_s, samples=samples_left)
                # fault injection: failed iterations are redone (Section 4.1)
                failures = int(rng.binomial(est.iters,
                                            self.platform.failure_rate))
                redo_s = failures * est.it_breakdown["total"]
                wall = est.wall_s + redo_s
                epoch_cost = est.cost_usd * (wall / est.wall_s)
                restarts = est.restarts_per_worker

                def commit(est=est, wall=wall, config=config):
                    # per-phase store-busy time from the plan (re-upload
                    # fan-in included, decompress CPU excluded) — the
                    # same basis epoch_estimate bills store_usd on
                    self.param_store.keep_alive(
                        est.iters * est.it_breakdown["store_busy"])
                    # Lambda semantics: every worker is a request, and every
                    # duration-cap restart re-invokes the whole fleet
                    self.platform.ledger.charge_fleet(
                        config.memory_mb, config.workers, wall,
                        invocations_per_worker=est.restarts_per_worker + 1)

            if (stop_at_deadline and goal.deadline_s is not None
                    and t + wall > goal.deadline_s):
                break
            if commit is not None:
                commit()      # deadline-skipped epochs are never billed
            t += wall
            cost += epoch_cost
            epochs_done += 1
            events.append(TraceEvent(
                t, i, "epoch", throughput=samples_left / wall,
                workers=config.workers, memory_mb=config.memory_mb,
                batch_size=plan.batch_size,
                model_params=plan.workload.param_count, cost_cum=cost,
                restarts=restarts, failures=failures))

        return RunResult(events=events, wall_s=t, cost_usd=cost - usd_prof,
                         profile_s=t_prof, profile_usd=usd_prof,
                         epochs_done=epochs_done, config_history=history)
