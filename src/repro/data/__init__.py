from repro.data.pipeline import (  # noqa: F401
    DataConfig, IteratorState, OnlineStream, ShardedLoader, TokenDataset)
