"""Data pipeline: sharded synthetic token stream + the paper's data-iterator
semantics (per-worker shards from the object store, resumable position
tracking for function restarts, online-learning arrival stream).

Real corpora are out of scope offline; the pipeline generates deterministic
pseudo-token streams keyed by (seed, epoch, shard) so restarts and elastic
rescaling are exactly reproducible — which is what the paper's data iterator
bookkeeping guarantees (Section 4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


def _base_stream(seed: int):
    """Deferred: ``repro.core.__init__`` reaches back into this module
    via cost_model → serverless → arrivals, so a top-level import of
    ``repro.core.rng`` makes ``import repro.data`` circular."""
    from repro.core.rng import base_stream
    return base_stream(seed)


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    dataset_tokens: int = 1 << 22
    seed: int = 0


class TokenDataset:
    """Deterministic synthetic LM dataset with markov-ish structure (so loss
    actually decreases during the example training runs)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = _base_stream(cfg.seed)
        # low-entropy transition structure: next token ~ f(prev token)
        self._shift = rng.randint(1, 17)
        self._noise = 0.1

    def sample(self, epoch: int, index: int, n: int, seq: int) -> np.ndarray:
        rng = _base_stream(
            (self.cfg.seed * 1_000_003 + epoch * 7919 + index) % (2 ** 31))
        start = rng.randint(0, self.cfg.vocab_size, size=(n, 1))
        steps = rng.randint(0, self.cfg.vocab_size, size=(n, seq))
        noisy = rng.random_sample((n, seq)) < self._noise
        out = np.zeros((n, seq), np.int32)
        cur = start[:, 0]
        for t in range(seq):
            cur = np.where(noisy[:, t], steps[:, t],
                           (cur + self._shift) % self.cfg.vocab_size)
            out[:, t] = cur
        return out


@dataclasses.dataclass
class IteratorState:
    """Resumable position (paper: 'tracks which training data points have
    been processed ... in case the worker needs to resume after a restart')."""
    epoch: int = 0
    index: int = 0  # samples consumed within the epoch


class ShardedLoader:
    """Yields global batches; each logical worker's slice is contiguous, so
    the same stream can be re-sliced when the fleet is rescaled."""

    def __init__(self, ds: TokenDataset, state: Optional[IteratorState] = None):
        self.ds = ds
        self.state = state or IteratorState()

    def next_batch(self, global_batch: int) -> Dict[str, np.ndarray]:
        s = self.state
        toks = self.ds.sample(s.epoch, s.index, global_batch, self.ds.cfg.seq_len)
        s.index += global_batch
        epoch_samples = self.ds.cfg.dataset_tokens // self.ds.cfg.seq_len
        if s.index >= epoch_samples:
            s.epoch += 1
            s.index = 0
        return {"tokens": toks, "labels": toks.copy()}


class OnlineStream:
    """Online-learning arrival process: samples/sec with diurnal variation
    (drives the paper's 24-hour online-training experiment, Fig. 11b)."""

    def __init__(self, base_rate: float, seed: int = 0,
                 period_s: float = 86_400.0, amplitude: float = 0.5):
        self.base_rate = base_rate
        self.period = period_s
        self.amp = amplitude
        self.rng = _base_stream(seed)

    def arrivals(self, t0: float, dt: float) -> int:
        mid = t0 + dt / 2
        rate = self.base_rate * (1 + self.amp * np.sin(2 * np.pi * mid / self.period))
        return int(self.rng.poisson(max(rate, 0.0) * dt))
