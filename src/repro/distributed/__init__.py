from repro.distributed.sharding import (  # noqa: F401
    batch_specs, cache_specs, named, opt_state_specs, param_specs)
