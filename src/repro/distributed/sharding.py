"""Parameter / activation / cache sharding rules for the production meshes.

Rules are path+shape driven and uniform across the model zoo:

 - tensor parallelism over the ``model`` axis: attention head dims, FFN
   hidden dims, MoE expert axis (expert parallelism), SSM head/inner dims,
   vocab dim of embed/unembed;
 - batch over ``data`` (x ``pod`` on the multi-pod mesh);
 - optional FSDP (ZeRO-3-style) over ``data`` for weight storage — the
   paper's hierarchical "shard the state, gather on demand" insight applied
   to parameters (used for the big decode configs and the ``hier`` training
   strategy's optimizer state).

Each rule lists candidate dim assignments in preference order; the first
whose dims all divide evenly by the mesh axis wins (e.g. qwen2-moe's 60
experts don't divide a 16-way model axis, so expert parallelism falls back
to per-expert FFN tensor parallelism). Stacked-layer leaves (under
blocks/encoder/decoder/cross) keep their leading layer axis unsharded.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# path-regex -> list of candidate {dim-from-right: axis} assignments
_RULES = [
    (r"embed/tok$",        [{-2: "model"}, {-1: "model"}]),   # (V, d)
    (r"embed/unembed$",    [{-1: "model"}]),                  # (d, V)
    (r"attn/w[qkv]$|self_attn/w[qkv]$|cross_attn/w[qkv]$", [{-1: "model"}]),
    (r"attn/wo$|self_attn/wo$|cross_attn/wo$", [{-2: "model"}]),
    (r"attn/b[qkv]$",      [{-1: "model"}]),
    (r"mlp/wi$|mlp/wg$|shared/wi$|shared/wg$|dense/wi$|dense/wg$",
                           [{-1: "model"}]),
    (r"mlp/wo$|shared/wo$|dense/wo$", [{-2: "model"}]),
    # MoE: expert parallel if E divides, else per-expert tensor parallel
    (r"experts/wi$|experts/wg$", [{-3: "model"}, {-1: "model"}]),
    (r"experts/wo$",       [{-3: "model"}, {-2: "model"}]),
    (r"router$",           [{}]),
    (r"/wz$|/wx$",         [{-1: "model"}]),          # (d, d_inner)
    (r"/wdt$",             [{-1: "model"}]),          # (d, nh)
    (r"/wB$|/wC$",         [{}]),                     # small, replicated
    (r"dt_bias$|A_log$|/D$", [{-1: "model"}]),        # (nh,)
    (r"conv_x$",           [{-1: "model"}]),          # (W, d_inner)
    (r"conv_BC$",          [{}]),
    (r"gate_ln/scale$",    [{-1: "model"}]),          # (d_inner,)
    (r"blocks/wo$",        [{-2: "model"}]),          # mamba out proj
    (r"vision_proj$|audio_proj$", [{}]),
]

_STACKED = re.compile(r"^(blocks|encoder|decoder|cross)/")


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _assign(path: str, shape, model_size: int):
    """Pick the first candidate assignment whose dims divide evenly."""
    ndim = len(shape)
    stacked = bool(_STACKED.match(path))
    for pat, cands in _RULES:
        if re.search(pat, path):
            for cand in cands:
                ok = True
                for off, _ax in cand.items():
                    i = ndim + off
                    if i < 0 or (stacked and i == 0) \
                            or shape[i] % model_size != 0:
                        ok = False
                        break
                if ok:
                    return cand, stacked
            return {}, stacked
    return {}, stacked


def _leaf_spec(path: str, shape, *, model_size: int,
               fsdp_axis: Optional[str] = None, fsdp_min_size: int = 0,
               fsdp_divisor: int = 1) -> P:
    ndim = len(shape)
    dims, stacked = _assign(path, shape, model_size)
    entries = [None] * ndim
    for off, ax in dims.items():
        entries[ndim + off] = ax
    size = int(np.prod(shape)) if shape else 1
    if fsdp_axis and size >= fsdp_min_size:
        cands = [i for i in range(1 if stacked else 0, ndim)
                 if entries[i] is None and shape[i] % fsdp_divisor == 0
                 and shape[i] >= fsdp_divisor]
        if cands:
            i = max(cands, key=lambda i: shape[i])
            entries[i] = fsdp_axis
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_specs(params_shapes, *, model_size: int = 1,
                fsdp_axis: Optional[str] = None,
                fsdp_min_size: int = 2 ** 20, fsdp_divisor: int = 1):
    """Pytree of PartitionSpec mirroring ``params_shapes`` (from eval_shape)."""

    def f(path, leaf):
        return _leaf_spec(_path_str(path), leaf.shape, model_size=model_size,
                          fsdp_axis=fsdp_axis, fsdp_min_size=fsdp_min_size,
                          fsdp_divisor=fsdp_divisor)

    return jax.tree_util.tree_map_with_path(f, params_shapes)


def batch_specs(batch_shapes, data_axes, *, data_size: int = 1):
    """Shard dim 0 (global batch) of every input over the data(-like) axes.
    Batches that don't divide (e.g. long_500k's batch=1) stay replicated."""
    return jax.tree.map(
        lambda x: P(data_axes) if x.shape and x.shape[0] % data_size == 0
        else P(), batch_shapes)


# second entry in the "model" tuple is the fallback dim when the first
# doesn't divide the axis (e.g. kv=8 heads on a 16-way model axis -> shard
# the 128-wide head_dim instead; GSPMD handles the sharded contraction)
_CACHE_RULES = [
    (r"(^|/)[kv]$", {1: ("data",), -2: ("model", -1)}),  # (L, b, s, kv, hd)
    (r"ssm$",    {1: ("data",), 2: ("model", 3)}),       # (L, b, nh, n, p)
    (r"conv_x$", {1: ("data",), -1: ("model",)}),        # (L, b, W-1, d_in)
    (r"conv_BC$", {1: ("data",)}),
]


def cache_specs(cache_shapes, data_axes, *, model_size: int = 1,
                data_size: int = 1):
    """KV/SSM cache specs: batch over data, heads/channels over model.
    Axes that don't divide evenly are left replicated."""

    def f(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        ndim = len(shape)
        entries = [None] * ndim
        for pat, rule in _CACHE_RULES:
            if re.search(pat, p):
                for d, spec in rule.items():
                    idx = d if d >= 0 else ndim + d
                    if spec[0] == "data":
                        if shape[idx] % data_size == 0:
                            entries[idx] = data_axes
                        continue
                    # "model" with optional fallback dim
                    cands = [idx] + [c if c >= 0 else ndim + c
                                     for c in spec[1:]]
                    for c in cands:
                        if entries[c] is None and shape[c] % model_size == 0:
                            entries[c] = "model"
                            break
                break
        else:
            if ndim >= 2 and shape[1] % data_size == 0:
                entries[1] = data_axes
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree_util.tree_map_with_path(f, cache_shapes)


def opt_state_specs(pspecs):
    """Optimizer-state specs mirror the parameter specs leaf-for-leaf."""
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(), mu=pspecs, nu=pspecs)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
