"""Pallas TPU kernels for the framework's compute hot-spots.

 - hier_agg:        sharded gradient mean-aggregation + fused SGD apply
                    (the paper's shard-aggregator hot loop)
 - flash_attention: online-softmax causal/sliding-window attention
 - ssd_scan:        Mamba2 chunked SSD scan with VMEM-resident state

``ops`` holds the jit'd padded wrappers (differentiable where training
needs it); ``ref`` the independent pure-jnp oracles. All kernels validate
in interpret mode on CPU; on TPU pass interpret=False.
"""
