"""Pallas TPU kernel: causal flash attention (forward).

Used for the 32k prefill shapes: O(seq^2) attention without materializing
the score matrix in HBM. Online-softmax accumulation in VMEM scratch; the
grid is (batch*heads, q_blocks, k_blocks) with the k axis innermost so the
(m, l, acc) running state lives in VMEM across k iterations. Fully-masked
k-blocks (k_start > q_end under the causal/sliding-window mask) are skipped
with @pl.when — the same block-sparsity the dense models rely on for the
sliding-window long-context variant.

MXU alignment: block_q x head_dim and block_k x head_dim tiles at 128
multiples; scores computed in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, seq_k: int, causal: bool,
                  window: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip blocks fully above the causal diagonal / outside the window
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window:
        run = jnp.logical_and(run,
                              k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run)
    def body():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                     # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = qpos >= kpos
        if window:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = True):
    """q: (b, h, sq, d); k, v: (b, h, sk, d) -> (b, h, sq, d).

    seq lengths must be multiples of the block sizes (ops.py pads).
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    assert sq % block_q == 0 and sk % block_k == 0
    bh = b * h
    qf = q.reshape(bh, sq, d)
    kf = k.reshape(bh, sk, d)
    vf = v.reshape(bh, sk, d)
    grid = (bh, sq // block_q, sk // block_k)
    kern = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_k=sk,
        causal=causal, window=window, scale=d ** -0.5)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
