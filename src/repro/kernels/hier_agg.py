"""Pallas TPU kernel: sharded gradient aggregation (the paper's hot loop).

The shard aggregator (Fig. 5, step 3) computes the mean of its assigned
shard across all n workers. On TPU this is the per-device compute inside
the reduce-scatter: each device reduces an (n_workers, shard_len) tile it
received. The kernel tiles shard_len into VMEM-resident blocks (the worker
axis stays whole — n is small), accumulates in f32, and optionally fuses
the SGD update (aggregate + apply) so gradients never round-trip to HBM
between aggregation and the optimizer — an SMLT-specific fusion: the paper's
'global aggregator reconstructs the updated model' step.

Block size: (n, 8, 1024) f32 tiles keep the working set << 16 MB VMEM while
keeping the lane dimension at the 128-multiple the VPU wants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(shards_ref, out_ref, *, n_workers: int):
    acc = shards_ref[0].astype(jnp.float32)
    for w in range(1, n_workers):
        acc = acc + shards_ref[w].astype(jnp.float32)
    out_ref[...] = (acc / n_workers).astype(out_ref.dtype)


def _agg_apply_kernel(shards_ref, param_ref, out_ref, *, n_workers: int,
                      lr: float):
    acc = shards_ref[0].astype(jnp.float32)
    for w in range(1, n_workers):
        acc = acc + shards_ref[w].astype(jnp.float32)
    g = acc / n_workers
    out_ref[...] = (param_ref[...].astype(jnp.float32) - lr * g).astype(
        out_ref.dtype)


def _grid_and_specs(n_workers: int, length: int, block: int):
    assert length % block == 0, (length, block)
    grid = (length // block,)
    in_spec = pl.BlockSpec((n_workers, block), lambda i: (0, i))
    out_spec = pl.BlockSpec((block,), lambda i: (i,))
    return grid, in_spec, out_spec


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def aggregate_shards(shards: jax.Array, *, block: int = 8 * 1024,
                     interpret: bool = True) -> jax.Array:
    """shards: (n_workers, shard_len) -> (shard_len,) mean.

    shard_len must be a multiple of ``block`` (ops.py pads).
    """
    n, length = shards.shape
    grid, in_spec, out_spec = _grid_and_specs(n, length, block)
    return pl.pallas_call(
        functools.partial(_agg_kernel, n_workers=n),
        grid=grid,
        in_specs=[in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((length,), shards.dtype),
        interpret=interpret,
    )(shards)


@functools.partial(jax.jit,
                   static_argnames=("lr", "block", "interpret"))
def aggregate_and_apply(shards: jax.Array, param_shard: jax.Array, *,
                        lr: float, block: int = 8 * 1024,
                        interpret: bool = True) -> jax.Array:
    """Fused mean-aggregate + SGD apply on the owned shard.
    shards: (n_workers, shard_len); param_shard: (shard_len,)."""
    n, length = shards.shape
    grid, in_spec, out_spec = _grid_and_specs(n, length, block)
    return pl.pallas_call(
        functools.partial(_agg_apply_kernel, n_workers=n, lr=lr),
        grid=grid,
        in_specs=[in_spec, pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((length,), param_shard.dtype),
        interpret=interpret,
    )(shards, param_shard)
