"""Jit'd public wrappers for the Pallas kernels: pad to block multiples,
invoke the kernel, slice back. ``interpret`` defaults to True (this
container is CPU-only; on a real TPU pass interpret=False)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import hier_agg as _hier
from repro.kernels import flash_attention as _flash
from repro.kernels import ssd_scan as _ssd


def _pad_to(x, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def aggregate_shards(shards, *, block: int = 8 * 1024,
                     interpret: bool = True):
    """(n_workers, L) -> (L,) mean — the paper's shard-aggregator step."""
    n, L = shards.shape
    block = min(block, max(128, L))
    x, pad = _pad_to(shards, 1, block)
    out = _hier.aggregate_shards(x, block=block, interpret=interpret)
    return out[:L]


@functools.partial(jax.jit, static_argnames=("lr", "block", "interpret"))
def aggregate_and_apply(shards, param, *, lr: float,
                        block: int = 8 * 1024, interpret: bool = True):
    n, L = shards.shape
    block = min(block, max(128, L))
    x, _ = _pad_to(shards, 1, block)
    p, _ = _pad_to(param, 0, block)
    out = _hier.aggregate_and_apply(x, p, lr=lr, block=block,
                                    interpret=interpret)
    return out[:L]


def _flash_ref_bhsd(q, k, v, causal, window):
    """Differentiable blockwise reference in (b, h, s, d) layout — used as
    the backward of the Pallas forward (a dedicated bwd kernel is the
    natural next step on real hardware; the vjp-of-blockwise keeps memory
    O(block x s) rather than O(s^2))."""
    from repro.models.layers import blockwise_attention
    out = blockwise_attention(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=causal, sliding_window=window)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_diff(q, k, v, causal, window, block_q, block_k, interpret):
    return _flash_pallas(q, k, v, causal, window, block_q, block_k,
                         interpret)


def _flash_diff_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    out = _flash_pallas(q, k, v, causal, window, block_q, block_k, interpret)
    return out, (q, k, v)


def _flash_diff_bwd(causal, window, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: _flash_ref_bhsd(q, k, v, causal, window),
                     q, k, v)
    return vjp(g)


_flash_diff.defvjp(_flash_diff_fwd, _flash_diff_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = True):
    """(b, h, s, d) attention; pads seq to block multiples. Differentiable:
    Pallas forward + blockwise-jnp backward via custom_vjp."""
    return _flash_diff(q, k, v, causal, window, block_q, block_k, interpret)


def _flash_pallas(q, k, v, causal, window, block_q, block_k, interpret):
    b, h, sq, d = q.shape
    sk = k.shape[2]
    block_q = min(block_q, max(16, sq))
    block_k = min(block_k, max(16, sk))
    qp, pq = _pad_to(q, 2, block_q)
    kp, pk = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    if pk:
        # mask out padded keys via an effective causal structure: padded keys
        # sit at positions >= sk, queries only at < sq <= padded kv end; with
        # causal=True they're already masked for q < sk. For non-causal we
        # must mask explicitly:
        if not causal:
            kp = kp.at[:, :, sk:].set(0)
            # give padded keys -inf scores by zero v and huge negative k? use
            # causal-free path only with window=0 and rely on value zeroing
            # is incorrect -> instead raise:
            raise NotImplementedError(
                "non-causal flash with padded kv not supported; pad inputs")
    out = _flash.flash_attention(qp, kp, vp, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    return out[:, :, :sq]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 256, interpret: bool = True):
    """Mamba2 SSD over (b, s, h, p); pads seq to the chunk multiple."""
    b, s, h, p = x.shape
    chunk = min(chunk, max(16, s))
    xp, pad = _pad_to(x, 1, chunk)
    dtp, _ = _pad_to(dt, 1, chunk)
    Bp, _ = _pad_to(B, 1, chunk)
    Cp, _ = _pad_to(C, 1, chunk)
    y, final = _ssd.ssd_scan(xp, dtp, A, Bp, Cp, D, chunk=chunk,
                             interpret=interpret)
    return y[:, :s], final
