"""Pure-jnp oracles for every Pallas kernel (independent implementations:
naive/sequential forms, not the chunked/blockwise algorithms the kernels
use — so agreement is a real check)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_aggregate(shards):
    """(n, L) -> (L,) mean in f32."""
    return jnp.mean(shards.astype(jnp.float32), axis=0).astype(shards.dtype)


def ref_aggregate_apply(shards, param, lr: float):
    g = jnp.mean(shards.astype(jnp.float32), axis=0)
    return (param.astype(jnp.float32) - lr * g).astype(param.dtype)


def ref_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """Naive full-softmax attention. q: (b, h, sq, d), k/v: (b, h, sk, d)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * d ** -0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ref_ssd(x, dt, A, B, C, D):
    """Sequential (per-token) SSD recurrence — the O(s) definition.
    x: (b, s, h, p)  dt: (b, s, h)  A, D: (h,)  B, C: (b, s, n)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def step(S, t):
        xt, dtt, Bt, Ct = xf[:, t], dtf[:, t], Bf[:, t], Cf[:, t]
        dA = jnp.exp(dtt * Af)                                # (b, h)
        S = S * dA[..., None, None] + jnp.einsum(
            "bn,bhp->bhnp", Bt, xt * dtt[..., None])
        y = jnp.einsum("bn,bhnp->bhp", Ct, S)
        return S, y

    S0 = jnp.zeros((b, h, n, p), jnp.float32)
    S, ys = jax.lax.scan(step, S0, jnp.arange(s))
    y = ys.transpose(1, 0, 2, 3) + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), S
