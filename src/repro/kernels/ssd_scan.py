"""Pallas TPU kernel: Mamba2 SSD chunked scan (forward).

One (batch, head) pair per grid row; the chunk axis is the innermost grid
dimension, so the recurrent state S (n x p) stays resident in VMEM scratch
across chunk iterations — the inter-chunk linear recurrence never touches
HBM. Per chunk the kernel computes the intra-chunk masked CB^T decay matmul
(the "dual" attention form), adds the carried-state contribution, and
updates S.

VMEM working set per step (chunk=256, n=128, p=64, f32):
  x (256x64) + B,C (256x128) + scores (256x256) + S (128x64) ≈ 0.6 MB.
MXU work is the (256x128)@(128x256) CB product and (256x256)@(256x64)
score-x product — both 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, final_ref,
                state_ref, *, chunk: int, nstate: int, headdim: int):
    # note: outputs (y_ref, final_ref) precede scratch (state_ref)
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, p)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1)
    A = a_ref[0].astype(jnp.float32)          # (1, 1)
    B = b_ref[0].astype(jnp.float32)          # (Q, n)
    C = c_ref[0].astype(jnp.float32)          # (Q, n)
    D = d_ref[0].astype(jnp.float32)          # (1, 1)

    dA = dt * A                               # (Q, 1)
    seg = jnp.cumsum(dA, axis=0)              # (Q, 1)
    xdt = x * dt                              # (Q, p)

    # intra-chunk: masked decayed CB^T
    CB = C @ B.T                              # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = ii >= jj
    diff = jnp.where(causal, seg - seg.T, -jnp.inf)   # seg_i - seg_j
    y = (CB * jnp.exp(diff)) @ xdt            # (Q, p)

    # carried-state contribution
    S = state_ref[...]                        # (n, p)
    y = y + jnp.exp(seg) * (C @ S)

    # state update
    seg_last = seg[chunk - 1:chunk, :]        # (1, 1)
    decay_to_end = jnp.exp(seg_last - seg)    # (Q, 1)
    state_ref[...] = S * jnp.exp(seg_last) + B.T @ (xdt * decay_to_end)

    y_ref[0] = (y + D * x).astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def fin():
        final_ref[0] = state_ref[...].astype(final_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 256, interpret: bool = True):
    """x: (b, s, h, p)  dt: (b, s, h)  A, D: (h,)  B, C: (b, s, n)
    -> (y: (b, s, h, p), final_state: (b, h, n, p)).

    s must be a multiple of ``chunk`` (ops.py pads).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    bh = b * h
    # lay out (b*h, s, ...) with B/C broadcast over heads
    xr = x.transpose(0, 2, 1, 3).reshape(bh, s, p)
    dtr = dt.transpose(0, 2, 1).reshape(bh, s, 1)
    Br = jnp.broadcast_to(B[:, None], (b, h, s, n)).reshape(bh, s, n)
    Cr = jnp.broadcast_to(C[:, None], (b, h, s, n)).reshape(bh, s, n)
    Ar = jnp.broadcast_to(A[None], (b, h)).reshape(bh, 1, 1)
    Dr = jnp.broadcast_to(D[None], (b, h)).reshape(bh, 1, 1)

    grid = (bh, nc)
    kern = functools.partial(_ssd_kernel, chunk=chunk, nstate=n, headdim=p)
    y, final = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, 1, 1), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, 1, 1), lambda g, i: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, n, p), lambda g, i: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, Ar, Br, Cr, Dr)
    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    final = final.reshape(b, h, n, p)
    return y, final
