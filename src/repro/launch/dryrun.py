import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers AND compiles for the production meshes, and extract the
roofline terms (FLOPs / bytes / collective bytes) from the compiled module.

MUST be run as its own process (the XLA_FLAGS line above must execute
before jax initializes devices):

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCHS, input_specs, pairs, supports
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (decode_cache_shapes, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models import registry
from repro.models.base import INPUT_SHAPES
from repro.optim.adamw import AdamW

from repro.launch.hlo_stats import collective_stats  # noqa: E402


def _sds_with(shapes, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def lower_one(arch_id: str, shape_name: str, *, multi_pod: bool,
              strategy: str = "hier", fsdp: bool = True,
              remat: bool = True, mesh_shape: Optional[str] = None,
              overrides: Optional[Dict] = None) -> Dict:
    cfg = ARCHS[arch_id]
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = INPUT_SHAPES[shape_name]
    if mesh_shape:
        from repro.launch.mesh import make_custom_mesh
        mesh = make_custom_mesh(mesh_shape)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    # mesh context: bare-PartitionSpec constraints (sequence parallelism)
    # resolve against it; reset to the empty mesh afterwards
    ctx = jax.set_mesh(mesh)
    ctx.__enter__()
    try:
        return _lower_inner(cfg, shape, mesh, arch_id, shape_name,
                            multi_pod, strategy, fsdp, remat, mesh_shape,
                            overrides, t0)
    finally:
        ctx.__exit__(None, None, None)


def _lower_inner(cfg, shape, mesh, arch_id, shape_name, multi_pod, strategy,
                 fsdp, remat, mesh_shape, overrides, t0):
    if shape.kind == "train":
        cfg = cfg.replace(remat=remat)
        opt = AdamW(lr=3e-4)
        step, pshard, oshard, bshard_fn = make_train_step(
            cfg, mesh, strategy=strategy, fsdp=fsdp, optimizer=opt,
            donate=True)
        pshapes = jax.eval_shape(
            lambda k: registry.init(k, cfg), jax.random.key(0))
        oshapes = jax.eval_shape(opt.init, pshapes)
        bspecs = input_specs(cfg, shape)
        args = (_sds_with(pshapes, pshard),
                _sds_with(oshapes, oshard),
                _sds_with(bspecs, bshard_fn(bspecs)))
        lowered = step.lower(*args)
    elif shape.kind == "prefill":
        step, pshard, bshard_fn = make_prefill_step(cfg, mesh, fsdp=fsdp)
        pshapes = jax.eval_shape(
            lambda k: registry.init(k, cfg), jax.random.key(0))
        bspecs = input_specs(cfg, shape)
        lowered = step.lower(_sds_with(pshapes, pshard),
                             _sds_with(bspecs, bshard_fn(bspecs)))
    else:  # decode
        step, pshard, cshard_fn, bshard_fn = make_serve_step(cfg, mesh,
                                                             fsdp=fsdp)
        pshapes = jax.eval_shape(
            lambda k: registry.init(k, cfg), jax.random.key(0))
        specs = input_specs(cfg, shape)
        extras = {k: v for k, v in specs.items()
                  if k not in ("tokens", "pos")}
        cshapes = decode_cache_shapes(cfg, shape.global_batch, shape.seq_len,
                                      extras_shapes=extras or None)
        tok_b = {"tokens": specs["tokens"]}
        lowered = step.lower(
            _sds_with(pshapes, pshard),
            _sds_with(cshapes, cshard_fn(cshapes)),
            specs["pos"],
            _sds_with(tok_b, bshard_fn(tok_b))["tokens"])

    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    mem_d = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "peak_memory_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_d[k] = int(v)
    coll = collective_stats(compiled.as_text())

    return {
        "arch": arch_id, "shape": shape_name,
        "mesh": mesh_shape or ("2x16x16" if multi_pod else "16x16"),
        "strategy": strategy, "fsdp": fsdp,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "collectives": coll,
        "collective_bytes": sum(d["bytes"] for d in coll.values()),
        "memory": mem_d,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params": registry.param_count(ARCHS[arch_id]),
        "active_params": registry.param_count(ARCHS[arch_id],
                                              active_only=True),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--strategy", default="hier",
                    choices=["hier", "hier1", "allreduce"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default=None, help="dir for per-pair JSON")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="custom mesh, e.g. 64x4 or 2x32x8 (§Perf)")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override, e.g. --set moe_group=1024")
    ap.add_argument("--tag", default="", help="suffix for the output JSON")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            import ast
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    if args.all:
        todo = list(pairs())
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        if not supports(args.arch, args.shape):
            print(f"SKIP {args.arch} x {args.shape}: unsupported "
                  "(see DESIGN.md §4)")
            return
        todo = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch_id, shape_name in todo:
        for mp in meshes:
            mesh_name = args.mesh_shape or ("2x16x16" if mp else "16x16")
            tag = (f"{arch_id}__{shape_name}__{mesh_name}"
                   f"__{args.strategy}{'' if not args.no_fsdp else '__nofsdp'}"
                   f"{args.tag}")
            if args.out and args.skip_existing:
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"skip {tag} (exists)")
                    continue
            print(f"=== {tag} ===", flush=True)
            try:
                res = lower_one(arch_id, shape_name, multi_pod=mp,
                                strategy=args.strategy, fsdp=not args.no_fsdp,
                                mesh_shape=args.mesh_shape,
                                overrides=overrides or None)
            except Exception as e:
                traceback.print_exc()
                failures.append((tag, repr(e)))
                continue
            print(json.dumps(
                {k: res[k] for k in ("flops", "bytes_accessed",
                                     "collective_bytes", "memory",
                                     "lower_s", "compile_s")}, indent=1),
                flush=True)
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1)
    if failures:
        print("FAILURES:")
        for tag, e in failures:
            print(" ", tag, e)
        raise SystemExit(1)
    print("dry-run complete: all combinations lowered + compiled")


if __name__ == "__main__":
    main()
