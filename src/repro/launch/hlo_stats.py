"""HLO-text collective statistics (no jax imports, no env side effects —
safe to import from tests; repro.launch.dryrun re-exports these)."""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]))[^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result-operand sizes of every collective op in the HLO. `-done`
    ops are skipped so async pairs aren't double counted.

    NOTE result-size is a proxy: for ring all-reduce the wire traffic is
    ~2x the result, for all-gather ~1x, for reduce-scatter the result is
    1/n of the input. The analytic model (benchmarks/flops_model.py)
    applies proper ring factors; these stats are for op-mix inspection and
    before/after comparison of the same program.
    """
    out: Dict[str, Dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        if f"{op}-done(" in m.group(0):
            continue
        d = out.setdefault(op, {"count": 0, "bytes": 0.0})
        d["count"] += 1
        d["bytes"] += _type_bytes(type_str)
    return out
