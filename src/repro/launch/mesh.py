"""Production meshes. Functions, not module constants: importing this module
never touches jax device state (the dry-run sets XLA_FLAGS first)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_custom_mesh(shape_str: str):
    """'64x4' -> (data=64, model=4); '2x32x8' -> (pod=2, data=32, model=8).
    The §Perf mesh-reshape experiments right-size TP to the model."""
    dims = tuple(int(x) for x in shape_str.split("x"))
    if len(dims) == 2:
        return jax.make_mesh(dims, ("data", "model"))
    if len(dims) == 3:
        return jax.make_mesh(dims, ("pod", "data", "model"))
    raise ValueError(shape_str)


def data_axes(mesh) -> tuple:
    """The batch-sharding axes of a mesh (pod folds into data-parallel)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def data_size(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= axis_size(mesh, a)
    return n
