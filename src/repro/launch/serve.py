"""Serving launcher: batched prefill + decode with a KV/SSM cache.

Runs a small request loop on the available devices — demonstrates the
serve_step path the decode dry-run shapes lower:

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --reduced \
        --requests 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced, reduced_batch
from repro.models import registry


def serve(cfg, *, n_requests: int, prompt_len: int, gen: int, seed: int = 0):
    params = registry.init(jax.random.key(seed), cfg)
    batch = reduced_batch(cfg, n_requests, prompt_len, seed=seed)
    max_seq = prompt_len + gen

    t0 = time.perf_counter()
    logits, cache = registry.prefill(params, cfg, batch, max_seq=max_seq)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(
        lambda p, c, pos, tok: registry.decode_step(p, cfg, c, pos, tok))
    tok = jnp.argmax(logits[:, -1:, :cfg.vocab_size], axis=-1)
    out = [tok]
    t0 = time.perf_counter()
    for t in range(gen - 1):
        logits, cache = decode(params, cache, jnp.int32(prompt_len + t), tok)
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    tokens = jnp.concatenate(out, axis=1)
    return tokens, t_prefill, t_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    toks, tp, td = serve(cfg, n_requests=args.requests,
                         prompt_len=args.prompt_len, gen=args.gen)
    per_tok = td / max(args.gen - 1, 1) / args.requests
    print(f"prefill {tp*1e3:.0f} ms; decode {td*1e3:.0f} ms "
          f"({per_tok*1e3:.1f} ms/token/request)")
    print("generated:", toks[0, :12].tolist(), "...")


if __name__ == "__main__":
    main()
