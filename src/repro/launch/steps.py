"""Jitted step builders for the production meshes.

The SMLT synchronization strategy is a first-class knob of ``train_step``:

  "allreduce" — gradients kept replicated over the data axes; XLA emits a
                flat all-reduce (the naive baseline).
  "hier"      — SMLT's hierarchical ScatterReduce: gradients are sharded
                over ``data`` (reduce-scatter), optimizer state lives
                sharded (each "worker" owns its shard — the paper's shard
                aggregator), and updated params are all-gathered. On the
                multi-pod mesh the RS/AG stay *intra-pod* and only the
                |G|/16-sized shards cross pods — the 2-level hierarchy.
  "hier1"     — flat 1-level variant over (pod, data) jointly, for the
                §Perf comparison against the 2-level schedule.

The centralized-PS baseline (Siren/Cirrus) is intentionally NOT lowered at
production scale — its O(n|G|) per-device gather is the pattern the paper
(and our Fig-7/8 benchmarks + shard_map semantic path) show to be
non-viable; see benchmarks/comm_scaling.py.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (batch_specs, cache_specs, named,
                                        param_specs)
from repro.launch.mesh import axis_size, data_axes, data_size
from repro.models import registry
from repro.models.base import ModelConfig
from repro.optim.adamw import AdamW, AdamWState


def _grad_axes(mesh, strategy: str):
    if strategy == "hier":
        return "data"
    if strategy == "hier1":
        return data_axes(mesh)
    if strategy == "allreduce":
        return None
    raise ValueError(f"unknown train sync strategy {strategy!r}")


def make_train_step(cfg: ModelConfig, mesh, *, strategy: str = "hier",
                    fsdp: bool = False, optimizer: Optional[AdamW] = None,
                    donate: bool = True):
    """-> (jitted step, params_shardings, opt_shardings, batch_shardings).

    step(params, opt_state, batch) -> (params, opt_state, loss)
    """
    opt = optimizer or AdamW(lr=3e-4)
    model_n = axis_size(mesh, "model")
    daxes = data_axes(mesh)
    dsize = data_size(mesh)
    gaxes = _grad_axes(mesh, strategy)
    rng = jax.random.key(0)
    pshapes = jax.eval_shape(lambda k: registry.init(k, cfg), rng)

    # FSDP spans ALL data-like axes (pod x data) so 512-chip ZeRO really
    # divides the optimizer state by 32, not 16
    pspecs = param_specs(pshapes, model_size=model_n,
                         fsdp_axis=(daxes if fsdp else None),
                         fsdp_divisor=dsize)
    # ZeRO-style placement for the hier strategies: gradients constrained
    # to the reduce-scatter layout...
    zspecs = (param_specs(pshapes, model_size=model_n, fsdp_axis=gaxes,
                          fsdp_min_size=2 ** 14,
                          fsdp_divisor=(dsize if strategy == "hier1"
                                        else axis_size(mesh, "data")))
              if gaxes else pspecs)
    # ...while the optimizer STATE always spans all data-like axes (the
    # cross-pod re-scatter of already-reduced G/16 shards is cheap, and
    # mu+nu must divide by 32 on the 512-chip mesh to fit HBM)
    ospecs_base = (param_specs(pshapes, model_size=model_n, fsdp_axis=daxes,
                               fsdp_min_size=2 ** 14, fsdp_divisor=dsize)
                   if gaxes else pspecs)
    ospecs = AdamWState(step=P(), mu=ospecs_base, nu=ospecs_base)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: registry.loss_fn(p, cfg, batch))(params)
        if gaxes:
            grads = jax.lax.with_sharding_constraint(grads, named(mesh, zspecs))
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, loss

    def batch_shardings(batch_shapes):
        return named(mesh, batch_specs(batch_shapes, daxes, data_size=dsize))

    pshard = named(mesh, pspecs)
    oshard = named(mesh, ospecs)
    jit_step = jax.jit(
        step,
        in_shardings=None,  # taken from arguments at lower time
        out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
        donate_argnums=(0, 1) if donate else ())
    return jit_step, pshard, oshard, batch_shardings


def make_prefill_step(cfg: ModelConfig, mesh, *, fsdp: bool = False):
    """step(params, batch) -> (logits, cache)."""
    model_n = axis_size(mesh, "model")
    daxes = data_axes(mesh)
    dsize = data_size(mesh)
    rng = jax.random.key(0)
    pshapes = jax.eval_shape(lambda k: registry.init(k, cfg), rng)
    pspecs = param_specs(pshapes, model_size=model_n,
                         fsdp_axis=(daxes if fsdp else None),
                         fsdp_divisor=dsize)

    def step(params, batch):
        return registry.prefill(params, cfg, batch)

    def batch_shardings(batch_shapes):
        return named(mesh, batch_specs(batch_shapes, daxes, data_size=dsize))

    return jax.jit(step), named(mesh, pspecs), batch_shardings


def make_serve_step(cfg: ModelConfig, mesh, *, fsdp: bool = False):
    """step(params, cache, pos, tokens) -> (logits, cache) — ONE new token
    against a seq_len KV/SSM cache."""
    model_n = axis_size(mesh, "model")
    daxes = data_axes(mesh)
    dsize = data_size(mesh)
    rng = jax.random.key(0)
    pshapes = jax.eval_shape(lambda k: registry.init(k, cfg), rng)
    pspecs = param_specs(pshapes, model_size=model_n,
                         fsdp_axis=(daxes if fsdp else None),
                         fsdp_divisor=dsize)

    def step(params, cache, pos, tokens):
        return registry.decode_step(params, cfg, cache, pos, tokens)

    def cache_shardings(cache_shapes):
        return named(mesh, cache_specs(cache_shapes, daxes,
                                       model_size=model_n, data_size=dsize))

    def batch_shardings(batch_shapes):
        return named(mesh, batch_specs(batch_shapes, daxes, data_size=dsize))

    return jax.jit(step), named(mesh, pspecs), cache_shardings, batch_shardings


def decode_cache_shapes(cfg: ModelConfig, batch: int, max_seq: int,
                        extras_shapes=None):
    """ShapeDtypeStructs of the decode cache (params never materialized)."""
    rng = jax.random.key(0)
    pshapes = jax.eval_shape(lambda k: registry.init(k, cfg), rng)

    def build(params, extras):
        return registry.init_decode_cache(params, cfg, batch, max_seq,
                                          batch_extras=extras)

    return jax.eval_shape(build, pshapes, extras_shapes)
