"""Training launcher.

Two modes:
 - real training on the available devices (reduced/any config that fits):
     PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
         --steps 50 --batch 8 --seq 128
 - production-mesh lowering check (delegates to dryrun for one pair):
     PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --dry-run

The SMLT strategy knob (--strategy hier|hier1|allreduce) selects the
gradient-synchronization dataflow (see launch/steps.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import ARCHS, reduced
from repro.data import DataConfig, ShardedLoader, TokenDataset
from repro.launch.steps import make_train_step
from repro.models import registry
from repro.optim import AdamW, warmup_cosine


def make_local_mesh():
    devs = np.array(jax.devices())
    return Mesh(devs.reshape(len(devs), 1), ("data", "model"))


def train(cfg, *, steps: int, batch: int, seq: int, strategy: str,
          lr: float = 3e-4, log_every: int = 10, loader=None):
    mesh = make_local_mesh()
    opt = AdamW(lr=lr, schedule=warmup_cosine(max(steps // 20, 1), steps))
    step_fn, pshard, oshard, bshard_fn = make_train_step(
        cfg, mesh, strategy=strategy, optimizer=opt)
    params = jax.device_put(registry.init(jax.random.key(0), cfg), pshard)
    opt_state = jax.device_put(opt.init(params), oshard)

    loader = loader or ShardedLoader(TokenDataset(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq)))
    losses = []
    t0 = time.perf_counter()
    for i in range(steps):
        batch_np = loader.next_batch(batch)
        b = {"tokens": jnp.asarray(batch_np["tokens"]),
             "labels": jnp.asarray(batch_np["labels"])}
        if cfg.family == "vlm":
            b["image_embeds"] = jnp.zeros(
                (batch, cfg.n_image_tokens, cfg.d_vision), cfg.dtype)
        if cfg.family == "audio":
            b["audio_frames"] = jnp.zeros(
                (batch, cfg.n_audio_frames, cfg.d_audio), cfg.dtype)
        params, opt_state, loss = step_fn(params, opt_state, b)
        losses.append(float(loss))
        if i % log_every == 0 or i == steps - 1:
            dt = time.perf_counter() - t0
            tput = (i + 1) * batch * seq / dt
            print(f"step {i:5d}  loss {float(loss):.4f}  "
                  f"{tput:,.0f} tok/s", flush=True)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--strategy", default="hier",
                    choices=["hier", "hier1", "allreduce"])
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch import dryrun  # noqa: F401 (sets XLA_FLAGS? no —)
        raise SystemExit(
            "use `python -m repro.launch.dryrun` directly: it must set "
            "XLA_FLAGS before jax initializes")

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    _, losses = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                      strategy=args.strategy, lr=args.lr)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
