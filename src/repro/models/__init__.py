from repro.models.base import INPUT_SHAPES, InputShape, ModelConfig  # noqa: F401
