"""Model configuration shared across all architecture families.

One dataclass covers every assigned family (dense / moe / ssm / hybrid /
vlm / audio enc-dec); family-specific fields default to "off".
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    arch_id: str = "unnamed"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""       # citation for the assigned config

    # core transformer dims
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024

    # attention details
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0     # 0 -> full causal attention
    # norm: "rmsnorm" | "layernorm" | "nonparametric_ln" (OLMo)
    norm: str = "rmsnorm"
    # mlp: "swiglu" | "gelu"
    mlp: str = "swiglu"
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0          # 0 -> dense FFN
    n_shared_experts: int = 0   # Qwen2-MoE style always-on experts
    top_k: int = 0
    moe_dense_residual: bool = False  # Arctic: dense FFN residual in parallel
    router_aux_weight: float = 0.01
    moe_capacity_factor: float = 1.25  # expert capacity = cf * k * T / E
    moe_group: int = 4096       # GShard dispatch group (perf knob, §Perf)
    moe_pad_experts: int = 0    # pad E up (e.g. 60->64) so the expert axis
                                # shards over the model mesh axis (§Perf)

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0          # 0 -> no ssm
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # hybrid (Zamba2): apply one *shared* attention block every k ssm layers
    attn_every: int = 0         # 0 -> no interleaved attention

    # VLM (Llama-3.2-Vision style): cross-attention image layers
    cross_attn_every: int = 0   # every k-th layer is a cross-attn layer
    n_image_tokens: int = 0
    d_vision: int = 0           # vision embedding width from the (stubbed) ViT

    # audio enc-dec (Seamless style)
    n_encoder_layers: int = 0   # >0 -> encoder-decoder model
    n_audio_frames: int = 0
    d_audio: int = 0            # frame embedding width from the (stubbed) codec

    # numerics / performance knobs
    dtype: Any = jnp.float32
    remat: bool = False
    # "full" re-computes everything; "dots" saves matmul outputs
    # (jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    remat_policy: str = "full"
    # Pallas kernels (TPU; interpret-mode on CPU). Self-attention prefill
    # and the SSD chunk scan dispatch to repro.kernels when enabled.
    use_flash_kernel: bool = False
    use_ssd_kernel: bool = False
    # Megatron-style sequence parallelism: between blocks, activations are
    # sharded over the model axis on the sequence dim (halves TP-AR bytes)
    seq_shard: bool = False

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 128 so TP=16 shards evenly and the
        unembed matmul stays MXU-aligned. Loss masks the padding columns."""
        return round_up(self.vocab_size, 128)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter-count estimate used by the cost model / roofline (dense math)
    def param_count(self) -> int:
        from repro.models import registry  # local import to avoid cycles
        return registry.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import registry
        return registry.param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the assigned workload shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
