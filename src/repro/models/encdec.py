"""Seamless-M4T-style encoder-decoder backbone [arXiv:2308.11596].

Transformer backbone only (per the brief): the mel-spectrogram + conv codec
frontend is a STUB — ``input_specs()`` supplies precomputed frame embeddings
(b, n_frames, d_audio). Encoder: bidirectional self-attention over projected
frames. Decoder: causal self-attention + cross-attention to encoder output.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.base import ModelConfig


def init_enc_block(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 2)
    return {"ln1": L.init_norm(cfg),
            "attn": L.init_attention(ks[0], cfg),
            "ln2": L.init_norm(cfg),
            "mlp": L.init_mlp(ks[1], cfg)}


def init_dec_block(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 3)
    return {"ln1": L.init_norm(cfg),
            "self_attn": L.init_attention(ks[0], cfg),
            "ln_x": L.init_norm(cfg),
            "cross_attn": L.init_attention(ks[1], cfg),
            "ln2": L.init_norm(cfg),
            "mlp": L.init_mlp(ks[2], cfg)}


def init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 5)
    return {
        "embed": L.init_embed(ks[0], cfg),
        "audio_proj": L.dense_init(ks[1], cfg.d_audio, cfg.d_model, cfg.dtype),
        "encoder": T.stack_init(lambda k: init_enc_block(k, cfg), ks[2],
                                cfg.n_encoder_layers),
        "enc_norm": L.init_norm(cfg),
        "decoder": T.stack_init(lambda k: init_dec_block(k, cfg), ks[3],
                                cfg.n_layers),
        "final_norm": L.init_norm(cfg),
    }


def encode(params, cfg: ModelConfig, audio_frames):
    """audio_frames: (b, f, d_audio) -> (b, f, d_model)."""
    h = audio_frames @ params["audio_proj"]

    def body(h, bp):
        h = T.seq_constraint(cfg, h)
        a, _ = L.apply_attention(bp["attn"], cfg,
                                 L.apply_norm(bp["ln1"], cfg, h), causal=False)
        h = h + a
        h = h + L.apply_mlp(bp["mlp"], cfg, L.apply_norm(bp["ln2"], cfg, h))
        return h, None

    body = T.remat_wrap(cfg, body)
    h, _ = jax.lax.scan(body, h, params["encoder"])
    return L.apply_norm(params["enc_norm"], cfg, h)


def cross_kv(params, cfg: ModelConfig, enc_out):
    """Precompute stacked decoder cross-attention K/V: (L, b, f, kv, hd)."""
    b, f, _ = enc_out.shape
    hd = cfg.resolved_head_dim

    def one(dbp):
        k = (enc_out @ dbp["cross_attn"]["wk"]).reshape(b, f, cfg.n_kv_heads, hd)
        v = (enc_out @ dbp["cross_attn"]["wv"]).reshape(b, f, cfg.n_kv_heads, hd)
        return {"k": k, "v": v}

    return jax.vmap(one)(params["decoder"])


def apply_dec_block(bp, cfg: ModelConfig, h, ckv, *, positions=None,
                    cache=None, cache_index=None):
    a, new_cache = L.apply_attention(
        bp["self_attn"], cfg, L.apply_norm(bp["ln1"], cfg, h),
        positions=positions, cache=cache, cache_index=cache_index)
    h = h + a
    b, s, _ = h.shape
    hd = cfg.resolved_head_dim
    x = L.apply_norm(bp["ln_x"], cfg, h)
    q = (x @ bp["cross_attn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = L._repeat_kv(ckv["k"], cfg.n_heads // cfg.n_kv_heads)
    v = L._repeat_kv(ckv["v"], cfg.n_heads // cfg.n_kv_heads)
    o = L.blockwise_attention(q, k, v, causal=False)
    h = h + o.reshape(b, s, cfg.n_heads * hd) @ bp["cross_attn"]["wo"]
    h = h + L.apply_mlp(bp["mlp"], cfg, L.apply_norm(bp["ln2"], cfg, h))
    return h, new_cache


def decode_stack(params, cfg: ModelConfig, tokens, ckv, *, positions=None,
                 cache=None, cache_index=None):
    h = L.embed_tokens(params["embed"], tokens)

    def body(h, xs):
        bp, kv, c = xs
        h = T.seq_constraint(cfg, h) if cache is None else h
        h, nc = apply_dec_block(bp, cfg, h, kv, positions=positions,
                                cache=c, cache_index=cache_index)
        return h, nc

    body = T.remat_wrap(cfg, body)
    h, new_cache = jax.lax.scan(body, h, (params["decoder"], ckv, cache))
    h = L.apply_norm(params["final_norm"], cfg, h)
    return L.unembed(params["embed"], cfg, h), new_cache


def loss_fn(params, cfg: ModelConfig, batch):
    enc_out = encode(params, cfg, batch["audio_frames"])
    ckv = cross_kv(params, cfg, enc_out)
    logits, _ = decode_stack(params, cfg, batch["tokens"], ckv)
    return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:], cfg)


def init_self_cache(cfg: ModelConfig, batch: int, max_seq: int):
    c = L.init_kv_cache(cfg, batch, max_seq)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), c)


def prefill(params, cfg: ModelConfig, tokens, audio_frames,
            max_seq: Optional[int] = None):
    b, s = tokens.shape
    enc_out = encode(params, cfg, audio_frames)
    ckv = cross_kv(params, cfg, enc_out)
    cache = init_self_cache(cfg, b, max_seq or s)
    logits, cache = decode_stack(params, cfg, tokens, ckv, cache=cache,
                                 cache_index=0)
    return logits, {"self": cache, "cross_kv": ckv}


def decode_step(params, cfg: ModelConfig, cache, pos, tokens):
    positions = pos + jnp.zeros((1,), jnp.int32)
    logits, new_self = decode_stack(params, cfg, tokens, cache["cross_kv"],
                                    positions=positions, cache=cache["self"],
                                    cache_index=pos)
    return logits, {"self": new_self, "cross_kv": cache["cross_kv"]}
