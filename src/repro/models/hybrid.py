"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention+MLP block
applied every ``attn_every`` SSM layers [arXiv:2411.15242].

The shared block's weights are reused at every application site, but each
site keeps its own KV cache. Attention uses a sliding window
(cfg.sliding_window) so the ``long_500k`` decode shape stays sub-quadratic
with an O(window) ring-buffer cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import transformer as T
from repro.models.base import ModelConfig


def n_groups(cfg: ModelConfig):
    return cfg.n_layers // cfg.attn_every, cfg.n_layers % cfg.attn_every


def init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 4)
    return {
        "embed": L.init_embed(ks[0], cfg),
        "blocks": T.stack_init(lambda k: M.init_mamba_block(k, cfg), ks[1],
                               cfg.n_layers),
        "shared": T.init_block(ks[2], cfg),   # one attn+MLP block, reused
        "final_norm": L.init_norm(cfg),
    }


# -- ring-buffer windowed attention cache ----------------------------------


def init_attn_cache(cfg: ModelConfig, batch: int, max_seq: int):
    size = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    return L.init_kv_cache(cfg, batch, size)


def shared_attn_decode(bp, cfg: ModelConfig, h, attn_cache, pos):
    """One-token attention against a ring-buffer window cache."""
    b = h.shape[0]
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    size = attn_cache["k"].shape[1]
    x = L.apply_norm(bp["ln1"], cfg, h)
    q = (x @ bp["attn"]["wq"]).reshape(b, 1, nq, hd)
    k = (x @ bp["attn"]["wk"]).reshape(b, 1, nkv, hd)
    v = (x @ bp["attn"]["wv"]).reshape(b, 1, nkv, hd)
    positions = pos + jnp.zeros((1,), jnp.int32)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    slot = jnp.mod(pos, size)
    ck = jax.lax.dynamic_update_slice(attn_cache["k"], k.astype(attn_cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(attn_cache["v"], v.astype(attn_cache["v"].dtype),
                                      (0, slot, 0, 0))
    kk = L._repeat_kv(ck, nq // nkv).astype(jnp.float32)
    vv = L._repeat_kv(cv, nq // nkv).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * hd ** -0.5, kk)
    valid = jnp.arange(size) < jnp.minimum(pos + 1, size)
    s = jnp.where(valid[None, None, None], s, -1e30)
    pvals = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", pvals, vv).astype(h.dtype)
    o = o.reshape(b, 1, nq * hd) @ bp["attn"]["wo"]
    h = h + o
    h = h + L.apply_mlp(bp["mlp"], cfg, L.apply_norm(bp["ln2"], cfg, h))
    return h, {"k": ck, "v": cv}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    ng, _ = n_groups(cfg)
    mc = M.init_block_cache(cfg, batch)
    mamba = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), mc)
    ac = init_attn_cache(cfg, batch, max_seq)
    attn = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (ng,) + x.shape), ac)
    return {"mamba": mamba, "attn": attn}


def forward_full(params, cfg: ModelConfig, tokens, *, mamba_cache=None,
                 collect_attn_kv: int = 0):
    """Train/prefill. If collect_attn_kv > 0, also build ring KV caches of
    that size for each shared-block application (for subsequent decode)."""
    ng, rem = n_groups(cfg)
    h = L.embed_tokens(params["embed"], tokens)
    per = cfg.attn_every
    blocks = params["blocks"]
    grouped = jax.tree.map(
        lambda x: x[:ng * per].reshape((ng, per) + x.shape[1:]), blocks)
    mcache = mamba_cache
    gm_cache = None
    if mcache is not None:
        gm_cache = jax.tree.map(
            lambda x: x[:ng * per].reshape((ng, per) + x.shape[1:]), mcache)

    def inner(h, xs):
        bp, c = xs
        h, nc = M.apply_mamba_block(bp, cfg, h, cache=c)
        return h, nc

    def group_body(h, xs):
        gbp, gc = xs
        h = T.seq_constraint(cfg, h)
        h, ncs = jax.lax.scan(inner, h, (gbp, gc))
        b, s, _ = h.shape
        x_in = L.apply_norm(params["shared"]["ln1"], cfg, h)
        a, _ = L.apply_attention(params["shared"]["attn"], cfg, x_in)
        h = h + a
        h = h + L.apply_mlp(params["shared"]["mlp"], cfg,
                            L.apply_norm(params["shared"]["ln2"], cfg, h))
        kv = None
        if collect_attn_kv:
            size = collect_attn_kv
            hd = cfg.resolved_head_dim
            k = (x_in @ params["shared"]["attn"]["wk"]).reshape(
                b, s, cfg.n_kv_heads, hd)
            v = (x_in @ params["shared"]["attn"]["wv"]).reshape(
                b, s, cfg.n_kv_heads, hd)
            k = L.apply_rope(k, jnp.arange(s), cfg.rope_theta)
            take = min(size, s)
            slots = jnp.mod(jnp.arange(s - take, s), size)
            ck = jnp.zeros((b, size, cfg.n_kv_heads, hd), cfg.dtype)
            ck = ck.at[:, slots].set(k[:, -take:].astype(cfg.dtype))
            cv = jnp.zeros((b, size, cfg.n_kv_heads, hd), cfg.dtype)
            cv = cv.at[:, slots].set(v[:, -take:].astype(cfg.dtype))
            kv = {"k": ck, "v": cv}
        return h, (ncs, kv)

    body = T.remat_wrap(cfg, group_body)
    h, (new_gm, attn_kv) = jax.lax.scan(body, h, (grouped, gm_cache))

    # remainder SSM layers (no shared block after them)
    if rem:
        tail = jax.tree.map(lambda x: x[ng * per:], blocks)
        tail_c = (jax.tree.map(lambda x: x[ng * per:], mcache)
                  if mcache is not None else None)
        h, new_tail = jax.lax.scan(inner, h, (tail, tail_c))
    else:
        new_tail = None

    h = L.apply_norm(params["final_norm"], cfg, h)
    logits = L.unembed(params["embed"], cfg, h)

    new_mcache = None
    if mcache is not None:
        new_mcache = jax.tree.map(
            lambda g: g.reshape((ng * per,) + g.shape[2:]), new_gm)
        if rem:
            new_mcache = jax.tree.map(
                lambda a, b_: jnp.concatenate([a, b_], axis=0),
                new_mcache, new_tail)
    return logits, new_mcache, attn_kv


def loss_fn(params, cfg: ModelConfig, batch):
    logits, _, _ = forward_full(params, cfg, batch["tokens"])
    return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:], cfg)


def prefill(params, cfg: ModelConfig, tokens, max_seq: Optional[int] = None):
    b, s = tokens.shape
    max_seq = max_seq or s
    size = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    mcache = M.init_cache(cfg, b)
    logits, new_m, attn_kv = forward_full(params, cfg, tokens,
                                          mamba_cache=mcache,
                                          collect_attn_kv=size)
    return logits, {"mamba": new_m, "attn": attn_kv}


def decode_step(params, cfg: ModelConfig, cache, pos, tokens):
    ng, rem = n_groups(cfg)
    per = cfg.attn_every
    h = L.embed_tokens(params["embed"], tokens)
    blocks = params["blocks"]
    grouped = jax.tree.map(
        lambda x: x[:ng * per].reshape((ng, per) + x.shape[1:]), blocks)
    gm_cache = jax.tree.map(
        lambda x: x[:ng * per].reshape((ng, per) + x.shape[1:]),
        cache["mamba"])

    def inner(h, xs):
        bp, c = xs
        h, nc = M.apply_mamba_decode(bp, cfg, h, c)
        return h, nc

    def group_body(h, xs):
        gbp, gc, ac = xs
        h, ncs = jax.lax.scan(inner, h, (gbp, gc))
        h, nac = shared_attn_decode(params["shared"], cfg, h, ac, pos)
        return h, (ncs, nac)

    h, (new_gm, new_attn) = jax.lax.scan(group_body, h,
                                         (grouped, gm_cache, cache["attn"]))
    new_m = jax.tree.map(lambda g: g.reshape((ng * per,) + g.shape[2:]), new_gm)
    if rem:
        tail = jax.tree.map(lambda x: x[ng * per:], blocks)
        tail_c = jax.tree.map(lambda x: x[ng * per:], cache["mamba"])
        h, new_tail = jax.lax.scan(inner, h, (tail, tail_c))
        new_m = jax.tree.map(lambda a, b_: jnp.concatenate([a, b_], axis=0),
                             new_m, new_tail)
    h = L.apply_norm(params["final_norm"], cfg, h)
    logits = L.unembed(params["embed"], cfg, h)
    return logits, {"mamba": new_m, "attn": new_attn}
