"""Shared neural-net primitives for the model zoo.

Pure-functional: parameters are nested dicts of jnp arrays, every layer is
``init_*`` (build params) + ``apply`` function. Attention is implemented with
a blockwise online-softmax formulation so that 32k-token prefill lowers with
O(block x seq) live memory instead of O(seq^2) — the jnp analogue of the
Pallas flash-attention kernel in ``repro.kernels.flash_attention``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(rng, (d_in, d_out)) * scale).astype(dtype)


def embed_init(rng, vocab: int, d_model: int, dtype):
    return (jax.random.normal(rng, (vocab, d_model)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dtype=None):
    dtype = dtype or cfg.dtype
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.norm == "nonparametric_ln":  # OLMo: LN without affine params
        return {}
    raise ValueError(f"unknown norm {cfg.norm!r}")


def apply_norm(params, cfg: ModelConfig, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_raw(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (b, s, h, d); positions: (b, s) or (s,) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, d/2)
    if angles.ndim == 2:  # (s, d/2) -> broadcast over batch
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig, d_model: Optional[int] = None,
                   n_heads: Optional[int] = None, n_kv: Optional[int] = None,
                   cross: bool = False):
    d_model = d_model or cfg.d_model
    n_heads = n_heads or cfg.n_heads
    n_kv = n_kv or cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * hd, cfg.dtype),
        "wk": dense_init(ks[1], d_model, n_kv * hd, cfg.dtype),
        "wv": dense_init(ks[2], d_model, n_kv * hd, cfg.dtype),
        "wo": dense_init(ks[3], n_heads * hd, d_model, cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_heads * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((n_kv * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((n_kv * hd,), cfg.dtype)
    return p


def _repeat_kv(x, n_rep: int):
    """(b, s, kv, d) -> (b, s, kv*n_rep, d) by head-group broadcast."""
    if n_rep == 1:
        return x
    b, s, kv, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, d)).reshape(
        b, s, kv * n_rep, d)


def blockwise_attention(q, k, v, *, causal: bool, q_offset=0,
                        sliding_window: int = 0, q_block: int = 512):
    """Online-softmax attention, scanned over query blocks.

    q: (b, sq, h, d); k, v: (b, skv, h, d). ``q_offset`` is the absolute
    position of q[0] relative to k[0] (decode: q_offset = cache length).
    Peak live memory is O(b*h*q_block*skv) rather than O(sq*skv).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kv_pos = jnp.arange(skv)

    q_block = min(q_block, sq)
    pad = (-sq) % q_block
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_blocks = qf.shape[1] // q_block
    qf = qf.reshape(b, n_blocks, q_block, h, d).transpose(1, 0, 2, 3, 4)

    def one_block(carry, args):
        qb, blk_idx = args
        q_pos = q_offset + blk_idx * q_block + jnp.arange(q_block)
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, kf)
        mask = jnp.ones((q_block, skv), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if sliding_window:
            mask &= q_pos[:, None] - kv_pos[None, :] < sliding_window
        s = jnp.where(mask[None, None], s, -1e30)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        denom = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhqk,bkhd->bqhd", p / jnp.maximum(denom, 1e-30), vf)
        return carry, o

    _, outs = jax.lax.scan(one_block, None,
                           (qf, jnp.arange(n_blocks)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_blocks * q_block, h, d)
    return out[:, :sq].astype(q.dtype)


def apply_attention(params, cfg: ModelConfig, x, *, positions=None,
                    causal: bool = True, cache: Optional[dict] = None,
                    cache_index=None, kv_input=None, use_rope: bool = True,
                    sliding_window: Optional[int] = None):
    """GQA attention with optional KV cache and cross-attention.

    cache: {"k": (b, max_s, kv, d), "v": ...} updated functionally; returns
    (out, new_cache). ``kv_input`` switches to cross-attention (no cache
    append, kv computed from ``kv_input``).
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    nq = params["wq"].shape[1] // hd
    nkv = params["wk"].shape[1] // hd
    window = cfg.sliding_window if sliding_window is None else sliding_window

    q = x @ params["wq"]
    kv_src = kv_input if kv_input is not None else x
    k = kv_src @ params["wk"]
    v = kv_src @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, nq, hd)
    k = k.reshape(b, kv_src.shape[1], nkv, hd)
    v = v.reshape(b, kv_src.shape[1], nkv, hd)

    if positions is None:
        positions = jnp.arange(s)
    if use_rope and kv_input is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    q_offset = 0
    new_cache = cache
    if cache is not None and kv_input is None:
        # functional cache append at cache_index (decode: s == 1)
        idx = cache_index if cache_index is not None else 0
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, idx, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        q_offset = idx

    n_rep = nq // nkv
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    if (cfg.use_flash_kernel and cache is None and kv_input is None
            and causal and s > 1):
        # Pallas flash-attention kernel (self-attention prefill/train path)
        from repro.kernels import ops as kops
        out = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=window,
            block_q=min(256, s), block_k=min(256, s),
            interpret=jax.default_backend() != "tpu")
        out = out.transpose(0, 2, 1, 3)
    else:
        out = blockwise_attention(q, k, v, causal=causal, q_offset=q_offset,
                                  sliding_window=window)
    out = out.reshape(b, s, nq * hd) @ params["wo"]
    return out, new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, n_kv=None,
                  dtype=None):
    nkv = n_kv or cfg.n_kv_heads
    dtype = dtype or cfg.dtype
    hd = cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, max_seq, nkv, hd), dtype),
            "v": jnp.zeros((batch, max_seq, nkv, hd), dtype)}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None,
             d_model: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    d_model = d_model or cfg.d_model
    ks = jax.random.split(rng, 3)
    if cfg.mlp == "swiglu":
        return {"wi": dense_init(ks[0], d_model, d_ff, cfg.dtype),
                "wg": dense_init(ks[1], d_model, d_ff, cfg.dtype),
                "wo": dense_init(ks[2], d_ff, d_model, cfg.dtype)}
    return {"wi": dense_init(ks[0], d_model, d_ff, cfg.dtype),
            "wo": dense_init(ks[2], d_ff, d_model, cfg.dtype)}


def apply_mlp(params, cfg: ModelConfig, x):
    if "wg" in params:
        return (jax.nn.silu(x @ params["wi"]) * (x @ params["wg"])) @ params["wo"]
    return jax.nn.gelu(x @ params["wi"]) @ params["wo"]


# ---------------------------------------------------------------------------
# embedding / unembedding / loss
# ---------------------------------------------------------------------------


def init_embed(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 2)
    p = {"tok": embed_init(ks[0], cfg.vocab_padded, cfg.d_model, cfg.dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], cfg.d_model, cfg.vocab_padded, cfg.dtype)
    return p


def embed_tokens(params, x):
    return jnp.take(params["tok"], x, axis=0)


def unembed(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        return h @ params["tok"].T
    return h @ params["unembed"]


def cross_entropy(logits, labels, cfg: ModelConfig):
    """Mean next-token CE; masks vocab-padding columns and label==-1."""
    vp = logits.shape[-1]
    col_mask = jnp.arange(vp) < cfg.vocab_size
    logits = jnp.where(col_mask, logits.astype(jnp.float32), -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    valid = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
