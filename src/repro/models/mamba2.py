"""Mamba2 / SSD (state-space duality) language model [arXiv:2405.21060].

The SSD forward pass is the chunked "dual" form: intra-chunk work is a masked
attention-like matmul (quadratic in the chunk length only), inter-chunk work
is a linear recurrence over per-chunk states, scanned with ``lax.scan``.
Decode is the O(1)-per-token recurrent form — this is why mamba2 runs the
``long_500k`` shape that quadratic-attention models cannot.

``repro.kernels.ssd_scan`` provides the Pallas TPU kernel for the chunk body;
this module is the pure-jnp reference implementation used on CPU and as the
kernel oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.base import ModelConfig


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------


def causal_conv(x, w, state=None):
    """x: (b, s, c); w: (W, c) depthwise. state: (b, W-1, c) carried inputs.
    Returns (out, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(w[i] * jax.lax.dynamic_slice_in_dim(xp, i, x.shape[1], axis=1)
              for i in range(W))
    new_state = xp[:, -(W - 1):]
    return jax.nn.silu(out), new_state


# ---------------------------------------------------------------------------
# SSD core (chunked dual form)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, B, C, D, chunk: int, initial_state=None):
    """x: (b,s,h,p)  dt: (b,s,h) (post-softplus)  A: (h,) (negative)
    B, C: (b,s,n)  D: (h,). Returns (y: (b,s,h,p), final_state: (b,h,n,p))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    def to_chunks(t):
        return t.reshape((b, nc, chunk) + t.shape[2:]).swapaxes(0, 1)

    xc, dtc, Bc, Cc = map(to_chunks, (x.astype(jnp.float32),
                                      dt.astype(jnp.float32),
                                      B.astype(jnp.float32),
                                      C.astype(jnp.float32)))
    Af = A.astype(jnp.float32)

    if initial_state is None:
        initial_state = jnp.zeros((b, h, n, p), jnp.float32)

    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]

    def chunk_body(S, xs):
        x_c, dt_c, B_c, C_c = xs               # (b,Q,h,p) (b,Q,h) (b,Q,n)
        dA = dt_c * Af                          # (b,Q,h)
        seg = jnp.cumsum(dA, axis=1)            # (b,Q,h)
        xdt = x_c * dt_c[..., None]
        # intra-chunk: attention-like masked matmul
        CB = jnp.einsum("bin,bjn->bij", C_c, B_c)
        # mask the exponent BEFORE exp: for i<j, seg_i - seg_j > 0 overflows
        diff = jnp.where(causal[None, :, :, None],
                         seg[:, :, None, :] - seg[:, None, :, :], -jnp.inf)
        scores = CB[..., None] * jnp.exp(diff)                    # (b,Q,Q,h)
        y = jnp.einsum("bijh,bjhp->bihp", scores, xdt)
        # inter-chunk: contribution of the carried state
        y = y + jnp.einsum("bin,bhnp->bihp", C_c, S) * jnp.exp(seg)[..., None]
        # state update
        seg_last = seg[:, -1, :]                # (b,h)
        Bx = jnp.einsum("bjn,bjhp->bhnp",
                        B_c, xdt * jnp.exp(seg_last[:, None] - seg)[..., None])
        S = S * jnp.exp(seg_last)[:, :, None, None] + Bx
        return S, y

    final_state, yc = jax.lax.scan(chunk_body, initial_state,
                                   (xc, dtc, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(b, nc * chunk, h, p)[:, :s]
    y = y + D.astype(jnp.float32)[None, None, :, None] * x[:, :s].astype(jnp.float32)
    return y.astype(x.dtype), final_state


def ssd_decode_step(S, x, dt, A, B, C, D):
    """One-token recurrence. x: (b,h,p)  dt: (b,h)  B, C: (b,n)  S: (b,h,n,p)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32))            # (b,h)
    Bx = jnp.einsum("bn,bhp->bhnp", B.astype(jnp.float32),
                    xf * dtf[..., None])
    S = S * dA[..., None, None] + Bx
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), S)
    y = y + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x.dtype), S


# ---------------------------------------------------------------------------
# mamba2 block
# ---------------------------------------------------------------------------


def init_mamba_block(rng, cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    di, nh, n = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_state
    W = cfg.ssm_conv_width
    ks = jax.random.split(rng, 9)
    return {
        "ln": {"scale": jnp.ones((d,), cfg.dtype)},
        "wz": L.dense_init(ks[0], d, di, cfg.dtype),
        "wx": L.dense_init(ks[1], d, di, cfg.dtype),
        "wB": L.dense_init(ks[2], d, n, cfg.dtype),
        "wC": L.dense_init(ks[3], d, n, cfg.dtype),
        "wdt": L.dense_init(ks[4], d, nh, cfg.dtype),
        "dt_bias": jnp.zeros((nh,), cfg.dtype),
        "A_log": jnp.log(jax.random.uniform(ks[5], (nh,), minval=1.0,
                                            maxval=16.0)).astype(cfg.dtype),
        "D": jnp.ones((nh,), cfg.dtype),
        "conv_x": (jax.random.normal(ks[6], (W, di)) * W ** -0.5).astype(cfg.dtype),
        "conv_BC": (jax.random.normal(ks[7], (W, 2 * n)) * W ** -0.5).astype(cfg.dtype),
        "gate_ln": {"scale": jnp.ones((di,), cfg.dtype)},
        "wo": L.dense_init(ks[8], di, d, cfg.dtype),
    }


def apply_mamba_block(bp, cfg: ModelConfig, h, cache=None):
    """cache: {"conv_x", "conv_BC", "ssm"} or None. Returns (out, new_cache)."""
    b, s, d = h.shape
    nh, p, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    hin = L.rmsnorm_raw(h, bp["ln"]["scale"])
    z = hin @ bp["wz"]
    x = hin @ bp["wx"]
    BC = jnp.concatenate([hin @ bp["wB"], hin @ bp["wC"]], axis=-1)
    dt = jax.nn.softplus((hin @ bp["wdt"]).astype(jnp.float32)
                         + bp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(bp["A_log"].astype(jnp.float32))

    cx = cache["conv_x"] if cache is not None else None
    cbc = cache["conv_BC"] if cache is not None else None
    x, new_cx = causal_conv(x, bp["conv_x"], cx)
    BC, new_cbc = causal_conv(BC, bp["conv_BC"], cbc)
    B, C = jnp.split(BC, 2, axis=-1)

    x = x.reshape(b, s, nh, p)
    s0 = cache["ssm"] if cache is not None else None
    if cfg.use_ssd_kernel and s0 is None:
        # Pallas SSD chunk-scan kernel (train/prefill-from-scratch path)
        from repro.kernels import ops as kops
        y, S = kops.ssd_scan(x, dt, A, B, C, bp["D"],
                             chunk=min(cfg.ssm_chunk, s),
                             interpret=jax.default_backend() != "tpu")
    else:
        y, S = ssd_chunked(x, dt, A, B, C, bp["D"], cfg.ssm_chunk,
                           initial_state=s0)
    y = y.reshape(b, s, nh * p)
    y = L.rmsnorm_raw(y * jax.nn.silu(z), bp["gate_ln"]["scale"])
    out = y @ bp["wo"]
    new_cache = {"conv_x": new_cx, "conv_BC": new_cbc, "ssm": S}
    return h + out, new_cache


def apply_mamba_decode(bp, cfg: ModelConfig, h, cache):
    """Single-token path (s == 1) using the recurrent form."""
    b, s, d = h.shape
    nh, p = cfg.ssm_nheads, cfg.ssm_headdim
    hin = L.rmsnorm_raw(h, bp["ln"]["scale"])
    z = hin @ bp["wz"]
    x = hin @ bp["wx"]
    BC = jnp.concatenate([hin @ bp["wB"], hin @ bp["wC"]], axis=-1)
    dt = jax.nn.softplus((hin @ bp["wdt"]).astype(jnp.float32)
                         + bp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(bp["A_log"].astype(jnp.float32))

    x, new_cx = causal_conv(x, bp["conv_x"], cache["conv_x"])
    BC, new_cbc = causal_conv(BC, bp["conv_BC"], cache["conv_BC"])
    B, C = jnp.split(BC, 2, axis=-1)

    y, S = ssd_decode_step(cache["ssm"], x[:, 0].reshape(b, nh, p),
                           dt[:, 0], A, B[:, 0], C[:, 0], bp["D"])
    y = y.reshape(b, 1, nh * p)
    y = L.rmsnorm_raw(y * jax.nn.silu(z), bp["gate_ln"]["scale"])
    new_cache = {"conv_x": new_cx, "conv_BC": new_cbc, "ssm": S}
    return h + y @ bp["wo"], new_cache


def init_block_cache(cfg: ModelConfig, batch: int):
    W, di, n = cfg.ssm_conv_width, cfg.d_inner, cfg.ssm_state
    return {
        "conv_x": jnp.zeros((batch, W - 1, di), cfg.dtype),
        "conv_BC": jnp.zeros((batch, W - 1, 2 * n), cfg.dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_nheads, n, cfg.ssm_headdim),
                         jnp.float32),
    }


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 2)
    return {
        "embed": L.init_embed(ks[0], cfg),
        "blocks": T.stack_init(lambda k: init_mamba_block(k, cfg), ks[1],
                               cfg.n_layers),
        "final_norm": L.init_norm(cfg),
    }


def forward(params, cfg: ModelConfig, tokens, *, cache=None, decode=False):
    h = L.embed_tokens(params["embed"], tokens)

    def body(h, xs):
        bp, c = xs
        if not decode:
            h = T.seq_constraint(cfg, h)
        if decode:
            h, nc = apply_mamba_decode(bp, cfg, h, c)
        else:
            h, nc = apply_mamba_block(bp, cfg, h, cache=c)
        return h, nc

    body = T.remat_wrap(cfg, body)
    h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache))
    h = L.apply_norm(params["final_norm"], cfg, h)
    return L.unembed(params["embed"], cfg, h), new_cache


def loss_fn(params, cfg: ModelConfig, batch):
    logits, _ = forward(params, cfg, batch["tokens"])
    return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:], cfg)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int = 0):
    c = init_block_cache(cfg, batch)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), c)


def prefill(params, cfg: ModelConfig, tokens, max_seq: Optional[int] = None):
    b, _ = tokens.shape
    cache = init_cache(cfg, b)
    logits, cache = forward(params, cfg, tokens, cache=cache)
    return logits, cache


def decode_step(params, cfg: ModelConfig, cache, pos, tokens):
    logits, cache = forward(params, cfg, tokens, cache=cache, decode=True)
    return logits, cache
