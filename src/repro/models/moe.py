"""Mixture-of-Experts transformer (qwen2-moe-a2.7b, arctic-480b).

Capacity-based GShard-style dispatch (one-hot dispatch/combine einsums) so the
all-to-all pattern is explicit in the lowered HLO. Experts are stacked on a
leading E axis (sharded over the ``model`` mesh axis = expert parallelism).

 - qwen2-moe: 4 shared (always-on) experts + 60 routed top-4.
 - arctic: 128 routed top-2 + a dense residual FFN in parallel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.base import ModelConfig

def init_moe_ffn(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 4)
    E = _n_experts_padded(cfg)
    d, f = cfg.d_model, cfg.d_ff

    def one_expert(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"wi": L.dense_init(k1, d, f, cfg.dtype),
                "wg": L.dense_init(k2, d, f, cfg.dtype),
                "wo": L.dense_init(k3, f, d, cfg.dtype)}

    p = {"router": L.dense_init(ks[0], d, E, cfg.dtype, scale=0.02),
         "experts": jax.vmap(one_expert)(jax.random.split(ks[1], E))}
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[2], cfg, d_ff=f * cfg.n_shared_experts)
    if cfg.moe_dense_residual:
        p["dense"] = L.init_mlp(ks[3], cfg, d_ff=f)
    return p


MOE_GROUP = 4096  # default GShard-style dispatch group (cfg.moe_group):
                  # keeps the one-hot dispatch/combine einsums O(t * g)
                  # instead of O(t^2)


def _n_experts_padded(cfg: ModelConfig) -> int:
    return max(cfg.n_experts, cfg.moe_pad_experts)


def _moe_group(p, cfg: ModelConfig, xt):
    """Dispatch one token group. xt: (g, d) -> (out (g, d), aux scalar)."""
    g, d = xt.shape
    E, k = _n_experts_padded(cfg), cfg.top_k
    cap = max(int(cfg.moe_capacity_factor * k * g / E), 1)

    logits = (xt @ p["router"]).astype(jnp.float32)          # (g, E)
    if E > cfg.n_experts:  # padding experts are never routed to
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, expert-slot) within its expert's capacity
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)   # (g, k, E)
    pos_in_expert = (jnp.cumsum(onehot.reshape(g * k, E), axis=0)
                     .reshape(g, k, E) - onehot)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1).astype(jnp.int32)  # (g, k)
    keep = pos < cap
    gate_vals = gate_vals * keep

    # dispatch (g, E, cap) / combine tensors
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.einsum("tke,tkc->tec", onehot, pos_oh)
    combine = jnp.einsum("tke,tkc,tk->tec", onehot, pos_oh, gate_vals)

    ex_in = jnp.einsum("tec,td->ecd", dispatch, xt.astype(jnp.float32))
    ex_in = ex_in.astype(xt.dtype)
    ex = p["experts"]
    hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ex_in, ex["wi"]))
    hidden = hidden * jnp.einsum("ecd,edf->ecf", ex_in, ex["wg"])
    ex_out = jnp.einsum("ecf,efd->ecd", hidden, ex["wo"])
    out = jnp.einsum("tec,ecd->td", combine, ex_out.astype(jnp.float32))

    # GShard load-balance aux loss
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)   # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_weight
    return out.astype(xt.dtype), aux


def apply_moe_ffn(p, cfg: ModelConfig, x):
    """x: (b, s, d) -> (out, aux_loss). Tokens are dispatched in GShard-style
    groups so the dispatch tensors stay (g, E, C) with g <= MOE_GROUP."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    group = cfg.moe_group or MOE_GROUP
    g = group if t % group == 0 else t
    xg = xt.reshape(t // g, g, d)
    out, aux = jax.vmap(lambda xx: _moe_group(p, cfg, xx))(xg)
    out = out.reshape(b, s, d)
    aux = jnp.mean(aux)

    if "shared" in p:
        out = out + L.apply_mlp(p["shared"], cfg, x)
    if "dense" in p:
        out = out + L.apply_mlp(p["dense"], cfg, x)
    return out, aux


def init_block(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 2)
    return {"ln1": L.init_norm(cfg),
            "attn": L.init_attention(ks[0], cfg),
            "ln2": L.init_norm(cfg),
            "moe": init_moe_ffn(ks[1], cfg)}


def apply_block(bp, cfg: ModelConfig, h, *, positions=None, cache=None,
                cache_index=None):
    a, new_cache = L.apply_attention(
        bp["attn"], cfg, L.apply_norm(bp["ln1"], cfg, h),
        positions=positions, cache=cache, cache_index=cache_index)
    h = h + a
    m, aux = apply_moe_ffn(bp["moe"], cfg, L.apply_norm(bp["ln2"], cfg, h))
    return h + m, new_cache, aux


def init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 3)
    return {
        "embed": L.init_embed(ks[0], cfg),
        "blocks": T.stack_init(lambda k: init_block(k, cfg), ks[1], cfg.n_layers),
        "final_norm": L.init_norm(cfg),
    }


def forward(params, cfg: ModelConfig, tokens, *, positions=None, cache=None,
            cache_index=None):
    h = L.embed_tokens(params["embed"], tokens)

    def body(carry, xs):
        h, aux = carry
        bp, c = xs
        h = T.seq_constraint(cfg, h) if cache is None else h
        h, nc, a = apply_block(bp, cfg, h, positions=positions, cache=c,
                               cache_index=cache_index)
        return (h, aux + a), nc

    body = T.remat_wrap(cfg, body)
    (h, aux), new_cache = jax.lax.scan(body, (h, 0.0),
                                       (params["blocks"], cache))
    h = L.apply_norm(params["final_norm"], cfg, h)
    return L.unembed(params["embed"], cfg, h), new_cache, aux


def loss_fn(params, cfg: ModelConfig, batch):
    logits, _, aux = forward(params, cfg, batch["tokens"])
    return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:], cfg) + aux


init_cache = T.init_cache


def prefill(params, cfg: ModelConfig, tokens, max_seq: Optional[int] = None):
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_seq or s)
    logits, cache, _ = forward(params, cfg, tokens, cache=cache, cache_index=0)
    return logits, cache


def decode_step(params, cfg: ModelConfig, cache, pos, tokens):
    positions = pos + jnp.zeros((1,), jnp.int32)
    logits, cache, _ = forward(params, cfg, tokens, positions=positions,
                               cache=cache, cache_index=pos)
    return logits, cache
