"""Family registry: one uniform API over all architecture families.

    init(rng, cfg)                 -> params
    loss_fn(params, cfg, batch)    -> scalar loss           (train_4k)
    prefill(params, cfg, batch)    -> (logits, cache)       (prefill_32k)
    decode_step(params, cfg, cache, pos, tokens) -> (logits, cache)
                                                      (decode_32k / long_500k)

``batch`` is a dict: always {"tokens", "labels"}; plus "image_embeds" for
vlm and "audio_frames" for audio enc-dec.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.models import encdec, hybrid, mamba2, moe, transformer, vlm
from repro.models.base import ModelConfig

_FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "ssm": mamba2,
    "hybrid": hybrid,
    "vlm": vlm,
    "audio": encdec,
}


def family_module(cfg: ModelConfig):
    return _FAMILIES[cfg.family]


def init(rng, cfg: ModelConfig):
    return family_module(cfg).init(rng, cfg)


def loss_fn(params, cfg: ModelConfig, batch):
    return family_module(cfg).loss_fn(params, cfg, batch)


def prefill(params, cfg: ModelConfig, batch, max_seq=None):
    mod = family_module(cfg)
    if cfg.family == "vlm":
        return mod.prefill(params, cfg, batch["tokens"], batch["image_embeds"],
                           max_seq=max_seq)
    if cfg.family == "audio":
        return mod.prefill(params, cfg, batch["tokens"], batch["audio_frames"],
                           max_seq=max_seq)
    return mod.prefill(params, cfg, batch["tokens"], max_seq=max_seq)


def decode_step(params, cfg: ModelConfig, cache, pos, tokens):
    return family_module(cfg).decode_step(params, cfg, cache, pos, tokens)


def init_decode_cache(params, cfg: ModelConfig, batch: int, max_seq: int,
                      batch_extras=None):
    """Build an empty/derived cache for decode-only lowering (no prefill).

    For cross-attention families the cross K/V is derived from the modality
    embeddings in ``batch_extras``.
    """
    mod = family_module(cfg)
    if cfg.family in ("dense", "moe"):
        return mod.init_cache(cfg, batch, max_seq)
    if cfg.family == "ssm":
        return mod.init_cache(cfg, batch)
    if cfg.family == "hybrid":
        return mod.init_cache(cfg, batch, max_seq)
    if cfg.family == "vlm":
        ikv = vlm.image_kv_from_embeds(params, cfg, batch_extras["image_embeds"])
        return {"self": vlm.init_self_cache(cfg, batch, max_seq),
                "image_kv": ikv}
    if cfg.family == "audio":
        enc_out = encdec.encode(params, cfg, batch_extras["audio_frames"])
        return {"self": encdec.init_self_cache(cfg, batch, max_seq),
                "cross_kv": encdec.cross_kv(params, cfg, enc_out)}
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# parameter counting (no allocation: jax.eval_shape over init)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _shapes(cfg: ModelConfig):
    rng = jax.random.key(0)
    return jax.eval_shape(lambda k: init(k, cfg), rng)


def _tree_size(tree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = _shapes(cfg)
    total = _tree_size(shapes)
    if active_only and cfg.n_experts:
        expert = _tree_size(shapes["blocks"]["moe"]["experts"])
        total = total - expert + expert * cfg.top_k // cfg.n_experts
    return total


def param_bytes(cfg: ModelConfig) -> int:
    shapes = _shapes(cfg)
    return int(sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(shapes)))
