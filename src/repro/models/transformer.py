"""Dense decoder-only transformer (olmo-1b, qwen2.5-3b, phi4-mini, mistral-large).

Layers are *stacked* (leading layer axis) and applied with ``jax.lax.scan`` so
that 88-layer configs lower to a compact HLO — essential for the 40-combo
multi-pod dry-run.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.base import ModelConfig


def seq_constraint(cfg: ModelConfig, h):
    """Megatron-style sequence parallelism: between blocks the activations
    live sharded over the model axis along the sequence dim. GSPMD then
    lowers the two per-block TP all-reduces into reduce-scatter/all-gather
    pairs — half the bytes on the wire (§Perf)."""
    if not cfg.seq_shard:
        return h
    return jax.lax.with_sharding_constraint(h, P(None, "model", None))


def remat_wrap(cfg: ModelConfig, body):
    if not cfg.remat:
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def stack_init(fn, rng, n: int):
    """vmap an init function over n layer rngs -> stacked params."""
    return jax.vmap(fn)(jax.random.split(rng, n))


def init_block(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 2)
    return {
        "ln1": L.init_norm(cfg),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_norm(cfg),
        "mlp": L.init_mlp(ks[1], cfg),
    }


def apply_block(bp, cfg: ModelConfig, h, *, positions=None, cache=None,
                cache_index=None):
    a, new_cache = L.apply_attention(
        bp["attn"], cfg, L.apply_norm(bp["ln1"], cfg, h),
        positions=positions, cache=cache, cache_index=cache_index)
    h = h + a
    h = h + L.apply_mlp(bp["mlp"], cfg, L.apply_norm(bp["ln2"], cfg, h))
    return h, new_cache


def init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 3)
    return {
        "embed": L.init_embed(ks[0], cfg),
        "blocks": stack_init(lambda k: init_block(k, cfg), ks[1], cfg.n_layers),
        "final_norm": L.init_norm(cfg),
    }


def _scan_blocks(params, cfg: ModelConfig, h, *, positions=None, cache=None,
                 cache_index=None):
    """Run all blocks via scan. cache (if given) is stacked on layer axis."""

    def body(h, xs):
        bp, c = xs
        h = seq_constraint(cfg, h)
        h, nc = apply_block(bp, cfg, h, positions=positions, cache=c,
                            cache_index=cache_index)
        return h, nc

    body = remat_wrap(cfg, body)
    h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache))
    return h, new_cache


def forward(params, cfg: ModelConfig, tokens, *, positions=None, cache=None,
            cache_index=None):
    h = L.embed_tokens(params["embed"], tokens)
    h, new_cache = _scan_blocks(params, cfg, h, positions=positions,
                                cache=cache, cache_index=cache_index)
    h = L.apply_norm(params["final_norm"], cfg, h)
    return L.unembed(params["embed"], cfg, h), new_cache


def loss_fn(params, cfg: ModelConfig, batch):
    logits, _ = forward(params, cfg, batch["tokens"])
    return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:], cfg)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    c = L.init_kv_cache(cfg, batch, max_seq, dtype=dtype)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), c)


def prefill(params, cfg: ModelConfig, tokens, max_seq: Optional[int] = None):
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_seq or s)
    logits, cache = forward(params, cfg, tokens, cache=cache, cache_index=0)
    return logits, cache


def decode_step(params, cfg: ModelConfig, cache, pos, tokens):
    """tokens: (b, 1); pos: scalar int32 index into the cache."""
    positions = pos + jnp.zeros((1,), jnp.int32)
    logits, cache = forward(params, cfg, tokens, positions=positions,
                            cache=cache, cache_index=pos)
    return logits, cache
