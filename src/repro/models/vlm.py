"""Llama-3.2-Vision-style VLM decoder [hf:meta-llama/Llama-3.2-11B-Vision].

Language backbone only (per the brief): the ViT/SigLIP vision encoder is a
STUB — ``input_specs()`` supplies precomputed patch embeddings
(b, n_image_tokens, d_vision). The backbone is a dense GQA decoder where
every ``cross_attn_every``-th layer is a gated cross-attention layer over the
projected image tokens. Layers are organized as scanned groups of
(cross_attn_every - 1) self layers + 1 cross layer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.base import ModelConfig


def group_shape(cfg: ModelConfig):
    per = cfg.cross_attn_every
    assert cfg.n_layers % per == 0, "n_layers must divide into cross groups"
    return cfg.n_layers // per, per - 1  # (n_groups, self_layers_per_group)


def init_cross_block(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 2)
    return {
        "ln1": L.init_norm(cfg),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_norm(cfg),
        "mlp": L.init_mlp(ks[1], cfg),
        "gate_attn": jnp.zeros((), cfg.dtype),   # tanh-gated residuals
        "gate_mlp": jnp.zeros((), cfg.dtype),
    }


def apply_cross_block(bp, cfg: ModelConfig, h, image_kv):
    """image_kv: {"k": (b, n_img, kv, hd), "v": ...} precomputed."""
    b, s, _ = h.shape
    hd = cfg.resolved_head_dim
    x = L.apply_norm(bp["ln1"], cfg, h)
    q = (x @ bp["attn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = L._repeat_kv(image_kv["k"], cfg.n_heads // cfg.n_kv_heads)
    v = L._repeat_kv(image_kv["v"], cfg.n_heads // cfg.n_kv_heads)
    o = L.blockwise_attention(q, k, v, causal=False)
    o = o.reshape(b, s, cfg.n_heads * hd) @ bp["attn"]["wo"]
    h = h + jnp.tanh(bp["gate_attn"]) * o
    m = L.apply_mlp(bp["mlp"], cfg, L.apply_norm(bp["ln2"], cfg, h))
    return h + jnp.tanh(bp["gate_mlp"]) * m


def image_kv_from_embeds(params, cfg: ModelConfig, image_embeds):
    """Project stubbed vision embeddings and precompute per-group cross K/V.
    image_embeds: (b, n_img, d_vision) -> stacked {"k","v"}: (G, b, n_img, kv, hd)."""
    b, n_img, _ = image_embeds.shape
    hd = cfg.resolved_head_dim
    x = image_embeds @ params["vision_proj"]   # (b, n_img, d_model)

    def one(cbp):
        k = (x @ cbp["attn"]["wk"]).reshape(b, n_img, cfg.n_kv_heads, hd)
        v = (x @ cbp["attn"]["wv"]).reshape(b, n_img, cfg.n_kv_heads, hd)
        return {"k": k, "v": v}

    return jax.vmap(one)(params["cross"])


def init(rng, cfg: ModelConfig):
    ng, per_self = group_shape(cfg)
    ks = jax.random.split(rng, 5)
    return {
        "embed": L.init_embed(ks[0], cfg),
        "vision_proj": L.dense_init(ks[1], cfg.d_vision, cfg.d_model, cfg.dtype),
        "blocks": T.stack_init(lambda k: T.init_block(k, cfg), ks[2],
                               ng * per_self),
        "cross": T.stack_init(lambda k: init_cross_block(k, cfg), ks[3], ng),
        "final_norm": L.init_norm(cfg),
    }


def forward(params, cfg: ModelConfig, tokens, image_kv, *, positions=None,
            self_cache=None, cache_index=None):
    ng, per_self = group_shape(cfg)
    h = L.embed_tokens(params["embed"], tokens)
    grouped = jax.tree.map(
        lambda x: x.reshape((ng, per_self) + x.shape[1:]), params["blocks"])
    gcache = None
    if self_cache is not None:
        gcache = jax.tree.map(
            lambda x: x.reshape((ng, per_self) + x.shape[1:]), self_cache)

    def inner(h, xs):
        bp, c = xs
        h, nc = T.apply_block(bp, cfg, h, positions=positions, cache=c,
                              cache_index=cache_index)
        return h, nc

    def group_body(h, xs):
        gbp, cbp, gc, ikv = xs
        h = T.seq_constraint(cfg, h) if self_cache is None else h
        h, ncs = jax.lax.scan(inner, h, (gbp, gc))
        h = apply_cross_block(cbp, cfg, h, ikv)
        return h, ncs

    body = T.remat_wrap(cfg, group_body)
    h, new_g = jax.lax.scan(body, h, (grouped, params["cross"], gcache,
                                      image_kv))
    h = L.apply_norm(params["final_norm"], cfg, h)
    logits = L.unembed(params["embed"], cfg, h)
    new_cache = None
    if self_cache is not None:
        new_cache = jax.tree.map(
            lambda x: x.reshape((ng * per_self,) + x.shape[2:]), new_g)
    return logits, new_cache


def loss_fn(params, cfg: ModelConfig, batch):
    ikv = image_kv_from_embeds(params, cfg, batch["image_embeds"])
    logits, _ = forward(params, cfg, batch["tokens"], ikv)
    return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:], cfg)


def init_self_cache(cfg: ModelConfig, batch: int, max_seq: int):
    ng, per_self = group_shape(cfg)
    c = L.init_kv_cache(cfg, batch, max_seq)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (ng * per_self,) + x.shape), c)


def prefill(params, cfg: ModelConfig, tokens, image_embeds,
            max_seq: Optional[int] = None):
    b, s = tokens.shape
    ikv = image_kv_from_embeds(params, cfg, image_embeds)
    self_cache = init_self_cache(cfg, b, max_seq or s)
    logits, self_cache = forward(params, cfg, tokens, ikv,
                                 self_cache=self_cache, cache_index=0)
    return logits, {"self": self_cache, "image_kv": ikv}


def decode_step(params, cfg: ModelConfig, cache, pos, tokens):
    positions = pos + jnp.zeros((1,), jnp.int32)
    logits, new_self = forward(params, cfg, tokens, cache["image_kv"],
                               positions=positions, self_cache=cache["self"],
                               cache_index=pos)
    return logits, {"self": new_self, "image_kv": cache["image_kv"]}
