from repro.optim.adamw import AdamW, AdamWState, apply_sgd  # noqa: F401
from repro.optim.schedules import (  # noqa: F401
    constant, doubling_batch, fixed_batch, step_batch, warmup_cosine)
