"""AdamW optimizer in pure JAX (no optax dependency).

State layout matches the param pytree leaf-for-leaf so the SMLT sharding
rules (ZeRO-style data-sharded optimizer state for the ``hier`` strategy)
apply uniformly: opt state leaves inherit the spec of their parameter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    schedule: Optional[Callable] = None  # step -> lr multiplier

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.grad_clip:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        sf = step.astype(jnp.float32)
        bc1 = 1 - b1 ** sf
        bc2 = 1 - b2 ** sf
        lr = self.lr * (self.schedule(step) if self.schedule else 1.0)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def apply_sgd(params, grads, lr: float):
    """Plain SGD used by the semantic serverless trainer examples."""
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
