"""LR schedules + the paper's dynamic *batch* schedulers (Section 3.2:
B = {b_1 ... b_n}, the per-epoch batch sizes of dynamic batching [23])."""
from __future__ import annotations

from typing import Callable, List, Sequence

import jax.numpy as jnp


def warmup_cosine(warmup: int, total: int, floor: float = 0.1) -> Callable:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        t = (step - warmup) / jnp.maximum(total - warmup, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(t, 0, 1)))
        return jnp.where(step < warmup, warm, cos)
    return f


def constant(_step):
    return 1.0


# -- batch schedulers (B in the paper's notation) ---------------------------


def fixed_batch(b: int, epochs: int) -> List[int]:
    return [b] * epochs


def doubling_batch(b0: int, epochs: int, every: int = 2,
                   cap: int = 1 << 16) -> List[int]:
    """Worker-adaptive batch scaling a la [23]: double every ``every`` epochs."""
    out = []
    b = b0
    for e in range(epochs):
        if e and e % every == 0:
            b = min(b * 2, cap)
        out.append(b)
    return out


def step_batch(sizes: Sequence[int], epochs_per: int) -> List[int]:
    out = []
    for s in sizes:
        out += [s] * epochs_per
    return out
