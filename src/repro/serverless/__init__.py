from repro.serverless.events import EngineResult, EventEngine  # noqa: F401
from repro.serverless.platform import BillingLedger, ServerlessPlatform  # noqa: F401
from repro.serverless.stores import ObjectStore, ParamStore, SharedLink  # noqa: F401
from repro.serverless.worker import (  # noqa: F401
    WORKLOADS, CommPhase, LocalWorkerPool, Workload, comm_breakdown,
    comm_plan, iteration_time, parse_sync_mode)
