from repro.serverless.arrivals import (  # noqa: F401
    ArrivalSpec, RequestStream, ServingTask)
from repro.serverless.backends import (  # noqa: F401
    BACKENDS, BackendSpec, PriceTrace, hazard_cadence_s, resolve_backend,
    simulate_spot_epoch, spot_variant)
from repro.serverless.events import (  # noqa: F401
    ContentionDomain, EngineResult, EventEngine, ServingJob, ServingResult)
from repro.serverless.platform import (  # noqa: F401
    BillingLedger, FleetSpec, ServerlessPlatform, ShockModel, WorkerSpec,
    fleet_from_config)
from repro.serverless.stores import ObjectStore, ParamStore, SharedLink  # noqa: F401
from repro.serverless.worker import (  # noqa: F401
    WORKLOADS, LocalWorkerPool, Workload, comm_breakdown, iteration_time,
    parse_sync_mode)
