from repro.serverless.platform import BillingLedger, ServerlessPlatform  # noqa: F401
from repro.serverless.stores import ObjectStore, ParamStore  # noqa: F401
from repro.serverless.worker import (  # noqa: F401
    WORKLOADS, LocalWorkerPool, Workload, comm_breakdown, iteration_time)
