"""Inference arrival processes for the event engine's serving jobs.

PAPER.md's loop is continuous — "models are continuously trained,
improved, and deployed" — so serving traffic must be a workload the
simulator can generate at production shape: a Poisson request stream
whose rate follows a diurnal cycle (reusing ``repro.data.OnlineStream``,
the same process that drives the online-training experiment) with
flash-crowd bursts layered on top. At planet scale ("millions of users")
the stream is generated slice-by-slice with vectorized placement, not
one draw per request chain.

``ServingTask`` packages an arrival process with a serving policy into
the workflow layer's ``deploy`` task kind: the closed-form ``estimate()``
gives the budget allocator a forecast the same way ``epoch_estimate``
does for training tasks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.rng import base_stream
from repro.data.pipeline import OnlineStream
from repro.serverless.platform import LAMBDA_GB_SECOND, LAMBDA_PER_REQUEST


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """A diurnal + bursty Poisson request process.

    ``base_rps`` is the diurnal-mean request rate; the rate swings by
    ``amplitude`` over ``period_s`` (the OnlineStream sine). Bursts are a
    Poisson process of flash-crowd episodes (``bursts_per_hour``): while
    one is active the instantaneous rate is multiplied by
    ``burst_multiplier`` for ``burst_s`` seconds."""
    base_rps: float
    period_s: float = 86_400.0
    amplitude: float = 0.5
    bursts_per_hour: float = 0.0
    burst_s: float = 60.0
    burst_multiplier: float = 3.0

    def mean_rps(self) -> float:
        """Long-run mean rate including the burst excess."""
        burst_frac = self.bursts_per_hour / 3600.0 * self.burst_s
        return self.base_rps * (1.0 + burst_frac
                                * (self.burst_multiplier - 1.0))

    def expected_requests(self, horizon_s: float) -> float:
        return self.mean_rps() * horizon_s


class RequestStream:
    """Samples concrete arrival timestamps from an :class:`ArrivalSpec`.

    Generation is sliced: per ``slice_s`` window the diurnal Poisson
    count comes from ``OnlineStream.arrivals`` (bit-compatible with the
    online-training stream), is scaled by any burst overlapping the
    slice, and the requests are placed uniformly inside the slice — one
    numpy call per slice, so a million-request day is cheap."""

    def __init__(self, spec: ArrivalSpec, seed: int = 0):
        self.spec = spec
        self.seed = seed

    def rate(self, t: float) -> float:
        """Deterministic diurnal rate (bursts excluded)."""
        s = self.spec
        return max(s.base_rps * (1.0 + s.amplitude
                                 * np.sin(2 * np.pi * t / s.period_s)), 0.0)

    def _burst_windows(self, t0: float, horizon_s: float,
                       rng: np.random.RandomState) -> list:
        s = self.spec
        if s.bursts_per_hour <= 0.0:
            return []
        out, t = [], t0
        while True:
            t += float(rng.exponential(3600.0 / s.bursts_per_hour))
            if t >= t0 + horizon_s:
                return out
            out.append((t, t + s.burst_s))

    def arrivals(self, t0: float = 0.0, horizon_s: float = 600.0,
                 slice_s: float = 1.0) -> np.ndarray:
        """Sorted arrival offsets in ``[0, horizon_s)`` (relative to
        ``t0``; ``t0`` only phases the diurnal cycle)."""
        s = self.spec
        diurnal = OnlineStream(s.base_rps, seed=self.seed,
                               period_s=s.period_s, amplitude=s.amplitude)
        rng = base_stream(self.seed + 1)
        bursts = self._burst_windows(t0, horizon_s, rng)
        chunks = []
        lo = t0
        while lo < t0 + horizon_s:
            dt = min(slice_s, t0 + horizon_s - lo)
            k = diurnal.arrivals(lo, dt)
            # burst excess: extra Poisson mass proportional to overlap
            overlap = sum(max(min(hi_b, lo + dt) - max(lo_b, lo), 0.0)
                          for lo_b, hi_b in bursts)
            if overlap > 0.0:
                extra = self.rate(lo + dt / 2) * overlap \
                    * (s.burst_multiplier - 1.0)
                k += int(rng.poisson(extra))
            if k:
                chunks.append(rng.uniform(lo - t0, lo - t0 + dt, size=k))
            lo += dt
        if not chunks:
            return np.empty(0, dtype=float)
        return np.sort(np.concatenate(chunks))


@dataclasses.dataclass(frozen=True)
class ServingTask:
    """The workflow-layer spec of one ``deploy`` task: serve ``arrivals``
    for ``duration_s`` under ``policy`` on an autoscaled serverless
    fleet. ``model_bytes`` is fetched from the ParamStore on every cold
    start (and every ``refresh_every_s`` — continuous deployment serves
    the *current* model), ``code_bytes`` from the ObjectStore; both ride
    the engine's shared links, so a deployed model contends with the
    training that produces its successor. ``link_priority`` is the
    water-filling priority of the serving fetches on those links."""
    policy: "object"                 # repro.serving.ServePolicy
    arrivals: ArrivalSpec
    duration_s: float
    flops_per_request: float
    model_bytes: float = 0.0
    code_bytes: float = 0.0
    slo_s: Optional[float] = None
    cold_start_s: float = 1.0
    keep_warm_s: float = 60.0
    max_instances: int = 64
    refresh_every_s: Optional[float] = None
    link_priority: float = 1.0

    def estimate(self) -> Tuple[float, float]:
        """Closed-form (wall_s, cost_usd) forecast for the allocator —
        the serving analogue of ``epoch_estimate``."""
        from repro.serving.batcher import exec_time
        pol = self.policy
        n_req = max(self.arrivals.expected_requests(self.duration_s), 1.0)
        rate = self.arrivals.mean_rps()
        # mean batch: bounded by the batch cap and by what a timeout
        # window collects at this rate
        mean_batch = min(float(pol.max_batch),
                         max(rate * pol.timeout_s, 1.0))
        batches = n_req / mean_batch
        dt = exec_time(self.flops_per_request, int(round(mean_batch)),
                       pol.memory_mb)
        gb_s = batches * pol.memory_mb / 1024.0 * dt
        cost = gb_s * LAMBDA_GB_SECOND + batches * LAMBDA_PER_REQUEST
        # the tail drains within one timeout + one execution past the
        # last arrival
        wall = self.duration_s + pol.timeout_s + dt
        return wall, cost
