"""Execution backends: where a job runs, as a typed searchable dimension.

`BackendSpec` carries the execution semantics that differ across
deployment targets — exactly like ``CommPlan`` did for communication:

- ``serverless``: per-request billing, 900 s duration cap, cold starts,
  instant elasticity. This is the repo's native target; a ``None`` (or
  ``"serverless"``) backend resolves to the legacy code path so
  serverless-only configs stay bit-identical.
- ``vm``: a provisioning delay of minutes replaces the cold start,
  per-second billing runs from the end of provisioning to teardown,
  there is no duration cap and no per-request fee.
- ``gpu_vm``: a VM with a high compute rate and a high $/s, optional
  spot tier priced by a `PriceTrace`.

Spot semantics: when the spot price crosses the bid, the spot subset is
preempted (a correlated shock in the event engine — in-flight work is
lost and the worker restarts from its last checkpoint). The
``spot_policy`` selects what happens next: ``"fallback"`` restarts
immediately on on-demand billing; ``"wait"`` sits out the spike unbilled
until the price drops back below the bid.

Checkpoint cadence under preemption is hazard-aware: the Young–Daly
interval ``sqrt(2 * ckpt_write_s / hazard)`` derived from the trace's
local preemption hazard rate instead of a constant (see
``hazard_cadence_s`` and ``docs/BACKENDS.md``).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, Optional, Tuple, Union

# ---------------------------------------------------------------------------
# spot-price model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PriceTrace:
    """Piecewise-constant per-worker spot price.

    ``prices_usd_per_hr[i]`` holds from ``times_s[i]`` until
    ``times_s[i+1]`` (the last segment holds forever). Frozen and
    tuple-backed so it hashes cleanly into probe-cache keys.
    """
    times_s: Tuple[float, ...]
    prices_usd_per_hr: Tuple[float, ...]

    def __post_init__(self):
        if len(self.times_s) != len(self.prices_usd_per_hr):
            raise ValueError("times_s and prices_usd_per_hr length mismatch")
        if not self.times_s:
            raise ValueError("PriceTrace needs at least one segment")
        if self.times_s[0] != 0.0:
            raise ValueError("PriceTrace must start at t=0")
        if any(b <= a for a, b in zip(self.times_s, self.times_s[1:])):
            raise ValueError("times_s must be strictly increasing")
        if any(p < 0 for p in self.prices_usd_per_hr):
            raise ValueError("negative price")

    def _seg(self, t_s: float) -> int:
        return max(bisect.bisect_right(self.times_s, t_s) - 1, 0)

    def price_at(self, t_s: float) -> float:
        """$/hr per worker in effect at ``t_s``."""
        return self.prices_usd_per_hr[self._seg(t_s)]

    def next_crossing_above(self, t_s: float, bid_usd_per_hr: float) -> float:
        """Earliest time ``>= t_s`` at which the price exceeds the bid
        (``math.inf`` when it never does)."""
        i = self._seg(t_s)
        if self.prices_usd_per_hr[i] > bid_usd_per_hr:
            return t_s
        for j in range(i + 1, len(self.times_s)):
            if self.prices_usd_per_hr[j] > bid_usd_per_hr:
                return self.times_s[j]
        return math.inf

    def next_drop_below(self, t_s: float, bid_usd_per_hr: float) -> float:
        """Earliest time ``>= t_s`` at which the price is at or below the
        bid (``math.inf`` when it never recovers)."""
        i = self._seg(t_s)
        if self.prices_usd_per_hr[i] <= bid_usd_per_hr:
            return t_s
        for j in range(i + 1, len(self.times_s)):
            if self.prices_usd_per_hr[j] <= bid_usd_per_hr:
                return self.times_s[j]
        return math.inf

    def integral_usd(self, t0_s: float, t1_s: float) -> float:
        """Dollars one worker accrues over ``[t0_s, t1_s]`` at the trace
        price."""
        if t1_s <= t0_s:
            return 0.0
        usd = 0.0
        i = self._seg(t0_s)
        t = t0_s
        while t < t1_s:
            seg_end = (self.times_s[i + 1] if i + 1 < len(self.times_s)
                       else math.inf)
            span_s = min(t1_s, seg_end) - t
            usd += span_s / 3600.0 * self.prices_usd_per_hr[i]
            t += span_s
            i += 1
        return usd

    @property
    def mean_usd_per_hr(self) -> float:
        """Time-average price over the trace's defined span (the
        analytic estimate's expected spot rate)."""
        span_s = self.times_s[-1]
        if span_s <= 0.0:
            return self.prices_usd_per_hr[0]
        return self.integral_usd(0.0, span_s) * 3600.0 / span_s

    def hazard_per_s(self, bid_usd_per_hr: float, t0_s: float = 0.0,
                     horizon_s: float = 0.0) -> float:
        """Preemption hazard rate: up-crossings of the bid per second over
        ``[t0_s, t0_s + horizon_s)`` (the whole remaining trace when
        ``horizon_s`` is 0). An up-crossing at a segment boundary counts
        when the previous segment was at/below the bid."""
        end_s = (t0_s + horizon_s) if horizon_s > 0 else self.times_s[-1]
        if end_s <= t0_s:
            end_s = t0_s + 1.0
        crossings = 0
        prev_above = self.price_at(t0_s) > bid_usd_per_hr
        for j in range(self._seg(t0_s) + 1, len(self.times_s)):
            if self.times_s[j] >= end_s:
                break
            above = self.prices_usd_per_hr[j] > bid_usd_per_hr
            if above and not prev_above:
                crossings += 1
            prev_above = above
        return crossings / (end_s - t0_s)


def hazard_cadence_s(hazard_per_s: float, ckpt_write_s: float,
                     floor_s: float = 1.0) -> float:
    """Hazard-aware checkpoint interval (Young–Daly first-order optimum).

    ``tau* = sqrt(2 * ckpt_write_s / hazard)`` balances checkpoint
    overhead (``ckpt_write_s / tau``) against expected rework
    (``hazard * tau / 2``). Zero hazard means never checkpoint
    (``math.inf``)."""
    if hazard_per_s <= 0.0:
        return math.inf
    return max(math.sqrt(2.0 * ckpt_write_s / hazard_per_s), floor_s)


# ---------------------------------------------------------------------------
# backend specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Execution semantics of one deployment target.

    ``kind`` is ``"serverless"`` (per-request billing, duration cap,
    cold starts) or ``"vm"`` (provisioning delay, per-second billing
    from the end of provisioning, no cap, no request fee). VM kinds
    override the memory-derived compute rate and NIC with flat
    ``gflops`` / ``net_gbps``; a spot tier adds a `PriceTrace` and a
    bid."""
    name: str
    kind: str = "serverless"
    provision_s: float = 0.0           # replaces the cold start (vm kinds)
    usd_per_hr: float = 0.0            # on-demand $/hr per worker (vm kinds)
    gflops: Optional[float] = None     # None: memory-derived fn_gflops
    net_gbps: Optional[float] = None   # None: memory-derived fn_net_gbps
    spot: bool = False
    price_trace: Optional[PriceTrace] = None
    bid_usd_per_hr: float = 0.0
    spot_policy: str = "fallback"      # "fallback" (on-demand) | "wait"

    def __post_init__(self):
        if self.kind not in ("serverless", "vm"):
            raise ValueError(f"backend kind {self.kind!r}")
        if self.spot_policy not in ("fallback", "wait"):
            raise ValueError(f"spot_policy {self.spot_policy!r}")
        if self.spot and self.price_trace is None:
            raise ValueError("spot backend needs a price_trace")
        if self.spot and self.bid_usd_per_hr <= 0:
            raise ValueError("spot backend needs a positive bid")

    @property
    def capped(self) -> bool:
        return self.kind == "serverless"

    @property
    def usd_per_s(self) -> float:
        return self.usd_per_hr / 3600.0

    @property
    def expected_usd_per_s(self) -> float:
        """The rate the analytic estimate bills at: the on-demand rate,
        or the trace's time-average for spot tiers."""
        if self.spot and self.price_trace is not None:
            return self.price_trace.mean_usd_per_hr / 3600.0
        return self.usd_per_s

    def gflops_for(self, memory_mb: float) -> float:
        if self.gflops is not None:
            return self.gflops
        from repro.serverless.platform import fn_gflops
        return fn_gflops(memory_mb)

    def net_gbps_for(self, memory_mb: float) -> float:
        if self.net_gbps is not None:
            return self.net_gbps
        from repro.serverless.platform import fn_net_gbps
        return fn_net_gbps(memory_mb)


# Registry of named targets. Rates follow the paper-era AWS price book
# already used by the VM baselines in ``core/cost_model.py``
# (c5.2xlarge-class CPU VM) plus a single-accelerator GPU instance
# (p3.2xlarge-class).
BACKENDS: Dict[str, BackendSpec] = {
    "serverless": BackendSpec("serverless", "serverless"),
    "vm": BackendSpec("vm", "vm", provision_s=120.0, usd_per_hr=0.34,
                      gflops=360.0, net_gbps=1.25),
    "gpu_vm": BackendSpec("gpu_vm", "vm", provision_s=180.0, usd_per_hr=3.06,
                          gflops=7800.0, net_gbps=10.0),
}

BackendLike = Union[None, str, BackendSpec]


def resolve_backend(backend: BackendLike) -> Optional[BackendSpec]:
    """Resolve a backend name/spec to the spec the engine executes.

    ``None``, ``""``, and plain (non-spot) ``"serverless"`` resolve to
    ``None`` — the legacy serverless code path, kept byte-identical."""
    if backend is None or backend == "":
        return None
    if isinstance(backend, str):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r} "
                             f"(known: {sorted(BACKENDS)})")
        backend = BACKENDS[backend]
    if backend.kind == "serverless" and not backend.spot:
        return None
    return backend


def spot_variant(base: BackendLike, price_trace: PriceTrace,
                 bid_usd_per_hr: float,
                 spot_policy: str = "fallback") -> BackendSpec:
    """A spot-tier copy of a VM backend priced by ``price_trace``."""
    spec = BACKENDS[base] if isinstance(base, str) else base
    if spec is None or spec.kind != "vm":
        raise ValueError("spot tier applies to vm backends")
    return dataclasses.replace(
        spec, name=spec.name + "_spot", spot=True, price_trace=price_trace,
        bid_usd_per_hr=bid_usd_per_hr, spot_policy=spot_policy)


# ---------------------------------------------------------------------------
# closed-form spot-run model (cadence study)
# ---------------------------------------------------------------------------


def simulate_spot_epoch(work_s: float, backend: BackendSpec, *,
                        cadence_s: Optional[float] = None,
                        ckpt_write_s: float = 2.0,
                        restore_s: float = 1.5,
                        n_workers: int = 1,
                        hazard_horizon_s: float = 1800.0) -> Dict[str, float]:
    """Deterministic trace-driven run of ``work_s`` seconds of lockstep
    work on a spot backend, checkpointing every ``cadence_s`` seconds
    (``None``: hazard-aware — the trace is treated as a price forecast;
    the base interval is the Young–Daly optimum for the forward hazard
    over ``hazard_horizon_s``, recomputed after every checkpoint, and
    progress-at-risk is flushed by a checkpoint timed to complete just
    before a forecast bid crossing).

    Preemption at each price up-crossing of the bid loses the work since
    the last completed checkpoint; the fleet then re-provisions and
    restores. ``spot_policy="wait"`` additionally sits out the spike
    unbilled until the price drops back below the bid;
    ``"fallback"`` resumes immediately on on-demand billing (no further
    preemptions). Billing runs from the end of each provisioning to the
    preemption/teardown, at the trace price (spot) or the flat
    on-demand rate (after fallback). Returns wall/cost/preemptions/
    checkpoint counts."""
    trace, bid = backend.price_trace, backend.bid_usd_per_hr
    if trace is None:
        raise ValueError("simulate_spot_epoch needs a spot backend")

    def _cadence(t: float) -> float:
        if cadence_s is not None:
            return cadence_s
        lam = trace.hazard_per_s(bid, t, hazard_horizon_s)
        return hazard_cadence_s(lam, ckpt_write_s)

    t = trace.next_drop_below(0.0, bid)    # can't provision above the bid
    if math.isinf(t):
        raise ValueError("price never at/below bid; spot run cannot start")
    done_s = 0.0                           # checkpointed progress
    usd = 0.0
    preemptions = checkpoints = 0
    on_demand = False
    t += backend.provision_s
    while done_s < work_s:
        kill_t = (math.inf if on_demand
                  else trace.next_crossing_above(t, bid))
        bill_t0 = t                        # billing arms after provisioning
        # run work-then-checkpoint stretches until finish or preemption
        while t < kill_t and done_s < work_s:
            span = min(_cadence(t), work_s - done_s)
            if cadence_s is None and not math.isinf(kill_t):
                # progress-at-risk flush: time the last checkpoint to
                # complete just before the forecast crossing
                span = min(span, kill_t - ckpt_write_s - t)
                if span <= 0.0:
                    t = kill_t             # nothing at risk fits; idle out
                    break
            fin = t + span
            if done_s + span >= work_s and fin <= kill_t:
                done_s = work_s            # final stretch: no trailing ckpt
                t = fin
            elif fin + ckpt_write_s <= kill_t:
                done_s += span             # checkpoint completes in time
                checkpoints += 1
                t = fin + ckpt_write_s
            else:
                t = kill_t                 # preempted mid-stretch/mid-ckpt:
                break                      # progress since last ckpt is lost
        preempted = done_s < work_s
        if preempted:
            preemptions += 1
        usd += n_workers * (
            (t - bill_t0) * backend.usd_per_s if on_demand
            else trace.integral_usd(bill_t0, t))
        if not preempted:
            break
        # restart from the last completed checkpoint
        if backend.spot_policy == "fallback":
            on_demand = True
            t = t + backend.provision_s + restore_s
        else:
            rec_t = trace.next_drop_below(t, bid)
            if math.isinf(rec_t):
                raise ValueError("price never recovers below bid")
            t = rec_t + backend.provision_s + restore_s
    return {"wall_s": t, "cost_usd": usd, "preemptions": float(preemptions),
            "checkpoints": float(checkpoints),
            "on_demand": 1.0 if on_demand else 0.0}
