"""Discrete-event execution core for the serverless simulator.

The closed-form ``epoch_estimate`` (repro.core.cost_model) costs a whole
epoch in one expression — nothing can *happen* inside it. This engine
replays the same epoch as a time-ordered event simulation with one state
machine per worker::

    invoke -> cold-start -> [data-fetch] -> { compute ->
        <CommPlan phases> -> step }* -> finish

The per-iteration communication is not hard-coded: the engine executes
the same ``repro.core.comm.CommPlan`` the analytic model prices, phase
by phase. The phase DAG contract it honors:

  - phases run in sequence per worker; workers ``0..fan_in-1``
    participate in a phase (aggregators relabeled to the lowest ids),
    everyone else skips it;
  - a participating worker opens one transfer of ``nbytes`` on the
    phase's store link (``requests`` round-trips of setup latency), so
    hierarchy levels contend on the *real* ``SharedLink`` — and interact
    with caps, failures, shocks, and cross-job traffic;
  - ``cpu_s`` (e.g. densifying a compressed payload) computes after the
    transfer, off the link — the store's keep-alive window excludes it;
  - in bsp, ``barrier_after`` joins **all** n workers before anyone
    proceeds; ssp(k)/async drop the joins and keep only their gates;
  - only ``store == "param"`` phases count toward the param store's
    keep-alive window — an object-store plan (``ps_s3``) bills the Redis
    container nothing;
  - a **pipelined** plan (``CommPlan.pipeline(depth)``) runs each
    iteration as ``depth`` compute segments with the overlappable
    leading uploads hidden underneath: the worker state machine gains a
    second activity slot (a compute lane and a transfer lane running
    concurrently), segment *i*'s upload share starts once segment *i*'s
    compute lands and queues behind segment *i-1*'s share, and the
    phase's barrier joins only after the *last* segment's upload.
    Duration-cap restarts pause **both** lanes and resume them with
    their progress; failures and shocks lose both and redo the
    iteration from its boundary.

This makes the paper's dynamics first-class:

  - **Contended stores**: transfers share store bandwidth only while they
    actually overlap (``SharedLink`` processor sharing), instead of the
    analytic model's static ``concurrent=n`` divisor.
  - **Heterogeneous fleets**: a ``FleetSpec`` gives each worker its own
    ``(memory_mb, tier)`` — per-worker compute rate (``compute_time``),
    network cap (``fn_net_gbps``, carried as a per-flow cap on the shared
    link), and GB-second billing rate. ``FleetSpec.homogeneous`` reproduces
    the classic ``(n, memory_mb)`` deployment exactly.
  - **Stragglers**: per-(worker, iteration) lognormal compute multipliers
    (mean 1, so the zero-variance limit reproduces the analytic model).
  - **Mid-flight failures**: a worker dies partway through an iteration,
    re-invokes, restores the checkpoint from the ObjectStore, and redoes
    the iteration — stalling its barrier peers, as it would on Lambda.
  - **Correlated failures**: a ``ShockModel`` layers a shared-shock process
    on top of the independent per-iteration ``failure_rate``: shocks arrive
    as a Poisson process and each one kills a random subset of the fleet at
    once (optionally only a tier, e.g. "spot"), losing in-flight work.
  - **Multi-job contention**: several engines can register into one
    ``ContentionDomain`` — a shared clock + event queue. Engines that use
    the same ``ParamStore``/``ObjectStore`` then contend on the *same*
    ``SharedLink``, so cross-job transfers slow each other by their actual
    overlap (the "noisy neighbor" regime of arXiv 2105.07806).
  - **Duration caps**: each invocation may hold at most
    ``max_duration_s - init - restore`` seconds of work; the engine
    checkpoints through the ObjectStore and restarts mid-segment (billing
    n requests per restart wave, per Lambda semantics).
  - **sync_mode**: "bsp" runs the comm plan's barriers; "ssp(k)" gates a
    worker only when it runs k iterations ahead of the slowest peer;
    "async" removes all inter-worker waits. (``LocalWorkerPool`` carries
    the matching stale-gradient *numerics*.)
  - **Mid-epoch adaptation**: ``on_iteration`` observes every global
    iteration completion; returning True checkpoints and stops the epoch
    early so the scheduler can re-optimize *mid-epoch*.

In the zero-variance, zero-failure, bsp limit the engine reproduces
``epoch_estimate`` wall-clock and cost within 1% (tested); with any
variance it yields the tail behavior the analytic path cannot express.

Throughput machinery (the 10k-worker regime; see docs/PERF.md):

  - the event queue is a bucketed **calendar queue** dispatching
    ``(t, seq, fn, arg)`` records — hot events are prebound methods with
    a tuple payload, not a fresh closure per event;
  - stochastic draws are **vectorized**: per-epoch ``(n, iters)`` blocks
    of straggler multipliers and failure outcomes are drawn in one numpy
    call per stream and consumed in per-worker attempt order, so
    same-seed runs stay bit-identical;
  - in the deterministic homogeneous bsp regime, identical workers that
    move in lockstep are **coalesced** into cohorts (split only at
    CommPlan ``fan_in`` boundaries) that advance as one state machine —
    per-worker billing records and trace lines are still emitted, so
    every bookkeeping invariant is preserved exactly;
  - ``record_trace=False`` skips trace-line accumulation entirely;
  - per-event fleet scans (min-iteration, all-finished) are replaced by
    an iteration histogram and an unfinished counter.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
import itertools
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import rng as rng_streams
from repro.serverless.backends import BackendLike, resolve_backend
from repro.serverless.platform import (CHECKPOINT_RESTORE_S,
                                       DATA_OBJECT_BYTES, LAMBDA_GB_SECOND,
                                       LAMBDA_MAX_DURATION_S,
                                       LAMBDA_PER_REQUEST, FleetSpec,
                                       InvocationRecord, ServerlessPlatform,
                                       ShockModel, fn_net_gbps)
from repro.core.comm import CommLike, CommPlan, build_plan
from repro.serverless.stores import (ECS_GB_HOUR, ECS_VCPU_HOUR, S3_GET_PER_1K,
                                     ObjectStore, ParamStore, SharedLink)
from repro.serverless.worker import (Workload, compute_time,
                                     fleet_local_batches, parse_sync_mode)

_EPS_GB = 1e-12          # flow remainder considered complete (~1e-3 byte)
_INF = math.inf


class CalendarQueue:
    """Bucketed future-event list: a ring of time-sliced buckets, each a
    small heap. Push hashes an event to the bucket covering its
    timestamp; pop scans forward from the current bucket, so dequeue
    order is exactly the ``(t, seq)`` total order a global heap gives,
    with O(1) expected push/pop instead of O(log n).

    The bucket count doubles (halves) when occupancy grows (shrinks)
    past 2 events/bucket, and the bucket width is re-derived from the
    observed inter-event gaps on each resize (Brown's calendar-queue
    heuristic). A scan that walks a whole empty "year" jumps straight to
    the bucket holding the global minimum, so sparse far-future events
    (keep-alive caps, shock arrivals) cannot stall the scan."""

    __slots__ = ("_nb", "_width", "_buckets", "_cur_abs", "_size", "_cold")

    def __init__(self, nbuckets: int = 32, width: float = 1.0):
        self._nb = nbuckets
        self._width = width
        self._buckets: List[list] = [[] for _ in range(nbuckets)]
        self._cur_abs = 0            # absolute (un-wrapped) bucket index
        self._size = 0
        self._cold = 0               # consecutive under-occupancy pops

    def __len__(self) -> int:
        return self._size

    def __bool__(self) -> bool:
        return self._size > 0

    def push(self, ev: tuple):
        """``ev`` sorts by its leading ``(t, seq)`` fields."""
        ab = int(ev[0] / self._width)
        if ab < self._cur_abs:       # same-instant event during dispatch
            ab = self._cur_abs
        if self._size == 0:
            self._cur_abs = ab       # fast-forward an idle scan position
        heapq.heappush(self._buckets[ab % self._nb], ev)
        self._size += 1
        if self._size > 2 * self._nb:
            self._resize(2 * self._nb)

    def pop(self) -> tuple:
        if not self._size:
            raise IndexError("pop from empty CalendarQueue")
        if self._nb > 32 and self._size < self._nb // 6:
            # shrink with hysteresis, straight to the occupancy-matched
            # size: a periodic workload (a fan-out window's wave every
            # iteration) keeps its ring across the brief sparse phase
            # instead of paying a shrink+regrow cycle per period
            self._cold += 1
            if self._cold >= 128:
                self._cold = 0
                nb = 32
                while nb < self._size:
                    nb <<= 1
                self._resize(max(nb, 32))
        else:
            self._cold = 0
        nb, width, buckets = self._nb, self._width, self._buckets
        ab = self._cur_abs
        scanned = 0
        while True:
            b = buckets[ab % nb]
            if b and b[0][0] < (ab + 1) * width:
                self._cur_abs = ab
                self._size -= 1
                return heapq.heappop(b)
            ab += 1
            scanned += 1
            if scanned > nb:
                # a full year of buckets is empty at this resolution:
                # jump to the bucket holding the global minimum
                head = min(b[0] for b in buckets if b)
                ab = max(int(head[0] / width), self._cur_abs)
                scanned = 0

    def push_bulk(self, evs: list):
        """Insert many events in one call: one resize check for the whole
        batch and no per-event method dispatch. The dequeue order is the
        ``(t, seq)`` record order either way, so bulk insertion is
        observationally identical to pushing one at a time."""
        if not evs:
            return
        nb, width, buckets = self._nb, self._width, self._buckets
        cur = self._cur_abs
        empty = self._size == 0
        for ev in evs:
            ab = int(ev[0] / width)
            if ab < cur:             # same-instant event during dispatch
                ab = cur
            if empty:                # fast-forward an idle scan position
                self._cur_abs = cur = ab
                empty = False
            heapq.heappush(buckets[ab % nb], ev)
        self._size += len(evs)
        new_nb = self._nb
        while self._size > 2 * new_nb:
            new_nb *= 2
        if new_nb != self._nb:
            self._resize(new_nb)

    def _resize(self, new_nb: int):
        evs = [e for b in self._buckets for e in b]
        if evs:
            # ~3 events per bucket-width keeps both the scan and the
            # per-bucket heaps short; the span/count estimate of the mean
            # gap needs no sort, so a resize is O(n)
            lo = min(evs)[0]
            hi = max(ev[0] for ev in evs)
            if hi > lo:
                self._width = max(3.0 * (hi - lo) / len(evs), 1e-9)
            base = int(lo / self._width)
        else:
            base = 0
        self._nb = new_nb
        self._buckets = [[] for _ in range(new_nb)]
        self._cur_abs = base
        width = self._width
        for e in evs:
            ab = int(e[0] / width)
            self._buckets[(ab if ab > base else base) % new_nb].append(e)
        for b in self._buckets:
            if len(b) > 1:
                b.sort()


class _Transfer:
    """A pausable store transfer: ``requests * latency`` of setup, then a
    flow on the link at the processor-sharing rate. ``cap_gbps`` is the
    issuing worker's function-network limit (per-flow cap on the link).
    ``weight`` counts the member streams a coalesced cohort's single
    flow stands for (bytes and rate stay per member)."""
    _ids = itertools.count()

    __slots__ = ("fid", "link", "remaining_gb", "total_gb", "latency_left",
                 "setup_latency_s", "cb", "token", "is_sync", "cap_gbps",
                 "weight", "prio")

    def __init__(self, link: SharedLink, nbytes: float, latency_s: float,
                 cb: Callable[[], None], is_sync: bool,
                 cap_gbps: Optional[float] = None, weight: int = 1,
                 prio: float = 1.0):
        self.fid = next(self._ids)
        self.link = link
        self.remaining_gb = nbytes / 1e9
        self.total_gb = self.remaining_gb
        self.latency_left = latency_s
        self.setup_latency_s = latency_s
        self.cb = cb
        self.token = 0          # invalidates scheduled setup events on pause
        self.is_sync = is_sync  # gradient sync (param-store keep-alive window)
        self.cap_gbps = cap_gbps
        self.weight = weight
        self.prio = prio        # water-filling priority (SharedLink.rates)


class ContentionDomain:
    """Shared clock + event queue + store links for one or more engines.

    Each ``EventEngine`` owns a private domain by default (single-job runs
    are unchanged). To co-simulate jobs, construct one domain and pass it
    to every engine: engines that name the same store object share its
    ``SharedLink``, so their transfers contend by actual overlap::

        dom = ContentionDomain()
        a = EventEngine(..., param_store=shared_ps, domain=dom, seed=0)
        b = EventEngine(..., param_store=shared_ps, domain=dom, seed=1)
        dom.run()
        ra, rb = a.result(), b.result()
    """

    def __init__(self):
        self.now = 0.0
        self._q = CalendarQueue()
        self._seq = itertools.count()
        self._links: Dict[Tuple[int, str], SharedLink] = {}
        self._engines: List["EventEngine"] = []
        self._groups: Dict[int, List["EventEngine"]] = {}
        self._running = False
        self.dispatched = 0     # queue events executed (profiling counter)
        # union of time *any* engine's sync transfers are outstanding: the
        # honest keep-alive window for one param store shared across jobs
        # (per-engine sync_s sums would double-bill the overlap).
        # Accounting is interval-based: engines report their 0<->1
        # sync-outstanding transitions (``_sync_on``/``_sync_off``) and the
        # domain closes [on, off) intervals — no per-time-advance scans.
        self.sync_union_s = 0.0
        self._sync_n = 0        # engines with sync transfers outstanding
        self._sync_t0 = 0.0
        # same union, kept per param store (id) — the billing basis when a
        # store is shared: each engine is billed its proportional share
        self._store_sync: Dict[int, float] = {}
        self._store_n: Dict[int, int] = {}
        self._store_t0: Dict[int, float] = {}
        # union seconds already allocated to taken results, per store —
        # lets late-arriving engines (workflow tasks admitted at t > 0)
        # bill against only the not-yet-allocated remainder
        self._store_billed: Dict[int, float] = {}
    def at(self, t: float, fn: Callable):
        self._q.push((t, next(self._seq), fn, None))

    def at2(self, t: float, fn: Callable, arg):
        """Schedule a record event: ``fn(arg)`` at ``t``. ``fn`` is a
        prebound method and ``arg`` its payload tuple — no per-event
        closure is allocated."""
        self._q.push((t, next(self._seq), fn, arg))

    def at2_bulk(self, items):
        """Bulk-schedule ``(t, fn, arg)`` records in one queue insert —
        the per-iteration compute-finish waves and serving arrival slabs
        ride this. Safe mid-dispatch: dequeue order is the ``(t, seq)``
        total order however events were inserted."""
        seq = self._seq
        self._q.push_bulk([(t, next(seq), fn, arg) for t, fn, arg in items])

    def link_for(self, store, kind: str) -> SharedLink:
        """The one SharedLink all engines in this domain use for ``store``
        (keyed by object identity, so distinct stores never contend)."""
        key = (id(store), kind)
        if key not in self._links:
            self._links[key] = store.link()
        return self._links[key]

    def _register(self, engine: "EventEngine"):
        """Admit an engine. Admission is legal at any point — before the
        first ``run()``, between runs, or *mid-run* (a workflow task whose
        dependencies completed at t > 0): a mid-run admission schedules the
        engine's start at ``max(start_at, now)`` on the live queue."""
        self._engines.append(engine)
        self._groups.setdefault(id(engine.param_store), []).append(engine)
        if len(self._engines) > 1:
            # a second job voids the sole-flow-source premise of any
            # armed drain cascade (see _cascade) — permanently
            for link in self._links.values():
                link.cascade = None
        if self._running:
            # the engine is still mid-__init__ when it registers: defer the
            # launch onto the live queue so it starts (at its own start_at,
            # never in the past) only once fully constructed
            self.at(max(engine.start_at, self.now),
                    lambda: self._launch(engine))
        return len(self._engines) - 1   # job index

    def _launch(self, eng: "EventEngine"):
        if eng._started:
            return
        if eng.start_at <= self.now:
            eng._start()                 # the clock never rewinds
        else:
            self.at(eng.start_at, eng._start)

    def run(self):
        """Run every registered engine to completion on the shared clock.
        May be called again after more engines are admitted: the clock is
        monotonic across calls, and engines with ``start_at`` in the
        future begin exactly then."""
        self._running = True
        try:
            q = self._q
            pop = q.pop
            for eng in list(self._engines):
                self._launch(eng)
            dispatched = 0
            heappop = heapq.heappop
            try:
                while q._size:
                    # inline CalendarQueue.pop fast path: the head of the
                    # current bucket is due within its year — the full
                    # pop() handles scans, shrink hysteresis and jumps
                    b = q._buckets[q._cur_abs % q._nb]
                    if b and b[0][0] < (q._cur_abs + 1) * q._width:
                        q._size -= 1
                        t, _, fn, arg = heappop(b)
                    else:
                        t, _, fn, arg = pop()
                    if t > self.now:
                        self.now = t
                    dispatched += 1
                    if arg is None:
                        fn()
                    else:
                        fn(arg)
            finally:
                self.dispatched += dispatched
        finally:
            self._running = False
        for eng in self._engines:
            eng._check_complete()

    # -- sync-window (keep-alive) interval accounting ------------------------
    def _sync_on(self, eng):
        """``eng`` now has at least one sync transfer outstanding (its
        count just went 0 -> 1): open its interval, and the store-group
        and domain union intervals if they were closed."""
        now = self.now
        eng._sync_t0 = now
        sid = eng._sid
        n = self._store_n.get(sid, 0)
        if n == 0:
            self._store_t0[sid] = now
        self._store_n[sid] = n + 1
        if self._sync_n == 0:
            self._sync_t0 = now
        self._sync_n += 1

    def _sync_off(self, eng):
        """``eng``'s sync-outstanding count just went 1 -> 0: close its
        interval (and the store/domain unions when it was the last
        engine holding them open)."""
        now = self.now
        eng._sync_busy += now - eng._sync_t0
        sid = eng._sid
        n = self._store_n[sid] - 1
        self._store_n[sid] = n
        if n == 0:
            self._store_sync[sid] = (self._store_sync.get(sid, 0.0)
                                     + (now - self._store_t0[sid]))
        self._sync_n -= 1
        if self._sync_n == 0:
            self.sync_union_s += now - self._sync_t0

    # -- link completion prediction (class-based, lazy) ----------------------
    def _relink(self, link: SharedLink):
        """Flow set changed: refresh the drain predictions. In class mode
        only each class's *earliest* drain target is (re-)keyed in the
        calendar queue, and only when it moved **earlier** than the
        pending prediction — predictions that moved later are left to
        fire early, find nothing drained, and re-arm (lazy deletion).
        Untracked links keep the legacy one-prediction-per-mutation
        scheme."""
        flows = link.flows
        if not flows:
            return
        if link._ntracked == len(flows):
            now = self.now
            for c in link.classes.values():
                if not c.n:
                    continue
                heap = c.heap
                target = c.target
                while True:
                    tgt, fid = heap[0]
                    if target.get(fid) == tgt:
                        break
                    heapq.heappop(heap)          # lazy-deleted entries
                d = tgt - c.served
                if d < 0.0:
                    d = 0.0
                t = now + d / c.rate
                if t < c.pred_t:
                    c.pred_t = t
                    c.pred_id += 1
                    self._q.push((t, next(self._seq),
                                  self._class_event, (link, c, c.pred_id)))
        else:
            link.generation += 1
            t_next = self.now + link.next_completion_dt()
            self.at2(t_next, self._legacy_link_event,
                     (link, link.generation))

    def _class_event(self, payload):
        """One class's predicted earliest drain time arrived."""
        link, c, pid = payload
        if pid != c.pred_id:
            return                               # stale prediction
        c.pred_t = _INF
        flows = link.flows
        if link._ntracked != len(flows) or not c.n:
            return                               # fell off the class path
        now = self.now
        if link.last_t != now:
            if link._active == 1:
                # c is the only active class: advance its served integral
                # inline (identical arithmetic to progress()). The
                # multi-class path stays a real progress() call — tests
                # observe link advances by wrapping it
                c.served += c.rate * (now - link.last_t)
                link.last_t = now
            else:
                link.progress(now)
        served = c.served
        heap, target = c.heap, c.target
        done = None
        while heap:
            tgt, fid = heap[0]
            if target.get(fid) != tgt:
                heapq.heappop(heap)
                continue
            if tgt - served > _EPS_GB:
                break
            # inlined remove_flow for the tracked drain path: same
            # arithmetic, but the live heap entry pops here instead of
            # lingering for lazy deletion, and rates refresh once after
            # the whole batch (nothing observes the intermediate sets)
            heapq.heappop(heap)
            del target[fid]
            tr = flows.pop(fid)
            d = tgt - served
            tr.remaining_gb = d if d > 0.0 else 0.0
            link.generation += 1
            w = tr.weight
            link._total_w -= w
            link._ntracked -= 1
            c.n -= 1
            c.w -= w
            if done is None:
                done = [tr]
            else:
                done.append(tr)
        if done is None:
            # the prediction was made at higher rates (the lazy scheme
            # never re-keys a drain that moved later): re-arm at the
            # class's current earliest drain
            if heap:
                d = heap[0][0] - served
                if d < 0.0:
                    d = 0.0
                t = now + d / c.rate
                c.pred_t = t
                c.pred_id += 1
                self._q.push((t, next(self._seq),
                              self._class_event, (link, c, c.pred_id)))
            return
        if c.n == 0:
            link._active -= 1
            heap.clear()
            c.pred_id += 1
        if link._active == 1 and c.n:
            # single-class fast path: the refresh is the processor-sharing
            # formula and the only class _relink could re-key is this one
            # — both inline (identical arithmetic to the generic path)
            c.rate = rate = min(c.cap, link.aggregate_gbps / link._total_w)
            win = link.cascade
            if (win is not None and c.n > 1 and link.setup == 0
                    and win.pending == 0):
                for tr in done:
                    tr.cb()
                self._cascade(link, c, win)
                return
            while True:
                tgt, fid = heap[0]
                if target.get(fid) == tgt:
                    break
                heapq.heappop(heap)
            d = tgt - served
            if d < 0.0:
                d = 0.0
            t = now + d / rate
            c.pred_t = t
            c.pred_id += 1
            self._q.push((t, next(self._seq),
                          self._class_event, (link, c, c.pred_id)))
            for tr in done:
                tr.cb()
            return
        if link._active:
            link._refresh_rates()
        self._relink(link)
        for tr in done:
            tr.cb()

    def _cascade(self, link: SharedLink, c, win):
        """Inline post-join drain cascade for a fan-out window that owns
        every flow on ``link`` (single window phase, window spanning the
        whole fleet, one engine in the domain — armed via
        ``link.cascade``).

        Once every member has joined, no flow-set change can precede the
        next drain: the remaining schedule is a closed cascade whose
        intermediate completions are pure counter updates (an arriving
        member is bookkeeping; the engine sync count stays positive
        while the last flow is in flight). Replaying the exact per-event
        arithmetic here — progress to the predicted drain time, drain
        every head within eps, refresh the single-class rate — commits
        those drains without dispatching an event each; only the final
        flow (sync-interval close + barrier merge) and anything past the
        invocation's cap deadline go back through the queue."""
        eng = win.eng
        agg = link.aggregate_gbps
        cap = c.cap
        cap_t = win.w.cap_t          # never cascade past a preemption
        heap, target, flows = c.heap, c.target, link.flows
        served = c.served
        rate = c.rate
        t = link.last_t              # == self.now: caller just progressed
        stage = win.stage
        trs = win.trs
        drained = 0
        while c.n > 1:
            while True:              # clean lazy-deleted heads
                tgt, fid = heap[0]
                if target.get(fid) == tgt:
                    break
                heapq.heappop(heap)
            d = tgt - served
            if d < 0.0:
                d = 0.0
            t2 = t + d / rate        # the prediction an event would carry
            if t2 >= cap_t:
                break                # the cap fires first: let it pause
            dt = t2 - t              # mirror SharedLink.progress exactly
            if dt > 0.0:
                served += rate * dt
            t = t2
            nb = 0
            while heap:              # the event's within-eps drain batch
                tgt, fid = heap[0]
                if target.get(fid) != tgt:
                    heapq.heappop(heap)
                    continue
                if tgt - served > _EPS_GB:
                    break
                heapq.heappop(heap)
                del target[fid]
                tr = flows.pop(fid)
                d = tgt - served
                tr.remaining_gb = d if d > 0.0 else 0.0
                link.generation += 1
                link._total_w -= tr.weight
                link._ntracked -= 1
                c.n -= 1
                c.w -= tr.weight
                if tr.is_sync:
                    eng._sync_active -= 1    # stays > 0: last flow lives
                i = tr.cb.args[0]            # cb is partial(_xfer_done, i)
                stage[i] = _FAN_ARRIVED
                trs[i] = None
                nb += 1
                if c.n == 1:
                    break
            if nb == 0:
                break                # fp guard: fall back to a real event
            drained += nb
            rate = c.rate = min(cap, agg / link._total_w)
        c.served = served
        link.last_t = t
        win.arrived += drained
        eng._levents += drained
        # the remainder — the final flow, or everything past the cap —
        # re-enters the normal prediction machinery
        while True:
            tgt, fid = heap[0]
            if target.get(fid) == tgt:
                break
            heapq.heappop(heap)
        d = tgt - served
        if d < 0.0:
            d = 0.0
        tf = t + d / rate
        c.pred_t = tf
        c.pred_id += 1
        self.at2(tf, self._class_event, (link, c, c.pred_id))

    def _legacy_link_event(self, payload):
        """Materialized-fallback drain event (untracked flow sets)."""
        link, gen = payload
        if gen != link.generation:
            return                               # stale prediction
        link.progress(self.now)
        done = link.take_drained(_EPS_GB)
        self._relink(link)
        for tr in done:
            tr.cb()

    def _setup_done(self, payload):
        """A transfer's setup-latency window elapsed: it becomes a flow
        on its link (shared by training engines and serving jobs)."""
        tr, token = payload
        if token != tr.token:
            return                               # paused during setup
        link = tr.link
        link.setup -= 1
        tr.latency_left = 0.0
        if tr.remaining_gb <= _EPS_GB:
            self._relink(link)                   # busy-window bookkeeping
            tr.cb()                              # cb releases the activity slot
            return
        c = link.add_flow(tr, self.now)
        if c is None:
            self._relink(link)
            return
        # a join only lowers rates (water-filling allocations are monotone
        # non-increasing in additions), so every other class's earliest
        # drain moved later — the lazy scheme leaves those to fire early.
        # Only the joined class can need an earlier prediction: re-key it
        # directly (same arithmetic as _relink restricted to c)
        heap, target = c.heap, c.target
        while True:
            tgt, fid = heap[0]
            if target.get(fid) == tgt:
                break
            heapq.heappop(heap)
        d = tgt - c.served
        if d < 0.0:
            d = 0.0
        t = self.now + d / c.rate
        if t < c.pred_t:
            c.pred_t = t
            c.pred_id += 1
            self._q.push((t, next(self._seq),
                          self._class_event, (link, c, c.pred_id)))

    def store_keep_alive_share(self, engine: "EventEngine") -> float:
        """One engine's billing share of its param store's keep-alive
        window: the per-store *union* (the container is alive once, not
        once per job) split across the sharing jobs in proportion to
        their own sync windows — so the per-store billed total always
        equals the union, never double-billing overlap.

        Shares are allocated in result-taking order: each engine takes
        its sync-proportional slice of the union seconds not yet
        allocated to an earlier result. For engines whose results are all
        taken after one ``run()`` this reproduces the plain proportional
        split exactly; for a workflow, where engines join and settle at
        different times, it keeps the running total honest."""
        sid = id(engine.param_store)
        now = self.now
        if self._store_n.get(sid, 0) > 0:
            # the store's keep-alive interval is still open (another job
            # mid-sync): settle it to ``now`` so the pool is current
            self._store_sync[sid] = (self._store_sync.get(sid, 0.0)
                                     + (now - self._store_t0[sid]))
            self._store_t0[sid] = now
        unbilled = [e for e in self._groups.get(sid, [engine])
                    if e._result is None]
        for e in unbilled:
            if e._sync_active > 0:               # settle open engine windows
                e._sync_busy += now - e._sync_t0
                e._sync_t0 = now
        total = sum(e._sync_busy for e in unbilled)
        if total <= 0.0:
            return 0.0
        pool = (self._store_sync.get(sid, 0.0)
                - self._store_billed.get(sid, 0.0))
        share = max(pool, 0.0) * (engine._sync_busy / total)
        self._store_billed[sid] = self._store_billed.get(sid, 0.0) + share
        return share


@dataclasses.dataclass
class EngineResult:
    """What one event-engine epoch (or partial epoch) produced."""
    wall_s: float
    lambda_usd: float
    store_usd: float
    iters_done: int              # globally completed iterations (min worker)
    samples_done: int
    sync_s: float                # this job's own sync-outstanding window
    store_billed_s: float        # keep-alive seconds this job was billed:
                                 # its share of the store's cross-job union
                                 # (== sync_s when the store isn't shared)
    restarts: int                # duration-cap restarts, fleet-wide
    failures: int                # mid-flight failures, fleet-wide (all kinds)
    invocations: int             # Lambda requests billed
    iter_times: List[float]      # completion timestamp per global iteration
    stopped_early: bool
    trace: List[str]
    shock_events: int = 0        # shocks that killed at least one worker
    sim_events: int = 0          # logical per-worker state transitions
                                 # (cohort-weighted: comparable whether or
                                 # not workers were coalesced)
    backend_usd: float = 0.0     # per-second VM/GPU compute dollars
    preemptions: int = 0         # spot price-crossing kills, fleet-wide

    @property
    def cost_usd(self) -> float:
        return self.lambda_usd + self.store_usd + self.backend_usd


class _FleetDraws:
    """Vectorized per-(worker, attempt) stochastic draws.

    Straggler z-scores, failure coins, and failure fractions each come
    from an independent named stream (``repro.core.rng``) and are drawn
    as whole ``(n, block)`` matrices — one numpy call per epoch instead
    of one scalar call per worker-iteration. Column ``k`` is a worker's
    k-th compute *attempt* (a retry after a failure consumes the next
    column), so same-seed runs consume identical values in identical
    order and stay bit-reproducible. Blocks extend lazily when retries
    run past the pre-drawn epoch."""

    __slots__ = ("n", "sigma", "failure_rate", "_block", "_z_rng", "_u_rng",
                 "_f_rng", "_factor", "_fail_u", "_frac", "_cols")

    def __init__(self, n: int, sigma: float, failure_rate: float, seed: int,
                 job_idx: int, iters: int):
        self.n = n
        self.sigma = sigma
        self.failure_rate = failure_rate
        self._block = min(iters + 2, 1024)
        self._z_rng = rng_streams.stream(seed, "straggler", job_idx)
        self._u_rng = rng_streams.stream(seed, "failure", job_idx)
        self._f_rng = rng_streams.stream(seed, "failfrac", job_idx)
        self._factor: Optional[np.ndarray] = None
        self._fail_u: Optional[np.ndarray] = None
        self._frac: Optional[np.ndarray] = None
        self._cols = 0

    def _grow(self, k: int):
        add = self._block
        while k >= self._cols + add:
            add += self._block
        if self.sigma > 0.0:
            z = self._z_rng.standard_normal((self.n, add))
            blk = np.exp(self.sigma * z - 0.5 * self.sigma * self.sigma)
            self._factor = (blk if self._factor is None else
                            np.concatenate([self._factor, blk], axis=1))
        if self.failure_rate > 0.0:
            u = self._u_rng.random_sample((self.n, add))
            f = self._f_rng.random_sample((self.n, add))
            self._fail_u = (u if self._fail_u is None else
                            np.concatenate([self._fail_u, u], axis=1))
            self._frac = (f if self._frac is None else
                          np.concatenate([self._frac, f], axis=1))
        self._cols += add

    def factor(self, wid: int, k: int) -> float:
        """Lognormal straggler multiplier for worker ``wid``, attempt
        ``k`` (1.0 exactly in the zero-variance limit)."""
        if self.sigma <= 0.0:
            return 1.0
        if k >= self._cols:
            self._grow(k)
        return float(self._factor[wid, k])

    def factor_row(self, members: range, k: int) -> np.ndarray:
        """One cohort's straggler multipliers for attempt ``k`` — the
        same cells ``factor(wid, k)`` returns, read as one slice."""
        if self.sigma <= 0.0:
            return np.ones(len(members))
        if k >= self._cols:
            self._grow(k)
        return self._factor[members.start:members.stop, k]

    def failed(self, wid: int, k: int) -> Tuple[bool, float]:
        """(did attempt ``k`` fail mid-iteration, fraction completed)."""
        if self.failure_rate <= 0.0:
            return False, 0.0
        if k >= self._cols:
            self._grow(k)
        return (bool(self._fail_u[wid, k] < self.failure_rate),
                float(self._frac[wid, k]))


class _WorkerState:
    """One engine state machine: a single worker, or a coalesced cohort
    of ``count`` identical workers moving in lockstep (``members`` is the
    contiguous worker-id range; ``wid`` is the leader). All billing
    records, checkpoints, and trace lines are still per member."""

    __slots__ = ("wid", "members", "count", "it", "draws", "inv_recs",
                 "inv_count", "inv_gen", "inv_cont", "cap_gen", "cap_t",
                 "seg_gen", "seg_end", "activity", "pending", "restarting",
                 "finished", "fan", "bill_t0")

    def __init__(self, members: range):
        self.wid = members.start
        self.members = members
        self.count = len(members)
        self.it = 0                   # completed iterations
        self.draws = 0                # compute attempts consumed (draw cursor)
        self.inv_recs: List[InvocationRecord] = []
        self.inv_count = 0
        self.inv_gen = 0              # invalidates stale init-window events
        self.inv_cont = None          # continuation owed by the init window
        self.cap_gen = 0              # invalidates scheduled cap events
        self.cap_t = math.inf         # current invocation's cap deadline
        self.seg_gen = 0              # invalidates scheduled compute ends
        self.seg_end = 0.0
        self.activity: Optional[Tuple] = None   # ("compute"|"transfer"|...)
        self.pending = None           # continuation to run after a restart
        self.restarting = False
        self.finished = False
        self.fan = None               # lazily-built _FanoutWindow (σ>0 cohorts)
        self.bill_t0 = math.inf       # per-second billing anchor (VM backends)


class _PipelineRun:
    """One worker's pipelined iteration window: a compute lane and a
    transfer lane running concurrently (the worker's second activity
    slot).

    The compute lane runs ``depth`` micro-batch segments back-to-back
    (gradient accumulation never waits for the network). The transfer
    lane uploads segment *i*'s share of each overlappable phase —
    ``nbytes / depth`` with the phase's full ``requests`` round-trips —
    as soon as segment *i* has landed **and** segment *i-1*'s share has
    drained (one connection per worker). The window completes when both
    lanes do; the engine then runs the overlappable phases' deferred
    barriers and the sequential remainder of the plan.

    A duration-cap preemption pauses both lanes and resumes them with
    their progress (compute remainder + transfer bytes kept); a shock
    loses both and redoes the iteration from its boundary."""

    __slots__ = ("eng", "w", "d", "seg_s", "phases", "computed", "ul_seg",
                 "ul_phase", "tr", "comp_end", "comp_left", "gen",
                 "computing")

    def __init__(self, eng: "EventEngine", w: "_WorkerState",
                 total_compute_s: float):
        self.eng = eng
        self.w = w
        self.d = eng.plan.pipeline_depth
        self.seg_s = total_compute_s / self.d
        self.phases = [ph for ph in eng._ov_phases if w.wid < ph.fan_in]
        self.computed = 0            # compute segments landed
        self.ul_seg = 0              # segments fully uploaded
        self.ul_phase = 0            # phase index inside the current segment
        self.tr = None               # in-flight transfer (transfer lane)
        self.comp_end = 0.0
        self.comp_left = None        # compute remainder while paused
        self.gen = 0                 # invalidates scheduled compute ends
        self.computing = False

    # -- compute lane --------------------------------------------------------
    def start(self):
        self.w.activity = ("pipeline", self)
        self._start_compute(self.seg_s)

    def _start_compute(self, dur: float):
        self.computing = True
        self.gen += 1
        self.comp_end = self.eng.now + dur
        self.eng.domain.at2(self.comp_end, self.eng._pipe_seg_done,
                            (self, self.gen))

    def _seg_done(self, gen: int):
        if gen != self.gen or not self.computing:
            return
        self.computing = False
        self.computed += 1
        self.eng._levents += self.w.count
        if self.computed < self.d:
            self._start_compute(self.seg_s)
        self._pump_ul()
        self._maybe_finish()

    # -- transfer lane -------------------------------------------------------
    def _pump_ul(self):
        if self.tr is not None or self.ul_seg >= min(self.computed, self.d):
            return
        if not self.phases:          # not a participant in any upload
            self.ul_seg = self.computed
            return

        ph = self.phases[self.ul_phase]

        def done():
            self.tr = None
            self.ul_phase += 1
            if self.ul_phase >= len(self.phases):
                self.ul_phase = 0
                self.ul_seg += 1
            self._pump_ul()
            self._maybe_finish()

        self.tr = self.eng._make_transfer(
            self.w, ph.store, ph.nbytes / self.d, ph.requests, done,
            is_sync=(ph.store == "param"))
        self.eng._begin_setup(self.w, self.tr)

    def _maybe_finish(self):
        if (self.computed >= self.d and self.ul_seg >= self.d
                and self.tr is None):
            w = self.w
            if w.activity is not None and w.activity[0] == "pipeline":
                w.activity = None
            self.eng._pipeline_done(w)

    # -- preemption ----------------------------------------------------------
    def pause(self):
        """Duration-cap preemption: both lanes keep their progress."""
        self.gen += 1
        if self.computing:
            self.comp_left = max(self.comp_end - self.eng.now, 0.0)
            self.computing = False
        else:
            self.comp_left = None
        if self.tr is not None:
            self.eng._detach_transfer(self.tr)

    def resume(self):
        self.w.activity = ("pipeline", self)
        if self.tr is not None:
            self.eng._reattach_transfer(self.w, self.tr)
        if self.comp_left is not None:
            self._start_compute(self.comp_left)
            self.comp_left = None

    def abort(self):
        """Shock kill: in-flight work on both lanes is lost (the caller
        redoes the whole iteration from its boundary)."""
        self.gen += 1
        self.computing = False
        if self.tr is not None:
            self.eng._detach_transfer(self.tr)
            self.tr = None


_FAN_COMPUTING = -1    # _FanoutWindow member stage: compute in flight
_FAN_ARRIVED = -2      # _FanoutWindow member stage: waiting at the merge


class _FanoutWindow:
    """One σ>0 cohort's per-iteration straggler fan-out.

    Under bsp, a cohort's members diverge exactly once per iteration —
    at the stochastic compute draw — and provably re-merge at the plan's
    first ``barrier_after`` phase: past that barrier every member has
    identical state again (deterministic equal transfers preserve
    lockstep, the same argument that makes σ=0 coalescing exact). So the
    cohort machinery runs everything outside the window (invocations,
    data fetch, post-barrier phases, billing), and this window runs the
    divergent stretch per member: one vectorized row of compute draws
    bulk-pushed as per-member finish events, then each member walks its
    participating leading phases as ordinary per-member link flows and
    counts itself arrived; the last arrival joins the cohort barrier
    with the full member weight.

    Every per-member step reuses the exact per-worker primitives
    (``_begin_setup`` / ``_detach_transfer`` / ``_reattach_transfer``,
    the domain's lazy drain predictions, the engine sync-window counter)
    so event times, rates, sync intervals, and logical-event counts are
    identical to the per-worker simulation — only the dispatch
    bookkeeping is batched. A duration-cap preemption pauses the window
    member-by-member (compute remainders kept, flows detached with
    progress) and resumes it after the cohort re-invoke."""

    __slots__ = ("eng", "w", "m", "phases", "bar_name", "cont", "stage",
                 "t_end", "trs", "cbs", "rem", "gen", "arrived", "pending",
                 "cascade_ok", "base_arr")

    def __init__(self, eng: "EventEngine", w: "_WorkerState"):
        self.eng = eng
        self.w = w
        m = self.m = w.count
        phases = eng.plan.phases
        bar = next(i for i, ph in enumerate(phases) if ph.barrier_after)
        self.bar_name = phases[bar].name
        # members share the leader's participation: cohorts cut at every
        # fan_in boundary, so w.wid decides for the whole range
        self.phases = [ph for ph in phases[:bar + 1] if w.wid < ph.fan_in]
        self.cont = lambda: eng._comm_phase(w, bar + 1)
        self.stage = [_FAN_COMPUTING] * m
        self.t_end = [0.0] * m
        self.trs: List[Optional[_Transfer]] = [None] * m
        self.cbs = [functools.partial(self._xfer_done, i) for i in range(m)]
        self.rem: Optional[List[float]] = None
        self.gen = 0
        self.arrived = 0
        self.pending = 0              # members whose compute has not finished
        self.base_arr = np.asarray(eng.base_compute_s[w.wid:w.wid + m])
        # drain-cascade eligibility (see ContentionDomain._cascade): a
        # single window phase and a window spanning the whole fleet mean
        # every flow on that link belongs to this window
        self.cascade_ok = len(self.phases) == 1 and m == eng.n

    def start(self):
        eng = self.eng
        w = self.w
        w.activity = ("fanout", self)
        k = w.draws
        w.draws = k + 1
        factors = eng._draws.factor_row(w.members, k)
        slow = (eng.slowdown_factor
                if (eng.slowdown_at_iter is not None
                    and w.it >= eng.slowdown_at_iter) else None)
        if slow is not None:
            factors = factors * slow
        now = eng.now
        m = self.m
        # the whole compute-end row in one vector op — elementwise IEEE
        # float64, bit-equal to the per-member Python arithmetic
        te_row = (now + self.base_arr * factors).tolist()
        self.gen += 1
        gen = self.gen
        self.arrived = 0
        self.pending = m
        self.stage = [_FAN_COMPUTING] * m
        self.t_end = te_row
        trs = self.trs
        fn = eng._fan_compute_done
        # members' first transfers are known up front: create them and
        # pre-push their setup-elapsed events (at compute end + latency)
        # alongside the compute ends — one bulk insert for the whole
        # window, and the compute handler shrinks to counter updates.
        # Per-worker equivalence: the setup event still fires at exactly
        # compute_end + latency with the same (tr, token) payload, and a
        # preemption stales it through the usual token bump.
        dom = eng.domain
        seq = dom._seq
        ph = self.phases[0] if self.phases else None
        if ph is not None:
            link = eng.links[ph.store]
            if self.cascade_ok and len(dom._engines) == 1:
                link.cascade = self      # sole flow source: cascade legal
            is_sync = ph.store == "param"
            nbytes = ph.nbytes
            lat = link.latency_s * max(ph.requests, 1)
            setup_done = dom._setup_done
            cbs = self.cbs
            net_cap = eng.net_cap
            wid0 = w.members.start
            trs[:] = [_Transfer(link, nbytes, lat, cbs[i], is_sync,
                                cap_gbps=net_cap[wid0 + i]
                                if is_sync else None,
                                prio=eng.link_priority)
                      for i in range(m)]
            # seq order: all compute ends, then all setup elapses. Only
            # equal-timestamp ties could notice (continuous draws: none);
            # each setup still fires at exactly compute_end + latency
            evs = [(te_row[i], next(seq), fn, (self, i, gen))
                   for i in range(m)]
            if lat > 0.0:
                evs += [(te_row[i] + lat, next(seq), setup_done,
                         (tr, tr.token)) for i, tr in enumerate(trs)]
        else:
            trs[:] = [None] * m
            evs = [(te_row[i], next(seq), fn, (self, i, gen))
                   for i in range(m)]
        dom._q.push_bulk(evs)

    def _advance(self, i: int, j: int):
        """Member ``i`` enters window phase ``j`` (or arrives)."""
        phases = self.phases
        if j >= len(phases):
            self.stage[i] = _FAN_ARRIVED
            self.trs[i] = None
            self.arrived += 1
            if self.arrived == self.m:
                self._merge()
            return
        eng = self.eng
        ph = phases[j]
        self.stage[i] = j
        link = eng.links[ph.store]
        is_sync = ph.store == "param"
        cap = (eng.net_cap[self.w.members.start + i]
               if ph.store == "param" else None)
        tr = _Transfer(link, ph.nbytes, link.latency_s * max(ph.requests, 1),
                       self.cbs[i], is_sync, cap_gbps=cap,
                       prio=eng.link_priority)
        self.trs[i] = tr
        if is_sync:
            eng._sync_on()
        eng._begin_setup(self.w, tr)

    def _xfer_done(self, i: int):
        eng = self.eng
        j = self.stage[i] + 1
        if self.trs[i].is_sync:
            # _sync_off inlined: only 1 -> 0 closes the interval
            eng._sync_active -= 1
            if eng._sync_active == 0:
                eng.domain._sync_off(eng)
        eng._levents += 1
        if j >= len(self.phases):        # inlined arrival (the hot case)
            self.stage[i] = _FAN_ARRIVED
            self.trs[i] = None
            self.arrived += 1
            if self.arrived == self.m:
                self._merge()
            return
        self._advance(i, j)

    def _merge(self):
        w = self.w
        w.activity = None
        self.eng._barrier((self.bar_name, w.it), w, self.cont)

    # -- preemption ----------------------------------------------------------
    def pause(self):
        """Duration-cap preemption: every member keeps its progress —
        compute remainders are measured now, in-flight transfers detach
        with their drained bytes (arrived members have nothing open)."""
        eng = self.eng
        now = eng.now
        self.gen += 1                   # stale the scheduled compute ends
        rem = self.rem = [0.0] * self.m
        for i in range(self.m):
            st = self.stage[i]
            if st == _FAN_COMPUTING:
                rem[i] = max(self.t_end[i] - now, 0.0)
                tr = self.trs[i]
                if tr is not None:
                    tr.token += 1       # stale the pre-pushed setup event
            elif st >= 0:
                eng._detach_transfer(self.trs[i])

    def resume(self):
        eng = self.eng
        w = self.w
        w.activity = ("fanout", self)
        self.gen += 1
        gen = self.gen
        now = eng.now
        rem = self.rem
        self.rem = None
        fn = eng._fan_compute_done
        dom = eng.domain
        seq = dom._seq
        setup_done = dom._setup_done
        evs = []
        for i in range(self.m):
            st = self.stage[i]
            if st == _FAN_COMPUTING:
                te = now + rem[i]
                self.t_end[i] = te
                evs.append((te, next(seq), fn, (self, i, gen)))
                tr = self.trs[i]
                if tr is not None and tr.latency_left > 0.0:
                    evs.append((te + tr.latency_left, next(seq), setup_done,
                                (tr, tr.token)))
            elif st >= 0:
                eng._reattach_transfer(w, self.trs[i])
        if evs:
            dom._q.push_bulk(evs)


class EventEngine:
    """Run one epoch of ``workload`` under deployment ``(n, memory_mb)``
    — or a heterogeneous ``fleet`` — as a discrete-event simulation. See
    the module docstring for the semantics; construction mirrors
    ``epoch_estimate``'s signature so the two paths are interchangeable.

    ``record_trace=False`` skips trace accumulation (perf runs);
    ``trace_enabled`` is the accepted legacy alias. ``coalesce`` controls
    lockstep-cohort batching: ``None`` auto-enables it exactly when it is
    provably exact (bsp, zero failures, no shocks, unpipelined plan;
    cohorts cut at every fleet/plan non-uniformity, and σ>0 additionally
    requires the ``_FanoutWindow`` regime — traces off, a bsp re-merge
    barrier, no cpu_s inside the window), ``True`` demands it
    (ValueError if the configuration diverges), ``False`` forces
    per-worker simulation."""

    def __init__(self, workload: Workload, scheme: CommLike, n_workers: int,
                 memory_mb: float, global_batch: int,
                 param_store: ParamStore, object_store: ObjectStore, *,
                 fleet: Optional[FleetSpec] = None,
                 backend: BackendLike = None,
                 link_priority: float = 1.0,
                 shocks: Optional[ShockModel] = None,
                 domain: Optional[ContentionDomain] = None,
                 platform: Optional[ServerlessPlatform] = None,
                 sync_mode: str = "bsp", staleness: int = 0,
                 straggler_sigma: float = 0.0, failure_rate: float = 0.0,
                 framework_init_s: float = 4.0, cold_start_s: float = 2.0,
                 max_duration_s: float = LAMBDA_MAX_DURATION_S,
                 samples: Optional[int] = None, seed: int = 0,
                 slowdown_at_iter: Optional[int] = None,
                 slowdown_factor: float = 1.0,
                 on_iteration: Optional[Callable] = None,
                 record_trace: Optional[bool] = None,
                 trace_enabled: Optional[bool] = None,
                 coalesce: Optional[bool] = None,
                 start_at: float = 0.0,
                 on_complete: Optional[Callable] = None):
        self.w = workload
        self.scheme = scheme
        if fleet is None:
            fleet = FleetSpec.homogeneous(n_workers, memory_mb)
        self.fleet = fleet
        self.n = len(fleet)
        self.mem: Tuple[float, ...] = fleet.memories
        self.global_batch = global_batch
        self.param_store = param_store
        self.object_store = object_store
        self.platform = platform or ServerlessPlatform(
            max_duration_s=max_duration_s, seed=seed)
        self.mode, self.staleness = parse_sync_mode(sync_mode, staleness)
        self.sigma = straggler_sigma
        if not 0.0 <= failure_rate < 1.0:
            # at 1.0 every iteration attempt fails and the simulated epoch
            # (like the real one) would never complete
            raise ValueError("failure_rate must be in [0, 1), "
                             f"got {failure_rate}")
        self.failure_rate = failure_rate
        self.shocks = shocks
        # budget-weight -> network-weight coupling: every transfer this
        # job opens claims the shared links at this priority (matches
        # ServingJob.link_priority; allocator task priorities land here)
        self.link_priority = link_priority
        self.backend = resolve_backend(backend)
        self.restore_s = CHECKPOINT_RESTORE_S
        self.max_duration_s = max_duration_s
        if self.backend is None:
            self.init_s = cold_start_s + framework_init_s
            self.usable_s = max_duration_s - self.init_s - self.restore_s
            if self.usable_s <= 0:
                raise ValueError("max_duration_s leaves no usable window")
        else:
            # VM-kind backend: provisioning replaces the cold start and
            # the duration cap disappears (no cap timer is ever armed)
            self.init_s = self.backend.provision_s + framework_init_s
            self.usable_s = math.inf
            if self.backend.spot and self.shocks is None:
                # spot preemptions ride the shock machinery: one
                # correlated kill-all shock per up-crossing of the bid
                # (an explicit ``shocks=`` wins over the synthesis)
                self.shocks = ShockModel(
                    interval_s=math.inf, kill_frac=1.0,
                    price_trace=self.backend.price_trace,
                    bid_usd_per_hr=self.backend.bid_usd_per_hr)
        self.samples = samples or workload.dataset_samples
        self.iters = max(math.ceil(self.samples / global_batch), 1)
        self.seed = seed
        self.slowdown_at_iter = slowdown_at_iter
        self.slowdown_factor = slowdown_factor
        self.on_iteration = on_iteration
        if record_trace is None:
            record_trace = True if trace_enabled is None else trace_enabled
        self.record_trace = self.trace_enabled = record_trace
        # admission offset on a shared domain clock: a workflow task whose
        # dependencies finish at t > 0 starts exactly then. wall_s stays
        # relative to the engine's own start (``_t0``); iter_times remain
        # absolute domain timestamps.
        self.start_at = max(start_at, 0.0)
        # called (with the engine) the instant every worker has finished —
        # the orchestrator's hook to resume the owning task mid-drain
        self.on_complete = on_complete
        self._t0 = 0.0

        if self.backend is not None:
            # flat per-worker compute rate and NIC: the fleet is
            # effectively homogeneous regardless of memory tiers (the
            # analytic iteration_time's exact VM regime)
            local_batch = max(global_batch // self.n, 1)
            self.base_compute_s = [
                compute_time(workload, local_batch, m,
                             gflops=self.backend.gflops_for(m))
                for m in self.mem]
        elif fleet.is_homogeneous:
            local_batch = max(global_batch // self.n, 1)
            self.base_compute_s = [compute_time(workload, local_batch, m)
                                   for m in self.mem]
        else:
            # load-aware shard placement: the global batch splits in
            # proportion to worker speed, so per-iteration compute is the
            # same on every worker (the analytic fleet estimate's exact
            # regime) — mixed fleets stop paying the barrier at the slow
            # tier's compute
            self.base_compute_s = [
                compute_time(workload, lb, m)
                for lb, m in zip(fleet_local_batches(fleet, global_batch),
                                 self.mem)]
        self.plan: CommPlan = build_plan(
            scheme, workload.grad_bytes, self.n,
            extra_upload_bytes=workload.extra_upload_bytes)
        # pipelined overlap: the overlappable phases must be a leading
        # prefix (CommPlan.pipeline guarantees it) — they execute inside
        # the compute window, the rest from index _ov_count onward
        flags = [ph.overlappable for ph in self.plan.phases]
        self._ov_count = 0
        while self._ov_count < len(flags) and flags[self._ov_count]:
            self._ov_count += 1
        if any(flags[self._ov_count:]):
            raise ValueError("overlappable phases must form a leading "
                             "prefix of the plan")
        if self.plan.pipeline_depth <= 1:
            self._ov_count = 0
        self._ov_phases = self.plan.phases[:self._ov_count]
        # per-worker function-network caps, carried as per-flow caps on the
        # (possibly cross-job shared) links; *8 as in the analytic model
        if self.backend is not None:
            self.net_cap = [self.backend.net_gbps_for(m) * 8
                            for m in self.mem]
        else:
            self.net_cap = [fn_net_gbps(m) * 8 for m in self.mem]
        self.domain = domain or ContentionDomain()
        self._job_idx = self.domain._register(self)
        self.links: Dict[str, SharedLink] = {
            "param": self.domain.link_for(param_store, "param"),
            "object": self.domain.link_for(object_store, "object"),
        }
        self.ckpt_bytes = 12.0 * workload.param_count  # params + Adam m,v

        eligible = self._coalesce_eligible()
        if coalesce is None:
            coalesce = eligible
        elif coalesce and not eligible:
            raise ValueError(
                "coalesce=True requires the lockstep-cohort regime: bsp, "
                "failure_rate=0, no shocks, unpipelined plan; a "
                "heterogeneous fleet needs record_trace=False, and "
                "straggler_sigma>0 additionally needs a bsp barrier in "
                "the plan, no cpu_s before it, and a single cohort when "
                "on_iteration is set")
        self.coalesced = coalesce
        self._workers = [_WorkerState(g) for g in self._cohorts(coalesce)]
        self._draws = _FleetDraws(self.n, self.sigma, self.failure_rate,
                                  seed, self._job_idx, self.iters)
        self._shock_rng = rng_streams.shock_stream(seed, self._job_idx)
        self._barriers: Dict[Tuple, Dict] = {}
        self._gate_waiters: List[Tuple[_WorkerState, Callable]] = []
        self._started = False
        self._stopping = False
        self._g_done = 0
        self._iter_times: List[float] = []
        self._trace: List[str] = []
        self._gb_seconds = 0.0
        self._requests = 0
        self._cap_restarts = 0
        self._failures = 0
        self._shock_events = 0
        self._backend_usd = 0.0      # per-second VM/GPU compute dollars
        self._preemptions = 0        # spot price-crossing kills
        self._spot_fallback = False  # spot died once; now billing on-demand
        self._levents = 0            # logical (cohort-weighted) transitions
        # O(1) fleet aggregates (replacing per-event fleet scans):
        # worker count per completed-iteration value, the running minimum,
        # and the not-yet-finished worker count
        self._it_hist = [0] * (self.iters + 2)
        self._it_hist[0] = self.n
        self._min_it = 0
        self._unfinished = self.n
        # union of time any gradient-sync transfer is outstanding — the
        # param store's keep-alive window (matches the analytic sync_s).
        # Accounted as closed [on, off) intervals reported to the domain
        # on 0<->1 transitions of the outstanding count.
        self._sync_active = 0
        self._sync_busy = 0.0
        self._sync_t0 = 0.0
        self._sid = id(self.param_store)
        self._wall = 0.0
        self._result: Optional[EngineResult] = None

    def _coalesce_eligible(self) -> bool:
        """Cohort batching is exact only when locally-identical workers
        provably move in lockstep between bsp barriers: no failures, no
        shocks, no second activity lane, and cohorts cut wherever the
        fleet or the plan stops being uniform. σ=0 cohorts never diverge
        at all; σ>0 cohorts diverge only inside the per-iteration
        straggler window, which ``_FanoutWindow`` simulates per member
        (see its docstring for the exactness argument)."""
        if not (self.mode == "bsp" and self.failure_rate == 0.0
                and self.shocks is None and self.plan.pipeline_depth <= 1
                and (self.backend is None or not self.backend.spot)):
            return False
        if self.sigma == 0.0:
            # a heterogeneous fleet coalesces only in perf runs: traced
            # runs keep the per-worker link decomposition observable
            return self.fleet.is_homogeneous or not self.trace_enabled
        return self._fanout_eligible()

    def _fanout_eligible(self) -> bool:
        """The σ>0 fan-out window additionally needs: traces off (the
        window emits no per-member trace lines), a bsp re-merge barrier
        to exist, no post-transfer cpu segments inside the window, and —
        when an ``on_iteration`` hook can stop the epoch mid-flight — a
        single cohort (a stop raised while another cohort's window is
        open would need per-member discard semantics)."""
        if self.trace_enabled:
            return False
        phases = self.plan.phases
        bar = next((i for i, ph in enumerate(phases) if ph.barrier_after),
                   None)
        if bar is None:
            return False
        if any(ph.cpu_s > 0.0 for ph in phases[:bar + 1]):
            return False
        if self.on_iteration is not None and len(self._cohorts(True)) > 1:
            return False
        return True

    def _cohorts(self, coalesce: bool) -> List[range]:
        if not coalesce:
            return [range(i, i + 1) for i in range(self.n)]
        # split where plan participation diverges (workers on the same
        # side of every phase's fan_in follow identical paths) and where
        # the fleet stops being locally identical: one spec and one
        # per-iteration base compute time per cohort (load-aware shard
        # placement can split a tier's batch unevenly)
        cuts = {min(ph.fan_in, self.n) for ph in self.plan.phases} | {self.n}
        specs = self.fleet.workers
        base = self.base_compute_s
        cuts.update(i for i in range(1, self.n)
                    if specs[i] != specs[i - 1] or base[i] != base[i - 1])
        groups, prev = [], 0
        for c in sorted(cuts):
            if c > prev:
                groups.append(range(prev, c))
                prev = c
        return groups

    # -- primitives ----------------------------------------------------------
    @property
    def now(self) -> float:
        return self.domain.now

    def _at(self, t: float, fn: Callable):
        self.domain.at(t, fn)

    def _tr(self, w: _WorkerState, what: str):
        if self.trace_enabled:
            stamp = f"{self.now:.6f}"
            if w.count == 1:
                self._trace.append(f"{stamp} w{w.wid} {what}")
            else:
                self._trace.extend(f"{stamp} w{wid} {what}"
                                   for wid in w.members)

    def _ckpt_put(self, w: _WorkerState):
        """Checkpoint every member's blob, namespaced by the engine's job
        index so concurrent workflow tasks sharing one ObjectStore never
        clobber each other's restart state (a private domain is j0)."""
        it = w.it
        for wid in w.members:
            self.object_store.put(f"ckpt/j{self._job_idx}/w{wid}",
                                  {"iter": it}, nbytes=self.ckpt_bytes)

    def _ckpt_restore(self, w: _WorkerState):
        for wid in w.members:
            key = f"ckpt/j{self._job_idx}/w{wid}"
            if key in self.object_store.blobs:
                self.object_store.get(key, nbytes=self.ckpt_bytes)

    def _sync_on(self):
        self._sync_active += 1
        if self._sync_active == 1:
            self.domain._sync_on(self)

    def _sync_off(self):
        self._sync_active -= 1
        if self._sync_active == 0:
            self.domain._sync_off(self)

    def _make_transfer(self, w: _WorkerState, store: str, nbytes: float,
                       requests: int, done: Callable,
                       is_sync: bool, weight: int = 1) -> _Transfer:
        """Create a transfer whose completion callback ``done`` also
        settles the sync-window counter. Claiming an activity slot is the
        caller's job (the serial path uses the worker's single slot, the
        pipeline window its transfer lane). ``nbytes`` is per member;
        ``weight`` is the cohort's member count (its claim on the link)."""
        link = self.links[store]

        def finished():
            if is_sync:
                self._sync_off()
            done()

        cap = self.net_cap[w.wid] if store == "param" else None
        tr = _Transfer(link, nbytes, link.latency_s * max(requests, 1),
                       finished, is_sync, cap_gbps=cap, weight=weight,
                       prio=self.link_priority)
        if is_sync:
            self._sync_on()
        return tr

    def _start_transfer(self, w: _WorkerState, store: str, nbytes: float,
                        requests: int, cont: Callable, is_sync: bool = False):
        def finished():
            w.activity = None
            self._levents += w.count
            cont()

        tr = self._make_transfer(w, store, nbytes, requests, finished,
                                 is_sync, weight=w.count)
        w.activity = ("transfer", tr, tr.cb)
        self._begin_setup(w, tr)

    def _begin_setup(self, w: _WorkerState, tr: _Transfer):
        link = tr.link
        tr.token += 1
        if tr.latency_left > 0:
            link.setup += 1
            self.domain.at2(self.now + tr.latency_left,
                            self.domain._setup_done, (tr, tr.token))
        else:
            link.add_flow(tr, self.now)  # resume directly into the flow
            self.domain._relink(link)

    def _do_compute(self, w: _WorkerState, duration: float, cont: Callable,
                    redo: Optional[Callable] = None):
        """``redo`` is what a correlated shock (which *loses* in-flight
        work) restarts instead of the whole iteration — e.g. a decompress
        segment inside a comm phase redoes that phase, not the compute."""
        w.activity = ("compute", cont, redo)
        w.seg_end = self.now + duration
        w.seg_gen += 1
        self.domain.at2(w.seg_end, self._compute_done, (w, w.seg_gen))

    def _compute_done(self, payload):
        w, gen = payload
        act = w.activity
        if gen != w.seg_gen or act is None or act[0] != "compute":
            return
        w.activity = None
        self._levents += w.count
        act[1]()                                 # cont

    # -- invocation lifecycle ------------------------------------------------
    def _begin_invocation(self, w: _WorkerState, overhead: float,
                          cont: Callable, resumed: bool):
        t = self.now
        recs = []
        for wid in w.members:
            rec = InvocationRecord(worker_id=wid, start=t,
                                   cold_start_s=self.init_s, resumed=resumed)
            self.platform.invocations.append(rec)
            recs.append(rec)
        w.inv_recs = recs
        w.inv_count += 1
        w.inv_gen += 1
        w.inv_cont = cont
        self._tr(w, "invoke" if not resumed else "re-invoke")
        self.domain.at2(t + overhead, self._invoke_armed, (w, w.inv_gen))

    def _invoke_armed(self, payload):
        w, gen = payload
        if gen != w.inv_gen:
            return                               # killed during init window
        # the usable window opens once init/restore completes
        cont, w.inv_cont = w.inv_cont, None
        w.cap_gen += 1
        w.cap_t = self.now + self.usable_s
        if w.cap_t != _INF:          # uncapped backends never arm the timer
            self.domain.at2(w.cap_t, self._cap_fire, (w, w.cap_gen))
        if self.backend is not None:
            # per-second billing arms when provisioning+init completes;
            # a worker killed during the provisioning gap bills nothing
            w.bill_t0 = self.now
        self._levents += w.count
        cont()

    def _close_invocation(self, w: _WorkerState):
        now = self.now
        if self.backend is not None:
            # per-second billing from the arming anchor to now: spot runs
            # integrate the price trace (engine-relative time) until the
            # first preemption flips them to the on-demand rate; no
            # GB-second or per-request fee, and no cap-splitting — so the
            # records close directly instead of through platform.finish
            if w.bill_t0 != _INF:
                if self.backend.spot and not self._spot_fallback:
                    usd = self.backend.price_trace.integral_usd(
                        w.bill_t0 - self._t0, now - self._t0) * w.count
                else:
                    usd = (now - w.bill_t0) * self.backend.usd_per_s * w.count
                self._backend_usd += usd
                self.platform.ledger.charge(
                    f"backend:{self.backend.name}", usd)
                w.bill_t0 = _INF
            for rec in w.inv_recs:
                rec.end = now
            w.inv_recs = []
            w.inv_gen += 1
            w.cap_gen += 1
            return
        for rec in w.inv_recs:
            mem = self.mem[rec.worker_id]
            for r in self.platform.finish(rec, mem, now):
                self._gb_seconds += mem / 1024.0 * (r.end - r.start)
                self._requests += 1
        w.inv_recs = []
        w.inv_gen += 1                           # stale any init-window event
        w.cap_gen += 1                           # disarm the cap timer

    def _detach_transfer(self, tr: _Transfer):
        """Remove a transfer from its link (setup or flow phase) and fix
        the sync-window counter. The transfer keeps its progress: only
        *this* flow's remaining_gb is materialized (class-tracked links
        never touch the other flows)."""
        tr.token += 1                            # cancel pending setup
        link = tr.link
        if tr.fid in link.flows:                 # mid-flow
            link.remove_flow(tr, self.now)
            self.domain._relink(link)
            tr.latency_left = 0.0
        else:
            link.setup -= 1
        if tr.is_sync:
            self._sync_off()

    def _pause_activity(self, w: _WorkerState):
        """Capture whatever the worker is doing as a resumable pending
        continuation (duration-cap preemption keeps progress: the work up
        to the checkpoint is durable)."""
        act = w.activity
        w.activity = None
        if act is None:
            return                               # waiting: barrier will defer
        kind = act[0]
        if kind == "compute":
            _, cont, redo = act
            remaining = max(w.seg_end - self.now, 0.0)
            w.seg_gen += 1
            w.pending = lambda: self._do_compute(w, remaining, cont,
                                                 redo=redo)
        elif kind == "transfer":
            _, tr, _cont = act
            self._detach_transfer(tr)
            w.pending = lambda: self._resume_transfer(w, tr)
        elif kind == "pipeline":
            _, pr = act
            pr.pause()                           # both lanes keep progress
            w.pending = pr.resume
        elif kind == "fanout":
            _, win = act
            win.pause()                          # every member keeps progress
            w.pending = win.resume

    def _reattach_transfer(self, w: _WorkerState, tr: _Transfer):
        """Put a detached transfer back on its link (keeping progress)."""
        if tr.is_sync:
            self._sync_on()
        self._begin_setup(w, tr)

    def _resume_transfer(self, w: _WorkerState, tr: _Transfer):
        w.activity = ("transfer", tr, tr.cb)
        self._reattach_transfer(w, tr)

    def _pipe_seg_done(self, payload):
        pr, gen = payload
        pr._seg_done(gen)

    def _fan_compute_done(self, payload):
        # _FanoutWindow._compute_done, inlined into the dispatch target:
        # one frame per member-compute event instead of two
        win, i, gen = payload
        if gen != win.gen:
            return
        self._levents += 1
        win.pending -= 1
        tr = win.trs[i]
        if tr is None:
            win._advance(i, 0)           # no participating phases: arrive
            return
        win.stage[i] = 0
        if tr.is_sync:
            # _sync_on inlined: only the 0 -> 1 transition leaves the fast
            # path (opens the domain keep-alive interval)
            self._sync_active += 1
            if self._sync_active == 1:
                self.domain._sync_on(self)
        if tr.latency_left > 0.0:
            tr.link.setup += 1           # setup event was pre-pushed
        else:
            link = tr.link
            link.add_flow(tr, self.now)
            self.domain._relink(link)

    def _cap_fire(self, payload):
        w, gen = payload
        if gen != w.cap_gen or w.finished or w.restarting:
            return
        self._cap_restarts += w.count
        self._tr(w, "cap-restart")
        self._pause_activity(w)
        self._close_invocation(w)
        # checkpoint out through the object store, restore on re-invoke
        self._ckpt_put(w)
        self._restart(w)

    def _fail(self, w: _WorkerState, retry: Callable):
        self._failures += w.count
        self._tr(w, "fail")
        w.activity = None
        w.seg_gen += 1
        self._close_invocation(w)
        # the dead function checkpointed nothing; the restart restores the
        # last iteration-boundary state (kept in the object store)
        self._ckpt_put(w)
        w.pending = retry
        self._restart(w)

    def _restart(self, w: _WorkerState):
        w.restarting = True

        def resume():
            self._ckpt_restore(w)
            w.restarting = False
            pending, w.pending = w.pending, None
            if callable(pending):
                pending()
            # else: worker was waiting at a barrier/gate — stays waiting

        self._begin_invocation(w, self._restart_overhead(), resume,
                               resumed=True)

    def _restart_overhead(self) -> float:
        """Re-invocation overhead: init + checkpoint restore, plus — for a
        spot backend under the "wait" policy — the unbilled wait until the
        spot price drops back below the bid (capacity is unavailable while
        the market is above it; billing re-arms only at ``_invoke_armed``)."""
        overhead = self.init_s + self.restore_s
        be = self.backend
        if (be is not None and be.spot and be.spot_policy == "wait"
                and not self._spot_fallback):
            now_rel = self.now - self._t0
            recover = be.price_trace.next_drop_below(now_rel, be.bid_usd_per_hr)
            if math.isinf(recover):
                raise ValueError("spot price never drops back below the bid; "
                                 "the wait policy cannot recover")
            overhead += max(recover - now_rel, 0.0)
        return overhead

    # -- correlated (shock) failures -----------------------------------------
    def _schedule_next_shock(self):
        if self.shocks.price_trace is not None:
            # deterministic arrivals: one shock per up-crossing of the
            # bid. A spike already in progress is skipped — the next kill
            # fires at the next genuine below->above transition.
            trace, bid = self.shocks.price_trace, self.shocks.bid_usd_per_hr
            t_rel = self.now - self._t0
            if trace.price_at(t_rel) > bid:
                t_rel = trace.next_drop_below(t_rel, bid)
                if math.isinf(t_rel):
                    return               # above the bid forever: no crossings
            t_rel = trace.next_crossing_above(t_rel, bid)
            if not math.isinf(t_rel):
                self._at(self._t0 + t_rel, self._shock_fire)
            return
        dt = float(self._shock_rng.exponential(self.shocks.interval_s))
        self._at(self.now + max(dt, 1e-9), self._shock_fire)

    def _shock_fire(self):
        """One shared shock: every eligible in-flight worker of the target
        tier dies with probability ``kill_frac`` — a correlated burst, not
        n independent coin flips spread over iterations. The fleet's kill
        coins are one vectorized draw per shock. Price-driven shocks
        (``ShockModel.price_trace``) additionally count as spot
        preemptions; under the backend's "fallback" spot policy the first
        one flips billing to on-demand and ends the preemption process."""
        if self._stopping or self._unfinished == 0 or self._spot_fallback:
            return                               # epoch over: stop the process
        us = self._shock_rng.random_sample(self.n)
        killed = 0
        for w in self._workers:      # singleton cohorts (shocks ⇒ uncoalesced)
            tier = self.fleet.workers[w.wid].tier
            if self.shocks.tier is not None and tier != self.shocks.tier:
                continue
            if us[w.wid] < self.shocks.kill_frac and self._shock_kill(w):
                killed += w.count
        if killed:
            self._shock_events += 1
            if self.shocks.price_trace is not None:
                self._preemptions += killed
                be = self.backend
                if be is not None and be.spot and be.spot_policy == "fallback":
                    # the kill itself billed at the spot price (settled in
                    # _close_invocation before this flag flips); everything
                    # after re-arms at the on-demand rate, preemption-free
                    self._spot_fallback = True
                    return
        self._schedule_next_shock()

    def _shock_kill(self, w: _WorkerState) -> bool:
        """Kill one worker mid-flight: unlike a duration-cap preemption the
        in-flight work is *lost* — compute restarts from the iteration
        boundary, a partial transfer re-sends from byte 0."""
        if w.finished or w.restarting:
            return False                         # nothing running to kill
        self._failures += w.count
        self._tr(w, "shock-fail")
        act = w.activity
        w.activity = None
        if act is None:
            if w.inv_cont is not None:
                # died inside the init window: redo the owed continuation
                w.pending = w.inv_cont
            # else: waiting at a barrier/gate — the release will deliver
        elif act[0] == "compute":
            w.seg_gen += 1
            redo = act[2]
            w.pending = redo if redo is not None else (
                lambda: self._compute_phase(w))
        elif act[0] == "pipeline":               # both lanes are lost
            act[1].abort()
            w.pending = lambda: self._compute_phase(w)
        else:                                    # transfer: bytes are lost
            _, tr, _cont = act
            self._detach_transfer(tr)
            tr.remaining_gb = tr.total_gb
            tr.latency_left = tr.setup_latency_s
            w.pending = lambda: self._resume_transfer(w, tr)
        self._close_invocation(w)
        self._ckpt_put(w)
        self._restart(w)
        return True

    # -- synchronization -----------------------------------------------------
    def _barrier(self, key: Tuple, w: _WorkerState, cont: Callable):
        if self._stopping:
            # epoch aborted at the last completed iteration's checkpoint:
            # the in-flight iteration is discarded, nobody else will arrive
            return self._finish_worker(w)
        b = self._barriers.setdefault(key, {"count": 0, "waiters": []})
        b["count"] += w.count
        w.activity = None
        if b["count"] >= self.n:
            del self._barriers[key]
            self._tr(w, f"barrier-release {key[0]}:{key[1]}")
            for ww, wcont in b["waiters"]:
                self._release(ww, wcont)
            self._release(w, cont)
        else:
            b["waiters"].append((w, cont))

    def _release(self, w: _WorkerState, cont: Callable):
        if w.restarting:
            w.pending = cont                     # deliver after re-invoke
        else:
            cont()

    def _gate_ok(self, w: _WorkerState) -> bool:
        if self.mode == "async" or self.staleness is None:
            return True
        return w.it - self._min_it <= self.staleness

    def _poke_gate(self):
        if not self._gate_waiters:
            return
        ready, self._gate_waiters = self._gate_waiters, []
        for w, cont in ready:
            if self._stopping or self._gate_ok(w):
                self._release(w, cont)
            else:
                self._gate_waiters.append((w, cont))

    # -- worker state machine ------------------------------------------------
    def _start_worker(self, w: _WorkerState):
        shard_bytes = self.w.sample_bytes * self.samples / self.n

        def fetch():
            self._tr(w, "data-fetch")
            self._start_transfer(w, "object", shard_bytes, 1,
                                 lambda: self._begin_iteration(w))

        # cap window is armed after init; the epoch's data fetch rides
        # before the first compute, as in the analytic model
        self._begin_invocation(w, self.init_s, fetch, resumed=False)

    def _begin_iteration(self, w: _WorkerState):
        if self._stopping or w.it >= self.iters:
            return self._finish_worker(w)
        if self.mode == "ssp" and not self._gate_ok(w):
            w.activity = None
            self._gate_waiters.append((w, lambda: self._begin_iteration(w)))
            return
        self._compute_phase(w)

    def _compute_phase(self, w: _WorkerState):
        if self.coalesced and self.sigma > 0.0:
            # σ>0 cohort: members diverge here and re-merge at the first
            # bsp barrier — the fan-out window runs that stretch per
            # member with one bulk event push (eligibility was proven at
            # construction)
            win = w.fan
            if win is None:
                win = w.fan = _FanoutWindow(self, w)
            return win.start()
        k = w.draws
        w.draws = k + 1
        factor = self._draws.factor(w.wid, k)
        if (self.slowdown_at_iter is not None
                and w.it >= self.slowdown_at_iter):
            factor *= self.slowdown_factor
        d = self.base_compute_s[w.wid] * factor
        if self.failure_rate > 0.0:
            failed, frac = self._draws.failed(w.wid, k)
            if failed:
                self._do_compute(w, d * frac,
                                 lambda: self._fail(
                                     w, lambda: self._compute_phase(w)))
                return
        if self.trace_enabled:
            self._tr(w, f"compute it{w.it}")
        if self._ov_count:
            # pipelined plan: compute and the overlappable uploads run
            # as two concurrent lanes inside one window
            return _PipelineRun(self, w, d).start()
        self._do_compute(w, d, lambda: self._comm_phase(w, 0))

    def _pipeline_done(self, w: _WorkerState):
        """Both lanes of the overlap window drained: run the deferred
        barriers of the overlappable phases (bsp), then the sequential
        remainder of the plan."""
        if self._stopping:
            return self._finish_worker(w)        # discard partial iteration
        self._chain_ov_barriers(w, 0)

    def _chain_ov_barriers(self, w: _WorkerState, i: int):
        if i >= self._ov_count:
            return self._comm_phase(w, self._ov_count)
        ph = self.plan.phases[i]
        nxt = lambda: self._chain_ov_barriers(w, i + 1)  # noqa: E731
        if self.mode == "bsp" and ph.barrier_after:
            self._barrier((ph.name, w.it), w, nxt)
        else:
            nxt()

    def _comm_phase(self, w: _WorkerState, pi: int):
        """Execute the plan's phases generically: workers ``0..fan_in-1``
        participate in phase ``pi`` (aggregators are relabeled to the
        lowest ids); the rest skip straight to its barrier. A phase with
        ``cpu_s`` (decompressing a sparse payload) computes after its
        transfer, off the store link. In bsp, ``barrier_after`` joins all
        n workers; ssp/async drop the joins."""
        if self._stopping:
            return self._finish_worker(w)        # discard partial iteration
        if pi >= len(self.plan.phases):
            return self._iteration_done(w)
        ph = self.plan.phases[pi]

        def advance():
            if self.mode == "bsp" and ph.barrier_after:
                self._barrier((ph.name, w.it), w,
                              lambda: self._comm_phase(w, pi + 1))
            else:
                self._comm_phase(w, pi + 1)

        if w.wid >= ph.fan_in:
            return advance()                     # not a participant

        def done():
            if ph.cpu_s > 0:
                # a shock mid-decompress redoes this phase (payload lost),
                # not the iteration's compute
                self._do_compute(w, ph.cpu_s, advance,
                                 redo=lambda: self._comm_phase(w, pi))
            else:
                advance()

        # only param-store phases hold the Redis container: an
        # object-store plan (ps_s3) accrues no keep-alive billing
        self._start_transfer(w, ph.store, ph.nbytes, ph.requests, done,
                             is_sync=(ph.store == "param"))

    def _iteration_done(self, w: _WorkerState):
        it0 = w.it
        w.it = it0 + 1
        if self.trace_enabled:
            self._tr(w, f"step it{it0}")
        self._levents += w.count
        hist = self._it_hist
        hist[it0] -= w.count
        hist[it0 + 1] += w.count
        if it0 == self._min_it and hist[it0] == 0:
            m = it0
            while m < self.iters and hist[m] == 0:
                m += 1
            self._min_it = m
        lo = self._min_it
        while self._g_done < lo:
            self._g_done += 1
            prev = self._iter_times[-1] if self._iter_times else None
            self._iter_times.append(self.now)
            if self.on_iteration is not None:
                dt = (self.now - prev) if prev is not None else 0.0
                if self.on_iteration(self._g_done, self.now, dt):
                    self._stopping = True
                    self._tr(w, "stop-requested")
                    self._flush_barriers()
        self._poke_gate()
        self._begin_iteration(w)

    def _flush_barriers(self):
        """On an early stop, peers parked at a barrier would wait forever
        (the stopping workers never arrive) — release them to finish."""
        barriers, self._barriers = self._barriers, {}
        for b in barriers.values():
            for ww, _cont in b["waiters"]:
                self._release(ww, lambda ww=ww: self._finish_worker(ww))

    def _finish_worker(self, w: _WorkerState):
        if w.finished:
            return
        w.finished = True
        if self._stopping:
            self._ckpt_put(w)
        self._close_invocation(w)
        self._tr(w, "finish")
        self._levents += w.count
        self._unfinished -= w.count
        if self._unfinished == 0:
            self._wall = self.now    # stale timer events may pop later
            if self.on_complete is not None:
                self.on_complete(self)

    # -- run -----------------------------------------------------------------
    def _start(self):
        if self._started:
            return
        self._started = True
        self._t0 = self.now
        for w in self._workers:
            self._start_worker(w)
        if self.shocks is not None:
            self._schedule_next_shock()

    def _check_complete(self):
        unfinished = [wid for w in self._workers if not w.finished
                      for wid in w.members]
        if unfinished:
            raise RuntimeError(f"event engine deadlock: workers {unfinished} "
                               f"never finished (mode={self.mode})")

    def run(self) -> EngineResult:
        """Run this engine's domain to completion and return this engine's
        result. (In a shared domain this runs *every* registered engine —
        the clock is shared; prefer ``domain.run()`` + ``engine.result()``
        for multi-job setups.)"""
        self.domain.run()
        return self.result()

    def result(self) -> EngineResult:
        if self._result is not None:
            return self._result
        self._check_complete()
        sync_s = self._sync_busy
        # billing basis: this job's share of the store's keep-alive union
        # (identical to sync_s unless the store is shared across jobs)
        billed_s = self.domain.store_keep_alive_share(self)
        self.param_store.keep_alive(billed_s)
        lambda_usd = (self._gb_seconds * LAMBDA_GB_SECOND
                      + self._requests * LAMBDA_PER_REQUEST)
        store_hourly = (self.param_store.vcpus * ECS_VCPU_HOUR
                        + self.param_store.memory_gb * ECS_GB_HOUR)
        n_objects = max(math.ceil(self.w.sample_bytes * self.samples
                                  / DATA_OBJECT_BYTES), 1)
        store_usd = (billed_s / 3600.0 * store_hourly
                     + n_objects * S3_GET_PER_1K / 1000.0 * self.n)
        self._result = EngineResult(
            wall_s=max(self._wall - self._t0, 0.0),
            lambda_usd=lambda_usd, store_usd=store_usd,
            iters_done=self._g_done,
            samples_done=min(self._g_done * self.global_batch, self.samples),
            sync_s=sync_s, store_billed_s=billed_s,
            restarts=self._cap_restarts,
            failures=self._failures, invocations=self._requests,
            iter_times=self._iter_times, stopped_early=self._stopping,
            trace=self._trace, shock_events=self._shock_events,
            sim_events=self._levents, backend_usd=self._backend_usd,
            preemptions=self._preemptions)
        return self._result


@dataclasses.dataclass
class ServingResult:
    """What one event-engine serving job produced."""
    wall_s: float                # first arrival admitted -> last batch done
    lambda_usd: float
    store_usd: float
    requests: int                # inference requests served
    batches: int                 # function invocations (one per batch)
    mean_batch: float
    p50_s: float
    p99_s: float
    slo_s: Optional[float]
    slo_violations: int          # requests whose latency exceeded slo_s
    cold_starts: int
    warm_hits: int               # batches served by a reused instance
    peak_instances: int
    sync_s: float                # own param-store fetch-outstanding window
    store_billed_s: float        # keep-alive share billed (cross-job union)
    sim_events: int

    @property
    def cost_usd(self) -> float:
        return self.lambda_usd + self.store_usd

    @property
    def cost_per_1k(self) -> float:
        return (self.cost_usd / self.requests * 1000.0
                if self.requests else 0.0)


class _ServeInstance:
    """One serverless serving function: a worker state machine of the
    serving fleet. States: ``cold`` (booting + fetching code/model),
    ``idle`` (warm, waiting for a batch, expires after keep_warm_s),
    ``busy`` (executing a batch), ``fetch`` (re-pulling the current model
    mid-flight — continuous deployment)."""

    __slots__ = ("iid", "state", "busy_until", "spin_t", "last_fetch",
                 "served", "expiry_gen")

    def __init__(self, iid: int, now: float, ready_est: float):
        self.iid = iid
        self.state = "cold"
        self.busy_until = ready_est  # prediction while cold/fetch, exact busy
        self.spin_t = now
        self.last_fetch = now
        self.served = 0
        self.expiry_gen = 0


class ServingJob:
    """Inference traffic as a first-class event-engine job.

    An autoscaled serverless serving fleet drains one arrival stream
    under a :class:`repro.serving.ServePolicy` (same SLO-driven dynamic
    batching semantics as ``repro.serving.simulate``, which this job
    reproduces exactly in the single-instance zero-cold-start limit —
    tested). Each function instance is a worker state machine; admission
    is cold-start-aware: a queued batch either waits for the earliest
    busy/cold instance or pays a fresh cold start, whichever is
    predicted faster.

    Registered into a ``ContentionDomain`` exactly like an
    ``EventEngine`` (duck-typed engine interface), so serving co-runs
    with training on one clock: cold starts fetch ``code_bytes`` from
    the ObjectStore and ``model_bytes`` from the ParamStore over the
    *shared* links — "serve the current model" genuinely contends with
    "train the next one" — and the model fetches hold the param store's
    keep-alive window (billed as this job's share of the cross-job
    union). With ``refresh_every_s`` set, warm instances re-pull the
    model at that cadence: continuous deployment serves the current
    weights, at a steady bandwidth price. ``link_priority`` raises the
    serving fetches' water-filling priority on the shared links, which
    bounds how much a training bulk-sync can inflate serving latency.

    Billing mirrors Lambda and lands on the shared platform ledger as it
    accrues: one request per batch plus GB-seconds of execution (and of
    model refreshes); the cold-start init window itself is unbilled, but
    its code fetch pays an S3 GET. ``result()`` attributes this job's
    total to ``ledger.job_usd[job]``."""

    def __init__(self, policy, arrivals: np.ndarray,
                 flops_per_request: float, param_store: ParamStore,
                 object_store: ObjectStore, *,
                 domain: Optional[ContentionDomain] = None,
                 platform: Optional[ServerlessPlatform] = None,
                 model_bytes: float = 0.0, code_bytes: float = 0.0,
                 cold_start_s: float = 1.0, keep_warm_s: float = 60.0,
                 max_instances: int = 64,
                 refresh_every_s: Optional[float] = None,
                 link_priority: float = 1.0, slo_s: Optional[float] = None,
                 job: str = "serving", start_at: float = 0.0,
                 on_complete: Optional[Callable] = None):
        if max_instances < 1:
            raise ValueError("max_instances must be >= 1")
        from repro.serving.batcher import exec_time  # deferred: no cycle
        self._exec_time = exec_time
        self.policy = policy
        self.arrivals = np.asarray(arrivals, dtype=float)
        if len(self.arrivals) > 1 and np.any(np.diff(self.arrivals) < 0):
            raise ValueError("arrivals must be sorted")
        self.flops_per_request = flops_per_request
        self.param_store = param_store
        self.object_store = object_store
        self.platform = platform
        self.model_bytes = model_bytes
        self.code_bytes = code_bytes
        self.cold_start_s = cold_start_s
        self.keep_warm_s = keep_warm_s
        self.max_instances = max_instances
        self.refresh_every_s = refresh_every_s
        self.link_priority = link_priority
        self.slo_s = slo_s
        self.job = job
        self.start_at = max(start_at, 0.0)
        self.on_complete = on_complete
        self.mem_gb = policy.memory_mb / 1024.0
        self.net_cap = fn_net_gbps(policy.memory_mb) * 8
        # full-batch execution estimate, used by the admission predictor
        self._exec_full = exec_time(flops_per_request, policy.max_batch,
                                    policy.memory_mb)
        self.domain = domain or ContentionDomain()
        self._job_idx = self.domain._register(self)
        self.links: Dict[str, SharedLink] = {
            "param": self.domain.link_for(param_store, "param"),
            "object": self.domain.link_for(object_store, "object"),
        }
        self.instances: List[_ServeInstance] = []
        self._iids = itertools.count()
        self._next = 0           # first unserved request index
        self._delivered = 0      # requests arrived so far
        self._timer_idx = -1     # oldest-request index the timer is armed for
        self._timer_gen = 0
        self._batch_log: List[Tuple[int, int, float]] = []  # (i, j, done_t)
        self._gb_seconds = 0.0
        self._requests = 0       # invocations billed (one per batch)
        self._cold_starts = 0
        self._warm_hits = 0
        self._peak = 0
        self._levents = 0
        self._started = False
        self._done = False
        self._t0 = 0.0
        self._wall = 0.0
        # ContentionDomain engine interface (sync-union accounting)
        self._sync_active = 0
        self._sync_busy = 0.0
        self._sync_t0 = 0.0
        self._sid = id(self.param_store)
        self._result: Optional[ServingResult] = None

    # -- primitives ----------------------------------------------------------
    @property
    def now(self) -> float:
        return self.domain.now

    def _sync_on(self):
        self._sync_active += 1
        if self._sync_active == 1:
            self.domain._sync_on(self)

    def _sync_off(self):
        self._sync_active -= 1
        if self._sync_active == 0:
            self.domain._sync_off(self)

    def _transfer(self, store: str, nbytes: float, cont: Callable,
                  is_sync: bool):
        """Open one serving-priority flow on a (possibly shared) store
        link; ``cont`` runs when it drains."""
        link = self.links[store]

        def finished():
            if is_sync:
                self._sync_off()
            cont()

        cap = self.net_cap if store == "param" else None
        tr = _Transfer(link, nbytes, link.latency_s, finished, is_sync,
                       cap_gbps=cap, prio=self.link_priority)
        if is_sync:
            self._sync_on()
        if tr.latency_left > 0:
            link.setup += 1
            self.domain.at2(self.now + tr.latency_left,
                            self.domain._setup_done, (tr, tr.token))
        else:
            link.add_flow(tr, self.now)
            self.domain._relink(link)

    def _bill(self, duration_s: float, request: bool):
        """Accrue GB-seconds (and optionally one Lambda request) both on
        this job's counters and — live, so co-running jobs see one shared
        bill — on the platform ledger."""
        self._gb_seconds += self.mem_gb * duration_s
        if request:
            self._requests += 1
        if self.platform is not None:
            led = self.platform.ledger
            led.gb_seconds += self.mem_gb * duration_s
            if request:
                led.requests += 1

    # -- arrival stream ------------------------------------------------------
    def _start(self):
        if self._started:
            return
        self._started = True
        self._t0 = self.now
        if len(self.arrivals) == 0:
            return self._finish()
        # bulk-push the whole arrival slab in one calendar insert rather
        # than chaining arrival k -> arrival k+1 one event at a time
        t0, arrive = self._t0, self._arrive
        self.domain.at2_bulk([(t0 + a, arrive, k)
                              for k, a in enumerate(self.arrivals.tolist())])

    def _arrive(self, k: int):
        self._delivered = k + 1
        self._levents += 1
        self._dispatch()

    # -- dynamic batching + admission ----------------------------------------
    def _dispatch(self):
        """Launch batches while the policy says go: a batch launches when
        the queue holds ``max_batch`` requests, the oldest has waited
        ``timeout_s`` since *arrival*, or the stream is exhausted — the
        exact (fixed) ``simulate`` semantics, with batch membership
        decided at launch."""
        pol = self.policy
        n = len(self.arrivals)
        while True:
            qlen = self._delivered - self._next
            if qlen == 0:
                return
            oldest = self._t0 + self.arrivals[self._next]
            full = qlen >= pol.max_batch
            exhausted = self._delivered == n
            overdue = self.now >= oldest + pol.timeout_s - 1e-12
            if not (full or overdue or exhausted):
                self._arm_timer(oldest + pol.timeout_s)
                return
            inst = self._acquire()
            if inst is None:
                return           # instance-ready/free events re-dispatch
            take = min(qlen, pol.max_batch)
            self._launch_batch(inst, self._next, self._next + take)
            self._next += take

    def _arm_timer(self, deadline: float):
        if self._timer_idx == self._next:
            return               # already armed for this oldest request
        self._timer_idx = self._next
        self._timer_gen += 1
        self.domain.at2(deadline, self._timeout_fire, self._timer_gen)

    def _timeout_fire(self, gen: int):
        if gen != self._timer_gen:
            return
        self._timer_idx = -1
        self._dispatch()

    def _acquire(self) -> Optional[_ServeInstance]:
        """A warm idle instance if one exists; otherwise the cold-start-
        aware admission decision: scale out only when a fresh cold start
        is predicted ready before the current fleet can reach the
        *backlog* — the earliest instance-free time plus the pending
        batches already queued ahead, drained fleet-wide (comparing
        against the earliest free time alone would never scale out: one
        busy instance always frees before a cold start lands, while the
        queue grows without bound)."""
        for inst in self.instances:
            if inst.state == "idle":
                return inst
        if len(self.instances) < self.max_instances:
            t_cold = self.now + self.cold_start_s + self._fetch_est()
            m = len(self.instances)
            if m:
                pending = -(-(self._delivered - self._next)
                            // self.policy.max_batch)
                t_wait = (min(inst.busy_until for inst in self.instances)
                          + (pending - 1) * self._exec_full / m)
            else:
                t_wait = math.inf
            if t_cold < t_wait:
                self._spin_up()
        return None

    def _fetch_est(self) -> float:
        """Uncontended estimate of the cold-start artifact fetches (the
        admission policy's prediction — actual fetches ride the shared
        links and may be slower)."""
        est = 0.0
        if self.code_bytes > 0:
            lnk = self.links["object"]
            est += lnk.latency_s + self.code_bytes / 1e9 / lnk.per_stream_gbps
        if self.model_bytes > 0:
            lnk = self.links["param"]
            bw = min(self.net_cap, lnk.per_stream_gbps)
            est += lnk.latency_s + self.model_bytes / 1e9 / bw
        return est

    # -- instance lifecycle --------------------------------------------------
    def _spin_up(self):
        inst = _ServeInstance(next(self._iids), self.now,
                              self.now + self.cold_start_s
                              + self._fetch_est())
        self.instances.append(inst)
        self._cold_starts += 1
        self._levents += 1
        self._peak = max(self._peak, len(self.instances))

        def after_model():
            inst.last_fetch = self.now
            self._instance_idle(inst)
            self._dispatch()

        def after_code():
            if self.model_bytes > 0:
                self._transfer("param", self.model_bytes, after_model,
                               is_sync=True)
            else:
                after_model()

        def boot_done():
            if self.code_bytes > 0:
                # the GET request itself is billed in result(); the bytes
                # ride the shared object link here
                self._transfer("object", self.code_bytes, after_code,
                               is_sync=False)
            else:
                after_code()

        self.domain.at(self.now + self.cold_start_s, boot_done)

    def _instance_idle(self, inst: _ServeInstance):
        inst.state = "idle"
        inst.busy_until = self.now
        inst.expiry_gen += 1
        if math.isfinite(self.keep_warm_s):
            self.domain.at2(self.now + self.keep_warm_s, self._expire_fire,
                            (inst, inst.expiry_gen))
        self._maybe_finish()

    def _expire_fire(self, payload):
        inst, gen = payload
        if gen != inst.expiry_gen or inst.state != "idle":
            return
        # keep-warm window elapsed unused: the platform reclaims it
        # (scale-in; idle time is the provider's cost, not billed)
        self.instances.remove(inst)
        self._levents += 1

    def _launch_batch(self, inst: _ServeInstance, i: int, j: int):
        batch = j - i
        dt = self._exec_time(self.flops_per_request, batch,
                             self.policy.memory_mb)
        if inst.served > 0:
            self._warm_hits += 1
        inst.served += 1
        inst.state = "busy"
        inst.expiry_gen += 1
        inst.busy_until = self.now + dt
        self._bill(dt, request=True)
        self._levents += batch
        self.domain.at2(self.now + dt, self._batch_done, (inst, i, j))

    def _batch_done(self, payload):
        inst, i, j = payload
        self._batch_log.append((i, j, self.now))
        if (self.refresh_every_s is not None and self.model_bytes > 0
                and self.now - inst.last_fetch >= self.refresh_every_s):
            # continuous deployment: re-pull the current weights before
            # taking more traffic; the function keeps billing while it
            # downloads, and the fetch contends on the shared param link
            inst.state = "fetch"
            inst.busy_until = self.now + self._fetch_est()
            t_fetch0 = self.now

            def refreshed():
                inst.last_fetch = self.now
                self._bill(self.now - t_fetch0, request=False)
                self._instance_idle(inst)
                self._dispatch()

            self._transfer("param", self.model_bytes, refreshed,
                           is_sync=True)
        else:
            self._instance_idle(inst)
        self._dispatch()

    def _maybe_finish(self):
        n = len(self.arrivals)
        if self._done or self._delivered < n or self._next < n:
            return
        if any(inst.state in ("busy", "cold", "fetch")
               for inst in self.instances):
            return
        self._finish()

    def _finish(self):
        self._done = True
        self._wall = self.now
        if self.on_complete is not None:
            self.on_complete(self)

    # -- results -------------------------------------------------------------
    def _check_complete(self):
        if not self._done:
            raise RuntimeError(
                f"serving job deadlock: {self._delivered - self._next} "
                f"queued of {len(self.arrivals)} requests never served")

    def run(self) -> ServingResult:
        """Run this job's domain to completion and return this job's
        result (prefer ``domain.run()`` + ``job.result()`` when sharing
        a domain)."""
        self.domain.run()
        return self.result()

    def result(self) -> ServingResult:
        if self._result is not None:
            return self._result
        self._check_complete()
        if self._batch_log:
            lat = np.concatenate([
                done - (self._t0 + self.arrivals[i:j])
                for i, j, done in self._batch_log])
        else:
            lat = np.zeros(1)
        billed_s = self.domain.store_keep_alive_share(self)
        self.param_store.keep_alive(billed_s)
        lambda_usd = (self._gb_seconds * LAMBDA_GB_SECOND
                      + self._requests * LAMBDA_PER_REQUEST)
        store_hourly = (self.param_store.vcpus * ECS_VCPU_HOUR
                        + self.param_store.memory_gb * ECS_GB_HOUR)
        gets = self._cold_starts if self.code_bytes > 0 else 0
        store_usd = (billed_s / 3600.0 * store_hourly
                     + gets * S3_GET_PER_1K / 1000.0)
        requests = sum(j - i for i, j, _ in self._batch_log)
        batches = len(self._batch_log)
        violations = (int(np.sum(lat > self.slo_s))
                      if self.slo_s is not None and requests else 0)
        self._result = ServingResult(
            wall_s=max(self._wall - self._t0, 0.0),
            lambda_usd=lambda_usd, store_usd=store_usd,
            requests=requests, batches=batches,
            mean_batch=requests / batches if batches else 0.0,
            p50_s=float(np.percentile(lat, 50)) if requests else 0.0,
            p99_s=float(np.percentile(lat, 99)) if requests else 0.0,
            slo_s=self.slo_s, slo_violations=violations,
            cold_starts=self._cold_starts, warm_hits=self._warm_hits,
            peak_instances=self._peak, sync_s=self._sync_busy,
            store_billed_s=billed_s, sim_events=self._levents)
        if self.platform is not None:
            self.platform.ledger.charge("store", store_usd)
            self.platform.ledger.attribute(self.job, self._result.cost_usd)
        return self._result
