"""Discrete-event execution core for the serverless simulator.

The closed-form ``epoch_estimate`` (repro.core.cost_model) costs a whole
epoch in one expression — nothing can *happen* inside it. This engine
replays the same epoch as a time-ordered event simulation with one state
machine per worker::

    invoke -> cold-start -> [data-fetch] -> { compute -> UL-shard ->
        aggregate (DL-shard + UL-aggr) -> DL-grad -> step }* -> finish

which makes the paper's dynamics first-class:

  - **Contended stores**: transfers share store bandwidth only while they
    actually overlap (``SharedLink`` processor sharing), instead of the
    analytic model's static ``concurrent=n`` divisor.
  - **Stragglers**: per-(worker, iteration) lognormal compute multipliers
    (mean 1, so the zero-variance limit reproduces the analytic model).
  - **Mid-flight failures**: a worker dies partway through an iteration,
    re-invokes, restores the checkpoint from the ObjectStore, and redoes
    the iteration — stalling its barrier peers, as it would on Lambda.
  - **Duration caps**: each invocation may hold at most
    ``max_duration_s - init - restore`` seconds of work; the engine
    checkpoints through the ObjectStore and restarts mid-segment (billing
    n requests per restart wave, per Lambda semantics).
  - **sync_mode**: "bsp" runs the comm plan's barriers; "ssp(k)" gates a
    worker only when it runs k iterations ahead of the slowest peer;
    "async" removes all inter-worker waits. (``LocalWorkerPool`` carries
    the matching stale-gradient *numerics*.)
  - **Mid-epoch adaptation**: ``on_iteration`` observes every global
    iteration completion; returning True checkpoints and stops the epoch
    early so the scheduler can re-optimize *mid-epoch*.

In the zero-variance, zero-failure, bsp limit the engine reproduces
``epoch_estimate`` wall-clock and cost within 1% (tested); with any
variance it yields the tail behavior the analytic path cannot express.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serverless.platform import (CHECKPOINT_RESTORE_S,
                                       DATA_OBJECT_BYTES, LAMBDA_GB_SECOND,
                                       LAMBDA_MAX_DURATION_S,
                                       LAMBDA_PER_REQUEST, InvocationRecord,
                                       ServerlessPlatform, fn_net_gbps)
from repro.serverless.stores import (ECS_GB_HOUR, ECS_VCPU_HOUR, S3_GET_PER_1K,
                                     ObjectStore, ParamStore, SharedLink)
from repro.serverless.worker import (CommPhase, Workload, comm_plan,
                                     compute_time, parse_sync_mode)

_EPS_GB = 1e-12          # flow remainder considered complete (~1e-3 byte)


class _Transfer:
    """A pausable store transfer: ``requests * latency`` of setup, then a
    flow on the link at the processor-sharing rate."""
    _ids = itertools.count()

    __slots__ = ("fid", "link", "remaining_gb", "latency_left", "cb", "token",
                 "is_sync")

    def __init__(self, link: SharedLink, nbytes: float, latency_s: float,
                 cb: Callable[[], None], is_sync: bool):
        self.fid = next(self._ids)
        self.link = link
        self.remaining_gb = nbytes / 1e9
        self.latency_left = latency_s
        self.cb = cb
        self.token = 0          # invalidates scheduled setup events on pause
        self.is_sync = is_sync  # gradient sync (param-store keep-alive window)


@dataclasses.dataclass
class EngineResult:
    """What one event-engine epoch (or partial epoch) produced."""
    wall_s: float
    lambda_usd: float
    store_usd: float
    iters_done: int              # globally completed iterations (min worker)
    samples_done: int
    sync_s: float                # param-link busy time (keep-alive billing)
    restarts: int                # duration-cap restarts, fleet-wide
    failures: int                # mid-flight failures, fleet-wide
    invocations: int             # Lambda requests billed
    iter_times: List[float]      # completion timestamp per global iteration
    stopped_early: bool
    trace: List[str]

    @property
    def cost_usd(self) -> float:
        return self.lambda_usd + self.store_usd


class _WorkerState:
    __slots__ = ("wid", "rng", "it", "inv_rec", "inv_count", "cap_gen",
                 "seg_gen", "seg_end", "activity", "pending", "restarting",
                 "finished")

    def __init__(self, wid: int, seed: int):
        self.wid = wid
        self.rng = np.random.RandomState((seed * 1_000_003 + wid) % 2**31)
        self.it = 0                   # completed iterations
        self.inv_rec: Optional[InvocationRecord] = None
        self.inv_count = 0
        self.cap_gen = 0              # invalidates scheduled cap events
        self.seg_gen = 0              # invalidates scheduled compute ends
        self.seg_end = 0.0
        self.activity: Optional[Tuple] = None   # ("compute"|"transfer"|...)
        self.pending = None           # continuation to run after a restart
        self.restarting = False
        self.finished = False


class EventEngine:
    """Run one epoch of ``workload`` under deployment ``(n, memory_mb)``
    as a discrete-event simulation. See the module docstring for the
    semantics; construction mirrors ``epoch_estimate``'s signature so the
    two paths are interchangeable."""

    def __init__(self, workload: Workload, scheme: str, n_workers: int,
                 memory_mb: float, global_batch: int,
                 param_store: ParamStore, object_store: ObjectStore, *,
                 platform: Optional[ServerlessPlatform] = None,
                 sync_mode: str = "bsp", staleness: int = 0,
                 straggler_sigma: float = 0.0, failure_rate: float = 0.0,
                 framework_init_s: float = 4.0, cold_start_s: float = 2.0,
                 max_duration_s: float = LAMBDA_MAX_DURATION_S,
                 samples: Optional[int] = None, seed: int = 0,
                 slowdown_at_iter: Optional[int] = None,
                 slowdown_factor: float = 1.0,
                 on_iteration: Optional[Callable] = None,
                 trace_enabled: bool = True):
        self.w = workload
        self.scheme = scheme
        self.n = n_workers
        self.memory_mb = memory_mb
        self.global_batch = global_batch
        self.param_store = param_store
        self.object_store = object_store
        self.platform = platform or ServerlessPlatform(
            max_duration_s=max_duration_s, seed=seed)
        self.mode, self.staleness = parse_sync_mode(sync_mode, staleness)
        self.sigma = straggler_sigma
        if not 0.0 <= failure_rate < 1.0:
            # at 1.0 every iteration attempt fails and the simulated epoch
            # (like the real one) would never complete
            raise ValueError(f"failure_rate must be in [0, 1), "
                             f"got {failure_rate}")
        self.failure_rate = failure_rate
        self.init_s = cold_start_s + framework_init_s
        self.restore_s = CHECKPOINT_RESTORE_S
        self.max_duration_s = max_duration_s
        self.usable_s = max_duration_s - self.init_s - self.restore_s
        if self.usable_s <= 0:
            raise ValueError("max_duration_s leaves no usable window")
        self.samples = samples or workload.dataset_samples
        self.iters = max(math.ceil(self.samples / global_batch), 1)
        self.seed = seed
        self.slowdown_at_iter = slowdown_at_iter
        self.slowdown_factor = slowdown_factor
        self.on_iteration = on_iteration
        self.trace_enabled = trace_enabled

        local_batch = max(global_batch // n_workers, 1)
        self.base_compute_s = compute_time(workload, local_batch, memory_mb)
        self.plan: List[CommPhase] = comm_plan(
            scheme, workload.grad_bytes, n_workers,
            extra_upload_bytes=workload.extra_upload_bytes)
        fn_bw = fn_net_gbps(memory_mb) * 8   # as in the analytic model
        self.links: Dict[str, SharedLink] = {
            "param": param_store.link(per_fn_gbps=fn_bw),
            "object": object_store.link(),
        }
        self.ckpt_bytes = 12.0 * workload.param_count  # params + Adam m,v

        # event queue: (time, seq, fn)
        self.now = 0.0
        self._q: List[Tuple[float, int, Callable]] = []
        self._seq = itertools.count()
        self._workers = [_WorkerState(i, seed) for i in range(n_workers)]
        self._barriers: Dict[Tuple, Dict] = {}
        self._gate_waiters: List[Tuple[_WorkerState, Callable]] = []
        self._stopping = False
        self._g_done = 0
        self._iter_times: List[float] = []
        self._trace: List[str] = []
        self._gb_seconds = 0.0
        self._requests = 0
        self._cap_restarts = 0
        self._failures = 0
        # union of time any gradient-sync transfer is outstanding — the
        # param store's keep-alive window (matches the analytic sync_s)
        self._sync_active = 0
        self._sync_busy = 0.0
        self._wall = 0.0

    # -- primitives ----------------------------------------------------------
    def _at(self, t: float, fn: Callable):
        heapq.heappush(self._q, (t, next(self._seq), fn))

    def _tr(self, w: _WorkerState, what: str):
        if self.trace_enabled:
            self._trace.append(f"{self.now:.6f} w{w.wid} {what}")

    def _reschedule(self, link: SharedLink):
        """Flow set changed: invalidate outstanding completion predictions
        and schedule the next one at the new processor-sharing rate."""
        link.generation += 1
        if not link.flows:
            return
        r = link.rate()
        t_next = self.now + min(tr.remaining_gb for tr in link.flows.values()) / r
        self._at(t_next, lambda gen=link.generation: self._link_event(link, gen))

    def _link_event(self, link: SharedLink, gen: int):
        if gen != link.generation:
            return                               # stale prediction
        done = [tr for tr in link.flows.values()
                if tr.remaining_gb <= _EPS_GB]
        for tr in done:
            del link.flows[tr.fid]
        self._reschedule(link)
        for tr in done:
            tr.cb()

    def _start_transfer(self, w: _WorkerState, store: str, nbytes: float,
                        requests: int, cont: Callable, is_sync: bool = False):
        link = self.links[store]

        def finished():
            w.activity = None
            if is_sync:
                self._sync_active -= 1
            cont()

        tr = _Transfer(link, nbytes, link.latency_s * max(requests, 1),
                       finished, is_sync)
        if is_sync:
            self._sync_active += 1
        w.activity = ("transfer", tr, tr.cb)
        self._begin_setup(w, tr)

    def _begin_setup(self, w: _WorkerState, tr: _Transfer):
        link = tr.link
        link.setup += 1
        tr.token += 1

        def activate(token=tr.token):
            if token != tr.token:
                return                           # paused during setup
            link.setup -= 1
            tr.latency_left = 0.0
            if tr.remaining_gb <= _EPS_GB:
                w.activity = None
                self._reschedule(link)           # busy-window bookkeeping
                tr.cb()
                return
            link.flows[tr.fid] = tr
            self._reschedule(link)

        if tr.latency_left > 0:
            self._at(self.now + tr.latency_left, activate)
        else:
            link.setup -= 1      # resume directly into the flow
            link.flows[tr.fid] = tr
            self._reschedule(link)

    def _do_compute(self, w: _WorkerState, duration: float, cont: Callable):
        w.activity = ("compute", cont)
        w.seg_end = self.now + duration
        w.seg_gen += 1

        def done(gen=w.seg_gen):
            if gen != w.seg_gen:
                return
            w.activity = None
            cont()

        self._at(w.seg_end, done)

    # -- invocation lifecycle ------------------------------------------------
    def _begin_invocation(self, w: _WorkerState, overhead: float,
                          cont: Callable, resumed: bool):
        rec = InvocationRecord(worker_id=w.wid, start=self.now,
                               cold_start_s=self.init_s, resumed=resumed)
        self.platform.invocations.append(rec)
        w.inv_rec = rec
        w.inv_count += 1
        self._tr(w, "invoke" if not resumed else "re-invoke")

        def armed():
            # the usable window opens once init/restore completes
            w.cap_gen += 1
            self._at(self.now + self.usable_s,
                     lambda gen=w.cap_gen: self._cap_fire(w, gen))
            cont()

        self._at(self.now + overhead, armed)

    def _close_invocation(self, w: _WorkerState):
        rec = w.inv_rec
        recs = self.platform.finish(rec, self.memory_mb, self.now)
        for r in recs:
            self._gb_seconds += self.memory_mb / 1024.0 * (r.end - r.start)
            self._requests += 1
        w.inv_rec = None
        w.cap_gen += 1                           # disarm the cap timer

    def _pause_activity(self, w: _WorkerState):
        """Capture whatever the worker is doing as a resumable pending
        continuation (duration-cap or failure preemption)."""
        act = w.activity
        w.activity = None
        if act is None:
            return                               # waiting: barrier will defer
        kind = act[0]
        if kind == "compute":
            _, cont = act
            remaining = max(w.seg_end - self.now, 0.0)
            w.seg_gen += 1
            w.pending = lambda: self._do_compute(w, remaining, cont)
        elif kind == "transfer":
            _, tr, _cont = act
            tr.token += 1                        # cancel pending setup
            link = tr.link
            if tr.fid in link.flows:             # mid-flow: keep the bytes
                del link.flows[tr.fid]
                self._reschedule(link)
                tr.latency_left = 0.0
            else:
                link.setup -= 1
            if tr.is_sync:
                self._sync_active -= 1
            w.pending = lambda: self._resume_transfer(w, tr)

    def _resume_transfer(self, w: _WorkerState, tr: _Transfer):
        if tr.is_sync:
            self._sync_active += 1
        w.activity = ("transfer", tr, tr.cb)
        self._begin_setup(w, tr)

    def _cap_fire(self, w: _WorkerState, gen: int):
        if gen != w.cap_gen or w.finished or w.restarting:
            return
        self._cap_restarts += 1
        self._tr(w, "cap-restart")
        self._pause_activity(w)
        self._close_invocation(w)
        # checkpoint out through the object store, restore on re-invoke
        self.object_store.put(f"ckpt/w{w.wid}", {"iter": w.it},
                              nbytes=self.ckpt_bytes)
        self._restart(w)

    def _fail(self, w: _WorkerState, retry: Callable):
        self._failures += 1
        self._tr(w, "fail")
        w.activity = None
        w.seg_gen += 1
        self._close_invocation(w)
        # the dead function checkpointed nothing; the restart restores the
        # last iteration-boundary state (kept in the object store)
        self.object_store.put(f"ckpt/w{w.wid}", {"iter": w.it},
                              nbytes=self.ckpt_bytes)
        w.pending = retry
        self._restart(w)

    def _restart(self, w: _WorkerState):
        w.restarting = True

        def resume():
            if f"ckpt/w{w.wid}" in self.object_store.blobs:
                self.object_store.get(f"ckpt/w{w.wid}", nbytes=self.ckpt_bytes)
            w.restarting = False
            pending, w.pending = w.pending, None
            if callable(pending):
                pending()
            # else: worker was waiting at a barrier/gate — stays waiting

        self._begin_invocation(w, self.init_s + self.restore_s, resume,
                               resumed=True)

    # -- synchronization -----------------------------------------------------
    def _barrier(self, key: Tuple, w: _WorkerState, cont: Callable):
        if self._stopping:
            # epoch aborted at the last completed iteration's checkpoint:
            # the in-flight iteration is discarded, nobody else will arrive
            return self._finish_worker(w)
        b = self._barriers.setdefault(key, {"count": 0, "waiters": []})
        b["count"] += 1
        w.activity = None
        if b["count"] >= self.n:
            del self._barriers[key]
            self._tr(w, f"barrier-release {key[0]}:{key[1]}")
            for ww, wcont in b["waiters"]:
                self._release(ww, wcont)
            self._release(w, cont)
        else:
            b["waiters"].append((w, cont))

    def _release(self, w: _WorkerState, cont: Callable):
        if w.restarting:
            w.pending = cont                     # deliver after re-invoke
        else:
            cont()

    def _gate_ok(self, w: _WorkerState) -> bool:
        if self.mode == "async" or self.staleness is None:
            return True
        lo = min(ww.it for ww in self._workers)
        return w.it - lo <= self.staleness

    def _poke_gate(self):
        if not self._gate_waiters:
            return
        ready, self._gate_waiters = self._gate_waiters, []
        for w, cont in ready:
            if self._stopping or self._gate_ok(w):
                self._release(w, cont)
            else:
                self._gate_waiters.append((w, cont))

    # -- worker state machine ------------------------------------------------
    def _start_worker(self, w: _WorkerState):
        shard_bytes = self.w.sample_bytes * self.samples / self.n

        def fetch():
            self._tr(w, "data-fetch")
            self._start_transfer(w, "object", shard_bytes, 1,
                                 lambda: self._begin_iteration(w))

        # cap window is armed after init; the epoch's data fetch rides
        # before the first compute, as in the analytic model
        self._begin_invocation(w, self.init_s, fetch, resumed=False)

    def _begin_iteration(self, w: _WorkerState):
        if self._stopping or w.it >= self.iters:
            return self._finish_worker(w)
        if self.mode == "ssp" and not self._gate_ok(w):
            w.activity = None
            self._gate_waiters.append((w, lambda: self._begin_iteration(w)))
            return
        self._compute_phase(w)

    def _compute_phase(self, w: _WorkerState):
        z = float(w.rng.standard_normal())
        factor = math.exp(self.sigma * z - 0.5 * self.sigma * self.sigma)
        if (self.slowdown_at_iter is not None
                and w.it >= self.slowdown_at_iter):
            factor *= self.slowdown_factor
        d = self.base_compute_s * factor
        fail_u = float(w.rng.random_sample())
        if fail_u < self.failure_rate:
            frac = float(w.rng.random_sample())
            self._do_compute(w, d * frac,
                             lambda: self._fail(
                                 w, lambda: self._compute_phase(w)))
            return
        self._tr(w, f"compute it{w.it}")
        self._do_compute(w, d, lambda: self._comm_phase(w, 0))

    def _comm_phase(self, w: _WorkerState, pi: int):
        if self._stopping:
            return self._finish_worker(w)        # discard partial iteration
        if pi >= len(self.plan):
            return self._iteration_done(w)
        ph = self.plan[pi]

        def done():
            if self.mode == "bsp" and ph.barrier_after:
                self._barrier((ph.name, w.it), w,
                              lambda: self._comm_phase(w, pi + 1))
            else:
                self._comm_phase(w, pi + 1)

        self._start_transfer(w, ph.store, ph.nbytes, ph.requests, done,
                             is_sync=True)

    def _iteration_done(self, w: _WorkerState):
        w.it += 1
        self._tr(w, f"step it{w.it - 1}")
        lo = min(ww.it for ww in self._workers)
        while self._g_done < lo:
            self._g_done += 1
            prev = self._iter_times[-1] if self._iter_times else None
            self._iter_times.append(self.now)
            if self.on_iteration is not None:
                dt = (self.now - prev) if prev is not None else 0.0
                if self.on_iteration(self._g_done, self.now, dt):
                    self._stopping = True
                    self._tr(w, "stop-requested")
                    self._flush_barriers()
        self._poke_gate()
        self._begin_iteration(w)

    def _flush_barriers(self):
        """On an early stop, peers parked at a barrier would wait forever
        (the stopping workers never arrive) — release them to finish."""
        barriers, self._barriers = self._barriers, {}
        for b in barriers.values():
            for ww, _cont in b["waiters"]:
                self._release(ww, lambda ww=ww: self._finish_worker(ww))

    def _finish_worker(self, w: _WorkerState):
        if w.finished:
            return
        w.finished = True
        if self._stopping:
            self.object_store.put(f"ckpt/w{w.wid}", {"iter": w.it},
                                  nbytes=self.ckpt_bytes)
        self._close_invocation(w)
        self._tr(w, "finish")
        if all(ww.finished for ww in self._workers):
            self._wall = self.now    # stale timer events may pop later

    # -- run -----------------------------------------------------------------
    def run(self) -> EngineResult:
        for w in self._workers:
            self._start_worker(w)
        links = list(self.links.values())
        while self._q:
            t, _, fn = heapq.heappop(self._q)
            if t > self.now:
                if self._sync_active > 0:
                    self._sync_busy += t - self.now
                for link in links:
                    link.progress(t)
                self.now = t
            fn()
        unfinished = [w.wid for w in self._workers if not w.finished]
        if unfinished:
            raise RuntimeError(f"event engine deadlock: workers {unfinished} "
                               f"never finished (mode={self.mode})")

        sync_s = self._sync_busy
        self.param_store.keep_alive(sync_s)
        lambda_usd = (self._gb_seconds * LAMBDA_GB_SECOND
                      + self._requests * LAMBDA_PER_REQUEST)
        store_hourly = (self.param_store.vcpus * ECS_VCPU_HOUR
                        + self.param_store.memory_gb * ECS_GB_HOUR)
        n_objects = max(math.ceil(self.w.sample_bytes * self.samples
                                  / DATA_OBJECT_BYTES), 1)
        store_usd = (sync_s / 3600.0 * store_hourly
                     + n_objects * S3_GET_PER_1K / 1000.0 * self.n)
        return EngineResult(
            wall_s=self._wall, lambda_usd=lambda_usd, store_usd=store_usd,
            iters_done=self._g_done,
            samples_done=min(self._g_done * self.global_batch, self.samples),
            sync_s=sync_s, restarts=self._cap_restarts,
            failures=self._failures, invocations=self._requests,
            iter_times=self._iter_times, stopped_early=self._stopping,
            trace=self._trace)
