"""Discrete-event serverless (FaaS) platform model.

The paper's economics run on AWS Lambda + S3 + Redis-on-ECS. TPU pods are
not pay-per-GB-second, so we keep the paper's *pricing and platform
semantics* (cold starts, 15-minute duration caps, memory-proportional
CPU/network, failures) in a deterministic simulator. The numerics of
training itself run as real JAX (small models) or through an analytic
workload model (paper-scale models); see ``repro.serverless.worker``.

Constants are calibrated to public AWS pricing (us-east-1, 2022):
  Lambda: $1.6667e-5 / GB-s, $2e-7 / request, 128MB..10240MB, 900s cap,
          1 vCPU per 1769MB, network scales with memory up to ~600 Mbps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serverless.backends import PriceTrace


LAMBDA_GB_SECOND = 1.6667e-5
LAMBDA_PER_REQUEST = 2e-7
LAMBDA_MAX_DURATION_S = 900.0
LAMBDA_MIN_MEMORY_MB = 128
LAMBDA_MAX_MEMORY_MB = 10_240
MB_PER_VCPU = 1769.0
PEAK_NET_GBPS = 0.075        # ~600 Mbit/s per function at full memory
PEAK_CPU_GFLOPS = 40.0       # effective GFLOP/s of one Lambda vCPU (f32)
CHECKPOINT_RESTORE_S = 1.5   # restore model + iterator state on restart
DATA_OBJECT_BYTES = 250e6    # paper: dataset split into <=250MB objects


def vcpus(memory_mb: float) -> float:
    return min(6.0, max(memory_mb / MB_PER_VCPU, 0.07))


def fn_gflops(memory_mb: float) -> float:
    """Effective compute of one function — scales with allocated memory."""
    return vcpus(memory_mb) * PEAK_CPU_GFLOPS


def fn_net_gbps(memory_mb: float) -> float:
    """Per-function network bandwidth (GB/s) — scales with memory, capped."""
    return PEAK_NET_GBPS * min(1.0, memory_mb / 10_240 * 4)


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """One function slot of a (possibly heterogeneous) fleet. Compute and
    network derive from ``memory_mb`` (``fn_gflops`` / ``fn_net_gbps``);
    ``tier`` labels the capacity pool (e.g. "spot" slots can be targeted by
    a correlated-failure ``ShockModel``)."""
    memory_mb: float
    tier: str = "standard"


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Per-worker deployment of one job: a tuple of ``WorkerSpec``s.

    ``FleetSpec.homogeneous(n, mem)`` reproduces the classic
    ``(n_workers, memory_mb)`` deployment exactly; mixed fleets give each
    worker its own compute rate, network cap, and GB-second billing rate.
    """
    workers: Tuple[WorkerSpec, ...]

    def __post_init__(self):
        if not self.workers:
            raise ValueError("FleetSpec needs at least one worker")

    def __len__(self) -> int:
        return len(self.workers)

    @classmethod
    def homogeneous(cls, n: int, memory_mb: float,
                    tier: str = "standard") -> "FleetSpec":
        return cls(tuple(WorkerSpec(memory_mb, tier) for _ in range(n)))

    @classmethod
    def mixed(cls, groups: Sequence[Tuple[int, float, str]]) -> "FleetSpec":
        """``groups``: (count, memory_mb, tier) per tier, concatenated in
        order (worker ids are assigned group by group)."""
        specs: List[WorkerSpec] = []
        for count, mem, tier in groups:
            specs.extend(WorkerSpec(mem, tier) for _ in range(count))
        return cls(tuple(specs))

    @property
    def memories(self) -> Tuple[float, ...]:
        return tuple(w.memory_mb for w in self.workers)

    @property
    def total_memory_mb(self) -> float:
        return sum(self.memories)

    @property
    def is_homogeneous(self) -> bool:
        return len(set(self.memories)) == 1

    def gflops_harmonic(self) -> float:
        """Weighted-harmonic effective per-worker compute rate: with equal
        local batches the *mean* iteration compute time equals the time at
        this rate (exact in the identical-memory limit)."""
        return len(self) / sum(1.0 / fn_gflops(m) for m in self.memories)

    def gflops_total(self) -> float:
        """Aggregate fleet compute rate — the load-aware placement's
        denominator: with the global batch split in proportion to worker
        speed, every worker computes for ``flops * batch / total``."""
        return sum(fn_gflops(m) for m in self.memories)

    def min_net_gbps(self) -> float:
        """Sync bound for the analytic approximation: a barriered exchange
        completes no faster than the narrowest worker's pipe."""
        return min(fn_net_gbps(m) for m in self.memories)


def fleet_from_config(workers: int, memory_mb: float, small_frac: float = 0.0,
                      small_memory_ratio: float = 0.5) -> FleetSpec:
    """The Bayesian optimizer's searchable fleet composition: a fraction
    ``small_frac`` of the fleet runs at ``memory_mb * small_memory_ratio``
    (tier "small"), the rest at full memory (tier "standard")."""
    n_small = int(round(workers * small_frac))
    n_small = min(max(n_small, 0), workers)
    small_mb = max(memory_mb * small_memory_ratio, LAMBDA_MIN_MEMORY_MB)
    return FleetSpec.mixed([(workers - n_small, memory_mb, "standard"),
                            (n_small, small_mb, "small")]
                           if n_small else [(workers, memory_mb, "standard")])


@dataclasses.dataclass(frozen=True)
class ShockModel:
    """Correlated (spot-style) failure process: shared shocks arrive as a
    Poisson process with mean inter-arrival ``interval_s``; at each shock
    every in-flight worker of the targeted ``tier`` (None = all tiers) dies
    independently with probability ``kill_frac`` — so one shock can kill a
    random subset of the fleet at once, unlike the per-iteration
    independent ``failure_rate``.

    With a ``price_trace`` + ``bid_usd_per_hr``, arrivals switch from
    Poisson to *deterministic*: a shock fires at every up-crossing of the
    bid by the spot price (engine-relative time), modeling correlated
    spot-market preemptions. ``kill_frac`` / ``tier`` still select which
    workers each crossing kills (e.g. only the "spot" tier of a mixed
    fleet)."""
    interval_s: float
    kill_frac: float = 0.5
    tier: Optional[str] = None
    price_trace: Optional[PriceTrace] = None
    bid_usd_per_hr: float = 0.0

    def __post_init__(self):
        if self.interval_s <= 0:
            raise ValueError("shock interval_s must be positive")
        if not 0.0 <= self.kill_frac <= 1.0:
            raise ValueError("shock kill_frac must be in [0, 1]")
        if self.price_trace is not None and self.bid_usd_per_hr <= 0:
            raise ValueError("price-driven shocks need a positive bid")


@dataclasses.dataclass
class BillingLedger:
    gb_seconds: float = 0.0
    requests: int = 0
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)
    # per-task cost attribution: when several workflow tasks bill one
    # shared platform, ``job_usd`` breaks the one bill down by job label
    # (a bookkeeping view — never added into ``total_cost``)
    job_usd: Dict[str, float] = dataclasses.field(default_factory=dict)

    def attribute(self, job: str, dollars: float):
        self.job_usd[job] = self.job_usd.get(job, 0.0) + dollars

    def charge_fn(self, memory_mb: float, duration_s: float):
        self.gb_seconds += memory_mb / 1024.0 * duration_s
        self.requests += 1

    def charge_fleet(self, memory_mb: float, n_workers: int,
                     duration_s: float, invocations_per_worker: int = 1):
        """Bill a fleet the way Lambda does: every worker is its own
        invocation (n requests), and every duration-cap restart is a fresh
        request on top. ``duration_s`` is the per-worker billed duration."""
        self.gb_seconds += memory_mb / 1024.0 * duration_s * n_workers
        self.requests += n_workers * max(invocations_per_worker, 1)

    def charge(self, key: str, dollars: float):
        self.extra[key] = self.extra.get(key, 0.0) + dollars

    @property
    def lambda_cost(self) -> float:
        return (self.gb_seconds * LAMBDA_GB_SECOND
                + self.requests * LAMBDA_PER_REQUEST)

    @property
    def total_cost(self) -> float:
        return self.lambda_cost + sum(self.extra.values())


@dataclasses.dataclass
class InvocationRecord:
    worker_id: int
    start: float
    end: float = 0.0
    cold_start_s: float = 0.0
    failed: bool = False
    resumed: bool = False        # continuation of a duration-capped invocation


class ServerlessPlatform:
    """Deterministic FaaS simulator: invocations, cold starts, duration caps,
    failure injection, and GB-second billing."""

    def __init__(self, *, max_duration_s: float = LAMBDA_MAX_DURATION_S,
                 cold_start_base_s: float = 0.25,
                 cold_start_per_code_gb_s: float = 2.5,
                 failure_rate: float = 0.0, seed: int = 0):
        self.max_duration_s = max_duration_s
        self.cold_start_base_s = cold_start_base_s
        self.cold_start_per_code_gb_s = cold_start_per_code_gb_s
        self.failure_rate = failure_rate
        # deferred import: repro.core's package init reaches back into
        # this leaf module, so a top-level import would cycle
        from repro.core.rng import base_stream
        self.rng = base_stream(seed)
        self.ledger = BillingLedger()
        self.invocations: List[InvocationRecord] = []
        self.now = 0.0

    # -- invocation lifecycle ------------------------------------------------
    def cold_start(self, code_size_mb: float, framework_init_s: float) -> float:
        """Time from invoke to user code running: container + deps + framework
        (e.g. ~4 s for Resnet-18 on TF per the paper, Section 4.1)."""
        return (self.cold_start_base_s
                + self.cold_start_per_code_gb_s * code_size_mb / 1024.0
                + framework_init_s)

    def invoke(self, worker_id: int, code_size_mb: float,
               framework_init_s: float) -> InvocationRecord:
        rec = InvocationRecord(worker_id=worker_id, start=self.now,
                               cold_start_s=self.cold_start(
                                   code_size_mb, framework_init_s))
        self.invocations.append(rec)
        return rec

    def iteration_fails(self) -> bool:
        return bool(self.rng.random_sample() < self.failure_rate)

    def finish(self, rec: InvocationRecord, memory_mb: float,
               end: float) -> List[InvocationRecord]:
        """Bill an invocation, enforcing the duration cap: a run longer than
        ``max_duration_s`` is split into a chain of capped invocations
        (checkpoint/restart), each billed as its own request — a single
        Lambda invocation can never bill beyond the cap."""
        recs = [rec]
        duration = max(end - rec.start, 0.0)
        while duration > self.max_duration_s:
            rec.end = rec.start + self.max_duration_s
            self.ledger.charge_fn(memory_mb, self.max_duration_s)
            duration -= self.max_duration_s
            rec = InvocationRecord(worker_id=rec.worker_id, start=rec.end,
                                   cold_start_s=rec.cold_start_s, resumed=True)
            self.invocations.append(rec)
            recs.append(rec)
        rec.end = rec.start + duration
        self.ledger.charge_fn(memory_mb, duration)
        return recs

    # -- time ------------------------------------------------------------------
    def advance(self, dt: float):
        self.now += dt
