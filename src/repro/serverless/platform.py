"""Discrete-event serverless (FaaS) platform model.

The paper's economics run on AWS Lambda + S3 + Redis-on-ECS. TPU pods are
not pay-per-GB-second, so we keep the paper's *pricing and platform
semantics* (cold starts, 15-minute duration caps, memory-proportional
CPU/network, failures) in a deterministic simulator. The numerics of
training itself run as real JAX (small models) or through an analytic
workload model (paper-scale models); see ``repro.serverless.worker``.

Constants are calibrated to public AWS pricing (us-east-1, 2022):
  Lambda: $1.6667e-5 / GB-s, $2e-7 / request, 128MB..10240MB, 900s cap,
          1 vCPU per 1769MB, network scales with memory up to ~600 Mbps.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import numpy as np

LAMBDA_GB_SECOND = 1.6667e-5
LAMBDA_PER_REQUEST = 2e-7
LAMBDA_MAX_DURATION_S = 900.0
LAMBDA_MIN_MEMORY_MB = 128
LAMBDA_MAX_MEMORY_MB = 10_240
MB_PER_VCPU = 1769.0
PEAK_NET_GBPS = 0.075        # ~600 Mbit/s per function at full memory
PEAK_CPU_GFLOPS = 40.0       # effective GFLOP/s of one Lambda vCPU (f32)
CHECKPOINT_RESTORE_S = 1.5   # restore model + iterator state on restart
DATA_OBJECT_BYTES = 250e6    # paper: dataset split into <=250MB objects


def vcpus(memory_mb: float) -> float:
    return min(6.0, max(memory_mb / MB_PER_VCPU, 0.07))


def fn_gflops(memory_mb: float) -> float:
    """Effective compute of one function — scales with allocated memory."""
    return vcpus(memory_mb) * PEAK_CPU_GFLOPS


def fn_net_gbps(memory_mb: float) -> float:
    """Per-function network bandwidth (GB/s) — scales with memory, capped."""
    return PEAK_NET_GBPS * min(1.0, memory_mb / 10_240 * 4)


@dataclasses.dataclass
class BillingLedger:
    gb_seconds: float = 0.0
    requests: int = 0
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)

    def charge_fn(self, memory_mb: float, duration_s: float):
        self.gb_seconds += memory_mb / 1024.0 * duration_s
        self.requests += 1

    def charge_fleet(self, memory_mb: float, n_workers: int,
                     duration_s: float, invocations_per_worker: int = 1):
        """Bill a fleet the way Lambda does: every worker is its own
        invocation (n requests), and every duration-cap restart is a fresh
        request on top. ``duration_s`` is the per-worker billed duration."""
        self.gb_seconds += memory_mb / 1024.0 * duration_s * n_workers
        self.requests += n_workers * max(invocations_per_worker, 1)

    def charge(self, key: str, dollars: float):
        self.extra[key] = self.extra.get(key, 0.0) + dollars

    @property
    def lambda_cost(self) -> float:
        return (self.gb_seconds * LAMBDA_GB_SECOND
                + self.requests * LAMBDA_PER_REQUEST)

    @property
    def total_cost(self) -> float:
        return self.lambda_cost + sum(self.extra.values())


@dataclasses.dataclass
class InvocationRecord:
    worker_id: int
    start: float
    end: float = 0.0
    cold_start_s: float = 0.0
    failed: bool = False
    resumed: bool = False        # continuation of a duration-capped invocation


class ServerlessPlatform:
    """Deterministic FaaS simulator: invocations, cold starts, duration caps,
    failure injection, and GB-second billing."""

    def __init__(self, *, max_duration_s: float = LAMBDA_MAX_DURATION_S,
                 cold_start_base_s: float = 0.25,
                 cold_start_per_code_gb_s: float = 2.5,
                 failure_rate: float = 0.0, seed: int = 0):
        self.max_duration_s = max_duration_s
        self.cold_start_base_s = cold_start_base_s
        self.cold_start_per_code_gb_s = cold_start_per_code_gb_s
        self.failure_rate = failure_rate
        self.rng = np.random.RandomState(seed)
        self.ledger = BillingLedger()
        self.invocations: List[InvocationRecord] = []
        self.now = 0.0

    # -- invocation lifecycle ------------------------------------------------
    def cold_start(self, code_size_mb: float, framework_init_s: float) -> float:
        """Time from invoke to user code running: container + deps + framework
        (e.g. ~4 s for Resnet-18 on TF per the paper, Section 4.1)."""
        return (self.cold_start_base_s
                + self.cold_start_per_code_gb_s * code_size_mb / 1024.0
                + framework_init_s)

    def invoke(self, worker_id: int, code_size_mb: float,
               framework_init_s: float) -> InvocationRecord:
        rec = InvocationRecord(worker_id=worker_id, start=self.now,
                               cold_start_s=self.cold_start(
                                   code_size_mb, framework_init_s))
        self.invocations.append(rec)
        return rec

    def iteration_fails(self) -> bool:
        return bool(self.rng.random_sample() < self.failure_rate)

    def finish(self, rec: InvocationRecord, memory_mb: float,
               end: float) -> List[InvocationRecord]:
        """Bill an invocation, enforcing the duration cap: a run longer than
        ``max_duration_s`` is split into a chain of capped invocations
        (checkpoint/restart), each billed as its own request — a single
        Lambda invocation can never bill beyond the cap."""
        recs = [rec]
        duration = max(end - rec.start, 0.0)
        while duration > self.max_duration_s:
            rec.end = rec.start + self.max_duration_s
            self.ledger.charge_fn(memory_mb, self.max_duration_s)
            duration -= self.max_duration_s
            rec = InvocationRecord(worker_id=rec.worker_id, start=rec.end,
                                   cold_start_s=rec.cold_start_s, resumed=True)
            self.invocations.append(rec)
            recs.append(rec)
        rec.end = rec.start + duration
        self.ledger.charge_fn(memory_mb, duration)
        return recs

    # -- time ------------------------------------------------------------------
    def advance(self, dt: float):
        self.now += dt
