"""Hybrid storage models (paper Section 4.3).

 - ``ObjectStore``: S3-like. High per-request latency, wide aggregate
   bandwidth, priced per-request + per-GB-month. Holds code + training data
   (infrequent access).
 - ``ParamStore``: Redis-on-ECS-like. Sub-millisecond latency, node-limited
   bandwidth, priced per container-hour while alive. Holds per-iteration
   gradients/shards (frequent access). SMLT keeps it alive only during
   synchronization phases.

Both can also hold real payloads (numpy arrays) so the *semantic* training
path (real JAX workers) uses the same interfaces as the analytic simulator.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Optional, Tuple

# Pricing (us-east-1, 2022)
S3_PUT_PER_1K = 0.005
S3_GET_PER_1K = 0.0004
S3_GB_MONTH = 0.023
ECS_VCPU_HOUR = 0.04048
ECS_GB_HOUR = 0.004445


@dataclasses.dataclass
class TransferStats:
    puts: int = 0
    gets: int = 0
    bytes_in: float = 0.0
    bytes_out: float = 0.0


class SharedLink:
    """Water-filling processor-sharing bandwidth resource for the event
    engine.

    The analytic model divides a store's aggregate bandwidth by a static
    ``concurrent=n``; here, transfers that *actually overlap in time*
    share the link by max-min fair water-filling: flows are offered equal
    shares of the aggregate, a flow capped below its share (its own
    ``cap_gbps``, defaulting to the link's ``per_stream_gbps``) keeps only
    its cap, and the share it cannot use is redistributed across the
    remaining flows — the link is never idle while any uncapped flow is
    backlogged, and total throughput never exceeds
    ``min(aggregate, sum of caps)``. Rates are re-evaluated whenever a
    flow joins or leaves. With identical caps this reduces to the classic
    ``min(cap, aggregate / k)`` processor sharing. One link may be shared
    by *several* engines in a ``ContentionDomain`` — cross-job transfers
    then slow each other by their actual overlap. (Keep-alive billing is
    the engine's job: it tracks the union of time gradient-sync transfers
    are outstanding, across links.)"""

    def __init__(self, name: str, aggregate_gbps: float,
                 per_stream_gbps: float, latency_s: float):
        self.name = name
        self.aggregate_gbps = aggregate_gbps
        self.per_stream_gbps = per_stream_gbps
        self.latency_s = latency_s
        self.flows: Dict[int, Any] = {}      # fid -> transfer (remaining_gb)
        self.setup = 0                       # transfers in the latency phase
        self.generation = 0                  # bumped on any flow-set change
        self.last_t = 0.0
        self._rates_key = None               # (generation, len) of the cache
        self._rates: Dict[int, float] = {}
        # incremental uniform-cap fast path (see add_flow): while every
        # flow has the same cap, all flows drain at one shared per-member
        # rate, so the link tracks a single virtual-work integral
        # ``_served`` (GB delivered per member stream) instead of touching
        # every flow on every clock advance. A flow added at served-level
        # S with R GB left drains when ``_served`` reaches its target
        # S + R; targets live in a lazy-deletion heap, making progress()
        # O(1) and next_completion_dt()/take_drained() O(log n).
        self._served = 0.0
        self._uniform_r = 0.0                # shared per-member rate
        self._target: Dict[int, float] = {}  # fid -> drain served-level
        self._theap: List[Tuple[float, int]] = []
        # uniformity is judged on (cap, prio) pairs: the fast path needs
        # every member stream to drain at one shared rate
        self._cap_counts: Dict[Tuple[float, float], int] = {}
        self._total_w = 0

    def _cap(self, tr: Any) -> float:
        return getattr(tr, "cap_gbps", None) or self.per_stream_gbps

    @staticmethod
    def _prio(tr: Any) -> float:
        """Water-filling priority weight: a flow with ``prio`` p claims p
        equal shares per member stream (default 1.0 — plain max-min).
        Lets latency-critical serving fetches keep a guaranteed fraction
        of a link they share with training bulk syncs."""
        return getattr(tr, "prio", 1.0) or 1.0

    def _tracked(self) -> bool:
        """True while every current flow was added via ``add_flow`` and
        caps are uniform — the O(1)/O(log n) accounting is valid. Flows
        injected directly into ``flows`` (tests, external tools) simply
        fall back to the materialized per-flow path."""
        return len(self._target) == len(self.flows) > 0

    # -- incremental flow-set maintenance (engine fast path) -----------------
    def add_flow(self, tr: Any):
        """Register a flow, keeping the uniform-mode accounting current.
        ``tr.remaining_gb`` must be up to date (it is captured into the
        drain target here)."""
        cap = self._cap(tr)
        key = (cap, self._prio(tr))
        was_uniform = self._tracked() or not self.flows
        self.flows[tr.fid] = tr
        self._cap_counts[key] = self._cap_counts.get(key, 0) + 1
        self._total_w += getattr(tr, "weight", 1)
        if len(self._cap_counts) == 1:
            # equal priorities cancel in the proportional share, so the
            # uniform per-member rate is the classic one
            if was_uniform:
                tgt = self._served + tr.remaining_gb
                self._target[tr.fid] = tgt
                heapq.heappush(self._theap, (tgt, tr.fid))
            else:
                self._enter_uniform()
            self._uniform_r = min(cap, self.aggregate_gbps / self._total_w)
        elif self._target:
            self._materialize_all()

    def remove_flow(self, tr: Any):
        """Drop a flow, materializing its ``remaining_gb`` first (pause /
        checkpoint paths read it)."""
        fid = tr.fid
        tgt = self._target.pop(fid, None)
        if tgt is not None:
            tr.remaining_gb = max(tgt - self._served, 0.0)
        del self.flows[fid]
        key = (self._cap(tr), self._prio(tr))
        c = self._cap_counts.get(key, 0) - 1
        if c > 0:
            self._cap_counts[key] = c
        elif key in self._cap_counts:
            del self._cap_counts[key]
        self._total_w -= getattr(tr, "weight", 1)
        if not self.flows:
            self._target.clear()
            self._theap.clear()
            self._uniform_r = 0.0
        elif len(self._cap_counts) == 1:
            if not self._target:
                self._enter_uniform()
            cap0 = next(iter(self._cap_counts))[0]
            self._uniform_r = min(cap0, self.aggregate_gbps / self._total_w)

    def take_drained(self, eps_gb: float = 1e-12) -> List[Any]:
        """Pop and return every flow whose remainder is within ``eps_gb``
        of drained (``remaining_gb`` is zeroed/materialized). O(k log n)
        in uniform mode, O(n) otherwise."""
        out: List[Any] = []
        if self._tracked():
            heap, target = self._theap, self._target
            while heap:
                tgt, fid = heap[0]
                if target.get(fid) != tgt:
                    heapq.heappop(heap)          # stale (removed/re-added)
                    continue
                if tgt - self._served > eps_gb:
                    break
                out.append(self.flows[fid])
                self.remove_flow(self.flows[fid])
        else:
            out = [tr for tr in self.flows.values()
                   if tr.remaining_gb <= eps_gb]
            for tr in out:
                self.remove_flow(tr)
        return out

    def _enter_uniform(self):
        """Caps just became uniform: snapshot every flow's (materialized)
        remainder into a drain target."""
        self._target.clear()
        heap = []
        served = self._served
        for fid, tr in self.flows.items():
            tgt = served + tr.remaining_gb
            self._target[fid] = tgt
            heap.append((tgt, fid))
        heapq.heapify(heap)
        self._theap = heap

    def _materialize_all(self):
        """Caps diverged: flush virtual-work progress into every flow's
        ``remaining_gb`` and fall back to per-flow accounting."""
        served = self._served
        for fid, tr in self.flows.items():
            tgt = self._target.get(fid)
            if tgt is not None:
                tr.remaining_gb = max(tgt - served, 0.0)
        self._target.clear()
        self._theap.clear()

    def rates(self) -> Dict[int, float]:
        """Max-min fair (water-filling) rate per flow id. Visiting flows
        narrowest-cap first, each takes ``min(cap, remaining / members
        left)`` — a capped flow's unused equal share waterfalls to the
        wider flows behind it. Rates only change when the flow set does
        (every mutation bumps ``generation``), so the allocation is
        cached per (generation, flow count).

        A flow may carry ``weight`` member streams (a coalesced worker
        cohort): it counts as ``weight`` equal claimants on the link and
        its returned rate is the **per-member** rate — exactly the
        allocation ``weight`` identical singleton flows would get. A flow
        may also carry ``prio`` (default 1.0): each of its member streams
        claims ``prio`` shares, so under contention it holds a
        ``prio``-weighted fraction of the aggregate (still bounded by its
        own cap, and still spilling unused share to the others)."""
        key = (self.generation, len(self.flows))
        if key == self._rates_key:
            return self._rates
        if self._tracked():
            r = self._uniform_r
            out = dict.fromkeys(self.flows, r)
            self._rates_key, self._rates = key, out
            return out
        flows = list(self.flows.values())
        default_cap = self.per_stream_gbps
        caps = [getattr(tr, "cap_gbps", None) or default_cap for tr in flows]
        wgts = [getattr(tr, "weight", 1) for tr in flows]
        prios = [self._prio(tr) for tr in flows]
        left = sum(wgts)
        cap0, prio0 = caps[0], prios[0]
        if (all(c == cap0 for c in caps)
                and all(p == prio0 for p in prios)):
            # uniform caps + priorities (the homogeneous-fleet common
            # case): water-filling degenerates to classic processor
            # sharing — either every flow is cap-bound or every flow takes
            # an equal share; no sort needed (equal priorities cancel)
            r = min(cap0, self.aggregate_gbps / left)
            out = {tr.fid: r for tr in flows}
        else:
            # weighted max-min: each member stream claims ``prio`` shares;
            # visiting flows by ascending cap-to-claim ratio, a flow whose
            # cap binds below its proportional share releases the excess
            # to everyone behind it
            order = sorted(range(len(flows)),
                           key=lambda i: (caps[i] / prios[i], flows[i].fid))
            out = {}
            remaining = self.aggregate_gbps
            claims = sum(w * p for w, p in zip(wgts, prios))
            for i in order:
                wgt = wgts[i]
                r = min(caps[i], prios[i] * remaining / claims)
                out[flows[i].fid] = r
                remaining -= r * wgt
                claims -= wgt * prios[i]
        self._rates_key, self._rates = key, out
        return out

    def next_completion_dt(self) -> float:
        """Time until the first flow drains at the current per-flow rates.
        (``remaining_gb`` is per member, as is the rate.)"""
        if self._tracked():
            heap, target = self._theap, self._target
            while heap and target.get(heap[0][1]) != heap[0][0]:
                heapq.heappop(heap)              # lazy-deleted entries
            return max(heap[0][0] - self._served, 0.0) / self._uniform_r
        rates = self.rates()
        return min(tr.remaining_gb / rates[tr.fid]
                   for tr in self.flows.values())

    def progress(self, now: float):
        """Advance all flows to ``now`` at the rates that held since the
        last flow-set change (rates only change when the set does). In
        uniform mode only the shared virtual-work integral advances —
        O(1) regardless of flow count."""
        dt = now - self.last_t
        if dt > 0 and self.flows:
            if self._tracked():
                self._served += self._uniform_r * dt
            else:
                rates = self.rates()
                for tr in self.flows.values():
                    tr.remaining_gb = max(
                        tr.remaining_gb - rates[tr.fid] * dt, 0.0)
        self.last_t = now


class ObjectStore:
    """S3-like object store."""

    def __init__(self, *, latency_s: float = 0.030,
                 per_stream_gbps: float = 0.090,   # ~90 MB/s per connection
                 aggregate_gbps: float = 100.0):
        self.latency_s = latency_s
        self.per_stream_gbps = per_stream_gbps
        self.aggregate_gbps = aggregate_gbps
        self.blobs: Dict[str, Any] = {}
        self.stats = TransferStats()

    def put_time(self, nbytes: float, concurrent: int = 1) -> float:
        bw = min(self.per_stream_gbps, self.aggregate_gbps / max(concurrent, 1))
        return self.latency_s + nbytes / 1e9 / bw

    def get_time(self, nbytes: float, concurrent: int = 1) -> float:
        return self.put_time(nbytes, concurrent)

    def put(self, key: str, value: Any, nbytes: Optional[float] = None):
        self.blobs[key] = value
        self.stats.puts += 1
        self.stats.bytes_in += nbytes or 0

    def get(self, key: str, nbytes: Optional[float] = None) -> Any:
        self.stats.gets += 1
        self.stats.bytes_out += nbytes or 0
        return self.blobs[key]

    def request_cost(self) -> float:
        return (self.stats.puts * S3_PUT_PER_1K / 1000.0
                + self.stats.gets * S3_GET_PER_1K / 1000.0)

    def link(self) -> SharedLink:
        """A contended-bandwidth view of this store for the event engine."""
        return SharedLink("object", self.aggregate_gbps,
                          self.per_stream_gbps, self.latency_s)


class ParamStore:
    """Redis-like in-memory KV store on an ECS container."""

    def __init__(self, *, latency_s: float = 0.0008,
                 node_gbps: float = 5.0,          # 40 Gbit/s ECS container
                 vcpus: float = 2.0, memory_gb: float = 8.0):
        self.latency_s = latency_s
        self.node_gbps = node_gbps
        self.vcpus = vcpus
        self.memory_gb = memory_gb
        self.blobs: Dict[str, Any] = {}
        self.stats = TransferStats()
        self.alive_seconds = 0.0   # only billed while synchronization runs

    def xfer_time(self, nbytes: float, concurrent: int = 1,
                  per_fn_gbps: float = 10.0) -> float:
        bw = min(per_fn_gbps, self.node_gbps / max(concurrent, 1))
        return self.latency_s + nbytes / 1e9 / bw

    def put(self, key: str, value: Any, nbytes: Optional[float] = None):
        self.blobs[key] = value
        self.stats.puts += 1
        self.stats.bytes_in += nbytes or 0

    def get(self, key: str, nbytes: Optional[float] = None) -> Any:
        self.stats.gets += 1
        self.stats.bytes_out += nbytes or 0
        return self.blobs[key]

    def keep_alive(self, seconds: float):
        self.alive_seconds += seconds

    def link(self, per_fn_gbps: float = 10.0) -> SharedLink:
        """A contended-bandwidth view of this store for the event engine."""
        return SharedLink("param", self.node_gbps, per_fn_gbps,
                          self.latency_s)

    def container_cost(self) -> float:
        hours = self.alive_seconds / 3600.0
        return hours * (self.vcpus * ECS_VCPU_HOUR
                        + self.memory_gb * ECS_GB_HOUR)
