"""Hybrid storage models (paper Section 4.3).

 - ``ObjectStore``: S3-like. High per-request latency, wide aggregate
   bandwidth, priced per-request + per-GB-month. Holds code + training data
   (infrequent access).
 - ``ParamStore``: Redis-on-ECS-like. Sub-millisecond latency, node-limited
   bandwidth, priced per container-hour while alive. Holds per-iteration
   gradients/shards (frequent access). SMLT keeps it alive only during
   synchronization phases.

Both can also hold real payloads (numpy arrays) so the *semantic* training
path (real JAX workers) uses the same interfaces as the analytic simulator.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Any, Dict, List, Optional, Tuple

# Pricing (us-east-1, 2022)
S3_PUT_PER_1K = 0.005
S3_GET_PER_1K = 0.0004
S3_GB_MONTH = 0.023
ECS_VCPU_HOUR = 0.04048
ECS_GB_HOUR = 0.004445


@dataclasses.dataclass
class TransferStats:
    puts: int = 0
    gets: int = 0
    bytes_in: float = 0.0
    bytes_out: float = 0.0


class _FlowClass:
    """One ``(cap, prio)`` equivalence class of flows on a SharedLink.

    Water-filling assigns every member stream of a class the same rate
    (flows with equal cap and priority are interchangeable claimants), so
    the class — not the flow — is the unit of incremental accounting: a
    single virtual-work integral ``served`` (GB delivered per member
    stream since the class was created) advances at ``rate`` per second,
    and a flow added at served-level S with R GB left drains when
    ``served`` reaches its target S + R. Targets live in a lazy-deletion
    min-heap, making membership changes O(log K-ish) with no per-flow
    touch-up on clock advances.

    ``pred_t``/``pred_id`` belong to the event engine's lazy completion
    re-prediction: the earliest pending ``CalendarQueue`` prediction for
    this class and its staleness stamp (see ``ContentionDomain._relink``).
    """

    __slots__ = ("cap", "prio", "n", "w", "served", "rate", "target",
                 "heap", "pred_t", "pred_id")

    def __init__(self, cap: float, prio: float):
        self.cap = cap
        self.prio = prio
        self.n = 0                    # flows currently in the class
        self.w = 0                    # member streams (sum of flow weights)
        self.served = 0.0             # GB delivered per member stream
        self.rate = 0.0               # current per-member rate (GB/s)
        self.target: Dict[int, float] = {}   # fid -> drain served-level
        self.heap: List[Tuple[float, int]] = []
        self.pred_t = math.inf        # earliest pending drain prediction
        self.pred_id = 0              # invalidates stale predictions


def _class_order(c: _FlowClass) -> Tuple[float, float, float]:
    """Water-filling visit order: ascending cap-to-claim ratio (a class
    whose cap binds below its proportional share releases the excess to
    everyone behind it). The (cap, prio) tail makes the order total."""
    return (c.cap / c.prio, c.cap, c.prio)


class SharedLink:
    """Water-filling processor-sharing bandwidth resource for the event
    engine.

    The analytic model divides a store's aggregate bandwidth by a static
    ``concurrent=n``; here, transfers that *actually overlap in time*
    share the link by max-min fair water-filling: flows are offered equal
    shares of the aggregate, a flow capped below its share (its own
    ``cap_gbps``, defaulting to the link's ``per_stream_gbps``) keeps only
    its cap, and the share it cannot use is redistributed across the
    remaining flows — the link is never idle while any uncapped flow is
    backlogged, and total throughput never exceeds
    ``min(aggregate, sum of caps)``. Rates are re-evaluated whenever a
    flow joins or leaves. With identical caps this reduces to the classic
    ``min(cap, aggregate / k)`` processor sharing. One link may be shared
    by *several* engines in a ``ContentionDomain`` — cross-job transfers
    then slow each other by their actual overlap. (Keep-alive billing is
    the engine's job: it tracks the union of time gradient-sync transfers
    are outstanding, across links.)

    Flows added through ``add_flow`` are grouped into K equivalence
    **classes** keyed by ``(cap, prio)`` — K = tiers x priorities, small
    and bounded — and water-filling runs over the classes instead of the
    n flows. Each class keeps its own served-integral and lazy-deletion
    drain heap, so ``add_flow``/``remove_flow``/``take_drained`` are
    O(log K) and a clock advance is O(K) regardless of flow count: mixed
    -cap fleets and priority-carrying serving fetches ride the same
    incremental path a uniform fleet does. Flows injected directly into
    ``flows`` (tests, external tools) fall back to materialized per-flow
    accounting; ``incremental=False`` forces that fallback everywhere
    (the property-test reference)."""

    def __init__(self, name: str, aggregate_gbps: float,
                 per_stream_gbps: float, latency_s: float,
                 incremental: bool = True):
        self.name = name
        self.aggregate_gbps = aggregate_gbps
        self.per_stream_gbps = per_stream_gbps
        self.latency_s = latency_s
        self.incremental = incremental
        self.flows: Dict[int, Any] = {}      # fid -> transfer (remaining_gb)
        self.setup = 0                       # transfers in the latency phase
        self.generation = 0                  # bumped on any flow-set change
        self.last_t = 0.0
        self._rates_key = None               # (generation, len) of the cache
        self._rates: Dict[int, float] = {}
        self.classes: Dict[Tuple[float, float], _FlowClass] = {}
        self._active = 0                     # classes with n > 0
        self._ntracked = 0                   # flows owned by a class
        self._total_w = 0                    # member streams, all classes
        self.cascade = None                  # sole fan-out window (engine opt)

    def _cap(self, tr: Any) -> float:
        return getattr(tr, "cap_gbps", None) or self.per_stream_gbps

    @staticmethod
    def _prio(tr: Any) -> float:
        """Water-filling priority weight: a flow with ``prio`` p claims p
        equal shares per member stream (default 1.0 — plain max-min).
        Lets latency-critical serving fetches keep a guaranteed fraction
        of a link they share with training bulk syncs."""
        return getattr(tr, "prio", 1.0) or 1.0

    def _tracked(self) -> bool:
        """True while every current flow was added via ``add_flow`` — the
        O(K) class accounting is valid. Flows injected directly into
        ``flows`` (tests, external tools) simply fall back to the
        materialized per-flow path."""
        return self._ntracked == len(self.flows) > 0

    # -- incremental flow-set maintenance (engine fast path) -----------------
    def add_flow(self, tr: Any, now: Optional[float] = None):
        """Register a flow in its ``(cap, prio)`` class. ``tr.remaining_gb``
        must be up to date (it is captured into the drain target here).
        Passing ``now`` advances the link first, so the capture is taken
        at the current instant. Returns the flow's class when the
        incremental path took it (None on the materialized fallback) —
        callers use it to re-key only that class's drain prediction."""
        if now is not None and now != self.last_t:
            if self._active == 1 and self._ntracked == len(self.flows):
                # single-class advance inline (identical arithmetic to
                # progress(); the one active class is found by scan, K≤2)
                for c in self.classes.values():
                    if c.n:
                        c.served += c.rate * (now - self.last_t)
                        break
                self.last_t = now
            else:
                self.progress(now)
        flows = self.flows
        was_tracked = not flows or self._ntracked == len(flows)
        fid = tr.fid
        flows[fid] = tr
        self.generation += 1
        w = tr.weight
        self._total_w += w
        if not (self.incremental and was_tracked):
            return                           # materialized fallback
        key = (tr.cap_gbps or self.per_stream_gbps, tr.prio or 1.0)
        c = self.classes.get(key)
        if c is None:
            c = self.classes[key] = _FlowClass(*key)
        if c.n == 0:
            self._active += 1
        c.n += 1
        c.w += w
        tgt = c.served + tr.remaining_gb
        c.target[fid] = tgt
        heapq.heappush(c.heap, (tgt, fid))
        self._ntracked += 1
        if self._active == 1:
            # single-class refresh inline: c is the one active class and
            # this is the classic processor-sharing formula (identical
            # arithmetic to _refresh_rates)
            c.rate = min(c.cap, self.aggregate_gbps / self._total_w)
        else:
            self._refresh_rates()
        return c

    def remove_flow(self, tr: Any, now: Optional[float] = None):
        """Drop a flow, materializing *its own* ``remaining_gb`` (pause /
        checkpoint paths read it). The rest of the flow set is untouched —
        no whole-set materialization."""
        if now is not None and now != self.last_t:
            self.progress(now)
        fid = tr.fid
        del self.flows[fid]
        self.generation += 1
        w = getattr(tr, "weight", 1)
        self._total_w -= w
        key = (self._cap(tr), self._prio(tr))
        c = self.classes.get(key)
        if c is None or fid not in c.target:
            return                           # untracked flow
        tgt = c.target.pop(fid)
        tr.remaining_gb = max(tgt - c.served, 0.0)
        self._ntracked -= 1
        c.n -= 1
        c.w -= w
        if c.n == 0:
            self._active -= 1
            c.heap.clear()
            c.pred_t = math.inf
            c.pred_id += 1                   # stale any pending prediction
        if self._active:
            self._refresh_rates()

    def _refresh_rates(self):
        """Recompute every active class's per-member rate (rates change
        exactly when the flow set does). O(K log K) worst case; the
        single-class common case is the classic processor-sharing
        formula, no sort."""
        agg = self.aggregate_gbps
        if self._active == 1:
            for c in self.classes.values():
                if c.n:
                    # equal priorities cancel in the proportional share
                    c.rate = min(c.cap, agg / self._total_w)
                    return
            return
        active = sorted((c for c in self.classes.values() if c.n),
                        key=_class_order)
        remaining = agg
        claims = sum(c.w * c.prio for c in active)
        for c in active:
            r = min(c.cap, c.prio * remaining / claims)
            c.rate = r
            remaining -= r * c.w
            claims -= c.w * c.prio

    def take_drained(self, eps_gb: float = 1e-12) -> List[Any]:
        """Pop and return every flow whose remainder is within ``eps_gb``
        of drained (``remaining_gb`` is zeroed/materialized). O(k log n)
        in class mode, O(n) in the materialized fallback."""
        out: List[Any] = []
        if self._tracked():
            for c in list(self.classes.values()):
                heap, target = c.heap, c.target
                while heap:
                    tgt, fid = heap[0]
                    if target.get(fid) != tgt:
                        heapq.heappop(heap)      # stale (removed/re-added)
                        continue
                    if tgt - c.served > eps_gb:
                        break
                    tr = self.flows[fid]
                    out.append(tr)
                    self.remove_flow(tr)
        else:
            out = [tr for tr in self.flows.values()
                   if tr.remaining_gb <= eps_gb]
            for tr in out:
                self.remove_flow(tr)
        return out

    def rates(self) -> Dict[int, float]:
        """Max-min fair (water-filling) rate per flow id. Visiting classes
        narrowest-cap first, each takes ``min(cap, share left)`` — a
        capped class's unused equal share waterfalls to the wider classes
        behind it. Rates only change when the flow set does (every
        mutation bumps ``generation``), so the allocation is cached per
        (generation, flow count).

        A flow may carry ``weight`` member streams (a coalesced worker
        cohort): it counts as ``weight`` equal claimants on the link and
        its returned rate is the **per-member** rate — exactly the
        allocation ``weight`` identical singleton flows would get. A flow
        may also carry ``prio`` (default 1.0): each of its member streams
        claims ``prio`` shares, so under contention it holds a
        ``prio``-weighted fraction of the aggregate (still bounded by its
        own cap, and still spilling unused share to the others).

        The materialized fallback (directly-injected flows) groups the
        flow set by ``(cap, prio)`` and runs the *same* class-sequence
        arithmetic, so class-mode and materialized rates are bit-equal
        for identical flow sets."""
        key = (self.generation, len(self.flows))
        if key == self._rates_key:
            return self._rates
        if self._tracked():
            classes = self.classes
            default_cap = self.per_stream_gbps
            out = {}
            for fid, tr in self.flows.items():
                k = (getattr(tr, "cap_gbps", None) or default_cap,
                     self._prio(tr))
                out[fid] = classes[k].rate
            self._rates_key, self._rates = key, out
            return out
        # materialized fallback: group by (cap, prio), then the identical
        # per-class water-filling sequence
        groups: Dict[Tuple[float, float], list] = {}
        default_cap = self.per_stream_gbps
        total_w = 0
        for tr in self.flows.values():
            k = (getattr(tr, "cap_gbps", None) or default_cap,
                 self._prio(tr))
            w = getattr(tr, "weight", 1)
            total_w += w
            g = groups.get(k)
            if g is None:
                groups[k] = [w, [tr.fid]]
            else:
                g[0] += w
                g[1].append(tr.fid)
        out = {}
        if len(groups) == 1:
            (cap0, _prio0), (_w, fids) = next(iter(groups.items()))
            r = min(cap0, self.aggregate_gbps / total_w)
            out = dict.fromkeys(fids, r)
        else:
            order = sorted(groups.items(),
                           key=lambda kv: (kv[0][0] / kv[0][1],
                                           kv[0][0], kv[0][1]))
            remaining = self.aggregate_gbps
            claims = sum(w * k[1] for k, (w, _f) in order)
            for (cap, prio), (w, fids) in order:
                r = min(cap, prio * remaining / claims)
                for fid in fids:
                    out[fid] = r
                remaining -= r * w
                claims -= w * prio
        self._rates_key, self._rates = key, out
        return out

    def next_completion_dt(self) -> float:
        """Time until the first flow drains at the current per-flow rates.
        (``remaining_gb`` is per member, as is the rate.)"""
        if self._tracked():
            best = math.inf
            for c in self.classes.values():
                if not c.n:
                    continue
                heap, target = c.heap, c.target
                while heap and target.get(heap[0][1]) != heap[0][0]:
                    heapq.heappop(heap)          # lazy-deleted entries
                dt = max(heap[0][0] - c.served, 0.0) / c.rate
                if dt < best:
                    best = dt
            return best
        rates = self.rates()
        return min(tr.remaining_gb / rates[tr.fid]
                   for tr in self.flows.values())

    def progress(self, now: float):
        """Advance all flows to ``now`` at the rates that held since the
        last flow-set change (rates only change when the set does). In
        class mode only the per-class virtual-work integrals advance —
        O(K) regardless of flow count."""
        dt = now - self.last_t
        if dt > 0 and self.flows:
            if self._ntracked == len(self.flows):
                for c in self.classes.values():
                    if c.n:
                        c.served += c.rate * dt
            else:
                rates = self.rates()
                for tr in self.flows.values():
                    tr.remaining_gb = max(
                        tr.remaining_gb - rates[tr.fid] * dt, 0.0)
        self.last_t = now


class ObjectStore:
    """S3-like object store."""

    def __init__(self, *, latency_s: float = 0.030,
                 per_stream_gbps: float = 0.090,   # ~90 MB/s per connection
                 aggregate_gbps: float = 100.0):
        self.latency_s = latency_s
        self.per_stream_gbps = per_stream_gbps
        self.aggregate_gbps = aggregate_gbps
        self.blobs: Dict[str, Any] = {}
        self.stats = TransferStats()

    def put_time(self, nbytes: float, concurrent: int = 1) -> float:
        bw = min(self.per_stream_gbps, self.aggregate_gbps / max(concurrent, 1))
        return self.latency_s + nbytes / 1e9 / bw

    def get_time(self, nbytes: float, concurrent: int = 1) -> float:
        return self.put_time(nbytes, concurrent)

    def put(self, key: str, value: Any, nbytes: Optional[float] = None):
        self.blobs[key] = value
        self.stats.puts += 1
        self.stats.bytes_in += nbytes or 0

    def get(self, key: str, nbytes: Optional[float] = None) -> Any:
        self.stats.gets += 1
        self.stats.bytes_out += nbytes or 0
        return self.blobs[key]

    def request_cost(self) -> float:
        return (self.stats.puts * S3_PUT_PER_1K / 1000.0
                + self.stats.gets * S3_GET_PER_1K / 1000.0)

    def link(self) -> SharedLink:
        """A contended-bandwidth view of this store for the event engine."""
        return SharedLink("object", self.aggregate_gbps,
                          self.per_stream_gbps, self.latency_s)


class ParamStore:
    """Redis-like in-memory KV store on an ECS container."""

    def __init__(self, *, latency_s: float = 0.0008,
                 node_gbps: float = 5.0,          # 40 Gbit/s ECS container
                 vcpus: float = 2.0, memory_gb: float = 8.0):
        self.latency_s = latency_s
        self.node_gbps = node_gbps
        self.vcpus = vcpus
        self.memory_gb = memory_gb
        self.blobs: Dict[str, Any] = {}
        self.stats = TransferStats()
        self.alive_seconds = 0.0   # only billed while synchronization runs

    def xfer_time(self, nbytes: float, concurrent: int = 1,
                  per_fn_gbps: float = 10.0) -> float:
        bw = min(per_fn_gbps, self.node_gbps / max(concurrent, 1))
        return self.latency_s + nbytes / 1e9 / bw

    def put(self, key: str, value: Any, nbytes: Optional[float] = None):
        self.blobs[key] = value
        self.stats.puts += 1
        self.stats.bytes_in += nbytes or 0

    def get(self, key: str, nbytes: Optional[float] = None) -> Any:
        self.stats.gets += 1
        self.stats.bytes_out += nbytes or 0
        return self.blobs[key]

    def keep_alive(self, seconds: float):
        self.alive_seconds += seconds

    def link(self, per_fn_gbps: float = 10.0) -> SharedLink:
        """A contended-bandwidth view of this store for the event engine."""
        return SharedLink("param", self.node_gbps, per_fn_gbps,
                          self.latency_s)

    def container_cost(self) -> float:
        hours = self.alive_seconds / 3600.0
        return hours * (self.vcpus * ECS_VCPU_HOUR
                        + self.memory_gb * ECS_GB_HOUR)
