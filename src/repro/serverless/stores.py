"""Hybrid storage models (paper Section 4.3).

 - ``ObjectStore``: S3-like. High per-request latency, wide aggregate
   bandwidth, priced per-request + per-GB-month. Holds code + training data
   (infrequent access).
 - ``ParamStore``: Redis-on-ECS-like. Sub-millisecond latency, node-limited
   bandwidth, priced per container-hour while alive. Holds per-iteration
   gradients/shards (frequent access). SMLT keeps it alive only during
   synchronization phases.

Both can also hold real payloads (numpy arrays) so the *semantic* training
path (real JAX workers) uses the same interfaces as the analytic simulator.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

# Pricing (us-east-1, 2022)
S3_PUT_PER_1K = 0.005
S3_GET_PER_1K = 0.0004
S3_GB_MONTH = 0.023
ECS_VCPU_HOUR = 0.04048
ECS_GB_HOUR = 0.004445


@dataclasses.dataclass
class TransferStats:
    puts: int = 0
    gets: int = 0
    bytes_in: float = 0.0
    bytes_out: float = 0.0


class SharedLink:
    """Water-filling processor-sharing bandwidth resource for the event
    engine.

    The analytic model divides a store's aggregate bandwidth by a static
    ``concurrent=n``; here, transfers that *actually overlap in time*
    share the link by max-min fair water-filling: flows are offered equal
    shares of the aggregate, a flow capped below its share (its own
    ``cap_gbps``, defaulting to the link's ``per_stream_gbps``) keeps only
    its cap, and the share it cannot use is redistributed across the
    remaining flows — the link is never idle while any uncapped flow is
    backlogged, and total throughput never exceeds
    ``min(aggregate, sum of caps)``. Rates are re-evaluated whenever a
    flow joins or leaves. With identical caps this reduces to the classic
    ``min(cap, aggregate / k)`` processor sharing. One link may be shared
    by *several* engines in a ``ContentionDomain`` — cross-job transfers
    then slow each other by their actual overlap. (Keep-alive billing is
    the engine's job: it tracks the union of time gradient-sync transfers
    are outstanding, across links.)"""

    def __init__(self, name: str, aggregate_gbps: float,
                 per_stream_gbps: float, latency_s: float):
        self.name = name
        self.aggregate_gbps = aggregate_gbps
        self.per_stream_gbps = per_stream_gbps
        self.latency_s = latency_s
        self.flows: Dict[int, Any] = {}      # fid -> transfer (remaining_gb)
        self.setup = 0                       # transfers in the latency phase
        self.generation = 0                  # bumped on any flow-set change
        self.last_t = 0.0
        self._rates_key = None               # (generation, len) of the cache
        self._rates: Dict[int, float] = {}

    def _cap(self, tr: Any) -> float:
        return getattr(tr, "cap_gbps", None) or self.per_stream_gbps

    def rates(self) -> Dict[int, float]:
        """Max-min fair (water-filling) rate per flow id. Visiting flows
        narrowest-cap first, each takes ``min(cap, remaining / flows
        left)`` — a capped flow's unused equal share waterfalls to the
        wider flows behind it. Rates only change when the flow set does
        (every mutation bumps ``generation``), so the allocation is
        cached per (generation, flow count)."""
        key = (self.generation, len(self.flows))
        if key == self._rates_key:
            return self._rates
        order = sorted(self.flows.values(), key=lambda tr: (self._cap(tr),
                                                            tr.fid))
        out: Dict[int, float] = {}
        remaining = self.aggregate_gbps
        left = len(order)
        for tr in order:
            r = min(self._cap(tr), remaining / left)
            out[tr.fid] = r
            remaining -= r
            left -= 1
        self._rates_key, self._rates = key, out
        return out

    def next_completion_dt(self) -> float:
        """Time until the first flow drains at the current per-flow rates."""
        rates = self.rates()
        return min(tr.remaining_gb / rates[tr.fid]
                   for tr in self.flows.values())

    def progress(self, now: float):
        """Advance all flows to ``now`` at the rates that held since the
        last flow-set change (rates only change when the set changes)."""
        dt = now - self.last_t
        if dt > 0 and self.flows:
            rates = self.rates()
            for tr in self.flows.values():
                tr.remaining_gb = max(tr.remaining_gb - rates[tr.fid] * dt,
                                      0.0)
        self.last_t = now


class ObjectStore:
    """S3-like object store."""

    def __init__(self, *, latency_s: float = 0.030,
                 per_stream_gbps: float = 0.090,   # ~90 MB/s per connection
                 aggregate_gbps: float = 100.0):
        self.latency_s = latency_s
        self.per_stream_gbps = per_stream_gbps
        self.aggregate_gbps = aggregate_gbps
        self.blobs: Dict[str, Any] = {}
        self.stats = TransferStats()

    def put_time(self, nbytes: float, concurrent: int = 1) -> float:
        bw = min(self.per_stream_gbps, self.aggregate_gbps / max(concurrent, 1))
        return self.latency_s + nbytes / 1e9 / bw

    def get_time(self, nbytes: float, concurrent: int = 1) -> float:
        return self.put_time(nbytes, concurrent)

    def put(self, key: str, value: Any, nbytes: Optional[float] = None):
        self.blobs[key] = value
        self.stats.puts += 1
        self.stats.bytes_in += nbytes or 0

    def get(self, key: str, nbytes: Optional[float] = None) -> Any:
        self.stats.gets += 1
        self.stats.bytes_out += nbytes or 0
        return self.blobs[key]

    def request_cost(self) -> float:
        return (self.stats.puts * S3_PUT_PER_1K / 1000.0
                + self.stats.gets * S3_GET_PER_1K / 1000.0)

    def link(self) -> SharedLink:
        """A contended-bandwidth view of this store for the event engine."""
        return SharedLink("object", self.aggregate_gbps,
                          self.per_stream_gbps, self.latency_s)


class ParamStore:
    """Redis-like in-memory KV store on an ECS container."""

    def __init__(self, *, latency_s: float = 0.0008,
                 node_gbps: float = 5.0,          # 40 Gbit/s ECS container
                 vcpus: float = 2.0, memory_gb: float = 8.0):
        self.latency_s = latency_s
        self.node_gbps = node_gbps
        self.vcpus = vcpus
        self.memory_gb = memory_gb
        self.blobs: Dict[str, Any] = {}
        self.stats = TransferStats()
        self.alive_seconds = 0.0   # only billed while synchronization runs

    def xfer_time(self, nbytes: float, concurrent: int = 1,
                  per_fn_gbps: float = 10.0) -> float:
        bw = min(per_fn_gbps, self.node_gbps / max(concurrent, 1))
        return self.latency_s + nbytes / 1e9 / bw

    def put(self, key: str, value: Any, nbytes: Optional[float] = None):
        self.blobs[key] = value
        self.stats.puts += 1
        self.stats.bytes_in += nbytes or 0

    def get(self, key: str, nbytes: Optional[float] = None) -> Any:
        self.stats.gets += 1
        self.stats.bytes_out += nbytes or 0
        return self.blobs[key]

    def keep_alive(self, seconds: float):
        self.alive_seconds += seconds

    def link(self, per_fn_gbps: float = 10.0) -> SharedLink:
        """A contended-bandwidth view of this store for the event engine."""
        return SharedLink("param", self.node_gbps, per_fn_gbps,
                          self.latency_s)

    def container_cost(self) -> float:
        hours = self.alive_seconds / 3600.0
        return hours * (self.vcpus * ECS_VCPU_HOUR
                        + self.memory_gb * ECS_GB_HOUR)
