"""SMLT worker model (paper Section 4.2).

Two execution paths share the same interfaces:

 - **Analytic path** (paper-scale models, e.g. BERT-medium x 200 workers):
   per-iteration compute/communication times from a calibrated workload
   model. The communication schedule is a ``repro.core.comm.CommPlan``
   priced in closed form with per-phase fan-in contention. This is what
   the paper-figure benchmarks use.
 - **Semantic path** (``LocalWorkerPool``): n logical workers each compute
   real JAX gradients on their minibatch slice and synchronize through the
   (simulated) stores with real numpy payloads — the plan's *strategy*
   selects matching numerics (shard aggregation, tree means, top-k +
   error-feedback sparse sync), used by tests/examples to prove the
   synchronization is exactly equivalent to full-batch all-reduce.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rng import base_stream
from repro.core.comm import (CommLike, CommPlan, CommSpec, build_plan,
                             overlap_iteration_time, plan_times)
from repro.serverless.backends import BackendLike, resolve_backend
from repro.serverless.platform import FleetSpec, fn_gflops, fn_net_gbps
from repro.serverless.stores import ObjectStore, ParamStore

# ---------------------------------------------------------------------------
# analytic workload model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Workload:
    """Calibrated description of one training task (paper Section 5.1)."""
    name: str
    param_count: int
    flops_per_sample: float          # fwd+bwd FLOPs per training sample
    sample_bytes: float              # bytes of one training sample
    dataset_samples: int
    extra_upload_bytes: float = 0.0  # e.g. Atari RL simulation data

    @property
    def grad_bytes(self) -> float:
        return 4.0 * self.param_count  # f32 gradients


# Paper benchmark models (Section 5.1)
WORKLOADS = {
    "resnet18": Workload("resnet18", 11_000_000, 5.4e9, 150e3, 1_281_167),
    "resnet50": Workload("resnet50", 23_000_000, 12.0e9, 150e3, 1_281_167),
    "bert-small": Workload("bert-small", 66_000_000, 5.1e10, 2_048, 1_000_000),
    "bert-medium": Workload("bert-medium", 110_000_000, 8.4e10, 2_048, 1_000_000),
    "atari-rl": Workload("atari-rl", 50_000_000, 4.0e10, 33_600, 50_000_000,
                         extra_upload_bytes=4.0 * 50_000_000),
}


def compute_time(w: Workload, local_batch: float, memory_mb: float,
                 gflops: Optional[float] = None) -> float:
    """Per-iteration compute seconds; ``gflops`` overrides the
    memory-derived function rate (VM/GPU backends have flat rates)."""
    rate = gflops if gflops is not None else fn_gflops(memory_mb)
    return w.flops_per_sample * local_batch / (rate * 1e9)


def fleet_local_batches(fleet: FleetSpec, global_batch: int) -> List[float]:
    """Load-aware shard placement: the global batch splits in proportion
    to each worker's compute rate, so every worker's compute time is the
    same ``flops * global_batch / sum(rates)`` — the mixed fleet stops
    paying the bsp barrier at its slowest worker's *compute* (network
    caps remain per-worker). Exactly the equal split for homogeneous
    fleets."""
    rates = [fn_gflops(m) for m in fleet.memories]
    total = sum(rates)
    return [global_batch * r / total for r in rates]


def comm_breakdown(scheme: CommLike, grad_bytes: float, n_workers: int,
                   memory_mb: float, param_store: ParamStore,
                   object_store: ObjectStore,
                   n_shards: Optional[int] = None,
                   extra_upload_bytes: float = 0.0,
                   topk_ratio: float = 0.05,
                   fn_net_override_gbps: Optional[float] = None
                   ) -> Dict[str, float]:
    """Static per-phase times of the communication plan. Each phase runs
    with its own ``fan_in`` workers contending (the event engine relaxes
    this to *actual* overlap). ``fn_net_override_gbps`` replaces the
    memory-derived per-function bandwidth — the mixed-fleet approximation
    passes the *narrowest* worker's pipe (a barriered exchange is bound
    by it)."""
    fn_net = (fn_net_override_gbps if fn_net_override_gbps is not None
              else fn_net_gbps(memory_mb))
    fn_bw = fn_net * 8  # not a bottleneck vs store; keep wide
    plan = build_plan(scheme, grad_bytes, n_workers, n_shards=n_shards,
                      extra_upload_bytes=extra_upload_bytes,
                      topk_ratio=topk_ratio)
    times, _busy = plan_times(plan, param_store, object_store, fn_bw)
    return times


def iteration_time(w: Workload, scheme: CommLike, n_workers: int,
                   memory_mb: float, global_batch: int,
                   param_store: ParamStore, object_store: ObjectStore, *,
                   fleet: Optional[FleetSpec] = None,
                   backend: BackendLike = None) -> Dict[str, float]:
    """Closed-form per-iteration time. With a ``fleet``, the mixed-memory
    approximation the Bayesian optimizer probes with: load-aware batch
    placement makes compute ``flops * batch / sum(worker rates)`` (exact,
    since every worker finishes its proportional slice together), while
    synchronization keeps the min-bandwidth bound (narrowest worker's
    pipe). Besides ``compute``/``comm``/``total`` and the per-phase
    entries, the breakdown carries ``store_busy`` — the seconds the
    param store is held by transfers (the keep-alive billing basis,
    which excludes any decompress CPU in ``comm`` and every
    object-store phase). A pipelined plan (``pipeline_depth > 1``)
    prices the iteration as ``max(compute, hidden comm) + exposed comm
    + bubble`` — the overlappable uploads hide under segmented compute
    — and reports the split under ``comm_hidden`` / ``comm_exposed`` /
    ``bubble`` (``comm`` stays the total communication *work*, hidden
    or not; ``store_busy`` is likewise unchanged by overlap, since a
    hidden transfer still holds the store)."""
    n_workers = len(fleet) if fleet is not None else n_workers
    spec = resolve_backend(backend)
    if spec is not None:
        # VM-kind backend: a flat per-worker compute rate and NIC make
        # the fleet homogeneous regardless of the memory tiers
        local_batch = max(global_batch // n_workers, 1)
        comp = compute_time(w, local_batch, memory_mb,
                            gflops=spec.gflops_for(memory_mb))
        net_override = spec.net_gbps_for(memory_mb)
    elif fleet is None or fleet.is_homogeneous:
        mem = fleet.memories[0] if fleet is not None else memory_mb
        local_batch = max(global_batch // n_workers, 1)
        comp = compute_time(w, local_batch, mem)
        net_override = None if fleet is None else fleet.min_net_gbps()
    else:
        comp = (w.flops_per_sample * global_batch
                / (fleet.gflops_total() * 1e9))
        net_override = fleet.min_net_gbps()
    fn_net = (net_override if net_override is not None
              else fn_net_gbps(memory_mb))
    plan = build_plan(scheme, w.grad_bytes, n_workers,
                      extra_upload_bytes=w.extra_upload_bytes)
    comm, store_busy = plan_times(plan, param_store, object_store, fn_net * 8)
    hidden_names = {ph.name for ph in plan.overlappable_phases}
    hidden = sum(t for name, t in comm.items() if name in hidden_names)
    exposed = sum(comm.values()) - hidden
    ov = overlap_iteration_time(comp, hidden, exposed, plan.pipeline_depth)
    return {"compute": comp, "comm": sum(comm.values()),
            "total": ov["total"], "store_busy": store_busy,
            "comm_hidden": ov["comm_hidden"],
            "comm_exposed": ov["comm_exposed"], "bubble": ov["bubble"],
            **comm}


# ---------------------------------------------------------------------------
# gradient sharding math (shared by simulator + semantic path + tests)
# ---------------------------------------------------------------------------


def flatten_grads(grads) -> np.ndarray:
    leaves = jax.tree.leaves(grads)
    return np.concatenate([np.asarray(x, dtype=np.float32).ravel()
                           for x in leaves])


def unflatten_grads(flat: np.ndarray, grads_like):
    leaves, treedef = jax.tree.flatten(grads_like)
    out, off = [], 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        out.append(flat[off:off + n].reshape(leaf.shape).astype(leaf.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def make_shards(flat: np.ndarray, m: int) -> List[np.ndarray]:
    """Split a flat gradient into m near-equal shards (shard generator, Fig 5)."""
    pad = (-len(flat)) % m
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, flat.dtype)])
    return list(flat.reshape(m, -1))


def join_shards(shards: List[np.ndarray], size: int) -> np.ndarray:
    return np.concatenate(shards)[:size]


def parse_sync_mode(sync_mode: str, staleness: int = 0):
    """Parse ``"bsp" | "ssp" | "ssp(k)" | "async"`` into (mode, bound).

    bsp is ssp with bound 0; async is ssp with an unbounded window."""
    m = sync_mode.strip().lower()
    if m.startswith("ssp(") and m.endswith(")"):
        return "ssp", int(m[4:-1])
    if m == "bsp":
        return "bsp", 0
    if m == "ssp":
        return "ssp", staleness
    if m == "async":
        return "async", None
    raise ValueError(f"sync_mode {sync_mode!r}")


class LocalWorkerPool:
    """Semantic SMLT: n logical workers with real JAX grads, synchronizing
    via the (simulated) param store under a ``CommPlan``.

    ``plan`` (a ``CommPlan``, ``CommSpec``, or legacy scheme string)
    selects the synchronization *numerics* to match what the simulator
    prices:
      - ``scatter_reduce`` (default; legacy scheme "hier"): workers shard
        their gradients, worker j aggregates shard j from everyone and
        re-uploads it, exactly as Figure 5 prescribes.
      - ``ps``: every worker uploads its full gradient; the mean is taken
        over all n full gradients (Cirrus/Siren pattern).
      - ``hier``: partial sums reduce up a ``branching``-ary tree of
        group aggregators; the root mean is redistributed.
      - a compressed plan (``ratio < 1``): workers upload top-k sparse
        gradients with per-worker error feedback
        (``repro.core.compression``); the aggregator sums the sparse
        contributions. ``ratio=1.0`` keeps every entry — numerically the
        dense mean.
      - a pipelined plan (``pipeline_depth > 1``): each worker computes
        its slice as micro-batched gradient accumulation — the schedule
        the simulator overlaps with the uploads; the weighted
        per-segment mean equals the full-slice gradient, so overlap
        never changes the numerics.

    ``use_kernel=True`` runs the shard aggregation (step 3 of Fig. 5)
    through the Pallas ``hier_agg`` kernel instead of numpy.

    ``sync_mode`` selects the staleness semantics that mirror the event
    engine's timing modes (``repro.serverless.events``):
      - "bsp": every worker's gradient is computed at the current params
        (exactly equivalent to full-batch all-reduce; the seed behavior).
      - "ssp(k)": worker w refreshes its param snapshot every k+1 steps
        (staggered by worker id), so gradients are computed at params at
        most k versions stale — the bounded-staleness numerics.
      - "async": workers refresh on an independent seeded schedule with no
        bound (geometric gaps), the fully-asynchronous numerics.
    """

    def __init__(self, grad_fn: Callable, n_workers: int,
                 param_store: ParamStore, *, use_kernel: bool = False,
                 plan: Optional[CommLike] = None,
                 sync_mode: str = "bsp", staleness: int = 0, seed: int = 0,
                 async_refresh_p: float = 0.5):
        self.grad_fn = grad_fn
        self.n = n_workers
        self.store = param_store
        self.use_kernel = use_kernel
        # the pool only consumes the plan's strategy/ratio/branching and
        # accounts store bytes from the real payloads it moves, so specs
        # and scheme strings bind to a token-size plan (grad bytes are
        # only known per step); a prebuilt plan is taken as-is
        if isinstance(plan, CommPlan):
            if plan.n_workers != n_workers:
                raise ValueError(f"plan built for n={plan.n_workers}, "
                                 f"pool has n={n_workers}")
            self.plan = plan
        else:
            self.plan = build_plan(plan if plan is not None else "hier",
                                   1.0, n_workers)
        self.mode, self.staleness = parse_sync_mode(sync_mode, staleness)
        self.async_refresh_p = async_refresh_p
        self._rng = base_stream(seed)
        self._iter = 0
        self._snaps: List = [None] * n_workers    # stale param snapshots
        self._vers = [0] * n_workers
        self._ef: Dict[int, "ErrorFeedback"] = {}  # compressed path only

    def _worker_params(self, w: int, params):
        """The (possibly stale) params worker ``w`` computes gradients at."""
        if self.mode == "bsp":
            return params
        if self._snaps[w] is None:
            refresh = True
        elif self.mode == "ssp":
            k = self.staleness
            # staggered refresh every k+1 steps; the gap never exceeds k
            refresh = ((self._iter + w) % (k + 1) == 0
                       or self._iter - self._vers[w] > k)
        else:                                      # async: unbounded gaps
            refresh = self._rng.random_sample() < self.async_refresh_p
        if refresh:
            self._snaps[w] = params
            self._vers[w] = self._iter
        return self._snaps[w]

    def _slice_grad(self, params, sl):
        """One worker's gradient on its batch slice. A pipelined plan
        (``pipeline_depth > 1``) computes it as micro-batched gradient
        accumulation — the schedule the simulator overlaps with uploads:
        per-segment gradients are combined with segment-size weights,
        which for a per-batch-mean loss *is* the full-slice gradient, so
        overlap changes the timing model and never the numerics."""
        d = self.plan.pipeline_depth
        rows = jax.tree.leaves(sl)[0].shape[0]
        if d <= 1 or rows < 2:
            return self.grad_fn(params, sl)
        d = min(d, rows)
        bounds = [round(i * rows / d) for i in range(d + 1)]
        acc, total = None, 0
        for a, b in zip(bounds, bounds[1:]):
            if b <= a:
                continue
            micro = jax.tree.map(lambda x: x[a:b], sl)
            g = self.grad_fn(params, micro)
            wgt = float(b - a)
            if acc is None:
                acc = jax.tree.map(lambda x: np.asarray(x, np.float32) * wgt,
                                   g)
            else:
                acc = jax.tree.map(
                    lambda s, x: s + np.asarray(x, np.float32) * wgt, acc, g)
            total += wgt
        return jax.tree.map(lambda s: s / total, acc)

    def _worker_grads(self, params, global_batch):
        """Each worker's flat gradient on its batch slice (stale-aware)."""
        n = self.n
        flats, g_like = [], None
        for w in range(n):
            sl = jax.tree.map(
                lambda x: x[w * (x.shape[0] // n):(w + 1) * (x.shape[0] // n)],
                global_batch)
            g = self._slice_grad(self._worker_params(w, params), sl)
            flats.append(flatten_grads(g))
            g_like = g
        return flats, g_like

    def step(self, params, global_batch) -> Dict:
        """global_batch: dict of arrays with leading dim divisible by n.
        Returns the aggregated (mean) gradient pytree."""
        if self.plan.ratio < 1.0:
            mean_flat, g_like = self._step_compressed(params, global_batch)
        elif self.plan.strategy == "ps":
            mean_flat, g_like = self._step_ps(params, global_batch)
        elif self.plan.strategy == "hier":
            mean_flat, g_like = self._step_hier(params, global_batch)
        else:
            mean_flat, g_like = self._step_scatter_reduce(params,
                                                          global_batch)
        self._iter += 1
        return unflatten_grads(mean_flat, g_like)

    # -- strategy numerics ---------------------------------------------------
    def _step_scatter_reduce(self, params, global_batch):
        n = self.n
        flats, g_like = self._worker_grads(params, global_batch)
        flat_size = len(flats[0])
        # (1) each worker shards its gradient and uploads the shards
        for w, flat in enumerate(flats):
            for j, s in enumerate(make_shards(flat, n)):
                self.store.put(f"shard/{w}/{j}", s, nbytes=s.nbytes)
        # (2) worker j aggregates shard j from all workers (mean), re-uploads
        for j in range(n):
            stacked = np.stack([self.store.get(f"shard/{w}/{j}")
                                for w in range(n)])
            if self.use_kernel:
                from repro.kernels import ops as kops
                agg = np.asarray(kops.aggregate_shards(jnp.asarray(stacked)))
            else:
                agg = stacked.mean(axis=0)
            self.store.put(f"aggr/{j}", agg, nbytes=agg.nbytes)
        # (3) every worker downloads all aggregated shards -> updated model;
        # they are identical, so reconstruct once.
        agg = [self.store.get(f"aggr/{j}") for j in range(n)]
        return join_shards(agg, flat_size), g_like

    def _step_ps(self, params, global_batch):
        n = self.n
        flats, g_like = self._worker_grads(params, global_batch)
        for w, flat in enumerate(flats):
            self.store.put(f"grad/{w}", flat, nbytes=flat.nbytes)
        acc = np.zeros(len(flats[0]), np.float32)
        for w in range(n):
            acc += self.store.get(f"grad/{w}", nbytes=flats[w].nbytes)
        return acc / n, g_like

    def _step_hier(self, params, global_batch):
        """Tree aggregation: partial sums reduce level by level through
        the store; the root's sum / n is the exact global mean."""
        n, b = self.n, max(self.plan.branching or 4, 2)
        flats, g_like = self._worker_grads(params, global_batch)
        nbytes = flats[0].nbytes
        partials = list(flats)                   # level-0 partial sums
        lvl = 0
        while len(partials) > 1:
            lvl += 1
            for i, p in enumerate(partials):
                self.store.put(f"hier/{lvl}/{i}", p, nbytes=nbytes)
            nxt = []
            for g0 in range(0, len(partials), b):
                members = range(g0, min(g0 + b, len(partials)))
                nxt.append(sum(self.store.get(f"hier/{lvl}/{i}",
                                              nbytes=nbytes)
                               for i in members))
            partials = nxt
        root = partials[0]
        self.store.put("hier/root", root, nbytes=nbytes)
        return self.store.get("hier/root", nbytes=nbytes) / n, g_like

    def _step_compressed(self, params, global_batch):
        """Top-k + error feedback: each worker uploads only its k largest
        (corrected) entries; the aggregator sums sparse contributions.
        Wire bytes follow the plan's compressed model (value + index)."""
        from repro.core.compression import ErrorFeedback, compressed_bytes
        n, ratio = self.n, self.plan.ratio
        flats, g_like = self._worker_grads(params, global_batch)
        size = len(flats[0])
        for w, flat in enumerate(flats):
            if w not in self._ef:
                self._ef[w] = ErrorFeedback.init(size)
            idx, vals = self._ef[w].compress(flat, ratio)
            self.store.put(f"sparse/{w}", (idx, vals),
                           nbytes=compressed_bytes(size, ratio))
        acc = np.zeros(size, np.float32)
        for w in range(n):
            idx, vals = self.store.get(
                f"sparse/{w}", nbytes=compressed_bytes(size, ratio))
            acc[idx] += vals
        return acc / n, g_like
