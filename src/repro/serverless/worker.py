"""SMLT worker model (paper Section 4.2).

Two execution paths share the same interfaces:

 - **Analytic path** (paper-scale models, e.g. BERT-medium x 200 workers):
   per-iteration compute/communication times from a calibrated workload
   model. This is what the paper-figure benchmarks use.
 - **Semantic path** (``LocalWorkerPool``): n logical workers each compute
   real JAX gradients on their minibatch slice and synchronize through the
   (simulated) stores with real numpy payloads — used by tests/examples to
   prove the hierarchical synchronization is exactly equivalent to
   full-batch all-reduce.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serverless.platform import FleetSpec, fn_gflops, fn_net_gbps
from repro.serverless.stores import ObjectStore, ParamStore

# ---------------------------------------------------------------------------
# analytic workload model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Workload:
    """Calibrated description of one training task (paper Section 5.1)."""
    name: str
    param_count: int
    flops_per_sample: float          # fwd+bwd FLOPs per training sample
    sample_bytes: float              # bytes of one training sample
    dataset_samples: int
    extra_upload_bytes: float = 0.0  # e.g. Atari RL simulation data

    @property
    def grad_bytes(self) -> float:
        return 4.0 * self.param_count  # f32 gradients


# Paper benchmark models (Section 5.1)
WORKLOADS = {
    "resnet18": Workload("resnet18", 11_000_000, 5.4e9, 150e3, 1_281_167),
    "resnet50": Workload("resnet50", 23_000_000, 12.0e9, 150e3, 1_281_167),
    "bert-small": Workload("bert-small", 66_000_000, 5.1e10, 2_048, 1_000_000),
    "bert-medium": Workload("bert-medium", 110_000_000, 8.4e10, 2_048, 1_000_000),
    "atari-rl": Workload("atari-rl", 50_000_000, 4.0e10, 33_600, 50_000_000,
                         extra_upload_bytes=4.0 * 50_000_000),
}


def compute_time(w: Workload, local_batch: int, memory_mb: float) -> float:
    return w.flops_per_sample * local_batch / (fn_gflops(memory_mb) * 1e9)


@dataclasses.dataclass(frozen=True)
class CommPhase:
    """One per-worker communication step of an iteration.

    Shared between the analytic model (``comm_breakdown`` sums static phase
    times) and the event engine (``repro.serverless.events`` turns each
    phase into a contended transfer on the store's SharedLink).
    """
    name: str
    store: str                 # "param" | "object"
    nbytes: float              # bytes moved by one (busiest) worker
    requests: int = 1          # store round-trips -> latency multiplier
    barrier_after: bool = False  # bsp data dependency (engine only)


def comm_plan(scheme: str, grad_bytes: float, n_workers: int,
              n_shards: Optional[int] = None,
              extra_upload_bytes: float = 0.0,
              topk_ratio: float = 0.05) -> List[CommPhase]:
    """Per-iteration communication phases (paper Figs. 5 and 7).

    schemes:
      "hier"      — SMLT: shard -> aggregate -> redistribute via param store.
      "hier_topk" — hier + top-k/error-feedback compressed uploads
                    (beyond-paper; see repro.core.compression): upload
                    bytes scale by 2*ratio (value+index per kept entry);
                    the aggregated download densifies as min(1, n*ratio).
      "ps"        — Cirrus-style central store (every worker downloads
                    everyone's gradients).
      "ps_s3"     — Siren-style: same pattern through the object store.
    """
    n = n_workers
    m = n_shards or n
    G = grad_bytes + extra_upload_bytes

    if scheme == "hier_topk":
        up = 2.0 * topk_ratio            # (4B value + 4B index) / 4B dense
        dense_dl = min(1.0, n * topk_ratio)
        return [
            CommPhase("UL-Shard", "param", G * up, m, barrier_after=True),
            CommPhase("DL-Shard", "param", n * G * up / m, n),
            CommPhase("UL-aggr", "param", G * dense_dl / m, 1,
                      barrier_after=True),
            CommPhase("DL-grad", "param", G * dense_dl, m),
        ]
    if scheme == "hier":
        # each of the busiest aggregators owns ceil(m/n) shards; with m < n
        # the n-m idle workers don't help and the busy ones pull n*G/m
        # (paper footnote 4: "m less than n will cause some workers to be
        # idle during aggregation, which will affect performance")
        spa = max(math.ceil(m / n), 1)
        return [
            CommPhase("UL-Shard", "param", G, m,          # own grad, m shards
                      barrier_after=True),
            CommPhase("DL-Shard", "param", spa * n * (G / m),
                      spa * n),                           # collect owned shards
            CommPhase("UL-aggr", "param", spa * G / m, spa,
                      barrier_after=True),
            CommPhase("DL-grad", "param", m * (G / m), m),  # all agg shards
        ]
    if scheme == "ps":
        return [CommPhase("UL-grad", "param", G, 1, barrier_after=True),
                CommPhase("DL-grad", "param", n * G, 1)]
    if scheme == "ps_s3":
        return [CommPhase("UL-grad", "object", G, 1, barrier_after=True),
                CommPhase("DL-grad", "object", n * G, 1)]
    raise ValueError(scheme)


def comm_breakdown(scheme: str, grad_bytes: float, n_workers: int,
                   memory_mb: float, param_store: ParamStore,
                   object_store: ObjectStore,
                   n_shards: Optional[int] = None,
                   extra_upload_bytes: float = 0.0,
                   topk_ratio: float = 0.05,
                   fn_net_override_gbps: Optional[float] = None
                   ) -> Dict[str, float]:
    """Static per-phase times: every phase is assumed to run with all n
    workers contending (the event engine relaxes this to *actual* overlap).
    ``fn_net_override_gbps`` replaces the memory-derived per-function
    bandwidth — the mixed-fleet approximation passes the *narrowest*
    worker's pipe (a barriered exchange is bound by it)."""
    n = n_workers
    fn_net = (fn_net_override_gbps if fn_net_override_gbps is not None
              else fn_net_gbps(memory_mb))
    fn_bw = fn_net * 8  # not a bottleneck vs store; keep wide
    out: Dict[str, float] = {}
    for ph in comm_plan(scheme, grad_bytes, n, n_shards=n_shards,
                        extra_upload_bytes=extra_upload_bytes,
                        topk_ratio=topk_ratio):
        if ph.store == "param":
            out[ph.name] = (param_store.xfer_time(ph.nbytes, concurrent=n,
                                                  per_fn_gbps=fn_bw)
                            + param_store.latency_s * max(ph.requests - 1, 0))
        else:
            out[ph.name] = (object_store.put_time(ph.nbytes, concurrent=n)
                            + object_store.latency_s * max(ph.requests - 1, 0))
    return out


def iteration_time(w: Workload, scheme: str, n_workers: int, memory_mb: float,
                   global_batch: int, param_store: ParamStore,
                   object_store: ObjectStore, *,
                   fleet: Optional[FleetSpec] = None) -> Dict[str, float]:
    """Closed-form per-iteration time. With a ``fleet``, the mixed-memory
    approximation the Bayesian optimizer probes with: compute at the
    weighted-harmonic per-worker rate (exact for identical memories),
    synchronization at the min-bandwidth bound (narrowest worker's pipe).
    """
    n_workers = len(fleet) if fleet is not None else n_workers
    local_batch = max(global_batch // n_workers, 1)
    if fleet is None:
        comp = compute_time(w, local_batch, memory_mb)
        net_override = None
    else:
        comp = w.flops_per_sample * local_batch / (fleet.gflops_harmonic()
                                                   * 1e9)
        net_override = fleet.min_net_gbps()
    comm = comm_breakdown(scheme, w.grad_bytes, n_workers, memory_mb,
                          param_store, object_store,
                          extra_upload_bytes=w.extra_upload_bytes,
                          fn_net_override_gbps=net_override)
    return {"compute": comp, "comm": sum(comm.values()),
            "total": comp + sum(comm.values()), **comm}


# ---------------------------------------------------------------------------
# gradient sharding math (shared by simulator + semantic path + tests)
# ---------------------------------------------------------------------------


def flatten_grads(grads) -> np.ndarray:
    leaves = jax.tree.leaves(grads)
    return np.concatenate([np.asarray(x, dtype=np.float32).ravel()
                           for x in leaves])


def unflatten_grads(flat: np.ndarray, grads_like):
    leaves, treedef = jax.tree.flatten(grads_like)
    out, off = [], 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        out.append(flat[off:off + n].reshape(leaf.shape).astype(leaf.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def make_shards(flat: np.ndarray, m: int) -> List[np.ndarray]:
    """Split a flat gradient into m near-equal shards (shard generator, Fig 5)."""
    pad = (-len(flat)) % m
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, flat.dtype)])
    return list(flat.reshape(m, -1))


def join_shards(shards: List[np.ndarray], size: int) -> np.ndarray:
    return np.concatenate(shards)[:size]


def parse_sync_mode(sync_mode: str, staleness: int = 0):
    """Parse ``"bsp" | "ssp" | "ssp(k)" | "async"`` into (mode, bound).

    bsp is ssp with bound 0; async is ssp with an unbounded window."""
    m = sync_mode.strip().lower()
    if m.startswith("ssp(") and m.endswith(")"):
        return "ssp", int(m[4:-1])
    if m == "bsp":
        return "bsp", 0
    if m == "ssp":
        return "ssp", staleness
    if m == "async":
        return "async", None
    raise ValueError(f"sync_mode {sync_mode!r}")


class LocalWorkerPool:
    """Semantic SMLT: n logical workers with real JAX grads, synchronizing
    via the (simulated) param store exactly as Figure 5 prescribes.

    ``use_kernel=True`` runs the shard aggregation (step 3 of Fig. 5)
    through the Pallas ``hier_agg`` kernel instead of numpy.

    ``sync_mode`` selects the staleness semantics that mirror the event
    engine's timing modes (``repro.serverless.events``):
      - "bsp": every worker's gradient is computed at the current params
        (exactly equivalent to full-batch all-reduce; the seed behavior).
      - "ssp(k)": worker w refreshes its param snapshot every k+1 steps
        (staggered by worker id), so gradients are computed at params at
        most k versions stale — the bounded-staleness numerics.
      - "async": workers refresh on an independent seeded schedule with no
        bound (geometric gaps), the fully-asynchronous numerics.
    """

    def __init__(self, grad_fn: Callable, n_workers: int,
                 param_store: ParamStore, *, use_kernel: bool = False,
                 sync_mode: str = "bsp", staleness: int = 0, seed: int = 0,
                 async_refresh_p: float = 0.5):
        self.grad_fn = grad_fn
        self.n = n_workers
        self.store = param_store
        self.use_kernel = use_kernel
        self.mode, self.staleness = parse_sync_mode(sync_mode, staleness)
        self.async_refresh_p = async_refresh_p
        self._rng = np.random.RandomState(seed)
        self._iter = 0
        self._snaps: List = [None] * n_workers    # stale param snapshots
        self._vers = [0] * n_workers

    def _worker_params(self, w: int, params):
        """The (possibly stale) params worker ``w`` computes gradients at."""
        if self.mode == "bsp":
            return params
        if self._snaps[w] is None:
            refresh = True
        elif self.mode == "ssp":
            k = self.staleness
            # staggered refresh every k+1 steps; the gap never exceeds k
            refresh = ((self._iter + w) % (k + 1) == 0
                       or self._iter - self._vers[w] > k)
        else:                                      # async: unbounded gaps
            refresh = self._rng.random_sample() < self.async_refresh_p
        if refresh:
            self._snaps[w] = params
            self._vers[w] = self._iter
        return self._snaps[w]

    def step(self, params, global_batch) -> Dict:
        """global_batch: dict of arrays with leading dim divisible by n.
        Returns the aggregated (mean) gradient pytree."""
        n = self.n
        shards_meta = None
        # (1) each worker computes grads on its slice, shards, uploads
        for w in range(n):
            sl = jax.tree.map(
                lambda x: x[w * (x.shape[0] // n):(w + 1) * (x.shape[0] // n)],
                global_batch)
            g = self.grad_fn(self._worker_params(w, params), sl)
            flat = flatten_grads(g)
            shards = make_shards(flat, n)
            shards_meta = (len(flat), g)
            for j, s in enumerate(shards):
                self.store.put(f"shard/{w}/{j}", s, nbytes=s.nbytes)
        # (2) worker j aggregates shard j from all workers (mean), re-uploads
        for j in range(self.n):
            stacked = np.stack([self.store.get(f"shard/{w}/{j}")
                                for w in range(n)])
            if self.use_kernel:
                from repro.kernels import ops as kops
                agg = np.asarray(kops.aggregate_shards(jnp.asarray(stacked)))
            else:
                agg = stacked.mean(axis=0)
            self.store.put(f"aggr/{j}", agg, nbytes=agg.nbytes)
        # (3) every worker downloads all aggregated shards -> updated model;
        # they are identical, so reconstruct once.
        flat_size, g_like = shards_meta
        agg = [self.store.get(f"aggr/{j}") for j in range(n)]
        mean_flat = join_shards(agg, flat_size)
        self._iter += 1
        return unflatten_grads(mean_flat, g_like)
