from repro.serving.batcher import (  # noqa: F401
    BatchRecord, ServePolicy, ServeStats, exec_time, optimize_policy,
    simulate)
from repro.serving.engine import Completion, Request, ServingEngine  # noqa: F401
