"""SLO-aware adaptive batching for serverless inference.

The SMLT authors' companion system (BATCH [17], Ali et al. SC'20) shows
serverless inference wants *adaptive batching*: invoke one function per
batch, choosing (max batch size B, batching timeout tau) to meet a latency
SLO at minimum GB-second cost. This module reproduces that control loop on
our serverless cost substrate:

 - a discrete-event queue simulator (Poisson arrivals, linear-in-batch
   execution model calibrated like Lambda),
 - a policy optimizer: grid/Bayesian search over (B, tau, memory) for
   min cost s.t. p99 latency <= SLO — the serving twin of the paper's
   Scenario 1.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.rng import base_stream
from repro.serverless.platform import LAMBDA_GB_SECOND, LAMBDA_PER_REQUEST, fn_gflops


@dataclasses.dataclass(frozen=True)
class ServePolicy:
    max_batch: int
    timeout_s: float
    memory_mb: int


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """One launched batch: requests ``[i, j)`` started executing at
    ``start`` and finished at ``done``. ``free`` is when the server came
    free for this batch (the previous batch's ``done``): the launch-wait
    invariant is ``start <= max(arrival[i] + timeout_s, free)``."""
    i: int
    j: int
    free: float
    start: float
    done: float


@dataclasses.dataclass
class ServeStats:
    p50_s: float
    p99_s: float
    cost_per_1k: float
    batches: int
    requests: int
    mean_batch: float
    records: Optional[List[BatchRecord]] = None


def exec_time(flops_per_request: float, batch: int, memory_mb: int,
              init_s: float = 0.15) -> float:
    """Serverless inference execution: fixed init + linear in batch."""
    return init_s + flops_per_request * batch / (fn_gflops(memory_mb) * 1e9)


def simulate(policy: ServePolicy, *, arrival_rate: float,
             flops_per_request: float, horizon_s: float = 600.0,
             seed: int = 0, arrivals: Optional[np.ndarray] = None,
             keep_records: bool = False) -> ServeStats:
    """Single-server batching queue: a batch launches when it reaches
    max_batch, the oldest queued request has waited timeout_s since it
    *arrived* (not since the server came free — a request already past
    its timeout launches the moment the server does), or the arrival
    stream is exhausted (a final partial batch never waits out a timeout
    no future arrival can fill).

    ``arrivals`` overrides the Poisson stream with explicit sorted
    timestamps (used by the event-engine parity test); ``keep_records``
    attaches per-batch :class:`BatchRecord` rows to the returned stats.
    """
    if arrivals is None:
        rng = base_stream(seed)
        n = max(int(arrival_rate * horizon_s), 1)
        arrivals = np.sort(rng.uniform(0.0, horizon_s, size=n))
    latencies: List[float] = []
    records: List[BatchRecord] = []
    gb_s = 0.0
    batches = 0
    i = 0
    t = 0.0
    while i < len(arrivals):
        # the oldest request's timeout clock starts at its arrival; when
        # the server is still busy past that deadline, the batch launches
        # the moment the server frees up
        deadline = arrivals[i] + policy.timeout_s
        launch = max(deadline, t)
        j = i
        while (j < len(arrivals) and j - i < policy.max_batch
               and arrivals[j] <= launch):
            j += 1
        batch = j - i
        if batch == policy.max_batch or j == len(arrivals):
            # full batch — or stream exhausted: nothing can join, go now
            start = max(arrivals[j - 1], t)
        else:
            start = launch
        dt = exec_time(flops_per_request, batch, policy.memory_mb)
        done = start + dt
        for k in range(i, j):
            latencies.append(done - arrivals[k])
        if keep_records:
            records.append(BatchRecord(i=i, j=j, free=t, start=start,
                                       done=done))
        gb_s += policy.memory_mb / 1024.0 * dt
        batches += 1
        t = done
        i = j
    lat = np.array(latencies)
    cost = gb_s * LAMBDA_GB_SECOND + batches * LAMBDA_PER_REQUEST
    return ServeStats(
        p50_s=float(np.percentile(lat, 50)),
        p99_s=float(np.percentile(lat, 99)),
        cost_per_1k=cost / len(lat) * 1000.0,
        batches=batches, requests=len(lat),
        mean_batch=len(lat) / batches,
        records=records if keep_records else None)


def optimize_policy(*, arrival_rate: float, flops_per_request: float,
                    slo_s: float, seed: int = 0,
                    batches=(1, 2, 4, 8, 16, 32, 64),
                    timeouts=(0.01, 0.05, 0.1, 0.25, 0.5, 1.0),
                    memories=(1024, 2048, 4096, 8192)) -> Tuple[
                        Optional[ServePolicy], Optional[ServeStats], Dict]:
    """Cheapest (B, tau, memory) meeting the p99 SLO. Returns
    (policy, stats, search_log); policy None if the SLO is infeasible."""
    best = None
    log = {"evaluated": 0, "feasible": 0}
    for mem in memories:
        for B in batches:
            for tau in timeouts:
                pol = ServePolicy(B, tau, mem)
                st = simulate(pol, arrival_rate=arrival_rate,
                              flops_per_request=flops_per_request, seed=seed)
                log["evaluated"] += 1
                if st.p99_s <= slo_s:
                    log["feasible"] += 1
                    if best is None or st.cost_per_1k < best[1].cost_per_1k:
                        best = (pol, st)
    if best is None:
        return None, None, log
    return best[0], best[1], log
