"""Real-model batched serving engine: collects requests, runs them through
prefill + KV/SSM-cache decode in adaptive batches on any zoo model.

The policy layer (batcher.py) decides batch size/timeouts from the cost
model; this engine executes a batch with real JAX and proves greedy decode
is batching-invariant (a request's tokens don't depend on its batchmates).
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models.base import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray


class ServingEngine:
    """Fixed-shape batched engine. Requests in one batch must share a
    prompt length (the batcher buckets by length): the zoo models take no
    per-row pad mask, so left-padding would leak pad tokens into
    attention. Per-row masks/ragged batching are the next increment."""

    def __init__(self, cfg: ModelConfig, params=None, seed: int = 0):
        self.cfg = cfg
        self.params = params if params is not None else registry.init(
            jax.random.key(seed), cfg)
        self._decode = jax.jit(
            lambda p, c, pos, tok: registry.decode_step(p, cfg, c, pos, tok))

    def serve_batch(self, requests: List[Request]) -> List[Completion]:
        cfg = self.cfg
        b = len(requests)
        lengths = {len(r.prompt) for r in requests}
        if len(lengths) != 1:
            # the zoo models take no per-row pad mask: left-padding would
            # leak pad tokens into shorter prompts' attention and hand
            # decode_step a wrong pos for them, silently corrupting output
            raise ValueError(
                "serve_batch requires all requests to share a prompt "
                f"length (got lengths {sorted(lengths)}); bucket requests "
                "by length before batching")
        plen = lengths.pop()
        gen = max(r.max_new_tokens for r in requests)
        toks = np.stack([r.prompt for r in requests]).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (b, cfg.n_image_tokens, cfg.d_vision), cfg.dtype)
        if cfg.family == "audio":
            batch["audio_frames"] = jnp.zeros(
                (b, cfg.n_audio_frames, cfg.d_audio), cfg.dtype)
        logits, cache = registry.prefill(self.params, cfg, batch,
                                         max_seq=plen + gen)
        tok = jnp.argmax(logits[:, -1:, :cfg.vocab_size], axis=-1)
        out = [tok]
        for t in range(gen - 1):
            logits, cache = self._decode(self.params, cache,
                                         jnp.int32(plen + t), tok)
            tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1)
            out.append(tok)
        gen_toks = np.asarray(jnp.concatenate(out, axis=1))
        return [Completion(r.rid, gen_toks[i, :r.max_new_tokens])
                for i, r in enumerate(requests)]
