"""Workflow layer: DAGs of ML tasks (HPO / NAS / fine-tune / eval /
deploy / online-update) under one global deadline + budget on a shared
serverless fleet.

 - dag:          ``TaskSpec`` / ``WorkflowDAG`` — the typed task graph;
                 ``deploy`` tasks carry a ``ServingTask`` and run as
                 event-engine ``ServingJob``s on the shared domain
 - allocator:    ``BudgetAllocator`` — splits one ``Goal`` into per-task
                 grants, deadlines, and worker windows; re-allocates on
                 every completion
 - tuner:        ``HPOSweep`` / ``SuccessiveHalving`` — rung-structured
                 successive-halving HPO with warm-started rungs
 - orchestrator: ``WorkflowOrchestrator`` — co-schedules ready tasks as
                 concurrent ``TaskScheduler`` jobs on one shared
                 ``ContentionDomain``
"""
from repro.workflow.allocator import (  # noqa: F401
    BudgetAllocator, TaskAllocation, TaskForecast)
from repro.workflow.dag import TaskSpec, WorkflowDAG  # noqa: F401
from repro.workflow.orchestrator import (  # noqa: F401
    WorkflowOrchestrator, WorkflowResult)
from repro.workflow.tuner import (  # noqa: F401
    HPOSweep, SuccessiveHalving, expand_hpo, sweep_final_tasks, trial_loss)
