"""Workflow-level budget + deadline allocation (the "overarching view").

One workflow ``Goal`` (``deadline_budget``: deadline_s + budget_usd) is
split into per-task grants from ``epoch_estimate`` forecasts, and
*re-split on every task completion*: unspent grants return to the pool
(an early-stopped HPO loser's dollars are reclaimed), and the pool flows
preferentially to the forecast critical path. When the remaining time can
no longer fit the pending critical path, droppable tasks are dropped in
ascending priority.

A grant is also converted into a *worker-count window* — the dollars →
fleet-scale dial the per-task Bayesian optimizer then searches inside —
so re-allocation is visible as deployment shape: a task granted more
dollars is allowed (and, through its ``min_workers`` floor, pushed) to
run wider. That is how a reclaimed HPO budget turns into the winning
trial's final rung running with more workers than its first.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.bayes_opt import Config, ConfigSpace
from repro.core.constraints import Goal
from repro.core.probe_cache import DEFAULT_CACHE
from repro.serverless.stores import ObjectStore, ParamStore
from repro.workflow.dag import TaskSpec, WorkflowDAG


@dataclasses.dataclass(frozen=True)
class TaskForecast:
    """Closed-form forecast of one whole task (all epochs): the fastest
    achievable wall across the probe grid (deadline feasibility is judged
    on what scale-out *can* do) and the cheapest achievable cost (the
    floor a budget split must at least cover)."""
    wall_s: float
    cost_usd: float


@dataclasses.dataclass(frozen=True)
class TaskAllocation:
    """One task's slice of the workflow goal. ``deadline_s`` is absolute
    on the workflow clock; ``budget_usd`` is the task's whole-run grant;
    ``[min_workers, max_workers]`` is the fleet-scale window the task's
    ConfigSpace is narrowed to."""
    task: str
    budget_usd: float
    deadline_s: float
    min_workers: int
    max_workers: int


class BudgetAllocator:
    """Splits a global ``Goal(deadline_s, budget_usd)`` across a
    ``WorkflowDAG`` and re-splits as tasks finish.

    ``safety`` keeps a fraction of the budget ungranted as a reserve for
    forecast error (the event engine tracks the analytic forecast within
    ~1% at zero variance, but stragglers/failures overshoot it).
    ``cp_boost`` multiplies the grant weight of tasks on the forecast
    critical path, so reclaimed budget flows there first."""

    def __init__(self, dag: WorkflowDAG, goal: Goal,
                 param_store: ParamStore, object_store: ObjectStore, *,
                 space: Optional[ConfigSpace] = None, scheme: str = "hier",
                 memory_mb: int = 3072, safety: float = 0.8,
                 cp_boost: float = 2.0, bo_max_iters: int = 8,
                 profile_iters: int = 1):
        if goal.deadline_s is None or goal.budget_usd is None:
            raise ValueError("a workflow goal needs both deadline_s and "
                             "budget_usd (kind 'deadline_budget')")
        self.dag = dag
        self.goal = goal
        self.space = space or ConfigSpace()
        self.scheme = scheme
        self.memory_mb = min(max(memory_mb, self.space.min_memory),
                             self.space.max_memory)
        self.safety = safety
        self.cp_boost = cp_boost
        # per-task (n -> whole-task wall/cost) probe curves on a geometric
        # worker grid: the basis of forecasts and of the dollars->workers
        # conversion
        self._grid = self._worker_grid()
        self._curves: Dict[str, List[Tuple[int, float, float]]] = {
            t.name: self._curve(t, param_store, object_store) for t in dag}
        # what a task's Bayesian optimization itself costs before the
        # first epoch runs (``bo_max_iters`` probes of ``profile_iters``
        # iterations each, at a mid-space deployment): grants must cover
        # it, and the dollars->workers conversion spends only what is
        # left after it
        mem_probe = min(max((self.space.min_memory
                             + self.space.max_memory) // 2,
                            self.memory_mb), self.space.max_memory)
        n_probe = self._grid[len(self._grid) // 2]
        self._probe_usd: Dict[str, float] = {}
        for t in dag:
            if t.kind == "deploy":
                # a serving job runs no Bayesian optimization probes
                self._probe_usd[t.name] = 0.0
                continue
            _, usd, _ = DEFAULT_CACHE.profile_cost(
                t.workload, scheme,
                Config(n_probe, mem_probe, backend=t.backend),
                t.batch_size, param_store, object_store, profile_iters)
            self._probe_usd[t.name] = usd * bo_max_iters
        self.forecasts: Dict[str, TaskForecast] = {
            name: TaskForecast(
                wall_s=min(w for _, w, _ in curve),
                cost_usd=(min(c for _, _, c in curve)
                          + self._probe_usd[name]))
            for name, curve in self._curves.items()}

    def _worker_grid(self) -> List[int]:
        lo, hi = self.space.min_workers, self.space.max_workers
        grid, n = [], max(lo, 1)
        while n < hi:
            grid.append(n)
            n *= 2
        grid.append(hi)
        return sorted(set(grid))

    def _curve(self, t: TaskSpec, param_store: ParamStore,
               object_store: ObjectStore) -> List[Tuple[int, float, float]]:
        if t.kind == "deploy":
            # serving: wall is the stream's duration (autoscaling absorbs
            # load, it does not shorten the stream) and cost is the
            # closed-form ServingTask estimate — flat across the worker
            # grid, since serving scale is the admission policy's call
            wall, cost = t.serving.estimate()
            return [(n, wall, cost) for n in self._grid]
        out = []
        for n in self._grid:
            # a pinned task backend prices the curve at that target's
            # provisioning/flat-rate semantics (Config.backend flows
            # through cost_model._config_backend)
            est = DEFAULT_CACHE.epoch_estimate(t.workload, self.scheme,
                                 Config(n, self.memory_mb,
                                        backend=t.backend),
                                 t.batch_size,
                                 param_store, object_store,
                                 samples=t.samples)
            out.append((n, est.wall_s * t.epochs, est.cost_usd * t.epochs))
        return out

    # -- queries ---------------------------------------------------------------
    def forecast(self, name: str) -> TaskForecast:
        return self.forecasts[name]

    def workers_for_budget(self, name: str, budget_usd: float
                           ) -> Tuple[int, int]:
        """The fleet-scale window a grant affords: after setting aside the
        task's own profiling overhead, the widest probe-grid deployment
        whose forecast cost fits the remainder caps the search, and half
        of it floors it — so a doubled grant *shows up* as a wider fleet,
        not just headroom the optimizer may ignore."""
        epoch_budget = budget_usd - self._probe_usd[name]
        affordable = [n for n, _, c in self._curves[name]
                      if c <= epoch_budget]
        hi = max(affordable) if affordable else self._grid[0]
        lo = max(self.space.min_workers, hi // 2)
        return lo, hi

    # -- allocation ------------------------------------------------------------
    def allocate(self, *, now_s: float, spent_usd: float,
                 running: Dict[str, TaskAllocation],
                 finished: Set[str], dropped: Set[str],
                 ready: Sequence[str]
                 ) -> Tuple[Dict[str, TaskAllocation], List[str]]:
        """Grants for the ``ready`` tasks, given what already finished,
        what is running under an outstanding grant, and what was dropped.
        Returns ``(allocations, newly_dropped)``.

        Budget: pool = safety * budget - spent - outstanding grants, split
        over all unfinished tasks by ``cost_floor * priority * cp_boost``
        weight (ready tasks draw their share now; the rest stays reserved
        for successors). Deadline: each task must finish by
        ``deadline - tail``, its slack before the longest forecast chain
        of descendants. Tasks whose chain cannot fit the remaining time
        are resolved by dropping droppable tasks in ascending priority
        (dependents drop with them)."""
        settled = finished | dropped
        new_drops: List[str] = []
        pending = [n for n in self.dag.order
                   if n not in settled and n not in running]

        def chain_len(drops_so_far: Set[str]) -> float:
            walls = {n: self.forecasts[n].wall_s for n in pending
                     if n not in drops_so_far}
            return self.dag.critical_path(walls)[0]

        # deadline pressure: drop droppable pending tasks, lowest priority
        # first (latest in topo order breaks ties, so leaves go before the
        # trunks they depend on), until the pending critical path fits
        remaining_s = max(self.goal.deadline_s - now_s, 0.0)
        drops: Set[str] = set()
        while chain_len(drops) > remaining_s:
            cands = [n for n in pending
                     if n not in drops and self.dag[n].droppable]
            if not cands:
                break               # nothing droppable: deadline stops truncate
            victim = min(cands, key=lambda n: (self.dag[n].priority,
                                               -self.dag.order.index(n)))
            drops.add(victim)
            # a dropped task's descendants can never run
            drops |= {d for d in self.dag.descendants(victim)
                      if d in pending}
        new_drops = [n for n in self.dag.order if n in drops]
        pending = [n for n in pending if n not in drops]

        committed = sum(a.budget_usd for a in running.values())
        pool = max(self.goal.budget_usd * self.safety - spent_usd
                   - committed, 0.0)

        walls = {n: self.forecasts[n].wall_s for n in pending}
        for name, alloc in running.items():
            walls[name] = self.forecasts[name].wall_s
        cp = set(self.dag.critical_path(walls)[1])
        weight = {n: (self.forecasts[n].cost_usd
                      * max(self.dag[n].priority, 1)
                      * (self.cp_boost if n in cp else 1.0))
                  for n in pending}
        total_w = sum(weight.values())

        tails = self.dag.tails(walls)
        allocs: Dict[str, TaskAllocation] = {}
        for name in ready:
            if name in drops or name not in weight:
                continue
            grant = pool * weight[name] / total_w if total_w > 0 else 0.0
            deadline = max(self.goal.deadline_s - tails[name], now_s)
            lo, hi = self.workers_for_budget(name, grant)
            allocs[name] = TaskAllocation(task=name, budget_usd=grant,
                                          deadline_s=deadline,
                                          min_workers=lo, max_workers=hi)
        return allocs, new_drops
