"""Typed task DAGs for ML design workflows (paper Sections 1 and 3.1).

SMLT frames ML design and training as a *continuous workflow of various
tasks with dynamic resource demands* — hyper-parameter trials, NAS
candidates, fine-tunes, evaluations — executed user-centrically under one
deadline and one budget. ``TaskSpec`` is one node of that workflow;
``WorkflowDAG`` is the validated dependency graph the
``WorkflowOrchestrator`` walks and the ``BudgetAllocator`` splits the
global ``Goal`` across.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.constraints import Goal
from repro.core.scheduler import EpochPlan
from repro.serverless.arrivals import ServingTask
from repro.serverless.worker import Workload

# "deploy" serves the current model as an event-engine ServingJob on the
# workflow's shared domain; "online_update" is a continuous fine-tune on
# freshly arrived samples (an OnlineStream window) — together they close
# the paper's train -> eval -> deploy -> continuous-fine-tune loop.
TASK_KINDS = ("train", "finetune", "eval", "hpo", "nas", "deploy",
              "online_update")


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One task of the workflow: a training/fine-tune/eval job with its
    workload, epoch count, dependencies, and scheduling metadata.

    ``priority`` weights the allocator's budget split (and decides what
    survives deadline pressure: ``droppable`` tasks are dropped in
    ascending priority). ``goal`` overrides the allocator's per-task
    grant with an explicit user goal. ``warm_start_from`` names a task —
    or an ``HPOSweep`` — whose winning config seeds this task's Bayesian
    optimization. ``sweep``/``rung``/``slot`` are HPO bookkeeping filled
    in by ``repro.workflow.tuner.expand_hpo``.

    A ``deploy`` task carries a :class:`ServingTask` in ``serving`` and
    executes as an event-engine ``ServingJob`` instead of a
    ``TaskScheduler`` run (``workload`` then names the *served* model).
    An ``online_update`` task runs the training path on the samples that
    arrived since the last update (the caller sizes ``samples`` from its
    arrival stream).

    ``backend`` pins the task to an execution target from
    ``repro.serverless.backends.BACKENDS`` ("vm", "gpu_vm", ...): the
    allocator forecasts the task at that backend's rates and the
    orchestrator runs it there. "" leaves the choice to the scheduler's
    config search (serverless unless the space searches backends)."""
    name: str
    workload: Workload
    epochs: int = 1
    batch_size: int = 1024
    samples: Optional[int] = None
    deps: Tuple[str, ...] = ()
    priority: int = 1
    goal: Optional[Goal] = None
    kind: str = "train"
    droppable: bool = False
    warm_start_from: Optional[str] = None
    sweep: Optional[str] = None
    rung: int = -1
    slot: int = -1
    serving: Optional[ServingTask] = None
    backend: str = ""

    def __post_init__(self):
        object.__setattr__(self, "deps", tuple(self.deps))
        if not self.name:
            raise ValueError("TaskSpec needs a name")
        if self.kind not in TASK_KINDS:
            raise ValueError(f"unknown task kind: {self.kind!r}")
        if self.kind == "deploy" and self.serving is None:
            raise ValueError(f"{self.name}: a deploy task needs a "
                             "ServingTask in `serving`")
        if self.kind != "deploy" and self.serving is not None:
            raise ValueError(f"{self.name}: `serving` is only valid on "
                             "deploy tasks")
        if self.epochs < 1:
            raise ValueError(f"{self.name}: epochs must be >= 1")
        if self.batch_size < 1:
            raise ValueError(f"{self.name}: batch_size must be >= 1")
        if self.name in self.deps:
            raise ValueError(f"{self.name}: depends on itself")

    def plans(self) -> List[EpochPlan]:
        if self.kind == "deploy":
            raise ValueError(f"{self.name}: deploy tasks run as a "
                             "ServingJob, not as epoch plans")
        return [EpochPlan(self.batch_size, self.workload,
                          samples=self.samples) for _ in range(self.epochs)]


class WorkflowDAG:
    """A validated task DAG: unique names, existing dependencies, no
    cycles. ``order`` is a deterministic topological order (ties broken
    by declaration order), the basis of every allocator/orchestrator
    iteration — so a workflow's schedule is reproducible run to run."""

    def __init__(self, tasks: Sequence[TaskSpec]):
        self.tasks: Dict[str, TaskSpec] = {}
        for t in tasks:
            if t.name in self.tasks:
                raise ValueError(f"duplicate task name: {t.name!r}")
            self.tasks[t.name] = t
        for t in tasks:
            for d in t.deps:
                if d not in self.tasks:
                    raise ValueError(f"{t.name}: unknown dependency {d!r}")
        self._succ: Dict[str, List[str]] = {n: [] for n in self.tasks}
        for t in tasks:
            for d in t.deps:
                self._succ[d].append(t.name)
        self.order = self._topo_order()

    def _topo_order(self) -> List[str]:
        indeg = {n: len(t.deps) for n, t in self.tasks.items()}
        queue = [n for n in self.tasks if indeg[n] == 0]  # declaration order
        order: List[str] = []
        while queue:
            n = queue.pop(0)
            order.append(n)
            for s in self._succ[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    queue.append(s)
        if len(order) != len(self.tasks):
            stuck = sorted(n for n in self.tasks if indeg[n] > 0)
            raise ValueError("workflow has a dependency cycle through "
                             f"{stuck}")
        return order

    # -- graph queries -------------------------------------------------------
    def __len__(self) -> int:
        return len(self.tasks)

    def __iter__(self):
        return iter(self.tasks.values())

    def __contains__(self, name: str) -> bool:
        return name in self.tasks

    def __getitem__(self, name: str) -> TaskSpec:
        return self.tasks[name]

    def successors(self, name: str) -> List[str]:
        return list(self._succ[name])

    def descendants(self, name: str) -> Set[str]:
        """Everything transitively downstream of ``name`` (exclusive)."""
        out: Set[str] = set()
        stack = list(self._succ[name])
        while stack:
            n = stack.pop()
            if n not in out:
                out.add(n)
                stack.extend(self._succ[n])
        return out

    def ready(self, done: Iterable[str],
              exclude: Iterable[str] = ()) -> List[TaskSpec]:
        """Tasks whose dependencies are all in ``done``, excluding
        ``exclude`` (running/dropped) and ``done`` itself — in
        topological order."""
        done, exclude = set(done), set(exclude)
        return [self.tasks[n] for n in self.order
                if n not in done and n not in exclude
                and all(d in done for d in self.tasks[n].deps)]

    # -- forecast-weighted paths --------------------------------------------
    def tails(self, wall_s: Dict[str, float]) -> Dict[str, float]:
        """Longest forecast path strictly *after* each task: the time that
        must still fit between a task's finish and the global deadline.
        Tasks missing from ``wall_s`` (finished/dropped) contribute 0."""
        tails: Dict[str, float] = {}
        for n in reversed(self.order):
            t = 0.0
            for s in self._succ[n]:
                t = max(t, wall_s.get(s, 0.0) + tails[s])
            tails[n] = t
        return tails

    def critical_path(self, wall_s: Dict[str, float]
                      ) -> Tuple[float, List[str]]:
        """The longest forecast chain (length, member tasks) over the
        tasks present in ``wall_s`` — where re-allocated budget flows
        first."""
        tails = self.tails(wall_s)
        best_len, best_head = 0.0, None
        for n in self.order:
            if n not in wall_s:
                continue
            # heads are tasks with no unfinished predecessors in wall_s
            if any(d in wall_s for d in self.tasks[n].deps):
                continue
            length = wall_s[n] + tails[n]
            if length > best_len:
                best_len, best_head = length, n
        if best_head is None:
            return 0.0, []
        path, n = [best_head], best_head
        while True:
            nxt, nxt_len = None, -1.0
            for s in self._succ[n]:
                if s in wall_s and wall_s[s] + tails[s] > nxt_len:
                    nxt, nxt_len = s, wall_s[s] + tails[s]
            if nxt is None:
                return best_len, path
            path.append(nxt)
            n = nxt
