"""Workflow orchestrator: a DAG of ML tasks under one deadline + budget.

This is the layer the paper promises in Sections 1/3.1 — the
*overarching view* over a continuous workflow of design and training
tasks — built on everything below it:

  - each task runs as its own ``TaskScheduler`` job (Bayesian
    optimization, mid-epoch adaptation, deadline/budget stops), driven
    through the scheduler's generator form (``TaskScheduler.drive``);
  - ready tasks execute *concurrently*: every event-engine chunk a task
    needs is admitted into one shared ``ContentionDomain`` at the task's
    workflow-clock offset, so co-running tasks contend on the same
    stores/links and bill one shared platform ledger (per-task
    attribution via ``ledger.job_usd``);
  - a ``BudgetAllocator`` splits the global ``Goal`` into per-task
    grants/deadlines/worker windows and *re-allocates on every task
    completion* — unspent and early-stopped budget flows to the critical
    path, and deadline pressure drops droppable tasks by priority;
  - ``SuccessiveHalving`` tuners resolve HPO survivor slots at runtime,
    warm-starting each rung's BO from the trial's previous deployment.

Everything is seeded: two runs with the same DAG and seed produce
bit-identical workflow traces (``WorkflowResult.trace``).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.bayes_opt import Config, ConfigSpace
from repro.core.constraints import Goal
from repro.core.scheduler import RunResult, TaskScheduler
from repro.serverless.arrivals import RequestStream
from repro.serverless.events import ContentionDomain, ServingJob, ServingResult
from repro.serverless.platform import ServerlessPlatform
from repro.serverless.stores import ObjectStore, ParamStore
from repro.workflow.allocator import BudgetAllocator, TaskAllocation
from repro.workflow.dag import TaskSpec, WorkflowDAG
from repro.workflow.tuner import HPOSweep, SuccessiveHalving


@dataclasses.dataclass
class WorkflowResult:
    """What one orchestrated workflow produced."""
    tasks: Dict[str, RunResult]
    start_s: Dict[str, float]
    finish_s: Dict[str, float]
    wall_s: float                       # makespan over the task schedule
    cost_usd: float                     # sum of per-task totals
    ledger_usd: float                   # the shared platform's actual bill
    dropped: List[str]
    allocations: Dict[str, TaskAllocation]
    assignments: Dict[str, int]         # HPO task -> trial id
    winners: Dict[str, Tuple[int, float]]   # sweep -> (trial, loss)
    trace: List[str]                    # deterministic workflow event log
    # deploy tasks keep their full serving detail (latency percentiles,
    # cold starts, fleet peak) alongside the RunResult stub in ``tasks``
    serving: Dict[str, ServingResult] = dataclasses.field(
        default_factory=dict)

    def config_of(self, name: str) -> Optional[Config]:
        hist = self.tasks[name].config_history
        return hist[-1] if hist else None


class _TaskRun:
    __slots__ = ("spec", "gen", "alloc", "start_t", "primed")

    def __init__(self, spec: TaskSpec, gen, alloc: TaskAllocation,
                 start_t: float):
        self.spec = spec
        self.gen = gen
        self.alloc = alloc
        self.start_t = start_t
        self.primed = False


class WorkflowOrchestrator:
    def __init__(self, dag: WorkflowDAG, goal: Goal,
                 platform: ServerlessPlatform, object_store: ObjectStore,
                 param_store: ParamStore, *,
                 space: Optional[ConfigSpace] = None, scheme: str = "hier",
                 engine: str = "event", engine_opts: Optional[Dict] = None,
                 sweeps: Sequence[HPOSweep] = (), seed: int = 0,
                 allocator: Optional[BudgetAllocator] = None,
                 profile_iters: int = 1, bo_max_iters: int = 8,
                 mid_epoch_adapt: bool = False,
                 record_trace: bool = False):
        self.dag = dag
        self.goal = goal
        self.platform = platform
        self.object_store = object_store
        self.param_store = param_store
        self.space = space or ConfigSpace()
        self.scheme = scheme
        self.engine = engine
        self.engine_opts = dict(engine_opts or {})
        # perf default: a workflow co-simulates many engines — per-event
        # trace lines are for debugging single tasks, so they are opt-in
        if record_trace and "record_trace" not in self.engine_opts:
            self.engine_opts["record_trace"] = True
        self.seed = seed
        self.profile_iters = profile_iters
        self.bo_max_iters = bo_max_iters
        self.mid_epoch_adapt = mid_epoch_adapt
        self.allocator = allocator or BudgetAllocator(
            dag, goal, param_store, object_store, space=self.space,
            scheme=scheme, bo_max_iters=bo_max_iters,
            profile_iters=profile_iters)
        self.tuners: Dict[str, SuccessiveHalving] = {
            s.name: SuccessiveHalving(s) for s in sweeps}
        for spec in dag:
            if spec.sweep is not None and spec.sweep not in self.tuners:
                raise ValueError(f"{spec.name} belongs to sweep "
                                 f"{spec.sweep!r} but no such HPOSweep was "
                                 "passed to the orchestrator")

        self.domain = ContentionDomain()
        self._running: Dict[str, _TaskRun] = {}
        self._finished: Dict[str, RunResult] = {}
        self._start_t: Dict[str, float] = {}
        self._finish_t: Dict[str, float] = {}
        self._dropped: Set[str] = set()
        self._serving: Dict[str, ServingResult] = {}
        self._allocs: Dict[str, TaskAllocation] = {}
        self._spent = 0.0
        self._trace: List[str] = []
        self._admitting = False
        self._admit_again = False
        self._ran = False

    # -- public ----------------------------------------------------------------
    def run(self) -> WorkflowResult:
        if self._ran:
            raise RuntimeError("a WorkflowOrchestrator runs once")
        self._ran = True
        self._admit_ready()
        self.domain.run()
        leftover = [n for n in self.dag.order
                    if n not in self._finished and n not in self._dropped]
        if leftover:
            raise RuntimeError(f"workflow stalled: {leftover} neither "
                               "finished nor dropped")
        winners = {}
        for name, tuner in self.tuners.items():
            if tuner.scores:
                trial, loss = tuner.best()
                winners[name] = (trial, loss)
                self._log(self._wall(), f"winner {name} trial={trial} "
                                        f"loss={loss:.6f}")
        assignments = {}
        for tuner in self.tuners.values():
            assignments.update(tuner.assignment)
        return WorkflowResult(
            tasks=dict(self._finished), start_s=dict(self._start_t),
            finish_s=dict(self._finish_t), wall_s=self._wall(),
            cost_usd=sum(r.total_cost for r in self._finished.values()),
            ledger_usd=self.platform.ledger.total_cost,
            dropped=[n for n in self.dag.order if n in self._dropped],
            allocations=dict(self._allocs), assignments=assignments,
            winners=winners, trace=list(self._trace),
            serving=dict(self._serving))

    # -- internals -------------------------------------------------------------
    def _wall(self) -> float:
        return max(self._finish_t.values(), default=0.0)

    def _log(self, t: float, line: str):
        self._trace.append(f"{t:.6f} {line}")

    def _task_seed(self, name: str) -> int:
        return (self.seed * 1_000_003 + zlib.crc32(name.encode())) % 2**31

    def _admit_ready(self):
        """Start every task whose dependencies are done, allocating its
        budget/deadline/worker window first. Re-entrant-safe: a task that
        finishes synchronously while being started (analytic engine, or a
        goal that stops before the first epoch) queues another admission
        round instead of recursing."""
        if self._admitting:
            self._admit_again = True
            return
        self._admitting = True
        try:
            while True:
                self._admit_again = False
                started = self._admit_once()
                if not started and not self._admit_again:
                    break
        finally:
            self._admitting = False

    def _admit_once(self) -> bool:
        # (_drop cascades through descendants, so a task with a dropped
        # dependency is itself already in _dropped and never shows here)
        ready = self.dag.ready(self._finished,
                               exclude=set(self._running) | self._dropped)
        if not ready:
            return False
        now = self.domain.now
        allocs, drops = self.allocator.allocate(
            now_s=now, spent_usd=self._spent,
            running={n: tr.alloc for n, tr in self._running.items()},
            finished=set(self._finished), dropped=set(self._dropped),
            ready=[r.name for r in ready])
        for name in drops:
            self._drop(name, "deadline pressure")
        started = False
        for spec in ready:
            if spec.name in self._dropped or spec.name not in allocs:
                continue
            self._start_task(spec, allocs[spec.name])
            started = True
        return started

    def _drop(self, name: str, reason: str):
        if name in self._dropped or name in self._finished:
            return
        self._dropped.add(name)
        self._log(self.domain.now, f"drop {name} ({reason})")
        for d in self.dag.descendants(name):
            self._drop(d, "dependency dropped")

    def _warm_config(self, spec: TaskSpec) -> Optional[Config]:
        if spec.sweep is not None:
            tuner = self.tuners[spec.sweep]
            trial = tuner.assign(spec)
            self._log(self.domain.now, f"assign {spec.name} trial={trial}")
            return tuner.warm_config(spec)
        src = spec.warm_start_from
        if src is None:
            return None
        if src in self.tuners:                   # a sweep: warm from winner
            tuner = self.tuners[src]
            if tuner.scores:
                return tuner.configs.get(tuner.best()[0])
            return None
        if src in self._finished:
            hist = self._finished[src].config_history
            return hist[-1] if hist else None
        return None

    def _start_task(self, spec: TaskSpec, alloc: TaskAllocation):
        start_t = max([self._finish_t[d] for d in spec.deps], default=0.0)
        start_t = max(start_t, 0.0)
        self._start_t[spec.name] = start_t
        self._allocs[spec.name] = alloc
        if spec.kind == "deploy":
            self._start_serving(spec, alloc, start_t)
            return
        warm = self._warm_config(spec)
        space = dataclasses.replace(self.space,
                                    min_workers=alloc.min_workers,
                                    max_workers=alloc.max_workers)
        # per-task engine opts: the allocator's task priority becomes the
        # training job's SharedLink flow priority (water-filling weight
        # against co-running tasks and serving traffic on the same
        # domain), and a pinned task backend overrides the search
        opts = dict(self.engine_opts)
        if "link_priority" not in opts:
            opts["link_priority"] = float(max(spec.priority, 1))
        if spec.backend and "backend" not in opts:
            opts["backend"] = spec.backend
        sched = TaskScheduler(
            self.platform, self.object_store, self.param_store,
            space=space, scheme=self.scheme,
            profile_iters=self.profile_iters,
            bo_max_iters=self.bo_max_iters,
            seed=self._task_seed(spec.name), engine=self.engine,
            engine_opts=opts,
            mid_epoch_adapt=self.mid_epoch_adapt, job=spec.name)
        # the task's own goal wins; otherwise its slice of the workflow
        # goal, with the absolute allocation deadline made task-relative
        goal = spec.goal or Goal("deadline_budget",
                                 deadline_s=max(alloc.deadline_s - start_t,
                                                1e-9),
                                 budget_usd=max(alloc.budget_usd, 1e-9))
        self._log(start_t,
                  f"start {spec.name} budget={alloc.budget_usd:.6f} "
                  f"deadline={alloc.deadline_s:.6f} "
                  f"workers={alloc.min_workers}-{alloc.max_workers}")
        gen = sched.drive(spec.plans(), goal, adaptive=True,
                          stop_at_deadline=True, stop_at_budget=True,
                          warm_start=warm)
        tr = _TaskRun(spec, gen, alloc, start_t)
        self._running[spec.name] = tr
        self._pump(tr, None)

    def _pump(self, tr: _TaskRun, value):
        """Advance a task's scheduler generator to its next engine request
        (admitting the engine into the shared domain at the task's current
        workflow time) or to completion."""
        try:
            if not tr.primed:
                tr.primed = True
                req = next(tr.gen)
            else:
                req = tr.gen.send(value)
        except StopIteration as stop:
            self._finish_task(tr, stop.value)
            return
        req.build(domain=self.domain,
                  start_at=tr.start_t + req.at_t,
                  on_complete=lambda eng, tr=tr: self._engine_done(tr, eng))

    def _engine_done(self, tr: _TaskRun, eng):
        self._pump(tr, eng.result())

    def _start_serving(self, spec: TaskSpec, alloc: TaskAllocation,
                       start_t: float):
        """Admit a ``deploy`` task as a ``ServingJob`` on the shared
        domain: inference traffic contends with every co-running
        training job on the same stores/links and bills the same
        ledger (``ServingJob.result`` self-attributes)."""
        sv = spec.serving
        arr = RequestStream(sv.arrivals,
                            seed=self._task_seed(spec.name)).arrivals(
            start_t, sv.duration_s)
        self._log(start_t,
                  f"serve {spec.name} requests={len(arr)} "
                  f"rate={sv.arrivals.mean_rps():.3f} "
                  f"budget={alloc.budget_usd:.6f}")
        tr = _TaskRun(spec, None, alloc, start_t)
        self._running[spec.name] = tr
        ServingJob(
            sv.policy, arr, sv.flops_per_request,
            self.param_store, self.object_store,
            domain=self.domain, platform=self.platform,
            model_bytes=sv.model_bytes, code_bytes=sv.code_bytes,
            cold_start_s=sv.cold_start_s, keep_warm_s=sv.keep_warm_s,
            max_instances=sv.max_instances,
            refresh_every_s=sv.refresh_every_s,
            link_priority=sv.link_priority, slo_s=sv.slo_s,
            job=spec.name, start_at=start_t,
            on_complete=lambda job, tr=tr: self._finish_serving(tr, job))

    def _finish_serving(self, tr: _TaskRun, job: ServingJob):
        name = tr.spec.name
        res = job.result()          # charges store + attributes the job
        self._serving[name] = res
        del self._running[name]
        # a RunResult stub so deploy tasks flow through the same
        # bookkeeping (finish times, spent budget, dependents) as
        # training tasks; the serving detail lives in ``serving``
        self._finished[name] = RunResult(
            events=[], wall_s=res.wall_s, cost_usd=res.cost_usd,
            profile_s=0.0, profile_usd=0.0, epochs_done=1,
            config_history=[])
        t_end = tr.start_t + res.wall_s
        self._finish_t[name] = t_end
        self._spent += res.cost_usd
        self._log(t_end,
                  f"served {name} wall={res.wall_s:.6f} "
                  f"cost={res.cost_usd:.6f} requests={res.requests} "
                  f"p99={res.p99_s:.6f} cold={res.cold_starts} "
                  f"peak={res.peak_instances}")
        self._admit_ready()

    def _finish_task(self, tr: _TaskRun, result: RunResult):
        name = tr.spec.name
        del self._running[name]
        self._finished[name] = result
        t_end = tr.start_t + result.wall_s
        self._finish_t[name] = t_end
        self._spent += result.total_cost
        cfg = result.config_history[-1] if result.config_history else None
        if tr.spec.sweep is not None:
            loss = self.tuners[tr.spec.sweep].report(
                tr.spec, result.epochs_done, cfg)
            self._log(t_end, f"score {name} loss={loss:.6f}")
        self._log(t_end,
                  f"done {name} wall={result.wall_s:.6f} "
                  f"cost={result.total_cost:.6f} "
                  f"epochs={result.epochs_done} "
                  f"stop={result.stop_reason} "
                  f"workers={cfg.workers if cfg else 0}")
        self._admit_ready()
