"""The "ML design" half of the workflow layer: successive-halving HPO.

An ``HPOSweep`` expands into a rung-structured sub-DAG
(``expand_hpo``): rung 0 runs every trial for a few epochs; each later
rung has ``n_trials / eta**rung`` *survivor slots* that depend on the
whole previous rung. Which trial occupies a slot is decided at runtime
by ``SuccessiveHalving``: when a slot becomes ready the top-scoring
survivors of the previous rung are assigned in rank order, each
warm-starting its Bayesian optimization from the config its previous
rung actually deployed (the scheduler's existing ``warm_start=`` hook).
Early-stopped losers simply have no later-rung task — the budget they
would have burned returns to the allocator's pool and flows to the
surviving rungs and the critical path.

Trial quality is a seeded synthetic loss curve (monotone improving in
epochs trained, deterministic per trial): the tuner under test is the
*resource allocation* — which trials get how many epochs and dollars —
not the model zoo.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bayes_opt import Config
from repro.core.rng import curve_stream
from repro.serverless.worker import Workload
from repro.workflow.dag import TaskSpec


@dataclasses.dataclass(frozen=True)
class HPOSweep:
    """A successive-halving hyper-parameter sweep over one workload.

    ``n_trials`` trials start in rung 0; each subsequent rung keeps the
    best ``1/eta`` fraction, for ``rungs`` rungs total. Every rung task
    trains ``epochs_per_rung`` epochs of ``samples`` samples."""
    name: str
    workload: Workload
    n_trials: int = 8
    rungs: int = 2
    eta: int = 2
    epochs_per_rung: int = 1
    batch_size: int = 1024
    samples: Optional[int] = None
    deps: Tuple[str, ...] = ()
    priority: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.n_trials < self.eta:
            raise ValueError("n_trials must be >= eta")
        if self.rungs < 1 or self.eta < 2:
            raise ValueError("need rungs >= 1 and eta >= 2")
        if self.n_trials // self.eta ** (self.rungs - 1) < 1:
            raise ValueError("halving schedule leaves an empty final rung")

    def survivors(self, rung: int) -> int:
        """How many trials run in ``rung`` (rung 0 = all trials)."""
        return max(self.n_trials // self.eta ** rung, 1)

    def task_name(self, rung: int, slot: int) -> str:
        kind = "t" if rung == 0 else "s"
        return f"{self.name}:r{rung}:{kind}{slot}"


def expand_hpo(sweep: HPOSweep, *, droppable: bool = True) -> List[TaskSpec]:
    """The sweep's static sub-DAG: rung-0 trial tasks (one per trial) and
    later-rung survivor slots, each rung depending on the entire previous
    rung (the selection barrier). Feed the specs into a ``WorkflowDAG``
    alongside any downstream fine-tune/eval tasks (see
    ``sweep_final_tasks`` for their deps)."""
    specs: List[TaskSpec] = []
    prev_rung: Tuple[str, ...] = sweep.deps
    for rung in range(sweep.rungs):
        names = []
        for slot in range(sweep.survivors(rung)):
            name = sweep.task_name(rung, slot)
            specs.append(TaskSpec(
                name=name, workload=sweep.workload,
                epochs=sweep.epochs_per_rung, batch_size=sweep.batch_size,
                samples=sweep.samples, deps=prev_rung,
                # later rungs concentrate the surviving budget: weight them
                # up so the allocator's split mirrors the halving shape
                priority=sweep.priority * (rung + 1),
                kind="hpo", droppable=droppable, sweep=sweep.name,
                rung=rung, slot=slot))
            names.append(name)
        prev_rung = tuple(names)
    return specs


def sweep_final_tasks(sweep: HPOSweep) -> Tuple[str, ...]:
    """The names of the sweep's final rung — what a dependent fine-tune
    task should declare as its ``deps``."""
    last = sweep.rungs - 1
    return tuple(sweep.task_name(last, s) for s in range(sweep.survivors(last)))


def trial_curves(sweep: HPOSweep) -> Tuple[np.ndarray, np.ndarray]:
    """The sweep's deterministic per-trial loss-curve parameters
    ``(quality, floor)``: trial *i* after *e* epochs sits at
    ``floor[i] + quality[i] / (1 + e)``. Shared by ``SuccessiveHalving``
    and by baselines (e.g. uniform-budget HPO) that must be judged on the
    *same* trials."""
    rng = curve_stream(sweep.seed)
    quality = rng.uniform(0.2, 1.0, size=sweep.n_trials)
    floor = rng.uniform(0.01, 0.05, size=sweep.n_trials)
    return quality, floor


def trial_loss(sweep: HPOSweep, trial: int, epochs: int) -> float:
    quality, floor = trial_curves(sweep)
    return float(floor[trial] + quality[trial] / (1.0 + epochs))


class SuccessiveHalving:
    """Runtime controller of one sweep: assigns trials to survivor slots,
    records per-trial progress, and scores trials on a deterministic
    synthetic loss curve ``loss_i(e) = floor + q_i / (1 + e)`` (``q_i``
    seeded per trial, ``e`` = epochs trained). Selection, warm-start
    configs, and the final winner all derive from it reproducibly."""

    def __init__(self, sweep: HPOSweep):
        self.sweep = sweep
        self.epochs: Dict[int, int] = {i: 0 for i in range(sweep.n_trials)}
        self.scores: Dict[int, float] = {}
        self.assignment: Dict[str, int] = {}     # task name -> trial id
        self.configs: Dict[int, Config] = {}     # trial -> last deployment
        self._rung_members: Dict[int, List[int]] = {}

    def loss(self, trial: int, epochs: Optional[int] = None) -> float:
        e = self.epochs[trial] if epochs is None else epochs
        return trial_loss(self.sweep, trial, e)

    # -- slot assignment -----------------------------------------------------
    def assign(self, spec: TaskSpec) -> int:
        """The trial that runs in ``spec`` (a task of this sweep): rung-0
        tasks are their own trial; a later-rung slot takes the slot-th
        best scorer among the previous rung's participants."""
        if spec.sweep != self.sweep.name:
            raise ValueError(f"{spec.name} is not a task of sweep "
                             f"{self.sweep.name!r}")
        if spec.name in self.assignment:
            return self.assignment[spec.name]
        if spec.rung == 0:
            trial = spec.slot
        else:
            ranked = self.survivors_of(spec.rung - 1)
            if spec.slot >= len(ranked):
                raise RuntimeError(f"{spec.name}: rung {spec.rung - 1} has "
                                   f"only {len(ranked)} scored trials")
            trial = ranked[spec.slot]
        self.assignment[spec.name] = trial
        self._rung_members.setdefault(spec.rung, []).append(trial)
        return trial

    def survivors_of(self, rung: int) -> List[int]:
        """Previous-rung participants ranked best-first (ties broken by
        trial id, so ranking is deterministic)."""
        members = self._rung_members.get(rung, [])
        return sorted((t for t in members if t in self.scores),
                      key=lambda t: (self.scores[t], t))

    # -- progress reporting --------------------------------------------------
    def report(self, spec: TaskSpec, epochs_done: int,
               config: Optional[Config]) -> float:
        """Record a finished rung task: credit the trial's epochs, refresh
        its score, and remember its deployed config for the next rung's
        warm start. Returns the trial's current loss."""
        trial = self.assign(spec)
        self.epochs[trial] += max(epochs_done, 0)
        self.scores[trial] = self.loss(trial)
        if config is not None:
            self.configs[trial] = config
        return self.scores[trial]

    def warm_config(self, spec: TaskSpec) -> Optional[Config]:
        """The config the slot's trial deployed in its previous rung."""
        return self.configs.get(self.assign(spec))

    def best(self) -> Tuple[int, float]:
        """(winner trial, loss) over everything scored so far."""
        if not self.scores:
            raise RuntimeError("no trials scored yet")
        trial = min(self.scores, key=lambda t: (self.scores[t], t))
        return trial, self.scores[trial]
