

def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess checks (~1 min each)")
