"""Degraded stand-in for the optional ``hypothesis`` dependency.

The property tests only use ``@given`` with ``st.integers`` /
``st.sampled_from`` strategies. When hypothesis is not installed, this
module provides the same decorator surface but materializes a fixed,
seeded set of example cases instead of doing adaptive search — the
properties are still exercised (including range endpoints), just without
shrinking or example databases.

Usage in a test module::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:                      # optional dep
        from hypothesis_fallback import given, settings, st
"""
from __future__ import annotations

import functools
import inspect

import numpy as np

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    """A deterministic example generator: endpoints first, then seeded
    draws — mirrors hypothesis's bias toward boundary values."""

    def __init__(self, endpoints, draw):
        self.endpoints = list(endpoints)
        self.draw = draw

    def examples(self, rng, k):
        out = list(self.endpoints[:k])
        while len(out) < k:
            out.append(self.draw(rng))
        return out


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            [min_value, max_value],
            lambda rng: int(rng.randint(min_value, max_value + 1)))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(
            elements[:1],
            lambda rng: elements[rng.randint(len(elements))])

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            [min_value, max_value],
            lambda rng: float(rng.uniform(min_value, max_value)))


st = _Strategies()


def settings(max_examples=DEFAULT_MAX_EXAMPLES, **_ignored):
    """Records max_examples for ``given``; other kwargs (deadline, ...)
    are meaningless without real hypothesis and ignored."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # read at call time: @settings may be applied above @given and
            # would then set the attribute after this decorator runs
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                DEFAULT_MAX_EXAMPLES))
            rng = np.random.RandomState(0)
            columns = {name: s.examples(rng, n)
                       for name, s in strategies.items()}
            for i in range(n):
                fn(*args, **kwargs, **{k: v[i] for k, v in columns.items()})

        # hide the strategy params from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strategies])
        del wrapper.__wrapped__
        return wrapper
    return deco
