"""known-bad: unseeded RNG construction and frozen-dataclass mutation."""
import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    workers: int = 4
    rate_usd: float = 0.1


def run_trial(cfg: EngineConfig, seed: int):
    rng = np.random.RandomState()            # api-unseeded-rng
    cfg.workers = 8                          # api-frozen-mutation
    object.__setattr__(cfg, "rate_usd", 0.2)  # api-frozen-mutation
    return rng.rand(cfg.workers)


def background_noise():
    return np.random.default_rng()           # api-unseeded-rng
