"""known-bad: trace-kind drift and an unbound event handler.

Mentions ``CalendarQueue`` so the handler-binding rule engages, the way
it does for the real engine modules.
"""
import dataclasses
from typing import ClassVar, FrozenSet


@dataclasses.dataclass
class TraceEvent:
    t: float
    epoch: int
    kind: str

    KINDS: ClassVar[FrozenSet[str]] = frozenset({"epoch", "dead_kind"})


class MiniEngine:
    """Pushes events at a CalendarQueue-backed domain."""

    def __init__(self, domain):
        self.domain = domain
        self.events = []

    def _compute_done(self, arg):
        self.events.append(TraceEvent(0.0, 0, "epoch"))

    def emit_typo(self):
        self.events.append(TraceEvent(0.0, 0, "epohc"))

    def arm(self, t):
        self.domain.at2(t, self._compute_done, None)
        self.domain.at2(t, self._compute_dnoe, None)
