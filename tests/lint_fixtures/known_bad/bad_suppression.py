"""known-bad: suppressions that are themselves violations."""
import time


def stamp_a():
    return time.time()  # simlint: ok(det-wallclock)


def stamp_b():
    return time.time()  # simlint: ok(no-such-rule, the rule id is a typo)
