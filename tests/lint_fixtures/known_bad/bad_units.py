"""known-bad: billing-unit mixing the suffix inference must catch."""


def bill(wall_s, rate_usd, state_mb, quota_gb, bw_gbps):
    total_usd = wall_s + rate_usd           # unit-mix (line 5)
    if state_mb > quota_gb:                 # unit-mix (line 6)
        total_usd += state_mb               # unit-mix (line 7, AugAssign)
    budget_s = rate_usd                     # unit-assign (line 8)
    charge(keepalive_s=rate_usd)            # unit-assign (line 9)
    ok_usd = wall_s * rate_usd              # conversion: not flagged
    return total_usd, budget_s, ok_usd


def spot_bill(rate_usd_per_s, price_usd_per_hr, bid_usd_per_hr, wall_s):
    blended = rate_usd_per_s + price_usd_per_hr   # unit-mix (line 15)
    if price_usd_per_hr > bid_usd_per_hr:         # like rates: not flagged
        blended = rate_usd_per_s
    if rate_usd_per_s > bid_usd_per_hr:           # unit-mix (line 18)
        pass
    spend_usd = price_usd_per_hr                  # unit-assign (line 20)
    charge(keepalive_s=rate_usd_per_s)            # unit-assign (line 21)
    ok_usd = wall_s * rate_usd_per_s              # conversion: not flagged
    return blended, spend_usd, ok_usd


def charge(keepalive_s=0.0):
    return keepalive_s
