"""known-bad: billing-unit mixing the suffix inference must catch."""


def bill(wall_s, rate_usd, state_mb, quota_gb, bw_gbps):
    total_usd = wall_s + rate_usd           # unit-mix (line 5)
    if state_mb > quota_gb:                 # unit-mix (line 6)
        total_usd += state_mb               # unit-mix (line 7, AugAssign)
    budget_s = rate_usd                     # unit-assign (line 8)
    charge(keepalive_s=rate_usd)            # unit-assign (line 9)
    ok_usd = wall_s * rate_usd              # conversion: not flagged
    return total_usd, budget_s, ok_usd


def charge(keepalive_s=0.0):
    return keepalive_s
