"""known-bad: every determinism rule fires in this file.

The path mirrors ``repro/serverless/`` so the scoped set-iteration rule
applies, exactly as it would inside the real engine package.
"""
import random
import time
from datetime import datetime

import numpy as np


def draw_noise(n):
    return np.random.rand(n)            # det-global-rng (line 14)


def pick_worker(workers):
    return random.choice(workers)       # det-global-rng (line 18)


def stamp():
    return time.time()                  # det-wallclock (line 22)


def stamp_iso():
    return datetime.now().isoformat()   # det-wallclock (line 26)


def make_rng(seed):
    return np.random.RandomState(seed)  # det-raw-randomstate (line 30)


def drain(pending):
    done = set()
    for wid in pending | done:          # det-set-iter (line 35)
        done.add(wid)
    return [w for w in done]            # det-set-iter (line 37)


def kinds(registry):
    return list(registry.keys())        # det-set-iter (line 41)
