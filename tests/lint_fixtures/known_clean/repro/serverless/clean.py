"""known-clean: the blessed idioms for everything known_bad does wrong.

Same scoped path (``repro/serverless/``) so the set-iteration rule is
active here too — ``sorted(...)`` is what keeps it quiet.
"""
import time

from repro.core.rng import base_stream, stream


def draw_noise(seed, n):
    return stream(seed, "noise").standard_normal(n)


def make_rng(seed):
    return base_stream(seed)


def drain(pending):
    done = set()
    for wid in sorted(pending | done):
        done.add(wid)
    return sorted(done)


def kinds(registry):
    return sorted(registry.keys())


def timed_region(fn):
    t0 = time.perf_counter()            # duration timer: allowed
    out = fn()
    return out, time.perf_counter() - t0


def bench_header():
    # operator-facing log stamp, never enters a trace or a hash
    # simlint: ok(det-wallclock, run header stamp only, not simulation state)
    return time.time()


def bill(wall_s, rate_usd, state_mb):
    state_gb = state_mb / 1024.0
    cost_usd = wall_s * rate_usd
    return cost_usd, state_gb
