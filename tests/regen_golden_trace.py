"""Regenerate tests/golden_engine_trace.txt after an *intentional* engine
semantics change.

    PYTHONPATH=src python tests/regen_golden_trace.py

Builds the exact engine `test_golden_trace_reproduced_verbatim` pins
(seed 42, 2 workers, 2 iterations, straggler sigma 0.3), runs it twice to
prove the trace is byte-stable, and rewrites the golden file. Review the
diff before committing: every changed line is a semantic change to the
event order or timestamps that the test suite will now enforce.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent))

from test_engine_invariants import GOLDEN, _golden_engine  # noqa: E402


def main() -> None:
    a = _golden_engine().run()
    b = _golden_engine().run()
    text_a = "\n".join(a.trace) + "\n"
    text_b = "\n".join(b.trace) + "\n"
    if text_a != text_b:
        raise SystemExit("trace is not byte-stable across runs; refusing "
                         "to regenerate")
    old = GOLDEN.read_text() if GOLDEN.exists() else ""
    GOLDEN.write_text(text_a)
    changed = "changed" if text_a != old else "unchanged"
    print(f"wrote {GOLDEN} ({len(a.trace)} events, {changed})")


if __name__ == "__main__":
    main()
