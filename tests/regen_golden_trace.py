"""Regenerate (or verify) tests/golden_engine_trace.txt.

    PYTHONPATH=src python tests/regen_golden_trace.py            # rewrite
    PYTHONPATH=src python tests/regen_golden_trace.py --check    # CI gate

Builds the exact engine `test_golden_trace_reproduced_verbatim` pins
(seed 42, 2 workers, 2 iterations, straggler sigma 0.3), runs it twice to
prove the trace is byte-stable, then either rewrites the golden file or
— with ``--check`` — compares against the checked-in file and exits 1 on
any drift without writing. Review the diff before committing a rewrite:
every changed line is a semantic change to the event order or timestamps
that the test suite will now enforce.
"""
import argparse
import difflib
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent))

from test_engine_invariants import GOLDEN, _golden_engine  # noqa: E402


def main() -> int:
    parser = argparse.ArgumentParser()
    parser.add_argument("--check", action="store_true",
                        help="verify the checked-in golden trace is "
                             "regenerable byte-identical; write nothing")
    args = parser.parse_args()

    a = _golden_engine().run()
    b = _golden_engine().run()
    text_a = "\n".join(a.trace) + "\n"
    text_b = "\n".join(b.trace) + "\n"
    if text_a != text_b:
        raise SystemExit("trace is not byte-stable across runs; refusing "
                         "to continue")
    old = GOLDEN.read_text() if GOLDEN.exists() else ""

    if args.check:
        if text_a != old:
            diff = difflib.unified_diff(
                old.splitlines(keepends=True),
                text_a.splitlines(keepends=True),
                fromfile="checked-in", tofile="regenerated")
            sys.stderr.writelines(diff)
            print(f"FAIL: {GOLDEN} is not regenerable byte-identical "
                  "(see diff above)", file=sys.stderr)
            return 1
        print(f"OK: {GOLDEN} regenerates byte-identical "
              f"({len(a.trace)} events)")
        return 0

    GOLDEN.write_text(text_a)
    changed = "changed" if text_a != old else "unchanged"
    print(f"wrote {GOLDEN} ({len(a.trace)} events, {changed})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
