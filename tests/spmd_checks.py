"""Subprocess payload for multi-device SPMD tests.

Run as: python tests/spmd_checks.py <check-name>
(sets XLA_FLAGS for 8 host devices BEFORE importing jax — kept out of the
pytest process so smoke tests/benches still see 1 device).
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import make_sync_grad_fn  # noqa: E402
from repro.core.elastic import ElasticRunner, make_data_mesh  # noqa: E402
from repro.optim import AdamW  # noqa: E402


def loss_fn(params, batch):
    pred = jnp.tanh(batch["x"] @ params["w1"]) @ params["w2"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_problem(seed=0):
    rng = np.random.RandomState(seed)
    params = {"w1": jnp.array(rng.randn(6, 16) * 0.3, jnp.float32),
              "w2": jnp.array(rng.randn(16, 3) * 0.3, jnp.float32)}
    batch = {"x": jnp.array(rng.randn(32, 6), jnp.float32),
             "y": jnp.array(rng.randn(32, 3), jnp.float32)}
    return params, batch


def check_sync_equivalence():
    params, batch = make_problem()
    ref_loss, ref_grads = jax.value_and_grad(loss_fn)(params, batch)
    meshes = [Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data")),
              Mesh(np.array(jax.devices()), ("data",))]
    for mesh in meshes:
        strategies = ["allreduce", "hier", "ps"]
        if "pod" in mesh.axis_names:
            strategies.append("hier2")
        for strat in strategies:
            f = make_sync_grad_fn(loss_fn, mesh, strat)
            loss, grads = f(params, batch)
            np.testing.assert_allclose(np.asarray(loss), np.asarray(ref_loss),
                                       rtol=1e-5)
            for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(ref_grads)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-5)
    print("OK sync_equivalence")


def check_sync_property():
    """Random pytrees with awkward shapes (incl. not divisible by n) stay
    exactly mean-reduced under the hierarchical strategy."""
    from repro.core.hier_sync import sync_grads
    mesh = Mesh(np.array(jax.devices()), ("data",))
    rng = np.random.RandomState(1)
    for trial in range(5):
        shapes = [tuple(rng.randint(1, 9) for _ in range(rng.randint(1, 4)))
                  for _ in range(4)]
        tree = {f"p{i}": jnp.array(rng.randn(8, *s), jnp.float32)
                for i, s in enumerate(shapes)}  # leading dim = per-device

        def f(tree):
            return sync_grads(tree, "hier", n_data=8)

        specs = jax.tree.map(
            lambda _: jax.sharding.PartitionSpec("data"), tree)
        from repro.core.hier_sync import shard_map_compat
        out = shard_map_compat(f, mesh=mesh, in_specs=(specs,),
                               out_specs=specs)(tree)
        for k in tree:
            want = np.broadcast_to(np.asarray(tree[k]).mean(0, keepdims=True),
                                   tree[k].shape)
            np.testing.assert_allclose(np.asarray(out[k]), want,
                                       rtol=1e-5, atol=1e-6)
    print("OK sync_property")


def check_elastic():
    """Rescaling the fleet mid-training keeps training exact: loss path on
    (4 workers -> 8 workers) matches a fixed 8-worker run (data parallel sync
    is exact, so fleet size must not change the math)."""
    params, batch = make_problem()
    opt = AdamW(lr=0.05, weight_decay=0.0, grad_clip=0.0)

    def builder(mesh):
        f = make_sync_grad_fn(loss_fn, mesh, "hier")

        def step(params, opt_state, batch):
            loss, grads = f(params, batch)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss

        return step

    def run(schedule):
        r = ElasticRunner(builder, params, opt.init(params),
                          n_workers=schedule[0])
        losses = []
        for i, n in enumerate(schedule):
            r.rescale(n)
            losses.append(float(r.train_step(batch)))
        return losses

    a = run([4, 4, 8, 8, 2, 8])
    b = run([8] * 6)
    np.testing.assert_allclose(a, b, rtol=1e-5)
    assert a[-1] < a[0], "loss must decrease"
    print("OK elastic")


def check_hier2_q():
    """bf16-compressed cross-pod hop: grads within bf16 tolerance of exact."""
    params, batch = make_problem()
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
    ref_loss, ref_grads = jax.value_and_grad(loss_fn)(params, batch)
    f = make_sync_grad_fn(loss_fn, mesh, "hier2_q")
    loss, grads = f(params, batch)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(ref_loss),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(ref_grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-2, atol=1e-3)  # bf16 hop
    print("OK hier2_q")


if __name__ == "__main__":
    {"sync_equivalence": check_sync_equivalence,
     "sync_property": check_sync_property,
     "elastic": check_elastic,
     "hier2_q": check_hier2_q}[sys.argv[1]]()
