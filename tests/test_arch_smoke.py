"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(2 layers, d_model<=512, <=4 experts) runs one forward/train step and one
prefill+decode step on CPU; output shapes + finiteness asserted."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced, reduced_batch
from repro.models import registry
from repro.optim import AdamW

B, S = 2, 32


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch(request):
    cfg = reduced(ARCHS[request.param])
    params = registry.init(jax.random.key(0), cfg)
    batch = reduced_batch(cfg, B, S)
    return cfg, params, batch


def test_train_step(arch):
    cfg, params, batch = arch
    opt = AdamW(lr=1e-3)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: registry.loss_fn(p, cfg, batch))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    opt_state = opt.init(params)
    p1, o1, loss1 = step(params, opt_state, batch)
    p2, o2, loss2 = step(p1, o1, batch)
    assert jnp.isfinite(loss1) and jnp.isfinite(loss2)
    assert loss2 < loss1  # one step on the same batch must reduce loss
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)):
        assert a.shape == b.shape
        assert bool(jnp.all(jnp.isfinite(b.astype(jnp.float32))))


def test_prefill_decode(arch):
    cfg, params, batch = arch
    logits, cache = registry.prefill(params, cfg, batch, max_seq=S + 4)
    assert logits.shape[:2] == (B, S)
    assert logits.shape[2] >= cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    logits2, cache2 = registry.decode_step(params, cfg, cache,
                                           jnp.int32(S), tok)
    assert logits2.shape[0] == B and logits2.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


def test_decode_matches_prefill(arch):
    """Teacher-forced decode logits must match prefill logits position-wise
    (the KV-cache path is numerically consistent with the parallel path)."""
    cfg, params, batch = arch
    toks = batch["tokens"]
    full_logits, _ = registry.prefill(params, cfg, batch, max_seq=S)
    # prefill only the first half, then decode the second half token by token
    half = S // 2
    pre_batch = dict(batch, tokens=toks[:, :half])
    _, cache = registry.prefill(params, cfg, pre_batch, max_seq=S)
    for t in range(half, min(half + 3, S)):
        logits, cache = registry.decode_step(params, cfg, cache,
                                             jnp.int32(t), toks[:, t:t + 1])
        ref = full_logits[:, t]
        got = logits[:, 0]
        assert jnp.allclose(ref, got, rtol=2e-2, atol=2e-3), (
            cfg.arch_id, t, float(jnp.max(jnp.abs(ref - got))))
