"""Bayesian optimizer: GP posterior, EI closed form, convergence,
constraint-aware search (paper Section 3.2)."""
import math

import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # optional dep: fixed example cases
    from hypothesis_fallback import given, settings, st

from repro.core import GP, BayesianOptimizer, ConfigSpace, expected_improvement


def test_gp_interpolates_training_points():
    X = np.array([[0.1, 0.2], [0.5, 0.9], [0.9, 0.1], [0.3, 0.6]])
    y = np.array([1.0, -2.0, 3.0, 0.5])
    gp = GP(noise=1e-8).fit(X, y)
    mu, sig = gp.predict(X)
    np.testing.assert_allclose(mu, y, atol=1e-4)
    assert np.all(sig < 1e-2)


def test_gp_uncertainty_grows_away_from_data():
    X = np.array([[0.5, 0.5]])
    gp = GP().fit(X, np.array([0.0]))
    _, s_near = gp.predict(np.array([[0.52, 0.5]]))
    _, s_far = gp.predict(np.array([[0.0, 1.0]]))
    assert s_far[0] > s_near[0]


def test_ei_closed_form():
    """EI(c) = (y* - mu) Phi(gamma) + sigma phi(gamma), gamma = (y*-mu)/sigma."""
    mu, sigma, ybest = np.array([1.0]), np.array([2.0]), 0.5
    gamma = (ybest - mu) / sigma
    phi = math.exp(-0.5 * gamma[0] ** 2) / math.sqrt(2 * math.pi)
    Phi = 0.5 * (1 + math.erf(gamma[0] / math.sqrt(2)))
    want = (ybest - mu[0]) * Phi + sigma[0] * phi
    got = expected_improvement(mu, sigma, ybest)[0]
    assert abs(got - want) < 1e-12


def test_ei_zero_at_no_uncertainty_worse_point():
    got = expected_improvement(np.array([2.0]), np.array([1e-15]), 1.0)[0]
    assert got <= 1e-9


@given(seed=st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_bo_beats_random_on_bowl(seed):
    space = ConfigSpace(max_workers=100)

    def f(c):
        return ((c.workers - 37) / 100.0) ** 2 + ((c.memory_mb - 5000) / 10240.0) ** 2

    bo = BayesianOptimizer(space, seed=seed, max_iters=15)
    while not bo.done():
        c = bo.suggest()
        bo.observe(c, f(c))
    rng = np.random.RandomState(seed)
    rand_best = min(f(c) for c in space.sample(rng, len(bo.obs)))
    assert bo.best().objective <= rand_best + 0.02


def test_bo_respects_constraint():
    """min cost s.t. time <= limit: best() must be feasible when feasible
    points were observed."""
    space = ConfigSpace(max_workers=50)

    def cost(c):
        return c.workers * c.memory_mb / 1e4

    def time_s(c):
        return 1000.0 / c.workers

    bo = BayesianOptimizer(space, constraint_limit=100.0, seed=0, max_iters=20)
    while not bo.done():
        c = bo.suggest()
        bo.observe(c, cost(c), time_s(c))
    best = bo.best()
    assert time_s(best.config) <= 100.0            # feasible
    assert best.config.workers >= 10               # implied by constraint


def test_bo_converges_in_bounded_probes():
    bo = BayesianOptimizer(ConfigSpace(), seed=3, max_iters=12)
    n = 0
    while not bo.done():
        c = bo.suggest()
        bo.observe(c, (c.workers / 200.0) ** 2)
        n += 1
    assert n <= 12
