"""CommPlan IR: strategy constructors, the compress and pipeline
transforms, closed-form pricing (overlap included), the water-filling
SharedLink, and load-aware shard placement — the one communication
schedule all three execution layers consume."""

import numpy as np
import pytest

from repro.core import Config
from repro.core.comm import (CommSpec, build_plan, hier,
                             overlap_iteration_time, parse_scheme, ps,
                             scatter_reduce)
from repro.core.cost_model import epoch_estimate
from repro.serverless import (WORKLOADS, EventEngine, FleetSpec, ObjectStore,
                              ParamStore, comm_breakdown, iteration_time)
from repro.serverless.stores import SharedLink
from repro.serverless.worker import fleet_local_batches

W = WORKLOADS["bert-small"]
G = W.grad_bytes


# -- plan construction --------------------------------------------------------

def test_ps_plan_shape():
    plan = ps(G, 16)
    assert [p.name for p in plan.phases] == ["UL-grad", "DL-grad"]
    assert plan.phases[0].nbytes == G
    assert plan.phases[1].nbytes == 16 * G
    assert all(p.fan_in == 16 for p in plan.phases)
    assert plan.phases[0].barrier_after and not plan.phases[1].barrier_after


def test_scatter_reduce_plan_matches_paper_fig5():
    plan = scatter_reduce(G, 16)
    names = [p.name for p in plan.phases]
    assert names == ["UL-Shard", "DL-Shard", "UL-aggr", "DL-grad"]
    by = {p.name: p for p in plan.phases}
    assert by["UL-Shard"].nbytes == pytest.approx(G)
    assert by["DL-Shard"].nbytes == pytest.approx(G)      # n shards of G/n
    assert by["UL-aggr"].nbytes == pytest.approx(G / 16)
    assert by["DL-grad"].nbytes == pytest.approx(G)
    assert all(p.fan_in == 16 for p in plan.phases)


def test_hier_plan_reduces_to_one_root():
    plan = hier(G, 16, branching=4)
    names = [p.name for p in plan.phases]
    assert names == ["UL-l1", "DL-l1", "UL-l2", "DL-l2", "UL-root", "DL-grad"]
    by = {p.name: p for p in plan.phases}
    assert by["UL-l1"].fan_in == 16 and by["DL-l1"].fan_in == 4
    assert by["UL-l2"].fan_in == 4 and by["DL-l2"].fan_in == 1
    assert by["DL-l1"].nbytes == pytest.approx(4 * G)     # b children each
    assert by["UL-root"].fan_in == 1
    assert by["DL-grad"].fan_in == 16
    assert by["DL-grad"].nbytes == pytest.approx(G)       # O(G), not O(nG)
    # fleet-wide wire bytes: far below ps's O(n^2 G)
    assert plan.wire_bytes < ps(G, 16).wire_bytes / 3


def test_hier_levels_cap_degenerates_to_single_root():
    plan = hier(G, 16, branching=4, levels=1)
    by = {p.name: p for p in plan.phases}
    assert by["DL-l1"].fan_in == 1                        # one root pulls all
    assert by["DL-l1"].nbytes == pytest.approx(16 * G)


def test_legacy_scheme_aliases():
    """The paper called ScatterReduce "hier"; the strings keep working."""
    assert parse_scheme("hier").strategy == "scatter_reduce"
    assert parse_scheme("ps_s3") == CommSpec("ps", store="object")
    assert build_plan("ps_s3", G, 8).phases[0].store == "object"
    with pytest.raises(ValueError):
        parse_scheme("nope")


def test_build_plan_rejects_mismatched_plans():
    plan = ps(G, 8)
    with pytest.raises(ValueError):
        build_plan(plan, G, 16)                          # wrong fleet size
    with pytest.raises(ValueError):
        build_plan(plan, G, 8, extra_upload_bytes=2e8)   # wrong payload
    assert build_plan(ps(G + 2e8, 8), G, 8, extra_upload_bytes=2e8) is not None


# -- compress transform -------------------------------------------------------

def test_compress_reproduces_legacy_hier_topk_bytes():
    """The generic transform must reproduce the hand-derived hier_topk
    wire model: uploads at 2*ratio (value+index), aggregates densified to
    min(1, n*ratio)."""
    r, n = 0.05, 16
    plan = scatter_reduce(G, n).compress(r)
    by = {p.name: p for p in plan.phases}
    dense = min(1.0, n * r)
    assert by["UL-Shard"].nbytes == pytest.approx(G * 2 * r)
    assert by["DL-Shard"].nbytes == pytest.approx(n * G * 2 * r / n)
    assert by["UL-aggr"].nbytes == pytest.approx(G * dense / n)
    assert by["DL-grad"].nbytes == pytest.approx(G * dense)


def test_compress_densifies_up_the_hier_tree():
    r, b = 0.01, 4
    plan = hier(G, 16, branching=b).compress(r)
    by = {p.name: p for p in plan.phases}
    assert by["UL-l1"].nbytes == pytest.approx(G * 2 * r)
    # a level-2 partial aggregates b contributions
    assert by["UL-l2"].nbytes == pytest.approx(G * min(1.0, b * r))
    assert by["DL-grad"].nbytes == pytest.approx(G * min(1.0, 16 * r))
    # downloads pay a decompress CPU charge; uploads don't
    assert all(p.cpu_s > 0 for p in plan.phases if p.direction == "dl")
    assert all(p.cpu_s == 0 for p in plan.phases if p.direction == "ul")


def test_wire_bytes_monotone_in_ratio():
    """Monotone across the whole range: where a sparse encoding would
    exceed the dense payload (2*ratio > 1), the sender falls back to
    dense, so compression never costs extra wire bytes."""
    for make in (lambda: ps(G, 16), lambda: scatter_reduce(G, 16),
                 lambda: hier(G, 16, branching=4)):
        dense = make().wire_bytes
        wire = [make().compress(r).wire_bytes
                for r in (0.01, 0.05, 0.1, 0.5, 0.7, 0.9, 1.0)]
        assert all(a <= b + 1e-6 for a, b in zip(wire, wire[1:])), wire
        assert all(wb <= dense + 1e-6 for wb in wire), wire


def test_compress_ratio_one_is_dense():
    plan = scatter_reduce(G, 16)
    assert plan.compress(1.0).phases == plan.phases
    # round-trip: un-compressing a compressed plan rebuilds the dense one
    assert plan.compress(0.05).compress(1.0).phases == plan.phases
    with pytest.raises(ValueError):
        plan.compress(0.0)


# -- pipeline (overlap) transform ---------------------------------------------

def test_pipeline_marks_only_leading_uploads():
    """Only the pre-barrier upload run — the phases moving the worker's
    own gradient — may hide under compute; everything after the first
    barrier or download stays sequential."""
    for make, first in ((lambda: ps(G, 16), "UL-grad"),
                        (lambda: scatter_reduce(G, 16), "UL-Shard"),
                        (lambda: hier(G, 16, branching=4), "UL-l1")):
        plan = make().pipeline(4)
        assert plan.pipeline_depth == 4
        ov = [ph.name for ph in plan.overlappable_phases]
        assert ov == [first], ov
        # barrier semantics preserved on the (deferred) phase itself
        by = {ph.name: ph for ph in plan.phases}
        assert by[first].barrier_after
        assert all(not ph.overlappable for ph in plan.phases
                   if ph.name != first)


def test_pipeline_depth_one_is_identity():
    plan = scatter_reduce(G, 16)
    assert plan.pipeline(1).phases == plan.phases
    assert plan.pipeline(1).pipeline_depth == 1
    # round-trip: un-pipelining a pipelined plan rebuilds the original
    assert plan.pipeline(4).pipeline(1).phases == plan.phases
    with pytest.raises(ValueError):
        plan.pipeline(0)
    with pytest.raises(ValueError):
        CommSpec("ps", pipeline_depth=0)


def test_pipeline_commutes_with_compress():
    a = scatter_reduce(G, 16).compress(0.05).pipeline(4)
    b = scatter_reduce(G, 16).pipeline(4).compress(0.05)
    assert a.phases == b.phases
    assert a.wire_bytes == pytest.approx(b.wire_bytes)
    # the transform moves no extra bytes
    assert a.wire_bytes == pytest.approx(
        scatter_reduce(G, 16).compress(0.05).wire_bytes)


def test_overlap_iteration_time_formula():
    """max(compute, hidden) + exposed + bubble, with the bubble one
    segment of the shorter side; depth=1 degenerates to the serial sum
    and depth→∞ hides min(compute, hidden) entirely."""
    seq = overlap_iteration_time(10.0, 6.0, 3.0, 1)
    assert seq["total"] == pytest.approx(19.0)
    assert seq["comm_hidden"] == 0.0 and seq["bubble"] == 0.0
    d4 = overlap_iteration_time(10.0, 6.0, 3.0, 4)
    assert d4["total"] == pytest.approx(10.0 + 6.0 / 4 + 3.0)
    assert d4["comm_hidden"] == pytest.approx(6.0 * (1 - 1 / 4))
    assert d4["bubble"] == pytest.approx(6.0 / 4)
    # comm-bound: compute hides under comm instead
    cb = overlap_iteration_time(4.0, 12.0, 3.0, 4)
    assert cb["total"] == pytest.approx(12.0 + 4.0 / 4 + 3.0)
    # monotone in depth, floored at max(c, u) + exposed
    totals = [overlap_iteration_time(10.0, 9.0, 3.0, d)["total"]
              for d in (1, 2, 4, 8, 64)]
    assert all(a >= b for a, b in zip(totals, totals[1:]))
    assert totals[-1] == pytest.approx(10.0 + 9.0 / 64 + 3.0)


def test_iteration_time_overlap_pricing_and_store_busy():
    """The pipelined iteration prices as max(compute, hidden) + exposed
    + bubble, while store-busy (keep-alive billing) stays the full
    transfer time — a hidden upload still holds the store."""
    ps_, os_ = ParamStore(), ObjectStore()
    seq = iteration_time(W, CommSpec("scatter_reduce"), 64, 4096, 512,
                         ps_, os_)
    d8 = iteration_time(W, CommSpec("scatter_reduce", pipeline_depth=8), 64,
                        4096, 512, ps_, os_)
    assert seq["comm_hidden"] == 0.0 and seq["bubble"] == 0.0
    assert d8["comm_hidden"] > 0.0
    assert d8["total"] < seq["total"]
    # what's hidden comes straight off the serial sum
    assert d8["total"] == pytest.approx(
        d8["compute"] + d8["comm"] - d8["comm_hidden"], rel=1e-9)
    # billing basis unchanged by overlap (up to the extra per-segment
    # request latency of the 8 sub-transfers)
    assert d8["store_busy"] >= seq["store_busy"]
    assert d8["store_busy"] == pytest.approx(seq["store_busy"], rel=0.05)


PIPELINED = (CommSpec("ps", pipeline_depth=4),
             CommSpec("scatter_reduce", pipeline_depth=4),
             CommSpec("hier", branching=4, pipeline_depth=4),
             CommSpec("scatter_reduce", ratio=0.05, pipeline_depth=4),
             CommSpec("ps", store="object", pipeline_depth=2))


@pytest.mark.parametrize("spec", PIPELINED,
                         ids=lambda s: f"{s.strategy}-{s.store}-r{s.ratio}")
def test_pipelined_zero_variance_engine_matches_analytic(spec):
    """Acceptance: pipelined plans execute on both paths with the
    engine-vs-analytic zero-variance gap ≤ 1% — compressed and S3-backed
    variants included."""
    est = epoch_estimate(W, spec, Config(16, 4096), 1024, ParamStore(),
                         ObjectStore(), samples=10_000)
    r = EventEngine(W, spec, 16, 4096, 1024, ParamStore(), ObjectStore(),
                    samples=10_000, seed=0).run()
    assert r.wall_s == pytest.approx(est.wall_s, rel=0.01), spec
    assert r.cost_usd == pytest.approx(est.cost_usd, rel=0.01), spec
    assert r.iters_done == est.iters


def test_overlap_wins_when_comm_near_compute():
    """At a comm/compute ratio near 1 the pipelined plan must strictly
    beat the sequential one on both paths — and depth=1 must reproduce
    the sequential engine trace bit-for-bit."""
    kw = dict(samples=4_096, seed=0)
    seq = EventEngine(W, CommSpec("scatter_reduce"), 64, 4096, 512,
                      ParamStore(), ObjectStore(), **kw).run()
    d1 = EventEngine(W, CommSpec("scatter_reduce", pipeline_depth=1), 64,
                     4096, 512, ParamStore(), ObjectStore(), **kw).run()
    d4 = EventEngine(W, CommSpec("scatter_reduce", pipeline_depth=4), 64,
                     4096, 512, ParamStore(), ObjectStore(), **kw).run()
    assert d1.trace == seq.trace and d1.wall_s == seq.wall_s
    assert d4.wall_s < seq.wall_s
    est_seq = epoch_estimate(W, "hier", Config(64, 4096), 512, ParamStore(),
                             ObjectStore(), samples=4_096)
    est_d4 = epoch_estimate(W, CommSpec("scatter_reduce", pipeline_depth=4),
                            Config(64, 4096), 512, ParamStore(),
                            ObjectStore(), samples=4_096)
    assert est_d4.wall_s < est_seq.wall_s


# -- ps_s3 keep-alive billing (headline bugfix) -------------------------------

def test_ps_s3_bills_no_param_store_keepalive():
    """Satellite (headline): the Siren-style S3 plan moves gradients
    through the *object* store — the Redis param store must accrue zero
    keep-alive seconds on both paths, and their store bills must agree
    (S3 data GETs only)."""
    it = iteration_time(W, "ps_s3", 16, 4096, 1024, ParamStore(),
                        ObjectStore())
    assert it["store_busy"] == 0.0
    param = ParamStore()
    est = epoch_estimate(W, "ps_s3", Config(16, 4096), 1024, param,
                         ObjectStore(), samples=10_000)
    eng_param = ParamStore()
    r = EventEngine(W, "ps_s3", 16, 4096, 1024, eng_param, ObjectStore(),
                    samples=10_000, seed=0).run()
    assert r.sync_s == 0.0 and r.store_billed_s == 0.0
    assert eng_param.alive_seconds == 0.0
    assert r.store_usd == pytest.approx(est.store_usd, rel=1e-9)
    # the param-store path still bills keep-alive, and more than ps_s3
    est_ps = epoch_estimate(W, "ps", Config(16, 4096), 1024, ParamStore(),
                            ObjectStore(), samples=10_000)
    assert est_ps.store_usd > est.store_usd


# -- closed-form pricing ------------------------------------------------------

def test_hier_beats_ps_on_closed_form_at_scale():
    """Acceptance: the aggregation tree must beat the central store on
    per-iteration comm from n=16 up (O(G) vs O(n*G) downloads)."""
    ps_, os_ = ParamStore(), ObjectStore()
    for n in (16, 64, 200):
        t_hier = sum(comm_breakdown(CommSpec("hier", branching=4), G, n,
                                    4096, ps_, os_).values())
        t_ps = sum(comm_breakdown(CommSpec("ps"), G, n, 4096,
                                  ps_, os_).values())
        assert t_hier < t_ps, (n, t_hier, t_ps)


def test_store_busy_excludes_decompress_cpu():
    ps_, os_ = ParamStore(), ObjectStore()
    it_dense = iteration_time(W, CommSpec("scatter_reduce"), 16, 4096, 1024,
                              ps_, os_)
    it_comp = iteration_time(W, CommSpec("scatter_reduce", ratio=0.05), 16,
                             4096, 1024, ps_, os_)
    assert it_dense["store_busy"] == pytest.approx(it_dense["comm"])
    assert it_comp["store_busy"] < it_comp["comm"]       # cpu_s not billed
    assert it_comp["comm"] < it_dense["comm"]            # fewer wire bytes


def test_store_billing_parity_engine_vs_analytic_all_strategies():
    """Satellite: per-phase store-busy billing must keep epoch_estimate's
    store_usd in parity with the engine's keep-alive window for every
    strategy — hierarchical fan-in levels and compressed plans included."""
    for spec in (CommSpec("ps"), CommSpec("scatter_reduce"),
                 CommSpec("hier", branching=4),
                 CommSpec("scatter_reduce", ratio=0.05),
                 CommSpec("hier", branching=4, ratio=0.05)):
        est = epoch_estimate(W, spec, Config(16, 4096), 1024, ParamStore(),
                             ObjectStore(), samples=10_000)
        r = EventEngine(W, spec, 16, 4096, 1024, ParamStore(), ObjectStore(),
                        samples=10_000, seed=0).run()
        assert r.store_usd == pytest.approx(est.store_usd, rel=0.01), spec
        assert r.wall_s == pytest.approx(est.wall_s, rel=0.01), spec


# -- water-filling SharedLink -------------------------------------------------

class _Flow:
    _next = [0]

    def __init__(self, cap_gbps=None, remaining_gb=1.0):
        self.fid = self._next[0]
        self._next[0] += 1
        self.cap_gbps = cap_gbps
        self.remaining_gb = remaining_gb


def _link(agg=10.0, per_stream=8.0):
    return SharedLink("t", agg, per_stream, 0.001)


def test_water_filling_redistributes_capped_share():
    """A flow capped below its equal share releases the rest: 10 GB/s
    over {cap 1, cap 8, cap 8} -> 1 + 4.5 + 4.5, not 1 + 3.33 + 3.33."""
    link = _link()
    flows = [_Flow(1.0), _Flow(8.0), _Flow(8.0)]
    for f in flows:
        link.flows[f.fid] = f
    rates = link.rates()
    assert rates[flows[0].fid] == pytest.approx(1.0)
    assert rates[flows[1].fid] == pytest.approx(4.5)
    assert rates[flows[2].fid] == pytest.approx(4.5)
    assert sum(rates.values()) == pytest.approx(10.0)


def test_water_filling_identical_caps_is_classic_processor_sharing():
    link = _link()
    flows = [_Flow(8.0) for _ in range(4)]
    for f in flows:
        link.flows[f.fid] = f
    rates = link.rates()
    assert all(r == pytest.approx(10.0 / 4) for r in rates.values())


def test_water_filling_all_capped_leaves_capacity_unused():
    link = _link()
    flows = [_Flow(1.0) for _ in range(3)]
    for f in flows:
        link.flows[f.fid] = f
    rates = link.rates()
    assert sum(rates.values()) == pytest.approx(3.0)     # = sum of caps


def test_water_filling_random_flow_sets_are_work_conserving():
    rng = np.random.RandomState(0)
    for _ in range(200):
        link = _link(agg=float(rng.uniform(1, 20)))
        caps = rng.uniform(0.1, 10, size=rng.randint(1, 8))
        flows = [_Flow(float(c)) for c in caps]
        for f in flows:
            link.flows[f.fid] = f
        rates = link.rates()
        total = sum(rates.values())
        # never over capacity, never idle while a flow is backlogged
        assert total <= link.aggregate_gbps + 1e-9
        assert total == pytest.approx(min(link.aggregate_gbps,
                                          float(caps.sum())), rel=1e-9)
        for f in flows:
            assert rates[f.fid] <= f.cap_gbps + 1e-12


def test_engine_links_water_fill_under_mixed_caps():
    """Engine-level invariant: at every link advance of a mixed-cap fleet
    run, aggregate throughput never exceeds capacity and never leaves it
    idle while any flow is backlogged — and the narrow tier's unused
    share really reaches the wide tier at least once."""
    # 12 concurrent flows push the equal share (5/12 GB/s) below the wide
    # tier's 0.6 GB/s cap, so the narrow tier's slack is redistributable
    fleet = FleetSpec.mixed([(6, 8192, "standard"), (6, 1024, "small")])
    eng = EventEngine(WORKLOADS["resnet18"], "ps", 12, 8192, 512,
                      ParamStore(), ObjectStore(), samples=2_048,
                      fleet=fleet, seed=0)
    saw_redistribution = [0]
    for link in eng.links.values():
        orig = link.progress

        def checked(now, link=link, orig=orig):
            if link.flows:
                rates = link.rates()
                caps = [link._cap(tr) for tr in link.flows.values()]
                total = sum(rates.values())
                assert total <= link.aggregate_gbps + 1e-9
                assert total >= min(link.aggregate_gbps, sum(caps)) - 1e-9
                share = link.aggregate_gbps / len(link.flows)
                if (any(c < share - 1e-12 for c in caps)
                        and any(r > share + 1e-12 for r in rates.values())):
                    saw_redistribution[0] += 1
            orig(now)

        link.progress = checked
    r = eng.run()
    assert r.iters_done == 4
    assert saw_redistribution[0] > 0


# -- load-aware shard placement -----------------------------------------------

def test_fleet_local_batches_proportional_to_speed():
    fleet = FleetSpec.mixed([(2, 4096, "standard"), (2, 2048, "small")])
    lbs = fleet_local_batches(fleet, 1024)
    assert sum(lbs) == pytest.approx(1024)
    assert lbs[0] > lbs[2]                               # fast gets more
    homog = fleet_local_batches(FleetSpec.homogeneous(4, 4096), 1024)
    assert homog == pytest.approx([256.0] * 4)


def test_load_aware_placement_closes_fleet_estimate_gap():
    """Satellite: with the batch split by worker speed, every worker
    computes for the same time, so the mixed-fleet analytic estimate is
    tight — strictly better than the old equal-split weighted-harmonic
    model, which priced the mean while bsp paid the max."""
    fleet = FleetSpec.mixed([(8, 4096, "standard"), (8, 2048, "small")])
    est = epoch_estimate(W, "hier", Config(16, 4096), 1024, ParamStore(),
                         ObjectStore(), samples=16_000, fleet=fleet)
    r = EventEngine(W, "hier", 16, 4096, 1024, ParamStore(), ObjectStore(),
                    samples=16_000, fleet=fleet, seed=0).run()
    new_err = abs(r.wall_s / est.wall_s - 1)
    assert new_err < 0.01
    # the old equal-split model: harmonic-mean compute per iteration
    it = est.it_breakdown
    local = 1024 // 16
    comp_harm = W.flops_per_sample * local / (fleet.gflops_harmonic() * 1e9)
    old_total = comp_harm + it["comm"]
    new_total = it["total"]
    old_err = abs(r.wall_s / (est.wall_s - est.iters * (new_total - old_total))
                  - 1)
    assert new_err < old_err
