"""Gradient compression (top-k + error feedback) and the training-dynamics
monitor (EWMA/CUSUM change detection)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # optional dep: fixed example cases
    from hypothesis_fallback import given, settings, st

from repro.core.compression import (CompressedWorkerPool, ErrorFeedback,
                                    compressed_bytes, topk_compress,
                                    topk_decompress)
from repro.core.monitor import ThroughputMonitor
from repro.serverless import ParamStore


# -- top-k + error feedback --------------------------------------------------


@given(size=st.integers(4, 300), ratio=st.sampled_from([0.01, 0.1, 0.5]))
@settings(max_examples=25, deadline=None)
def test_topk_roundtrip_keeps_largest(size, ratio):
    rng = np.random.RandomState(size)
    flat = rng.randn(size).astype(np.float32)
    idx, vals = topk_compress(flat, ratio)
    back = topk_decompress(idx, vals, size)
    k = max(int(size * ratio), 1)
    assert len(idx) == k
    # the kept entries are exactly the k largest-|.|
    kept = set(idx.tolist())
    order = np.argsort(-np.abs(flat))
    assert kept == set(order[:k].tolist())
    np.testing.assert_array_equal(back[idx], flat[idx])


def test_error_feedback_preserves_total_signal():
    """sum over steps of (sent + residual delta) == sum of gradients."""
    ef = ErrorFeedback.init(50)
    rng = np.random.RandomState(0)
    total_grad = np.zeros(50, np.float32)
    total_sent = np.zeros(50, np.float32)
    for _ in range(20):
        g = rng.randn(50).astype(np.float32)
        total_grad += g
        idx, vals = ef.compress(g, 0.1)
        total_sent += topk_decompress(idx, vals, 50)
    np.testing.assert_allclose(total_sent + ef.residual, total_grad,
                               rtol=1e-4, atol=1e-4)


def test_compressed_training_converges():
    """Least squares with 5% top-k + EF reaches near the dense optimum."""
    rng = np.random.RandomState(1)
    X = jnp.array(rng.randn(64, 20), jnp.float32)
    w_true = jnp.array(rng.randn(20, 1), jnp.float32)
    y = X @ w_true
    params = {"w": jnp.zeros((20, 1))}
    batch = {"x": X, "y": y}

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

    gf = jax.jit(lambda p, b: jax.grad(loss)(p, b))
    pool = CompressedWorkerPool(gf, 4, ParamStore(), ratio=0.05)
    lr = 0.3
    for _ in range(300):
        g = pool.step(params, batch)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
    assert float(loss(params, batch)) < 1e-2


def test_compression_reduces_accounted_bytes():
    store_dense = ParamStore()
    store_sparse = ParamStore()
    rng = np.random.RandomState(0)
    params = {"w": jnp.array(rng.randn(100, 10), jnp.float32)}
    batch = {"x": jnp.array(rng.randn(8, 100), jnp.float32),
             "y": jnp.array(rng.randn(8, 10), jnp.float32)}

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

    gf = lambda p, b: jax.grad(loss)(p, b)
    from repro.serverless import LocalWorkerPool
    LocalWorkerPool(gf, 4, store_dense).step(params, batch)
    CompressedWorkerPool(gf, 4, store_sparse, ratio=0.05).step(params, batch)
    assert store_sparse.stats.bytes_in < store_dense.stats.bytes_in * 0.2


def _lsq_problem(seed=0):
    rng = np.random.RandomState(seed)
    params = {"w": jnp.array(rng.randn(100, 10), jnp.float32)}
    batch = {"x": jnp.array(rng.randn(8, 100), jnp.float32),
             "y": jnp.array(rng.randn(8, 10), jnp.float32)}

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

    return params, batch, loss, (lambda p, b: jax.grad(loss)(p, b))


def test_compressed_pool_matches_dense_at_ratio_one():
    """Satellite: at ratio=1.0 the folded-in CompressedWorkerPool is the
    dense synchronization — training trajectories must coincide with the
    dense LocalWorkerPool's (up to float summation order)."""
    from repro.serverless import LocalWorkerPool
    params0, batch, loss, gf = _lsq_problem()
    lr = 0.2

    def train(pool):
        p = params0
        losses = []
        for _ in range(10):
            g = pool.step(p, batch)
            p = jax.tree.map(lambda a, b: a - lr * b, p, g)
            losses.append(float(loss(p, batch)))
        return losses

    dense = train(LocalWorkerPool(gf, 4, ParamStore()))
    comp = train(CompressedWorkerPool(gf, 4, ParamStore(), ratio=1.0))
    np.testing.assert_allclose(comp, dense, rtol=1e-4)
    # and error feedback at full ratio keeps everything, carries nothing
    ef = ErrorFeedback.init(32)
    flat = np.random.RandomState(0).randn(32).astype(np.float32)
    idx, vals = ef.compress(flat, 1.0)
    np.testing.assert_array_equal(topk_decompress(idx, vals, 32), flat)
    np.testing.assert_array_equal(ef.residual, np.zeros(32, np.float32))


def test_wire_bytes_monotone_in_ratio_on_store():
    """Satellite: accounted upload bytes must grow monotonically with the
    keep ratio (and the compressed-plan wire model agrees)."""
    params, batch, _loss, gf = _lsq_problem()
    seen = []
    for r in (0.01, 0.05, 0.2, 0.5, 1.0):
        store = ParamStore()
        CompressedWorkerPool(gf, 4, store, ratio=r).step(params, batch)
        seen.append(store.stats.bytes_in)
    assert all(a <= b for a, b in zip(seen, seen[1:])), seen


# -- monitor ------------------------------------------------------------------


def test_monitor_detects_sustained_shift():
    m = ThroughputMonitor()
    rng = np.random.RandomState(0)
    fired_before = any(m.observe(100 + rng.randn()) for _ in range(50))
    assert not fired_before
    fired = [m.observe(60 + rng.randn()) for _ in range(30)]
    assert any(fired)


def test_monitor_ignores_noise_and_single_spikes():
    m = ThroughputMonitor()
    rng = np.random.RandomState(1)
    fired = []
    for i in range(200):
        x = 100 + 3 * rng.randn()
        if i == 97:
            x = 140.0  # single spike
        fired.append(m.observe(x))
    assert not any(fired)
