"""Config/registry coverage: assigned dims are exact, input specs build
for every (arch x shape) pair, reduced variants respect the smoke bounds."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, input_specs, pairs, reduced, supports
from repro.models import registry
from repro.models.base import INPUT_SHAPES

# the assigned table, verbatim from the brief
ASSIGNED = {
    "mamba2-2.7b": dict(n_layers=64, d_model=2560, d_ff=0, vocab_size=50280,
                        ssm_state=128, family="ssm"),
    "seamless-m4t-medium": dict(n_layers=12, d_model=1024, n_heads=16,
                                n_kv_heads=16, d_ff=4096, vocab_size=256206,
                                family="audio"),
    "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16,
                            n_kv_heads=16, d_ff=1408, vocab_size=151936,
                            n_experts=60, top_k=4, family="moe"),
    "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
                        d_ff=4864, vocab_size=32000, n_experts=128, top_k=2,
                        family="moe"),
    "olmo-1b": dict(n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
                    d_ff=8192, vocab_size=50304, family="dense"),
    "qwen2.5-3b": dict(n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
                       d_ff=11008, vocab_size=151936, family="dense"),
    "phi4-mini-3.8b": dict(n_layers=32, d_model=3072, n_heads=24,
                           n_kv_heads=8, d_ff=8192, vocab_size=200064,
                           family="dense"),
    "llama-3.2-vision-90b": dict(n_layers=100, d_model=8192, n_heads=64,
                                 n_kv_heads=8, d_ff=28672,
                                 vocab_size=128256, family="vlm"),
    "zamba2-7b": dict(n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
                      d_ff=14336, vocab_size=32000, ssm_state=64,
                      family="hybrid"),
    "mistral-large-123b": dict(n_layers=88, d_model=12288, n_heads=96,
                               n_kv_heads=8, d_ff=28672, vocab_size=32768,
                               family="dense"),
}


@pytest.mark.parametrize("arch_id", sorted(ASSIGNED))
def test_assigned_dims_exact(arch_id):
    cfg = ARCHS[arch_id]
    for k, v in ASSIGNED[arch_id].items():
        assert getattr(cfg, k) == v, (arch_id, k, getattr(cfg, k), v)
    assert cfg.source, "every config must cite its source"


def test_pair_count_and_skips():
    ps = list(pairs())
    assert len(ps) == 32  # 10x4 - 8 long_500k skips
    assert not supports("mistral-large-123b", "long_500k")
    assert supports("mamba2-2.7b", "long_500k")
    assert supports("zamba2-7b", "long_500k")


@pytest.mark.parametrize("arch_id,shape_name", list(pairs()))
def test_input_specs_build(arch_id, shape_name):
    """ShapeDtypeStruct stand-ins exist for every model input of every
    supported pair — no device allocation."""
    cfg = ARCHS[arch_id]
    shape = INPUT_SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    assert "tokens" in specs
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    if shape.kind == "train":
        assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
    if shape.kind == "decode":
        assert specs["tokens"].shape == (shape.global_batch, 1)
        assert specs["pos"].shape == ()
    if cfg.family == "vlm":
        assert specs["image_embeds"].shape[1:] == (cfg.n_image_tokens,
                                                   cfg.d_vision)
    if cfg.family == "audio":
        assert specs["audio_frames"].shape[2] == cfg.d_audio


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_reduced_respects_smoke_bounds(arch_id):
    cfg = reduced(ARCHS[arch_id])
    assert cfg.n_layers <= 5
    assert cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    assert cfg.dtype == jnp.float32
    assert cfg.family == ARCHS[arch_id].family


def test_vocab_padding_is_mxu_and_tp_aligned():
    for cfg in ARCHS.values():
        assert cfg.vocab_padded % 128 == 0
        assert cfg.vocab_padded % 16 == 0
        assert cfg.vocab_padded >= cfg.vocab_size
        assert cfg.vocab_padded - cfg.vocab_size < 128
