"""Cost model + communication model properties (paper Sections 3.3, 4.3)."""
import dataclasses

import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # optional dep: fixed example cases
    from hypothesis_fallback import given, settings, st

from repro.core import Config
from repro.core.cost_model import (VM_TYPES, epoch_estimate, profile_cost,
                                   vm_epoch_estimate)
from repro.serverless import (WORKLOADS, EventEngine, FleetSpec, ObjectStore,
                              ParamStore, comm_breakdown, iteration_time)
from repro.serverless.platform import fn_gflops, fn_net_gbps
from repro.serverless.worker import Workload

W = WORKLOADS["bert-small"]


def _stores():
    return ParamStore(), ObjectStore()


def test_hier_beats_ps_and_s3_at_scale():
    """The paper's core claim (Figs. 7-8): hierarchical sync's DL-grad is
    O(G) vs the centralized baselines' O(n*G)."""
    ps, os_ = _stores()
    for n in (16, 64, 200):
        h = comm_breakdown("hier", W.grad_bytes, n, 4096, ps, os_)
        c = comm_breakdown("ps", W.grad_bytes, n, 4096, ps, os_)
        s = comm_breakdown("ps_s3", W.grad_bytes, n, 4096, ps, os_)
        assert sum(h.values()) < sum(c.values())
        assert sum(h.values()) < sum(s.values())
        # the baselines' bottleneck step is DL-grad, as in Fig. 7
        assert c["DL-grad"] > c["UL-grad"]
        assert h["DL-grad"] < c["DL-grad"] / 4


@given(n=st.sampled_from([16, 32, 64, 128]))
@settings(max_examples=10, deadline=None)
def test_comm_grows_linearly_with_workers(n):
    """Fig. 8: communication grows ~linearly in n for all schemes."""
    ps, os_ = _stores()
    t1 = sum(comm_breakdown("hier", W.grad_bytes, n, 4096, ps, os_).values())
    t2 = sum(comm_breakdown("hier", W.grad_bytes, 2 * n, 4096, ps, os_).values())
    assert 1.5 < t2 / t1 < 2.5


def test_memory_scales_compute_and_network():
    assert fn_gflops(8192) > fn_gflops(1024)
    assert fn_net_gbps(8192) > fn_net_gbps(512)


def test_more_workers_less_compute_more_comm():
    ps, os_ = _stores()
    a = iteration_time(W, "hier", 8, 4096, 2048, ps, os_)
    b = iteration_time(W, "hier", 64, 4096, 2048, ps, os_)
    assert b["compute"] < a["compute"]
    assert b["comm"] > a["comm"]


def test_epoch_cost_components_positive():
    ps, os_ = _stores()
    est = epoch_estimate(W, "hier", Config(32, 4096), 1024, ps, os_,
                         samples=50_000)
    assert est.lambda_usd > 0 and est.store_usd > 0
    assert est.wall_s > 0 and est.iters == 49 or est.iters == 50


def test_atari_extra_upload_slows_comm():
    """Fig. 7(d-f): the RL workload's simulation data inflates uploads."""
    ps, os_ = _stores()
    rl = WORKLOADS["atari-rl"]
    no_extra = comm_breakdown("hier", rl.grad_bytes, 32, 4096, ps, os_)
    extra = comm_breakdown("hier", rl.grad_bytes, 32, 4096, ps, os_,
                           extra_upload_bytes=rl.extra_upload_bytes)
    assert sum(extra.values()) > sum(no_extra.values())


def test_profile_cost_resolves_fleet_over_config_shape():
    """Satellite: an explicit ``fleet=`` wins over the config's
    (workers, memory): a probe of an 8×2048 fleet under a mismatched
    32×4096 config must price identically to the honest 8×2048 config —
    n, iteration times, GB-seconds, and requests all from the fleet."""
    ps_, os_ = _stores()
    fleet = FleetSpec.homogeneous(8, 2048)
    wall_f, usd_f, it_f = profile_cost(W, "hier", Config(32, 4096), 1024,
                                       ps_, os_, fleet=fleet)
    wall_h, usd_h, it_h = profile_cost(W, "hier", Config(8, 2048), 1024,
                                       ps_, os_)
    assert wall_f == pytest.approx(wall_h, rel=1e-12)
    assert usd_f == pytest.approx(usd_h, rel=1e-12)
    assert it_f == pytest.approx(it_h)
    # same for epoch_estimate (the other fleet-aware closed form)
    est_f = epoch_estimate(W, "hier", Config(32, 4096), 1024, ps_, os_,
                           samples=20_000, fleet=fleet)
    est_h = epoch_estimate(W, "hier", Config(8, 2048), 1024, ps_, os_,
                           samples=20_000)
    assert est_f.wall_s == pytest.approx(est_h.wall_s, rel=1e-12)
    assert est_f.cost_usd == pytest.approx(est_h.cost_usd, rel=1e-12)


def test_epoch_estimate_throughput_is_a_real_field():
    """Satellite: ``global_batch`` is a dataclass field, so
    ``dataclasses.replace`` and independent construction keep
    ``throughput`` working (no bolted-on ``_gb`` attribute)."""
    ps_, os_ = _stores()
    est = epoch_estimate(W, "hier", Config(16, 4096), 1024, ps_, os_,
                         samples=20_000)
    assert est.global_batch == 1024
    assert est.throughput == pytest.approx(est.iters * 1024 / est.wall_s)
    doubled = dataclasses.replace(est, wall_s=est.wall_s * 2)
    assert doubled.throughput == pytest.approx(est.throughput / 2)
    fresh = type(est)(wall_s=10.0, lambda_usd=0.0, store_usd=0.0, iters=5,
                      it_breakdown={}, restarts_per_worker=0,
                      global_batch=100)
    assert fresh.throughput == pytest.approx(50.0)


def test_restart_count_folds_data_fetch_into_first_window():
    """Satellite: the engine runs the per-epoch data fetch inside the
    first invocation's cap window, so a compute load that alone fits one
    window can still restart once the fetch is folded in — the analytic
    count must agree (and the engine must reproduce the wall-clock)."""
    ps_, os_ = _stores()
    # ~874 s of compute (fits the 892.5 s usable window) + ~30 s fetch
    w = Workload("cap-probe", 1_000_000, 7.9e10, 5.3e6, 2_048)
    est = epoch_estimate(w, "ps", Config(4, 2048), 512, ps_, os_)
    usable = 900.0 - 6.0 - 1.5
    epoch_compute = est.iters * est.it_breakdown["total"]
    assert epoch_compute <= usable           # the old formula said 0 restarts
    assert est.restarts_per_worker == 1      # the fetch pushes past the cap
    r = EventEngine(w, "ps", 4, 2048, 512, ParamStore(), ObjectStore(),
                    seed=0).run()
    assert r.restarts == 4
    assert r.wall_s == pytest.approx(est.wall_s, rel=0.01)
    assert r.cost_usd == pytest.approx(est.cost_usd, rel=0.01)


def test_vm_baseline_costs():
    wall, usd = vm_epoch_estimate(W, VM_TYPES["c5.4xlarge"], 8, 1024,
                                  samples=50_000)
    assert wall > 0 and usd > 0


@given(mem=st.integers(128, 10240))
@settings(max_examples=20, deadline=None)
def test_lambda_billing_monotone_in_memory(mem):
    ps, os_ = _stores()
    e1 = epoch_estimate(W, "hier", Config(16, mem), 1024, ps, os_,
                        samples=20_000)
    e2 = epoch_estimate(W, "hier", Config(16, min(mem * 2, 10_240)), 1024,
                        ps, os_, samples=20_000)
    # doubling memory at fixed workers never doubles cost savings for free:
    # wall time drops (more cpu) but $/s rises
    assert e2.wall_s <= e1.wall_s + 1e-9
