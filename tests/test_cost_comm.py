"""Cost model + communication model properties (paper Sections 3.3, 4.3)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # optional dep: fixed example cases
    from hypothesis_fallback import given, settings, st

from repro.core import Config
from repro.core.cost_model import epoch_estimate, vm_epoch_estimate, VM_TYPES
from repro.serverless import (WORKLOADS, ObjectStore, ParamStore,
                              comm_breakdown, iteration_time)
from repro.serverless.platform import fn_gflops, fn_net_gbps

W = WORKLOADS["bert-small"]


def _stores():
    return ParamStore(), ObjectStore()


def test_hier_beats_ps_and_s3_at_scale():
    """The paper's core claim (Figs. 7-8): hierarchical sync's DL-grad is
    O(G) vs the centralized baselines' O(n*G)."""
    ps, os_ = _stores()
    for n in (16, 64, 200):
        h = comm_breakdown("hier", W.grad_bytes, n, 4096, ps, os_)
        c = comm_breakdown("ps", W.grad_bytes, n, 4096, ps, os_)
        s = comm_breakdown("ps_s3", W.grad_bytes, n, 4096, ps, os_)
        assert sum(h.values()) < sum(c.values())
        assert sum(h.values()) < sum(s.values())
        # the baselines' bottleneck step is DL-grad, as in Fig. 7
        assert c["DL-grad"] > c["UL-grad"]
        assert h["DL-grad"] < c["DL-grad"] / 4


@given(n=st.sampled_from([16, 32, 64, 128]))
@settings(max_examples=10, deadline=None)
def test_comm_grows_linearly_with_workers(n):
    """Fig. 8: communication grows ~linearly in n for all schemes."""
    ps, os_ = _stores()
    t1 = sum(comm_breakdown("hier", W.grad_bytes, n, 4096, ps, os_).values())
    t2 = sum(comm_breakdown("hier", W.grad_bytes, 2 * n, 4096, ps, os_).values())
    assert 1.5 < t2 / t1 < 2.5


def test_memory_scales_compute_and_network():
    assert fn_gflops(8192) > fn_gflops(1024)
    assert fn_net_gbps(8192) > fn_net_gbps(512)


def test_more_workers_less_compute_more_comm():
    ps, os_ = _stores()
    a = iteration_time(W, "hier", 8, 4096, 2048, ps, os_)
    b = iteration_time(W, "hier", 64, 4096, 2048, ps, os_)
    assert b["compute"] < a["compute"]
    assert b["comm"] > a["comm"]


def test_epoch_cost_components_positive():
    ps, os_ = _stores()
    est = epoch_estimate(W, "hier", Config(32, 4096), 1024, ps, os_,
                         samples=50_000)
    assert est.lambda_usd > 0 and est.store_usd > 0
    assert est.wall_s > 0 and est.iters == 49 or est.iters == 50


def test_atari_extra_upload_slows_comm():
    """Fig. 7(d-f): the RL workload's simulation data inflates uploads."""
    ps, os_ = _stores()
    rl = WORKLOADS["atari-rl"]
    no_extra = comm_breakdown("hier", rl.grad_bytes, 32, 4096, ps, os_)
    extra = comm_breakdown("hier", rl.grad_bytes, 32, 4096, ps, os_,
                           extra_upload_bytes=rl.extra_upload_bytes)
    assert sum(extra.values()) > sum(no_extra.values())


def test_vm_baseline_costs():
    wall, usd = vm_epoch_estimate(W, VM_TYPES["c5.4xlarge"], 8, 1024,
                                  samples=50_000)
    assert wall > 0 and usd > 0


@given(mem=st.integers(128, 10240))
@settings(max_examples=20, deadline=None)
def test_lambda_billing_monotone_in_memory(mem):
    ps, os_ = _stores()
    e1 = epoch_estimate(W, "hier", Config(16, mem), 1024, ps, os_,
                        samples=20_000)
    e2 = epoch_estimate(W, "hier", Config(16, min(mem * 2, 10_240)), 1024,
                        ps, os_, samples=20_000)
    # doubling memory at fixed workers never doubles cost savings for free:
    # wall time drops (more cpu) but $/s rises
    assert e2.wall_s <= e1.wall_s + 1e-9
