"""Dry-run tooling: HLO collective parser + analytic roofline model
invariants. (The dry-run itself needs 512 host devices and its own process;
the full sweep is exercised by `python -m repro.launch.dryrun --all`.)"""
import numpy as np
import pytest

from benchmarks import flops_model as FM
from repro.configs import ARCHS

HLO = """
  %ag = bf16[16,512,1024]{2,1,0} all-gather(bf16[1,512,1024]{2,1,0} %p0), replica_groups={...}
  %ar.1 = f32[2048]{0} all-reduce(f32[2048]{0} %x), to_apply=%add
  %rs = (f32[128]{0}, f32[128]{0}) reduce-scatter(f32[2048]{0} %a, f32[2048]{0} %b), dimensions={0}
  %a2a = bf16[4,64]{1,0} all-to-all(bf16[4,64]{1,0} %y), dimensions={0}
  %cp = u32[8]{0} collective-permute(u32[8]{0} %z), source_target_pairs={{0,1}}
  %start = f32[64]{0} all-gather-start(f32[8]{0} %w)
  %done = f32[64]{0} all-gather-done(f32[64]{0} %start)
"""


def test_collective_parser_counts_and_bytes():
    from repro.launch import dryrun  # safe: only sets XLA_FLAGS env string
    stats = dryrun.collective_stats(HLO)
    assert stats["all-gather"]["count"] == 2          # ag + ag-start
    assert stats["all-reduce"]["count"] == 1
    assert stats["reduce-scatter"]["count"] == 1
    assert stats["all-to-all"]["count"] == 1
    assert stats["collective-permute"]["count"] == 1
    assert stats["all-gather"]["bytes"] == 16 * 512 * 1024 * 2 + 64 * 4
    assert stats["all-reduce"]["bytes"] == 2048 * 4
    assert stats["reduce-scatter"]["bytes"] == 2 * 128 * 4  # tuple result


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_roofline_terms_positive_and_finite(arch_id, shape):
    t = FM.step_terms(ARCHS[arch_id], shape)
    assert t.flops > 0 and t.hbm_bytes > 0 and t.coll_bytes > 0
    assert np.isfinite([t.t_compute, t.t_memory, t.t_collective]).all()
    assert t.dominant() in ("compute", "memory", "collective")


def test_roofline_levers_move_the_right_terms():
    cfg = ARCHS["mistral-large-123b"]
    base = FM.step_terms(cfg, "train_4k")
    # sequence parallelism cuts collective only
    sp = FM.step_terms(cfg.replace(seq_shard=True), "train_4k")
    assert sp.coll_bytes < base.coll_bytes
    assert sp.flops == base.flops
    # dots remat cuts compute, raises HBM
    dots = FM.step_terms(cfg.replace(remat_policy="dots"), "train_4k")
    assert dots.flops < base.flops
    assert dots.hbm_bytes > base.hbm_bytes
    # more DP, less TP cuts per-device TP-activation collectives
    reshape = FM.step_terms(cfg, "train_4k", n_data=32, n_model=8)
    assert reshape.t_collective < base.t_collective


def test_moe_dispatch_levers():
    cfg = ARCHS["qwen2-moe-a2.7b"]
    base = FM.step_terms(cfg, "train_4k")
    small_group = FM.step_terms(cfg.replace(moe_group=512), "train_4k")
    assert small_group.flops < base.flops * 0.5
    padded = FM.step_terms(cfg.replace(moe_group=512, moe_pad_experts=64),
                           "train_4k")
    assert padded.flops < small_group.flops


def test_useful_ratio_bounded():
    for arch_id in ARCHS:
        m = FM.model_flops_per_step(ARCHS[arch_id], "train_4k")
        t = FM.step_terms(ARCHS[arch_id], "train_4k")
        assert 0 < m / (t.flops * 256) <= 1.01, arch_id
