"""Property-based invariants of the discrete-event engine.

For *any* valid configuration — scheme, fleet shape, sync mode, straggler
variance, independent failures, correlated shocks — the engine must keep
its bookkeeping honest:

  - trace timestamps are non-decreasing (events execute in time order);
  - ``invocations == n + cap_restarts + failure_restarts`` (every worker
    is one Lambda request, every restart of either kind is one more);
  - ``lambda_usd`` is exactly the GB-second formula over the platform's
    invocation records, and ``store_usd`` exactly the keep-alive +
    S3-GET formula;
  - every iteration a worker starts is eventually stepped, and every
    worker finishes the full epoch;
  - same-seed runs are bit-identical (trace, wall, cost).

Runs under real hypothesis when installed, else the deterministic
``hypothesis_fallback`` shim (endpoints first, then seeded draws).
"""
import math
import pathlib

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # optional dep
    from hypothesis_fallback import given, settings, st

from repro.serverless import (BACKENDS, WORKLOADS, ContentionDomain,
                              EventEngine, FleetSpec, ObjectStore, ParamStore,
                              PriceTrace, ServerlessPlatform, ShockModel,
                              spot_variant)
from repro.serverless.platform import (DATA_OBJECT_BYTES, LAMBDA_GB_SECOND,
                                       LAMBDA_PER_REQUEST)
from repro.serverless.stores import ECS_GB_HOUR, ECS_VCPU_HOUR, S3_GET_PER_1K

W = WORKLOADS["resnet18"]
BATCH = 512
SAMPLES = 3 * BATCH                      # 3 iterations: fast but non-trivial


def _build(scheme, n, mem, sigma, failure_rate, sync_mode, hetero, shocked,
           seed, depth=1, backend=None):
    from repro.core.comm import CommSpec, parse_scheme
    if scheme == "tree":                 # asymmetric-participation CommPlan
        scheme = CommSpec("hier", branching=2)
    if depth > 1:                        # pipelined overlap window
        import dataclasses
        spec = scheme if isinstance(scheme, CommSpec) else parse_scheme(scheme)
        scheme = dataclasses.replace(spec, pipeline_depth=depth)
    plat = ServerlessPlatform(seed=0)
    fleet = None
    if hetero:                           # half the fleet at half memory
        fleet = FleetSpec.mixed([(n - n // 2, mem, "standard"),
                                 (n // 2, max(mem // 2, 512), "small")])
    shocks = ShockModel(interval_s=40.0, kill_frac=0.4) if shocked else None
    eng = EventEngine(W, scheme, n, mem, BATCH, ParamStore(), ObjectStore(),
                      samples=SAMPLES, straggler_sigma=sigma,
                      failure_rate=failure_rate, sync_mode=sync_mode,
                      fleet=fleet, shocks=shocks, platform=plat, seed=seed,
                      backend=backend)
    return eng, plat


def _check_invariants(eng, plat, r, samples=SAMPLES, batch=BATCH):
    n = eng.n
    # (1) trace timestamps never go backwards
    times = [float(line.split()[0]) for line in r.trace]
    assert all(a <= b for a, b in zip(times, times[1:])), "time went backwards"

    # (2) request accounting: one per worker + one per restart of any kind
    assert r.invocations == n + r.restarts + r.failures

    # (3) cost is exactly the published formulas
    gb_s = sum(eng.mem[rec.worker_id] / 1024.0 * (rec.end - rec.start)
               for rec in plat.invocations)
    assert r.lambda_usd == pytest.approx(
        gb_s * LAMBDA_GB_SECOND + r.invocations * LAMBDA_PER_REQUEST,
        rel=1e-9)
    ps = eng.param_store
    hourly = ps.vcpus * ECS_VCPU_HOUR + ps.memory_gb * ECS_GB_HOUR
    n_objects = max(math.ceil(W.sample_bytes * samples / DATA_OBJECT_BYTES), 1)
    assert r.store_usd == pytest.approx(
        r.store_billed_s / 3600.0 * hourly
        + n_objects * S3_GET_PER_1K / 1000.0 * n, rel=1e-9)
    # alone on its store, a job is billed exactly its own sync window
    if all(e.param_store is not eng.param_store
           for e in eng.domain._engines if e is not eng):
        assert r.store_billed_s == r.sync_s
    assert r.cost_usd == r.lambda_usd + r.store_usd

    # (4) every started iteration completes, and the whole epoch ran
    iters = max(math.ceil(samples / batch), 1)
    assert not r.stopped_early
    assert r.iters_done == iters
    stepped = {}
    for line in r.trace:
        _, wid, what = line.split(" ", 2)
        if what.startswith("step it"):
            stepped.setdefault(wid, set()).add(int(what[len("step it"):]))
        elif what.startswith("compute it"):
            pass                         # may repeat after a failure/shock
    for wid, steps in stepped.items():
        assert steps == set(range(iters)), (wid, steps)
    assert len(stepped) == n
    for line in r.trace:
        _, wid, what = line.split(" ", 2)
        if what.startswith("compute it"):
            assert int(what[len("compute it"):]) in stepped[wid]


@settings(max_examples=12, deadline=None, derandomize=True)
@given(scheme=st.sampled_from(("hier", "ps", "ps_s3", "tree")),
       n=st.integers(2, 10),
       mem=st.sampled_from((1024, 2048, 4096)),
       sigma=st.sampled_from((0.0, 0.3, 0.6)),
       failure_rate=st.sampled_from((0.0, 0.04)),
       sync_mode=st.sampled_from(("bsp", "ssp(1)", "async")),
       hetero=st.sampled_from((False, True)),
       shocked=st.sampled_from((False, True)),
       depth=st.sampled_from((1, 2, 4)),
       seed=st.integers(0, 9999))
def test_engine_invariants_hold_for_random_configs(
        scheme, n, mem, sigma, failure_rate, sync_mode, hetero, shocked,
        depth, seed):
    eng, plat = _build(scheme, n, mem, sigma, failure_rate, sync_mode,
                       hetero, shocked, seed, depth=depth)
    r = eng.run()
    _check_invariants(eng, plat, r)
    if scheme == "ps_s3":
        # headline bugfix: the S3 sync path never holds the Redis store
        assert r.sync_s == 0.0 and r.store_billed_s == 0.0


@settings(max_examples=8, deadline=None, derandomize=True)
@given(scheme=st.sampled_from(("hier", "ps")),
       n=st.integers(2, 8),
       sigma=st.sampled_from((0.0, 0.5)),
       shocked=st.sampled_from((False, True)),
       depth=st.sampled_from((1, 4)),
       backend=st.sampled_from((None, "vm", "gpu_vm")),
       seed=st.integers(0, 9999))
def test_same_seed_runs_are_bit_identical(scheme, n, sigma, shocked, depth,
                                          backend, seed):
    runs = []
    for _ in range(2):
        eng, _plat = _build(scheme, n, 2048, sigma, 0.03, "bsp", True,
                            shocked, seed, depth=depth, backend=backend)
        runs.append(eng.run())
    a, b = runs
    assert a.trace == b.trace
    assert a.wall_s == b.wall_s
    assert a.lambda_usd == b.lambda_usd and a.store_usd == b.store_usd
    assert a.backend_usd == b.backend_usd
    assert a.invocations == b.invocations and a.failures == b.failures


# -- multi-backend execution: vm / gpu_vm / spot -----------------------------

@settings(max_examples=10, deadline=None, derandomize=True)
@given(backend=st.sampled_from(("vm", "gpu_vm")),
       scheme=st.sampled_from(("hier", "ps")),
       n=st.integers(2, 8),
       sigma=st.sampled_from((0.0, 0.4)),
       seed=st.integers(0, 9999))
def test_vm_backends_bill_per_second_without_requests(backend, scheme, n,
                                                      sigma, seed):
    """A VM-kind backend bills per second of post-provisioning lifetime:
    the Lambda meters (requests, GB-seconds) never move, the provisioning
    gap contributes nothing, and the platform ledger carries exactly the
    engine's backend total."""
    eng, plat = _build(scheme, n, 2048, sigma, 0.0, "bsp", False, False,
                       seed, backend=backend)
    r = eng.run()
    spec = BACKENDS[backend]
    assert r.invocations == 0 and r.lambda_usd == 0.0
    assert r.restarts == 0               # uncapped: no duration-cap splits
    # per-second audit from the invocation records: billing arms when
    # provisioning + framework init completes (the worker's first
    # ``init_s`` seconds are the unbilled provisioning gap)
    billed_s = sum(rec.end - rec.start - eng.init_s
                   for rec in plat.invocations)
    assert r.backend_usd == pytest.approx(billed_s * spec.usd_per_s, rel=1e-9)
    assert r.backend_usd > 0.0
    assert plat.ledger.extra[f"backend:{backend}"] == pytest.approx(
        r.backend_usd, rel=1e-9)
    assert r.cost_usd == r.lambda_usd + r.store_usd + r.backend_usd
    # the epoch itself still completes like any serverless run
    assert r.iters_done == max(math.ceil(SAMPLES / BATCH), 1)
    assert not r.stopped_early


def test_spot_preemption_loses_work_but_never_double_bills():
    """A spot price crossing kills the fleet mid-epoch: the in-flight
    work is lost and redone (never skipped), and every invocation record
    is billed exactly once — pre-preemption lifetimes integrate the spot
    trace, post-preemption lifetimes bill at the policy's rate, and their
    sum reproduces ``backend_usd`` to the penny."""
    n, seed, bid = 4, 7, 0.2
    base = BACKENDS["vm"]
    # calibrate with a quiet trace (never crosses the bid): no preemptions
    quiet = spot_variant(base, PriceTrace((0.0,), (0.10,)),
                         bid_usd_per_hr=bid)
    eng0, _ = _build("ps", n, 2048, 0.0, 0.0, "bsp", False, False, seed,
                     backend=quiet)
    r0 = eng0.run()
    assert r0.preemptions == 0
    # one spike above the bid in the middle of that calibrated window
    t1, t2 = 0.4 * r0.wall_s, 0.5 * r0.wall_s
    trace = PriceTrace((0.0, t1, t2), (0.10, 1.00, 0.10))
    results = {}
    for policy in ("fallback", "wait"):
        spec = spot_variant(base, trace, bid_usd_per_hr=bid,
                            spot_policy=policy)
        eng, plat = _build("ps", n, 2048, 0.0, 0.0, "bsp", False, False,
                           seed, backend=spec)
        r = eng.run()
        results[policy] = r
        assert r.preemptions == n and r.shock_events == 1
        assert r.failures == n           # the kill is a correlated failure
        assert r.wall_s > r0.wall_s      # lost work is redone, never skipped
        assert r.iters_done == r0.iters_done and not r.stopped_early
        # exactly-once billing audit over the invocation records
        usd = 0.0
        for rec in plat.invocations:
            if not rec.resumed:          # armed post-init, killed at t1
                usd += trace.integral_usd(rec.start - eng._t0 + eng.init_s,
                                          rec.end - eng._t0)
            elif policy == "fallback":   # re-armed at the on-demand rate
                armed = rec.start + eng.init_s + eng.restore_s
                usd += (rec.end - armed) * base.usd_per_s
            else:                        # waited out the spike, still spot
                armed = (trace.next_drop_below(rec.start - eng._t0, bid)
                         + eng.init_s + eng.restore_s)
                usd += trace.integral_usd(armed, rec.end - eng._t0)
        assert r.backend_usd == pytest.approx(usd, rel=1e-9)
        assert plat.ledger.extra[f"backend:{spec.name}"] == pytest.approx(
            r.backend_usd, rel=1e-9)
        assert r.invocations == 0 and r.lambda_usd == 0.0
        # determinism: the same spot run replays bit-identically
        eng2, _ = _build("ps", n, 2048, 0.0, 0.0, "bsp", False, False,
                         seed, backend=spec)
        r2 = eng2.run()
        assert r2.trace == r.trace and r2.backend_usd == r.backend_usd
    # the wait policy idles through the spike the fallback pays to skip
    assert results["wait"].wall_s == pytest.approx(
        results["fallback"].wall_s + (t2 - t1), rel=1e-9)
    assert results["wait"].backend_usd < results["fallback"].backend_usd


def test_multi_job_domain_preserves_per_job_invariants():
    """Two jobs co-simulated on one shared ParamStore: each job's
    bookkeeping must hold exactly as if it ran alone, and sharing the
    link can only slow a job down, never speed it up."""
    def solo(seed):
        eng, plat = _build("ps", 6, 2048, 0.2, 0.0, "bsp", False, False,
                           seed)
        return eng.run()

    iso = [solo(0), solo(1)]
    shared_ps = ParamStore()
    dom = ContentionDomain()
    plats = [ServerlessPlatform(seed=0), ServerlessPlatform(seed=0)]
    engs = [EventEngine(W, "ps", 6, 2048, BATCH, shared_ps, ObjectStore(),
                        samples=SAMPLES, straggler_sigma=0.2, seed=i,
                        platform=plats[i], domain=dom)
            for i in range(2)]
    dom.run()
    for i, eng in enumerate(engs):
        r = eng.result()
        _check_invariants(eng, plats[i], r)
        assert r.wall_s >= iso[i].wall_s - 1e-9
    # the union keep-alive window never exceeds the per-job sum and never
    # undershoots the longest single window
    sync = [e.result().sync_s for e in engs]
    assert max(sync) - 1e-9 <= dom.sync_union_s <= sum(sync) + 1e-9
    # billing splits exactly the union (no double-billed overlap): the
    # per-job shares sum to what the shared container is actually alive
    billed = [e.result().store_billed_s for e in engs]
    assert sum(billed) == pytest.approx(dom.sync_union_s, rel=1e-9)
    assert shared_ps.alive_seconds == pytest.approx(dom.sync_union_s,
                                                    rel=1e-9)


# -- golden trace regression -------------------------------------------------

GOLDEN = pathlib.Path(__file__).parent / "golden_engine_trace.txt"


def _golden_engine():
    return EventEngine(WORKLOADS["resnet18"], "hier", 2, 2048, 512,
                       ParamStore(), ObjectStore(), samples=1024,
                       straggler_sigma=0.3, seed=42)


def test_golden_trace_reproduced_verbatim():
    """The checked-in trace (seed 42, 2 workers, 2 iterations) must be
    reproduced byte-for-byte, twice in a row — engine edits that reorder
    events or change a timestamp fail loudly here, not silently."""
    a = _golden_engine().run()
    b = _golden_engine().run()
    text_a = "\n".join(a.trace) + "\n"
    text_b = "\n".join(b.trace) + "\n"
    assert text_a == text_b                      # byte-stable across runs
    assert text_a == GOLDEN.read_text()          # and across engine edits
