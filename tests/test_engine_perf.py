"""The throughput overhaul's correctness surface.

The engine rebuild (calendar-queue dispatch, coalesced homogeneous
cohorts, vectorized draws, incremental ``SharedLink`` accounting, probe
cache) must be invisible to everything above it:

  - the calendar queue pops in exactly ``(t, seq)`` order for arbitrary
    push/pop interleavings at any timescale;
  - a coalesced run equals a ``coalesce=False`` per-worker run of the
    same config — wall, cost, invocations, per-iteration times;
  - a 2048-worker fleet simulates in seconds (the scale smoke test) and
    still satisfies every engine invariant;
  - the probe cache returns exactly what the uncached closed forms
    return, and actually hits;
  - the named RNG streams are deterministic, independent, and preserve
    the legacy seed formulas the engine/trace tests pin;
  - ``record_trace=False`` changes the trace only (wall/cost identical).
"""
import time

import numpy as np
import pytest

from repro.core import Config
from repro.core.cost_model import epoch_estimate
from repro.core.probe_cache import DEFAULT_CACHE, ProbeCache
from repro.core.rng import (base_stream, curve_stream, shock_stream,
                            stream, stream_seed, worker_stream)
from repro.serverless import (WORKLOADS, EventEngine, FleetSpec, ObjectStore,
                              ParamStore, ServerlessPlatform)
from repro.serverless.events import CalendarQueue

from test_engine_invariants import _check_invariants

W = WORKLOADS["resnet18"]


# -- calendar queue -----------------------------------------------------------

def test_calendar_queue_pops_in_total_order():
    rng = np.random.RandomState(7)
    q = CalendarQueue()
    pushed = []
    seq = 0
    popped = []
    # interleave pushes and pops; times span 9 orders of magnitude and
    # include duplicates, so bucket resizing and same-bucket ordering both
    # get exercised
    for _ in range(3000):
        if pushed and rng.random_sample() < 0.4:
            popped.append(q.pop())
            pushed.sort()
            assert popped[-1] == pushed.pop(0)
        else:
            scale = 10.0 ** rng.randint(-3, 6)
            t = float(rng.random_sample() * scale)
            if pushed and rng.random_sample() < 0.1:
                t = pushed[-1][0]                    # duplicate timestamp
            ev = (t, seq, None, None)
            seq += 1
            q.push(ev)
            pushed.append(ev)
    # drain: the remainder must come out exactly in (t, seq) sorted order
    pushed.sort()
    drained = []
    while q:
        drained.append(q.pop())
    assert drained == pushed


def test_calendar_queue_monotone_time_pattern():
    # the engine's actual access pattern: pops interleaved with pushes of
    # near-future events
    q = CalendarQueue()
    q.push((0.0, 0, None, None))
    t, n = 0.0, 1
    last = (-1.0, -1)
    for _ in range(5000):
        ev = q.pop()
        assert ev[:2] >= last, "queue went backwards"
        last = ev[:2]
        t = ev[0]
        if n < 5000:
            q.push((t + 0.37, n, None, None))
            n += 1
    assert len(q) == 0


# -- coalesced cohorts --------------------------------------------------------

@pytest.mark.parametrize("scheme", ["hier", "ps", "scatter_reduce"])
def test_coalesced_equals_per_worker(scheme):
    def run(coalesce):
        plat = ServerlessPlatform(seed=0)
        return EventEngine(W, scheme, 32, 2048, 16_384, ParamStore(),
                           ObjectStore(), samples=32_768, seed=3,
                           platform=plat, coalesce=coalesce).run()
    a, b = run(None), run(False)
    assert a.wall_s == pytest.approx(b.wall_s, rel=1e-9)
    assert a.lambda_usd == pytest.approx(b.lambda_usd, rel=1e-9)
    assert a.store_usd == pytest.approx(b.store_usd, rel=1e-9)
    assert a.invocations == b.invocations
    assert a.iters_done == b.iters_done
    assert a.iter_times == pytest.approx(b.iter_times, rel=1e-9)


def test_coalesce_refused_when_ineligible():
    with pytest.raises(ValueError):
        EventEngine(W, "hier", 4, 2048, 2048, ParamStore(), ObjectStore(),
                    samples=4096, straggler_sigma=0.3, coalesce=True)


def test_large_fleet_smoke_is_fast_and_invariant():
    """2048 homogeneous bsp workers, 2 epochs — the scale the overhaul
    exists for. Must finish in seconds, not minutes, and keep every
    engine invariant."""
    n, gb = 2048, 2048 * 512
    plat = ServerlessPlatform(seed=0)
    eng = EventEngine(W, "hier", n, 2048, gb, ParamStore(), ObjectStore(),
                      samples=2 * gb, seed=11, platform=plat)
    t0 = time.perf_counter()
    r = eng.run()
    wall = time.perf_counter() - t0
    assert eng.coalesced
    assert wall < 60.0, f"2048-worker 2-epoch run took {wall:.1f}s"
    assert r.iters_done == 2
    _check_invariants(eng, plat, r, samples=2 * gb, batch=gb)


# -- probe cache --------------------------------------------------------------

def _probe_args():
    return dict(w=W, scheme="hier", config=Config(8, 2048),
                global_batch=4096, param_store=ParamStore(),
                object_store=ObjectStore())


def test_probe_cache_hits_and_matches_uncached():
    cache = ProbeCache()
    kw = _probe_args()
    raw = epoch_estimate(kw["w"], kw["scheme"], kw["config"],
                         kw["global_batch"], kw["param_store"],
                         kw["object_store"])
    first = cache.epoch_estimate(**kw)
    assert cache.misses == 1 and cache.hits == 0
    second = cache.epoch_estimate(**kw)
    assert cache.misses == 1 and cache.hits == 1
    for est in (first, second):
        assert est.wall_s == raw.wall_s
        assert est.cost_usd == raw.cost_usd
        assert est.it_breakdown == raw.it_breakdown
    # cached results are defensive copies, not shared mutables
    first.it_breakdown["poison"] = 1.0
    assert "poison" not in cache.epoch_estimate(**kw).it_breakdown


def test_probe_cache_distinguishes_configs_and_fleets():
    cache = ProbeCache()
    kw = _probe_args()
    cache.epoch_estimate(**kw)
    kw2 = dict(kw, config=Config(16, 2048))
    cache.epoch_estimate(**kw2)
    assert cache.misses == 2
    kw3 = dict(kw, fleet=FleetSpec.homogeneous(8, 2048))
    cache.epoch_estimate(**kw3)
    assert cache.misses == 3
    assert len(cache) == 3


def test_scheduler_uses_probe_cache():
    from repro.core import ConfigSpace, EpochPlan, Goal, TaskScheduler
    DEFAULT_CACHE.clear()
    plat = ServerlessPlatform(seed=0)
    sched = TaskScheduler(plat, ObjectStore(), ParamStore(),
                          space=ConfigSpace(max_workers=32), seed=0)
    sched.run([EpochPlan(batch_size=512, workload=W, samples=2048)],
              Goal("min_cost"))
    assert DEFAULT_CACHE.hits + DEFAULT_CACHE.misses > 0


# -- rng streams --------------------------------------------------------------

def test_stream_seed_deterministic_and_independent():
    a = stream_seed(42, "straggler", 0)
    assert a == stream_seed(42, "straggler", 0)
    others = {stream_seed(42, "straggler", 1), stream_seed(42, "failure", 0),
              stream_seed(43, "straggler", 0)}
    assert a not in others and len(others) == 3
    assert 0 <= a < 2 ** 31
    x = stream(42, "straggler", 0).random_sample(4)
    y = stream(42, "straggler", 0).random_sample(4)
    assert (x == y).all()


def test_legacy_seed_formulas_preserved():
    # the engine/trace tests pin traces produced by these exact formulas
    assert (worker_stream(5, 3, job_idx=2).random_sample()
            == np.random.RandomState(
                (5 * 1_000_003 + 3 + 611_953 * 2) % 2 ** 31).random_sample())
    assert (shock_stream(5, job_idx=1).random_sample()
            == np.random.RandomState(
                (5 * 2_147_483_029 + 97 + 1) % 2 ** 31).random_sample())
    assert (curve_stream(9).random_sample()
            == np.random.RandomState(9 * 9176 + 13).random_sample())
    assert (base_stream(7).random_sample()
            == np.random.RandomState(7).random_sample())


# -- record_trace=False -------------------------------------------------------

def test_record_trace_off_changes_only_the_trace():
    def run(**kw):
        return EventEngine(W, "hier", 8, 2048, 4096, ParamStore(),
                           ObjectStore(), samples=8192,
                           straggler_sigma=0.2, seed=5, **kw).run()
    on, off = run(), run(record_trace=False)
    assert off.trace == []
    assert on.trace
    assert off.wall_s == on.wall_s
    assert off.lambda_usd == on.lambda_usd
    assert off.store_usd == on.store_usd
    assert off.sim_events == on.sim_events
