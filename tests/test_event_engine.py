"""Discrete-event execution core: determinism, zero-variance equivalence
with the analytic model, straggler/sync-mode dynamics, duration-cap and
billing semantics, and the LocalWorkerPool's matching stale-gradient
numerics."""

import numpy as np
import pytest

from repro.core import Config, ConfigSpace, EpochPlan, Goal, TaskScheduler
from repro.core.comm import CommSpec
from repro.core.cost_model import epoch_estimate
from repro.serverless import (WORKLOADS, EventEngine, FleetSpec,
                              LocalWorkerPool, ObjectStore, ParamStore,
                              ServerlessPlatform, ShockModel)
from repro.serverless.platform import InvocationRecord

W = WORKLOADS["bert-small"]


def engine(w=W, scheme="hier", n=16, mem=4096, batch=1024, samples=20_000,
           **kw):
    return EventEngine(w, scheme, n, mem, batch, ParamStore(), ObjectStore(),
                       samples=samples, **kw)


# -- zero-variance equivalence (acceptance criterion) ------------------------

CASES = [
    ("resnet18", "hier", 16, 3072, 1024, 20_000),
    ("resnet18", "ps", 16, 3072, 1024, 20_000),
    ("resnet18", "ps_s3", 16, 3072, 1024, 20_000),
    ("bert-small", "hier", 32, 4096, 2048, 40_000),
    ("bert-small", "ps", 32, 4096, 2048, 40_000),
    ("bert-small", "ps_s3", 32, 4096, 2048, 40_000),
    ("resnet50", "hier", 8, 2048, 512, 10_000),
    ("resnet50", "ps", 8, 2048, 512, 10_000),
    ("resnet50", "ps_s3", 8, 2048, 512, 10_000),
]


@pytest.mark.parametrize("name,scheme,n,mem,batch,samples", CASES)
def test_zero_variance_matches_analytic(name, scheme, n, mem, batch, samples):
    """With zero straggler variance, no failures, bsp: the event engine
    must reproduce the closed-form epoch_estimate within 1%."""
    w = WORKLOADS[name]
    est = epoch_estimate(w, scheme, Config(n, mem), batch, ParamStore(),
                         ObjectStore(), samples=samples)
    r = engine(w, scheme, n, mem, batch, samples, seed=0).run()
    assert r.wall_s == pytest.approx(est.wall_s, rel=0.01)
    assert r.cost_usd == pytest.approx(est.cost_usd, rel=0.01)
    assert r.iters_done == est.iters


@pytest.mark.parametrize("name,scheme,n,mem,batch,samples", CASES)
def test_identical_fleet_matches_homogeneous_and_analytic(name, scheme, n,
                                                          mem, batch,
                                                          samples):
    """A heterogeneous fleet whose workers are all *identical* is the
    homogeneous deployment: the engine must reproduce the homogeneous
    engine bit-for-bit and the (fleet-aware) epoch_estimate within 1% in
    the zero-variance bsp limit."""
    w = WORKLOADS[name]
    fleet = FleetSpec.homogeneous(n, mem)
    est = epoch_estimate(w, scheme, Config(n, mem), batch, ParamStore(),
                         ObjectStore(), samples=samples, fleet=fleet)
    homog = engine(w, scheme, n, mem, batch, samples, seed=0).run()
    r = engine(w, scheme, n, mem, batch, samples, seed=0, fleet=fleet).run()
    assert r.wall_s == homog.wall_s
    assert r.lambda_usd == homog.lambda_usd
    assert r.store_usd == homog.store_usd
    assert r.trace == homog.trace
    assert r.wall_s == pytest.approx(est.wall_s, rel=0.01)
    assert r.cost_usd == pytest.approx(est.cost_usd, rel=0.01)


STRATEGIES = [CommSpec("ps"), CommSpec("scatter_reduce"),
              CommSpec("hier", branching=4),
              # overlap rows: the same strategies with a pipelined window
              CommSpec("ps", pipeline_depth=4),
              CommSpec("scatter_reduce", pipeline_depth=4),
              CommSpec("hier", branching=4, pipeline_depth=4)]
MODES = ["bsp", "ssp(2)", "async"]


@pytest.mark.parametrize("spec", STRATEGIES,
                         ids=[f"{s.strategy}-d{s.pipeline_depth}"
                              for s in STRATEGIES])
@pytest.mark.parametrize("mode", MODES)
def test_zero_variance_strategy_sync_matrix(spec, mode):
    """The {ps, scatter_reduce, hier} x {bsp, ssp, async} matrix, with and
    without compute∥comm overlap (``pipeline_depth=4``): at zero
    variance the engine must reproduce the closed form within 1% for
    every symmetric plan (all workers run every phase, so lockstep holds
    with or without barriers). The hier tree is asymmetric: without
    barriers its leaves overlap the root's aggregation with their next
    compute, so ssp/async may only be *faster* than the bsp closed form
    (bounded — the pipelining can't beat the root's critical path by
    much)."""
    est = epoch_estimate(W, spec, Config(16, 4096), 1024, ParamStore(),
                         ObjectStore(), samples=10_000)
    r = engine(W, spec, 16, 4096, 1024, 10_000, seed=0,
               sync_mode=mode).run()
    assert r.iters_done == est.iters
    if spec.strategy != "hier" or mode == "bsp":
        assert r.wall_s == pytest.approx(est.wall_s, rel=0.01)
        assert r.cost_usd == pytest.approx(est.cost_usd, rel=0.01)
    else:
        assert r.wall_s <= est.wall_s * 1.01
        assert r.wall_s >= est.wall_s * 0.90


def test_zero_variance_matches_with_duration_cap_restarts():
    """Equivalence must survive the checkpoint/restart path (long epoch,
    small fleet -> many 15-min windows)."""
    w = WORKLOADS["bert-medium"]
    est = epoch_estimate(w, "hier", Config(4, 2048), 512, ParamStore(),
                         ObjectStore(), samples=60_000)
    r = engine(w, "hier", 4, 2048, 512, 60_000, seed=0).run()
    assert est.restarts_per_worker >= 1
    assert r.restarts == 4 * est.restarts_per_worker
    assert r.wall_s == pytest.approx(est.wall_s, rel=0.01)
    assert r.cost_usd == pytest.approx(est.cost_usd, rel=0.01)


# -- determinism -------------------------------------------------------------

def test_trace_byte_identical_same_seed():
    kw = dict(straggler_sigma=0.4, failure_rate=0.03, seed=7)
    a = engine(**kw).run()
    b = engine(**kw).run()
    assert "\n".join(a.trace) == "\n".join(b.trace)
    assert a.wall_s == b.wall_s and a.cost_usd == b.cost_usd


def test_trace_differs_across_seeds():
    a = engine(straggler_sigma=0.4, seed=1).run()
    b = engine(straggler_sigma=0.4, seed=2).run()
    assert "\n".join(a.trace) != "\n".join(b.trace)


# -- straggler dynamics ------------------------------------------------------

def test_straggler_tail_monotone():
    """BSP pays the max of n lognormals per iteration: wall-clock must
    grow strictly with the straggler sigma."""
    walls = [engine(straggler_sigma=s, seed=0, samples=10_000).run().wall_s
             for s in (0.0, 0.25, 0.6)]
    assert walls[0] < walls[1] < walls[2]


def test_relaxed_sync_never_slower():
    """Gates only remove waiting: under stragglers,
    wall(async) <= wall(ssp(2)) <= wall(bsp)."""
    kw = dict(straggler_sigma=0.5, seed=0, samples=10_000)
    bsp = engine(sync_mode="bsp", **kw).run()
    ssp = engine(sync_mode="ssp", staleness=2, **kw).run()
    asy = engine(sync_mode="async", **kw).run()
    assert asy.wall_s <= ssp.wall_s + 1e-9
    assert ssp.wall_s <= bsp.wall_s + 1e-9
    assert bsp.iters_done == ssp.iters_done == asy.iters_done


def test_failures_redo_iterations_and_invoke():
    ok = engine(seed=3).run()
    bad = engine(failure_rate=0.05, seed=3).run()
    assert bad.failures > 0
    assert bad.wall_s > ok.wall_s
    assert bad.invocations > ok.invocations      # each failure re-invokes


# -- duration-cap / billing semantics ---------------------------------------

def test_platform_finish_clamps_to_cap():
    """An invocation reported past max_duration_s is split into capped
    restarts, each billed as its own request."""
    plat = ServerlessPlatform(max_duration_s=900.0)
    rec = InvocationRecord(worker_id=0, start=0.0)
    plat.invocations.append(rec)
    recs = plat.finish(rec, 1024.0, end=2000.0)
    assert len(recs) == 3                        # 900 + 900 + 200
    assert all(r.end - r.start <= 900.0 + 1e-9 for r in recs)
    assert plat.ledger.requests == 3
    assert plat.ledger.gb_seconds == pytest.approx(2000.0)
    assert recs[1].resumed and recs[2].resumed


def test_fleet_billing_one_request_per_worker_invocation():
    """Satellite: the scheduler must record n requests per epoch (plus
    restarts), not 1 for the whole fleet."""
    plat = ServerlessPlatform(seed=0)
    sched = TaskScheduler(plat, ObjectStore(), ParamStore(), seed=0,
                          space=ConfigSpace(max_workers=64))
    res = sched.run([EpochPlan(512, WORKLOADS["resnet18"], samples=20_000)],
                    Goal("min_time"), adaptive=False,
                    fixed_config=Config(workers=16, memory_mb=3072))
    eps = [e for e in res.events if e.kind == "epoch"]
    assert plat.ledger.requests == 16 * (eps[0].restarts + 1)


def test_engine_invocations_match_lambda_semantics():
    r = engine(w=WORKLOADS["bert-medium"], n=4, mem=2048, batch=512,
               samples=60_000, seed=0).run()
    assert r.invocations == 4 + r.restarts       # 1 per worker + 1 per restart


def test_engine_billing_parity_with_platform_ledger():
    """Satellite: EngineResult's Lambda bill and the ServerlessPlatform
    ledger (which charges per invocation record as they close) must agree
    on a run with both cap-restarts and failures — the two billing paths
    can never drift apart."""
    plat = ServerlessPlatform(seed=0)
    r = engine(w=WORKLOADS["bert-medium"], n=4, mem=2048, batch=512,
               samples=60_000, seed=1, failure_rate=0.03,
               platform=plat).run()
    assert r.restarts > 0 and r.failures > 0     # both paths exercised
    assert plat.ledger.requests == r.invocations
    assert plat.ledger.lambda_cost == pytest.approx(r.lambda_usd, rel=1e-9)


def test_engine_billing_parity_hetero_fleet_with_shocks():
    """Billing parity must survive per-worker memory rates and correlated
    shock kills (each billed at the dead worker's own memory)."""
    plat = ServerlessPlatform(seed=0)
    fleet = FleetSpec.mixed([(3, 3072, "standard"), (3, 1536, "spot")])
    r = engine(n=6, mem=3072, batch=512, samples=4_096, seed=2,
               fleet=fleet, platform=plat,
               shocks=ShockModel(interval_s=60.0, kill_frac=0.5,
                                 tier="spot")).run()
    assert r.failures > 0 and r.shock_events > 0
    assert plat.ledger.requests == r.invocations == 6 + r.restarts + r.failures
    assert plat.ledger.lambda_cost == pytest.approx(r.lambda_usd, rel=1e-9)


# -- mid-epoch adaptation ----------------------------------------------------

def test_on_iteration_early_stop_checkpoints():
    r = engine(n=8, samples=20_000, seed=0,
               on_iteration=lambda g, t, dt: g >= 7).run()
    assert r.stopped_early
    assert r.iters_done == 7
    assert r.samples_done == 7 * 1024


def test_scheduler_reoptimizes_mid_epoch_on_drift():
    """A 4x platform slowdown partway through the epoch must trip the
    ThroughputMonitor and trigger a mid-epoch re-optimization."""
    plat = ServerlessPlatform(seed=0)
    sched = TaskScheduler(plat, ObjectStore(), ParamStore(), seed=0,
                          space=ConfigSpace(max_workers=64), engine="event",
                          engine_opts={"straggler_sigma": 0.1,
                                       "slowdown_at_iter": 20,
                                       "slowdown_factor": 4.0})
    res = sched.run([EpochPlan(1024, WORKLOADS["bert-small"],
                               samples=300_000)], Goal("min_time"))
    kinds = [e.kind for e in res.events]
    assert "reoptimize_mid" in kinds
    assert res.epochs_done == 1
    assert len(res.config_history) >= 2          # redeployed mid-epoch


def test_scheduler_event_path_near_analytic_at_zero_variance():
    def run(engine_kind):
        plat = ServerlessPlatform(seed=0)
        sched = TaskScheduler(plat, ObjectStore(), ParamStore(), seed=0,
                              space=ConfigSpace(max_workers=64),
                              engine=engine_kind)
        return sched.run([EpochPlan(1024, W, samples=30_000)],
                         Goal("min_time"), adaptive=False,
                         fixed_config=Config(workers=16, memory_mb=4096))

    a, e = run("analytic"), run("event")
    assert e.wall_s == pytest.approx(a.wall_s, rel=0.01)
    assert e.cost_usd == pytest.approx(a.cost_usd, rel=0.01)


# -- LocalWorkerPool stale-gradient numerics --------------------------------

def _tiny_model():
    import jax
    from repro.configs import ARCHS, reduced, reduced_batch
    from repro.models import registry
    cfg = reduced(ARCHS["olmo-1b"]).replace(n_layers=1, d_model=64)
    batch = reduced_batch(cfg, batch=8, seq=16)
    params0 = registry.init(jax.random.key(0), cfg)
    grad_fn = jax.jit(lambda p, b: jax.grad(
        lambda q: registry.loss_fn(q, cfg, b))(p))
    loss_fn = jax.jit(lambda p, b: registry.loss_fn(p, cfg, b))
    return params0, batch, grad_fn, loss_fn


def _train(pool, params0, batch, loss_fn, steps=6, lr=0.1):
    from repro.optim import apply_sgd
    p = params0
    losses = [float(loss_fn(p, batch))]
    for _ in range(steps):
        g = pool.step(p, batch)
        p = apply_sgd(p, g, lr)
        losses.append(float(loss_fn(p, batch)))
    return losses


def test_ssp0_is_exactly_bsp():
    """ssp with bound 0 refreshes every step -> bit-identical to bsp."""
    params0, batch, grad_fn, loss_fn = _tiny_model()
    bsp = _train(LocalWorkerPool(grad_fn, 4, ParamStore()),
                 params0, batch, loss_fn)
    ssp0 = _train(LocalWorkerPool(grad_fn, 4, ParamStore(),
                                  sync_mode="ssp(0)"),
                  params0, batch, loss_fn)
    np.testing.assert_array_equal(bsp, ssp0)


def test_pipelined_pool_matches_sequential_numerics():
    """A pipelined plan maps to micro-batched gradient accumulation in
    the semantic pool: the weighted per-segment mean *is* the full-slice
    gradient, so overlap changes the timing model and never the
    training numerics — across strategies and depths (including a depth
    that doesn't divide the slice)."""
    import jax
    params0, batch, grad_fn, loss_fn = _tiny_model()
    base = LocalWorkerPool(grad_fn, 4, ParamStore()).step(params0, batch)
    flat = np.concatenate([np.asarray(x).ravel()
                           for x in jax.tree.leaves(base)])
    for spec in (CommSpec("scatter_reduce", pipeline_depth=2),
                 CommSpec("ps", pipeline_depth=4),
                 CommSpec("hier", branching=2, pipeline_depth=3)):
        pool = LocalWorkerPool(grad_fn, 4, ParamStore(), plan=spec)
        g = pool.step(params0, batch)
        f = np.concatenate([np.asarray(x).ravel()
                            for x in jax.tree.leaves(g)])
        np.testing.assert_allclose(f, flat, rtol=1e-4, atol=1e-6,
                                   err_msg=str(spec))


def test_ssp_and_async_converge_on_quickstart_model():
    """Bounded-stale and async gradients still train the quickstart model:
    loss decreases clearly under both; small k stays close to bsp."""
    params0, batch, grad_fn, loss_fn = _tiny_model()
    results = {}
    for mode, kw in [("bsp", {}), ("ssp2", {"sync_mode": "ssp(2)"}),
                     ("async", {"sync_mode": "async", "seed": 0})]:
        pool = LocalWorkerPool(grad_fn, 4, ParamStore(), **kw)
        results[mode] = _train(pool, params0, batch, loss_fn, steps=8)
    for mode, losses in results.items():
        assert losses[-1] < losses[0] - 0.5, (mode, losses)
    # staleness costs something but not divergence
    assert results["ssp2"][-1] < results["ssp2"][0] - 0.5
