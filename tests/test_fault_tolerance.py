"""Fault-tolerance properties of the task scheduler (paper Section 4.1)."""
import numpy as np

from repro.core import Config, ConfigSpace, EpochPlan, Goal, TaskScheduler
from repro.serverless import (WORKLOADS, ObjectStore, ParamStore,
                              ServerlessPlatform)

W = WORKLOADS["resnet18"]


def run_with_failures(rate, seed=0):
    plat = ServerlessPlatform(failure_rate=rate, seed=seed)
    sched = TaskScheduler(plat, ObjectStore(), ParamStore(), seed=seed,
                          space=ConfigSpace(max_workers=64))
    plans = [EpochPlan(512, W, samples=30_000) for _ in range(3)]
    return sched.run(plans, Goal("min_time"), adaptive=False,
                     fixed_config=Config(workers=16, memory_mb=3072))


def test_training_completes_under_heavy_failures():
    res = run_with_failures(0.20)
    assert res.epochs_done == 3
    assert sum(e.failures for e in res.events) > 0


def test_cost_and_time_grow_with_failure_rate():
    walls, costs = [], []
    for rate in (0.0, 0.05, 0.25):
        r = run_with_failures(rate, seed=1)
        walls.append(r.wall_s)
        costs.append(r.total_cost)
    assert walls[0] < walls[1] < walls[2]
    assert costs[0] < costs[1] < costs[2]


def test_restart_overhead_vs_duration_cap():
    """Shorter duration caps -> more restarts -> strictly more wall time."""
    from repro.core.cost_model import epoch_estimate
    cfg = Config(workers=8, memory_mb=2048)
    long_cap = epoch_estimate(WORKLOADS["bert-medium"], "hier", cfg, 512,
                              ParamStore(), ObjectStore(), samples=100_000,
                              max_duration_s=900.0)
    short_cap = epoch_estimate(WORKLOADS["bert-medium"], "hier", cfg, 512,
                               ParamStore(), ObjectStore(), samples=100_000,
                               max_duration_s=120.0)
    assert short_cap.restarts_per_worker > long_cap.restarts_per_worker
    assert short_cap.wall_s > long_cap.wall_s


def test_checkpoint_restart_resumes_training_exactly():
    """The full duration-cap path: train, checkpoint, 'die', restore into a
    fresh process-equivalent, continue — must equal uninterrupted run."""
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import CheckpointMeta, DiskCheckpointer
    from repro.configs import ARCHS, reduced
    from repro.data import DataConfig, IteratorState, ShardedLoader, TokenDataset
    from repro.models import registry
    from repro.optim import AdamW
    import tempfile

    cfg = reduced(ARCHS["olmo-1b"]).replace(n_layers=1, d_model=64)
    opt = AdamW(lr=1e-2)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=16)

    @jax.jit
    def step(params, opt_state, batch):
        loss, g = jax.value_and_grad(
            lambda p: registry.loss_fn(p, cfg, batch))(params)
        params, opt_state = opt.update(g, opt_state, params)
        return params, opt_state, loss

    def fresh():
        params = registry.init(jax.random.key(0), cfg)
        return params, opt.init(params), ShardedLoader(TokenDataset(data))

    # uninterrupted
    p, o, loader = fresh()
    losses_a = []
    for i in range(6):
        b = {k: jnp.asarray(v) for k, v in loader.next_batch(4).items()}
        p, o, loss = step(p, o, b)
        losses_a.append(float(loss))

    # interrupted at step 3
    with tempfile.TemporaryDirectory() as d:
        ck = DiskCheckpointer(d)
        p, o, loader = fresh()
        losses_b = []
        for i in range(3):
            b = {k: jnp.asarray(v) for k, v in loader.next_batch(4).items()}
            p, o, loss = step(p, o, b)
            losses_b.append(float(loss))
        ck.save("w", {"p": p, "o": o},
                CheckpointMeta(step=3, epoch=loader.state.epoch,
                               index=loader.state.index))
        restored, meta = ck.restore("w", {"p": p, "o": o})
        p2, o2 = restored["p"], restored["o"]
        loader2 = ShardedLoader(TokenDataset(data),
                                IteratorState(meta.epoch, meta.index))
        for i in range(3):
            b = {k: jnp.asarray(v) for k, v in loader2.next_batch(4).items()}
            p2, o2, loss = step(p2, o2, b)
            losses_b.append(float(loss))

    np.testing.assert_allclose(losses_a, losses_b, rtol=1e-5)
