"""Hierarchical synchronization tests.

Semantic checks that need >1 device run in a subprocess with 8 host
devices (see spmd_checks.py); single-process tests cover the shard math
of the LocalWorkerPool (real payloads through the simulated param store).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # optional dep: fixed example cases
    from hypothesis_fallback import given, settings, st

from repro.serverless import LocalWorkerPool, ParamStore
from repro.serverless.worker import (flatten_grads, join_shards, make_shards,
                                     unflatten_grads)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_check(name):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "spmd_checks.py"), name],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert f"OK {name}" in out.stdout


@pytest.mark.slow
def test_sync_equivalence_8dev():
    """allreduce/hier/hier2/ps all equal the full-batch gradient on a real
    8-device mesh (1-axis and pod x data)."""
    _run_check("sync_equivalence")


@pytest.mark.slow
def test_sync_property_8dev():
    """Hierarchical RS+AG is an exact mean for random leaf shapes (incl.
    sizes not divisible by the worker count — padding path)."""
    _run_check("sync_property")


@pytest.mark.slow
def test_elastic_rescale_8dev():
    """Elastic fleet rescaling mid-training is numerically invisible."""
    _run_check("elastic")


@pytest.mark.slow
def test_hier2_q_compressed_cross_pod_8dev():
    """bf16-compressed cross-pod hop stays within bf16 error of exact."""
    _run_check("hier2_q")


# ---------------------------------------------------------------------------
# shard math (paper Fig. 5) — property-based
# ---------------------------------------------------------------------------


@given(n=st.integers(1, 12), size=st.integers(1, 500))
@settings(max_examples=40, deadline=None)
def test_shard_roundtrip(n, size):
    rng = np.random.RandomState(size * 131 + n)
    flat = rng.randn(size).astype(np.float32)
    shards = make_shards(flat, n)
    assert len(shards) == n
    assert len({s.shape for s in shards}) == 1  # equal-sized (paper: m equal)
    back = join_shards(shards, size)
    np.testing.assert_array_equal(back, flat)


@given(seed=st.integers(0, 100), n_workers=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=15, deadline=None)
def test_local_pool_equals_fullbatch(seed, n_workers):
    """The Figure-5 dataflow through the param store == full-batch grad."""
    rng = np.random.RandomState(seed)
    params = {"w": jnp.array(rng.randn(4, 3), jnp.float32),
              "b": jnp.array(rng.randn(3), jnp.float32)}
    batch = {"x": jnp.array(rng.randn(8 * n_workers, 4), jnp.float32),
             "y": jnp.array(rng.randn(8 * n_workers, 3), jnp.float32)}

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)

    pool = LocalWorkerPool(lambda p, b: jax.grad(loss)(p, b), n_workers,
                           ParamStore())
    g = pool.step(params, batch)
    ref = jax.grad(loss)(params, batch)
    for a, b_ in zip(jax.tree.leaves(g), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-6)


def test_local_pool_kernel_aggregation():
    """Fig-5 step 3 through the Pallas hier_agg kernel == numpy path."""
    rng = np.random.RandomState(3)
    params = {"w": jnp.array(rng.randn(6, 5), jnp.float32)}
    batch = {"x": jnp.array(rng.randn(16, 6), jnp.float32),
             "y": jnp.array(rng.randn(16, 5), jnp.float32)}

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

    gf = lambda p, b: jax.grad(loss)(p, b)
    g_np = LocalWorkerPool(gf, 4, ParamStore()).step(params, batch)
    g_k = LocalWorkerPool(gf, 4, ParamStore(),
                          use_kernel=True).step(params, batch)
    for a, b_ in zip(jax.tree.leaves(g_np), jax.tree.leaves(g_k)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-6, atol=1e-7)


def test_flatten_unflatten_roundtrip():
    tree = {"a": jnp.ones((3, 4)), "b": {"c": jnp.arange(5.0)}}
    flat = flatten_grads(tree)
    back = unflatten_grads(flat, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
