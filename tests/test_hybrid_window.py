"""Hybrid (Zamba2-style) sliding-window ring cache: decode past the window
boundary must match the windowed full-attention reference — this is the
mechanism that makes long_500k sub-quadratic for the hybrid."""
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models import registry

WINDOW = 16
S_TOTAL = 48  # decode well past the window (3x wrap)


def test_ring_cache_wraparound_matches_windowed_attention():
    cfg = reduced(ARCHS["zamba2-7b"]).replace(sliding_window=WINDOW)
    params = registry.init(jax.random.key(0), cfg)
    rng = jax.random.key(1)
    toks = jax.random.randint(rng, (2, S_TOTAL), 0, cfg.vocab_size)

    # reference: full forward with the sliding-window mask
    full_logits, _ = registry.prefill(params, cfg,
                                      {"tokens": toks}, max_seq=S_TOTAL)

    # decode path: prefill half the window, then decode one-by-one through
    # 3 wraps of the ring buffer
    start = WINDOW // 2
    _, cache = registry.prefill(params, cfg, {"tokens": toks[:, :start]},
                                max_seq=S_TOTAL)
    max_diff = 0.0
    for t in range(start, S_TOTAL):
        logits, cache = registry.decode_step(params, cfg, cache,
                                             jnp.int32(t), toks[:, t:t + 1])
        d = float(jnp.max(jnp.abs(full_logits[:, t] - logits[:, 0])))
        max_diff = max(max_diff, d)
    assert max_diff < 5e-3, max_diff


def test_ring_cache_is_window_sized():
    cfg = reduced(ARCHS["zamba2-7b"]).replace(sliding_window=WINDOW)
    params = registry.init(jax.random.key(0), cfg)
    cache = registry.init_decode_cache(params, cfg, batch=2,
                                       max_seq=1 << 16)
    # attention K/V allocated at window size, not 64k — O(window) memory
    assert cache["attn"]["k"].shape[2] == WINDOW
