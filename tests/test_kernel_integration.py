"""Kernel-in-model integration: enabling the Pallas paths
(use_flash_kernel / use_ssd_kernel) must not change model outputs."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced, reduced_batch
from repro.models import registry


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2.5-3b"])
def test_flash_kernel_path_matches(arch):
    cfg = reduced(ARCHS[arch]).replace(head_dim=32)
    params = registry.init(jax.random.key(0), cfg)
    batch = reduced_batch(cfg, 2, 64)
    base = registry.loss_fn(params, cfg, batch)
    flash = registry.loss_fn(params, cfg.replace(use_flash_kernel=True),
                             batch)
    np.testing.assert_allclose(float(base), float(flash), rtol=1e-5)


def test_flash_kernel_grads_match():
    cfg = reduced(ARCHS["olmo-1b"])
    params = registry.init(jax.random.key(1), cfg)
    batch = reduced_batch(cfg, 2, 32)
    g0 = jax.grad(lambda p: registry.loss_fn(p, cfg, batch))(params)
    g1 = jax.grad(lambda p: registry.loss_fn(
        p, cfg.replace(use_flash_kernel=True), batch))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=1e-5)


def test_ssd_kernel_path_matches():
    cfg = reduced(ARCHS["mamba2-2.7b"])
    params = registry.init(jax.random.key(0), cfg)
    batch = reduced_batch(cfg, 2, 48)
    base = registry.loss_fn(params, cfg, batch)
    kern = registry.loss_fn(params, cfg.replace(use_ssd_kernel=True), batch)
    np.testing.assert_allclose(float(base), float(kern), rtol=1e-4)


def test_hybrid_window_kernel_matches():
    """Sliding-window flash path == windowed blockwise in the hybrid."""
    cfg = reduced(ARCHS["zamba2-7b"])
    params = registry.init(jax.random.key(0), cfg)
    batch = reduced_batch(cfg, 2, 64)
    base = registry.loss_fn(params, cfg, batch)
    both = registry.loss_fn(
        params, cfg.replace(use_flash_kernel=True, use_ssd_kernel=True),
        batch)
    np.testing.assert_allclose(float(base), float(both), rtol=1e-4)
