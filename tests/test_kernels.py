"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
pure-jnp oracles in repro.kernels.ref (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.RandomState(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# hier_agg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_workers", [1, 2, 8, 17])
@pytest.mark.parametrize("length", [128, 1000, 8192, 20000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_aggregate_shards(n_workers, length, dtype):
    x = jnp.array(RNG.randn(n_workers, length), dtype)
    got = ops.aggregate_shards(x, block=1024)
    want = ref.ref_aggregate(x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("length", [512, 5000])
def test_aggregate_and_apply(length):
    x = jnp.array(RNG.randn(4, length), jnp.float32)
    p = jnp.array(RNG.randn(length), jnp.float32)
    got = ops.aggregate_and_apply(x, p, lr=0.05, block=512)
    want = ref.ref_aggregate_apply(x, p, 0.05)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seq,block", [(128, 64), (160, 64), (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal(seq, block, dtype):
    b, h, d = 2, 3, 64
    q = jnp.array(RNG.randn(b, h, seq, d), dtype)
    k = jnp.array(RNG.randn(b, h, seq, d), dtype)
    v = jnp.array(RNG.randn(b, h, seq, d), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=block,
                              block_k=block)
    want = ref.ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_sliding_window(window):
    b, h, seq, d = 1, 2, 192, 32
    q = jnp.array(RNG.randn(b, h, seq, d), jnp.float32)
    k = jnp.array(RNG.randn(b, h, seq, d), jnp.float32)
    v = jnp.array(RNG.randn(b, h, seq, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    want = ref.ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_flash_matches_model_blockwise():
    """The model-side jnp blockwise attention and the Pallas kernel agree."""
    from repro.models.layers import blockwise_attention
    b, h, seq, d = 2, 2, 128, 32
    q = jnp.array(RNG.randn(b, h, seq, d), jnp.float32)
    k = jnp.array(RNG.randn(b, h, seq, d), jnp.float32)
    v = jnp.array(RNG.randn(b, h, seq, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    # model layout is (b, s, h, d)
    want = blockwise_attention(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3),
                               causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------


def _ssd_inputs(b, s, h, p, n, dtype=jnp.float32):
    x = jnp.array(RNG.randn(b, s, h, p), dtype)
    dt = jnp.array(np.abs(RNG.randn(b, s, h)) * 0.5 + 0.01, dtype)
    A = -jnp.array(np.abs(RNG.randn(h)) + 0.5, jnp.float32)
    B = jnp.array(RNG.randn(b, s, n), dtype)
    C = jnp.array(RNG.randn(b, s, n), dtype)
    D = jnp.array(RNG.randn(h), jnp.float32)
    return x, dt, A, B, C, D


@pytest.mark.parametrize("s,chunk", [(64, 16), (100, 32), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(s, chunk, dtype):
    x, dt, A, B, C, D = _ssd_inputs(2, s, 4, 16, 8, dtype)
    y, S = ops.ssd_scan(x, dt, A, B, C, D, chunk=chunk)
    yr, Sr = ref.ref_ssd(x, dt, A, B, C, D)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(S), np.asarray(Sr),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 2e-4,
                               atol=1e-2 if dtype == jnp.bfloat16 else 2e-4)


def test_ssd_kernel_matches_model_chunked():
    from repro.models.mamba2 import ssd_chunked
    x, dt, A, B, C, D = _ssd_inputs(1, 96, 2, 8, 4)
    y, S = ops.ssd_scan(x, dt, A, B, C, D, chunk=32)
    y2, S2 = ssd_chunked(x, dt, A, B, C, D, 32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S2),
                               rtol=1e-4, atol=1e-4)
