"""Fixture tests for the simlint passes.

``tests/lint_fixtures/known_bad/`` holds one file per pass with every
rule violated on a commented line; ``known_clean/`` holds the blessed
idioms for the same operations. The two trees are linted separately —
the trace-kind cross-check is project-wide, and the bad tree declares
its own ``TraceEvent`` that must not be merged with the clean one.
"""
from pathlib import Path

import pytest

from repro.analysis import RULES, Linter

FIXTURES = Path(__file__).parent / "lint_fixtures"
BAD = FIXTURES / "known_bad"
CLEAN = FIXTURES / "known_clean"


def _findings(root):
    return Linter().lint_paths([str(root)])


@pytest.fixture(scope="module")
def bad():
    return _findings(BAD)


def _at(findings, filename, rule):
    """Lines in ``filename`` where ``rule`` fired."""
    return sorted(f.line for f in findings
                  if f.path.endswith(filename) and f.rule == rule)


def test_clean_tree_is_clean():
    assert _findings(CLEAN) == []


def test_every_rule_has_a_fixture(bad):
    fired = {f.rule for f in bad}
    missing = set(RULES) - fired - {"parse-error"}
    assert not missing, f"rules with no known-bad fixture: {sorted(missing)}"


def test_determinism_rules(bad):
    f = "known_bad/repro/serverless/bad_det.py"
    assert _at(bad, f, "det-global-rng") == [14, 18]
    assert _at(bad, f, "det-wallclock") == [22, 26]
    assert _at(bad, f, "det-raw-randomstate") == [30]
    assert _at(bad, f, "det-set-iter") == [35, 37, 41]


def test_unit_rules(bad):
    f = "known_bad/bad_units.py"
    assert _at(bad, f, "unit-mix") == [5, 6, 7, 15, 18]
    assert _at(bad, f, "unit-assign") == [8, 9, 20, 21]
    # multiplication is a conversion: line 10 must NOT be flagged
    assert all(x.line != 10 for x in bad if x.path.endswith(f))


def test_coverage_rules(bad):
    f = "known_bad/bad_coverage.py"
    assert _at(bad, f, "trace-kind-dead") == [16]
    assert _at(bad, f, "trace-kind-undeclared") == [30]
    assert _at(bad, f, "event-unbound-handler") == [34]
    # the correctly-bound push on line 33 is not flagged
    assert all(x.line != 33 for x in bad if x.path.endswith(f))


def test_api_rules(bad):
    f = "known_bad/bad_api.py"
    assert _at(bad, f, "api-unseeded-rng") == [14, 21]
    assert _at(bad, f, "api-frozen-mutation") == [15, 16]


def test_suppressions_require_a_reason(bad):
    f = "known_bad/bad_suppression.py"
    # a reasonless ok(...) is reported AND does not suppress
    assert _at(bad, f, "suppression-needs-reason") == [6]
    assert 6 in _at(bad, f, "det-wallclock")
    # an unknown rule id is reported AND does not suppress
    assert _at(bad, f, "suppression-unknown-rule") == [10]
    assert 10 in _at(bad, f, "det-wallclock")


def test_suppression_with_reason_suppresses():
    # clean.py carries exactly one suppression (a comment-only line
    # covering the wall-clock read below it) and lints clean
    src = (CLEAN / "repro/serverless/clean.py").read_text()
    assert "simlint: ok(det-wallclock," in src
    assert "time.time()" in src
    assert _findings(CLEAN / "repro/serverless/clean.py") == []


def test_docstring_mention_is_not_a_suppression(tmp_path):
    # `# simlint: ok(...)` inside a string literal is documentation;
    # only real comment tokens suppress (or get policy-checked)
    p = tmp_path / "doc.py"
    p.write_text('"""example: # simlint: ok(det-wallclock)"""\nx = 1\n')
    assert Linter().lint_paths([str(p)]) == []


def test_severity_threshold_exit_codes():
    from repro.analysis.lint import main
    bad_paths = [str(BAD)]
    assert main(bad_paths + ["--fail-on", "never"]) == 0
    assert main(bad_paths + ["--fail-on", "error"]) == 1
    assert main(bad_paths + ["--fail-on", "warning"]) == 1
    clean_paths = [str(CLEAN)]
    assert main(clean_paths + ["--fail-on", "warning"]) == 0
