"""Regression pins for the real violations simlint surfaced (PR 9).

The linter's first run over the tree found, among others:

- ``TokenDataset``/``OnlineStream``/``LocalWorkerPool``/the serving
  batcher constructing ``np.random.RandomState`` directly instead of
  going through ``repro.core.rng`` (det-raw-randomstate) — fixed by
  routing through ``base_stream``, which is bit-identical by contract.
- ``TraceEvent.KINDS`` declaring a ``"profile"`` kind that nothing has
  emitted since the profiling events moved onto the cost ledger
  (trace-kind-dead) — the runtime ``__post_init__`` check can only see
  the *other* direction, so the dead kind sat there keeping
  ``e.kind == "profile"`` filters looking alive.
- wall-clock ``time.time()`` duration timing in the launch scripts and
  the e2e example (det-wallclock) — moved to ``time.perf_counter``.

These tests pin each fix so it cannot quietly regress, and assert the
lint baseline of zero findings over the shipped tree.
"""
import ast
from pathlib import Path

import numpy as np

from repro.analysis import Linter
from repro.core.rng import base_stream
from repro.core.scheduler import TraceEvent
from repro.data.pipeline import DataConfig, TokenDataset

REPO = Path(__file__).parent.parent


def test_token_dataset_draws_are_stream_routed_and_stable():
    """The nastiest pre-existing violation: TokenDataset seeded a raw
    RandomState from an ad-hoc formula. base_stream must reproduce the
    exact bit pattern (same-seed batches are golden-trace inputs)."""
    cfg = DataConfig(vocab_size=64, seq_len=8, seed=7)
    a = TokenDataset(cfg).sample(epoch=3, index=11, n=4, seq=8)
    b = TokenDataset(cfg).sample(epoch=3, index=11, n=4, seq=8)
    np.testing.assert_array_equal(a, b)
    # and base_stream is RandomState bit-for-bit at the formula's seed,
    # so every pre-fix golden artifact derived from this data stays valid
    seed = (7 * 1_000_003 + 3 * 7919 + 11) % (2 ** 31)
    np.testing.assert_array_equal(
        base_stream(seed).randint(0, 64, size=(4, 8)),
        np.random.RandomState(seed).randint(0, 64, size=(4, 8)))


def test_trace_kinds_match_emissions():
    """Both directions of KINDS sync, statically: every literal kind
    constructed anywhere in src/ is declared, and every declared kind
    is constructed somewhere (no dead kinds — the 'profile' bug)."""
    emitted = set()
    for path in (REPO / "src").rglob("*.py"):
        tree = ast.parse(path.read_text())
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                fn = node.func
                name = fn.attr if isinstance(fn, ast.Attribute) else \
                    getattr(fn, "id", None)
                if name != "TraceEvent":
                    continue
                if len(node.args) > 2 and isinstance(
                        node.args[2], ast.Constant):
                    emitted.add(node.args[2].value)
                for kw in node.keywords:
                    if kw.arg == "kind" and isinstance(
                            kw.value, ast.Constant):
                        emitted.add(kw.value.value)
    assert emitted == set(TraceEvent.KINDS), (
        "TraceEvent.KINDS drifted from the actual emission sites: "
        f"declared={sorted(TraceEvent.KINDS)} emitted={sorted(emitted)}")


def test_no_wallclock_in_launch_or_examples():
    for rel in ("src/repro/launch", "examples"):
        for path in (REPO / rel).rglob("*.py"):
            assert "time.time()" not in path.read_text(), (
                f"{path}: wall-clock read reintroduced; use "
                "time.perf_counter for durations")


def test_shipped_tree_lints_clean():
    """The zero-findings baseline CI enforces, asserted from pytest too
    so a local run catches drift before CI does."""
    roots = [str(REPO / d) for d in ("src", "benchmarks", "examples")]
    findings = Linter().lint_paths(roots)
    assert findings == [], "\n".join(f.render() for f in findings)
