"""Model-layer numerics: property-based checks of the blockwise/chunked
forms against naive references, vocab-padding handling, rope invariants."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # optional dep: fixed example cases
    from hypothesis_fallback import given, settings, st

from repro.kernels import ref
from repro.models import layers as L
from repro.models.base import ModelConfig
from repro.models.mamba2 import ssd_chunked


@given(seq=st.integers(4, 96), qb=st.sampled_from([4, 16, 64]),
       window=st.sampled_from([0, 8, 32]))
@settings(max_examples=20, deadline=None)
def test_blockwise_attention_property(seq, qb, window):
    rng = np.random.RandomState(seq * 7 + qb)
    b, h, d = 1, 2, 16
    q = jnp.array(rng.randn(b, seq, h, d), jnp.float32)
    k = jnp.array(rng.randn(b, seq, h, d), jnp.float32)
    v = jnp.array(rng.randn(b, seq, h, d), jnp.float32)
    got = L.blockwise_attention(q, k, v, causal=True, sliding_window=window,
                                q_block=qb)
    want = ref.ref_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=True,
                             window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@given(s=st.integers(8, 80), chunk=st.sampled_from([8, 16, 32]))
@settings(max_examples=15, deadline=None)
def test_ssd_chunked_equals_sequential(s, chunk):
    rng = np.random.RandomState(s * 13 + chunk)
    b, h, p, n = 1, 2, 8, 4
    x = jnp.array(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.array(np.abs(rng.randn(b, s, h)) * 0.4 + 0.01, jnp.float32)
    A = -jnp.array(np.abs(rng.randn(h)) + 0.3, jnp.float32)
    B = jnp.array(rng.randn(b, s, n), jnp.float32)
    C = jnp.array(rng.randn(b, s, n), jnp.float32)
    D = jnp.array(rng.randn(h), jnp.float32)
    y, S = ssd_chunked(x, dt, A, B, C, D, chunk)
    yr, Sr = ref.ref_ssd(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(Sr),
                               rtol=3e-4, atol=3e-4)


def test_ssd_state_continuation():
    """Splitting a sequence and carrying the state == processing it whole."""
    rng = np.random.RandomState(0)
    b, s, h, p, n = 1, 64, 2, 8, 4
    x = jnp.array(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.array(np.abs(rng.randn(b, s, h)) * 0.4, jnp.float32)
    A = -jnp.array(np.abs(rng.randn(h)) + 0.3, jnp.float32)
    B = jnp.array(rng.randn(b, s, n), jnp.float32)
    C = jnp.array(rng.randn(b, s, n), jnp.float32)
    D = jnp.zeros(h, jnp.float32)
    y_full, S_full = ssd_chunked(x, dt, A, B, C, D, 16)
    h1 = 32
    y1, S1 = ssd_chunked(x[:, :h1], dt[:, :h1], A, B[:, :h1], C[:, :h1], D, 16)
    y2, S2 = ssd_chunked(x[:, h1:], dt[:, h1:], A, B[:, h1:], C[:, h1:], D, 16,
                         initial_state=S1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full),
                               rtol=1e-4, atol=1e-4)


def test_cross_entropy_ignores_vocab_padding():
    cfg = ModelConfig(vocab_size=500)
    rng = np.random.RandomState(0)
    logits_core = jnp.array(rng.randn(2, 8, 500), jnp.float32)
    # padded columns filled with huge values must not change the loss
    pad = jnp.full((2, 8, cfg.vocab_padded - 500), 50.0)
    logits_padded = jnp.concatenate([logits_core, pad], axis=-1)
    labels = jnp.array(rng.randint(0, 500, (2, 8)), jnp.int32)
    a = L.cross_entropy(logits_padded, labels, cfg)
    cfg_exact = ModelConfig(vocab_size=500)
    b = L.cross_entropy(
        jnp.concatenate([logits_core,
                         jnp.full((2, 8, cfg.vocab_padded - 500), -1e30)],
                        axis=-1), labels, cfg_exact)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_cross_entropy_masks_negative_labels():
    cfg = ModelConfig(vocab_size=100)
    logits = jnp.zeros((1, 4, cfg.vocab_padded))
    labels = jnp.array([[5, -1, -1, 7]], jnp.int32)
    loss = L.cross_entropy(logits, labels, cfg)
    want = np.log(100.0)  # uniform over true vocab
    np.testing.assert_allclose(float(loss), want, rtol=1e-5)


def test_rope_relative_position_invariance():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    rng = np.random.RandomState(0)
    q = jnp.array(rng.randn(1, 1, 1, 32), jnp.float32)
    k = jnp.array(rng.randn(1, 1, 1, 32), jnp.float32)

    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.array([i]), 10_000.0)
        kj = L.apply_rope(k, jnp.array([j]), 10_000.0)
        return float(jnp.sum(qi * kj))

    np.testing.assert_allclose(dot_at(5, 3), dot_at(105, 103), rtol=1e-4)
    np.testing.assert_allclose(dot_at(0, 0), dot_at(77, 77), rtol=1e-4)


def test_causal_conv_state_continuation():
    from repro.models.mamba2 import causal_conv
    rng = np.random.RandomState(1)
    x = jnp.array(rng.randn(2, 20, 6), jnp.float32)
    w = jnp.array(rng.randn(4, 6), jnp.float32)
    y_full, st_full = causal_conv(x, w)
    y1, st1 = causal_conv(x[:, :11], w)
    y2, st2 = causal_conv(x[:, 11:], w, state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-5)
