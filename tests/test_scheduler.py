"""Task scheduler: adaptation triggers, duration-cap restarts, failures,
user-centric scenarios (paper Sections 4.1 and 5.3-5.5)."""
import numpy as np
import pytest

from repro.core import Config, ConfigSpace, EpochPlan, Goal, TaskScheduler
from repro.core.cost_model import epoch_estimate, profile_cost
from repro.serverless import (WORKLOADS, ObjectStore, ParamStore,
                              ServerlessPlatform)


def make_sched(scheme="hier", failure_rate=0.0, seed=0, max_workers=120):
    plat = ServerlessPlatform(failure_rate=failure_rate, seed=seed)
    return TaskScheduler(plat, ObjectStore(), ParamStore(), scheme=scheme,
                         space=ConfigSpace(max_workers=max_workers),
                         seed=seed), plat


W = WORKLOADS["bert-small"]


def plans(batches, samples=50_000, w=W):
    return [EpochPlan(batch_size=b, workload=w, samples=samples)
            for b in batches]


def test_reoptimizes_on_batch_change():
    sched, _ = make_sched()
    res = sched.run(plans([512, 512, 2048, 2048]), Goal("min_time"))
    reopts = [e for e in res.events if e.kind == "reoptimize"]
    assert len(reopts) == 2  # initial + on the batch-size change
    assert res.epochs_done == 4


def test_fixed_config_baseline_no_adaptation():
    """LambdaML-style fixed allocation never re-optimizes."""
    sched, _ = make_sched()
    res = sched.run(plans([512, 2048]), Goal("min_time"), adaptive=False,
                    fixed_config=Config(workers=32, memory_mb=4096))
    assert all(e.kind == "epoch" for e in res.events)
    assert res.profile_usd == 0.0


def test_adaptive_beats_fixed_on_dynamic_batching():
    """Paper Fig. 12: when batch size changes, SMLT adapts and outperforms a
    fixed random allocation in cost."""
    batches = [256, 256, 4096, 4096, 4096]
    sched_a, _ = make_sched(seed=1)
    adaptive = sched_a.run(plans(batches), Goal("min_cost"))
    sched_f, _ = make_sched(seed=1)
    fixed = sched_f.run(plans(batches), Goal("min_cost"), adaptive=False,
                        fixed_config=Config(workers=100, memory_mb=2048))
    assert adaptive.cost_usd < fixed.cost_usd


def test_duration_cap_restarts_accounted():
    """Epochs longer than the 15-min cap must show restarts (checkpoint +
    reinit overhead appears in wall time)."""
    cfg = Config(workers=4, memory_mb=2048)
    est = epoch_estimate(WORKLOADS["bert-medium"], "hier", cfg, 512,
                         ParamStore(), ObjectStore(), samples=200_000)
    assert est.restarts_per_worker >= 1
    base = est.iters * est.it_breakdown["total"]
    assert est.wall_s > base  # restart + init overhead visible


def test_failures_redo_iterations():
    s_ok, _ = make_sched(failure_rate=0.0, seed=2)
    s_bad, _ = make_sched(failure_rate=0.05, seed=2)
    g = Goal("min_time")
    a = s_ok.run(plans([1024] * 3), g)
    b = s_bad.run(plans([1024] * 3), g)
    assert b.wall_s > a.wall_s
    assert sum(e.failures for e in b.events) > 0


def test_deadline_scenario_feasible():
    """Scenario 1: minimize cost s.t. T <= deadline — the chosen deployment
    must meet the deadline."""
    sched, _ = make_sched()
    goal = Goal("min_cost_deadline", deadline_s=3600.0)
    res = sched.run(plans([1024], samples=100_000), goal)
    assert res.wall_s - res.profile_s <= goal.deadline_s * 1.05


def test_budget_scenario_feasible():
    """Scenario 2: minimize time s.t. $ <= budget."""
    sched, _ = make_sched()
    goal = Goal("min_time_budget", budget_usd=50.0)
    res = sched.run(plans([1024] * 2, samples=100_000), goal)
    assert res.cost_usd <= goal.budget_usd * 1.05


def test_nas_model_size_change_triggers_reopt():
    """Paper Fig. 13 (ENAS): changing model size re-triggers optimization."""
    small = WORKLOADS["resnet18"]
    big = WORKLOADS["bert-medium"]
    sched, _ = make_sched()
    p = [EpochPlan(1024, small, 20_000), EpochPlan(1024, big, 20_000),
         EpochPlan(1024, small, 20_000)]
    res = sched.run(p, Goal("min_time"))
    assert len([e for e in res.events if e.kind == "reoptimize"]) == 3


def test_profile_cost_positive_and_small():
    w, c = WORKLOADS["resnet50"], Config(workers=16, memory_mb=3072)
    t, usd, it = profile_cost(w, "hier", c, 1024, ParamStore(), ObjectStore())
    assert t > 0 and usd > 0
    est = epoch_estimate(w, "hier", c, 1024, ParamStore(), ObjectStore())
    assert usd < est.cost_usd  # profiling an epoch costs less than the epoch
