"""Task scheduler: adaptation triggers, duration-cap restarts, failures,
user-centric scenarios (paper Sections 4.1 and 5.3-5.5)."""
import numpy as np
import pytest

from repro.core import Config, ConfigSpace, EpochPlan, Goal, TaskScheduler
from repro.core.cost_model import epoch_estimate, profile_cost
from repro.serverless import (WORKLOADS, ObjectStore, ParamStore,
                              ServerlessPlatform)


def make_sched(scheme="hier", failure_rate=0.0, seed=0, max_workers=120):
    plat = ServerlessPlatform(failure_rate=failure_rate, seed=seed)
    return TaskScheduler(plat, ObjectStore(), ParamStore(), scheme=scheme,
                         space=ConfigSpace(max_workers=max_workers),
                         seed=seed), plat


W = WORKLOADS["bert-small"]


def plans(batches, samples=50_000, w=W):
    return [EpochPlan(batch_size=b, workload=w, samples=samples)
            for b in batches]


def test_reoptimizes_on_batch_change():
    sched, _ = make_sched()
    res = sched.run(plans([512, 512, 2048, 2048]), Goal("min_time"))
    reopts = [e for e in res.events if e.kind == "reoptimize"]
    assert len(reopts) == 2  # initial + on the batch-size change
    assert res.epochs_done == 4


def test_fixed_config_baseline_no_adaptation():
    """LambdaML-style fixed allocation never re-optimizes."""
    sched, _ = make_sched()
    res = sched.run(plans([512, 2048]), Goal("min_time"), adaptive=False,
                    fixed_config=Config(workers=32, memory_mb=4096))
    assert all(e.kind == "epoch" for e in res.events)
    assert res.profile_usd == 0.0


def test_adaptive_beats_fixed_on_dynamic_batching():
    """Paper Fig. 12: when batch size changes, SMLT adapts and outperforms a
    fixed random allocation in cost."""
    batches = [256, 256, 4096, 4096, 4096]
    sched_a, _ = make_sched(seed=1)
    adaptive = sched_a.run(plans(batches), Goal("min_cost"))
    sched_f, _ = make_sched(seed=1)
    fixed = sched_f.run(plans(batches), Goal("min_cost"), adaptive=False,
                        fixed_config=Config(workers=100, memory_mb=2048))
    assert adaptive.cost_usd < fixed.cost_usd


def test_duration_cap_restarts_accounted():
    """Epochs longer than the 15-min cap must show restarts (checkpoint +
    reinit overhead appears in wall time)."""
    cfg = Config(workers=4, memory_mb=2048)
    est = epoch_estimate(WORKLOADS["bert-medium"], "hier", cfg, 512,
                         ParamStore(), ObjectStore(), samples=200_000)
    assert est.restarts_per_worker >= 1
    base = est.iters * est.it_breakdown["total"]
    assert est.wall_s > base  # restart + init overhead visible


def test_failures_redo_iterations():
    s_ok, _ = make_sched(failure_rate=0.0, seed=2)
    s_bad, _ = make_sched(failure_rate=0.05, seed=2)
    g = Goal("min_time")
    a = s_ok.run(plans([1024] * 3), g)
    b = s_bad.run(plans([1024] * 3), g)
    assert b.wall_s > a.wall_s
    assert sum(e.failures for e in b.events) > 0


def test_deadline_scenario_feasible():
    """Scenario 1: minimize cost s.t. T <= deadline — the chosen deployment
    must meet the deadline."""
    sched, _ = make_sched()
    goal = Goal("min_cost_deadline", deadline_s=3600.0)
    res = sched.run(plans([1024], samples=100_000), goal)
    assert res.wall_s - res.profile_s <= goal.deadline_s * 1.05


def test_budget_scenario_feasible():
    """Scenario 2: minimize time s.t. $ <= budget."""
    sched, _ = make_sched()
    goal = Goal("min_time_budget", budget_usd=50.0)
    res = sched.run(plans([1024] * 2, samples=100_000), goal)
    assert res.cost_usd <= goal.budget_usd * 1.05


def test_nas_model_size_change_triggers_reopt():
    """Paper Fig. 13 (ENAS): changing model size re-triggers optimization."""
    small = WORKLOADS["resnet18"]
    big = WORKLOADS["bert-medium"]
    sched, _ = make_sched()
    p = [EpochPlan(1024, small, 20_000), EpochPlan(1024, big, 20_000),
         EpochPlan(1024, small, 20_000)]
    res = sched.run(p, Goal("min_time"))
    assert len([e for e in res.events if e.kind == "reoptimize"]) == 3


def test_profile_cost_positive_and_small():
    w, c = WORKLOADS["resnet50"], Config(workers=16, memory_mb=3072)
    t, usd, it = profile_cost(w, "hier", c, 1024, ParamStore(), ObjectStore())
    assert t > 0 and usd > 0
    est = epoch_estimate(w, "hier", c, 1024, ParamStore(), ObjectStore())
    assert usd < est.cost_usd  # profiling an epoch costs less than the epoch


# -- fleet composition + ssp-aware objective ---------------------------------

def test_staleness_inflation_ordering():
    """bsp pays no penalty; ssp grows with k; async is judged at the
    worst-case n-1 staleness — the objective ordering the optimizer sees."""
    from repro.core.constraints import staleness_inflation
    n = 16
    bsp = staleness_inflation("bsp", n_workers=n)
    ssp2 = staleness_inflation("ssp(2)", n_workers=n)
    ssp8 = staleness_inflation("ssp(8)", n_workers=n)
    asy = staleness_inflation("async", n_workers=n)
    assert bsp == 1.0
    assert bsp < ssp2 < ssp8 < asy
    g = Goal("min_cost_deadline", deadline_s=100.0)
    obj, cons, _ = g.objective_and_constraint(50.0, 5.0, inflation=ssp2)
    assert obj == pytest.approx(5.0 * ssp2)
    assert cons == pytest.approx(50.0 * ssp2)


def test_fleet_config_estimate_and_search_space():
    """A searched fleet mix (small_frac) expands to a mixed fleet: cheaper
    GB-seconds than the all-big fleet, slower iterations; and a
    search_fleet space actually samples mixed candidates."""
    w = WORKLOADS["bert-small"]
    full = epoch_estimate(w, "hier", Config(16, 4096), 1024, ParamStore(),
                          ObjectStore(), samples=20_000)
    mixed = epoch_estimate(w, "hier", Config(16, 4096, small_frac=0.5), 1024,
                           ParamStore(), ObjectStore(), samples=20_000)
    assert mixed.wall_s > full.wall_s            # slow tier drags the epoch
    # the mixed fleet bills less memory per second
    assert (mixed.lambda_usd / mixed.wall_s) < (full.lambda_usd / full.wall_s)
    space = ConfigSpace(max_workers=32, search_fleet=True)
    cands = space.sample(np.random.RandomState(0), 64)
    fracs = {c.small_frac for c in cands}
    assert fracs == set(space.small_frac_choices)
    # the GP input embeds every search dimension, incl. the comm plan
    # and the execution backend
    assert all(len(c.as_unit(space)) == 8 for c in cands)
    assert all(c.comm == "" and c.compress_ratio == 1.0
               and c.pipeline_depth == 1 and c.backend == ""
               for c in cands)


def test_comm_search_space_samples_plans():
    """search_comm adds (strategy, ratio, branching, pipeline_depth)
    candidates; every choice appears, branching only rides on hier, and
    the unit embedding stays in [0, 1]."""
    space = ConfigSpace(max_workers=32, search_comm=True)
    cands = space.sample(np.random.RandomState(0), 256)
    assert {c.comm for c in cands} == set(space.comm_choices)
    assert {c.compress_ratio for c in cands} == set(space.ratio_choices)
    assert {c.branching for c in cands if c.comm == "hier"} == \
        set(space.branching_choices)
    assert all(c.branching == 0 for c in cands if c.comm != "hier")
    assert {c.pipeline_depth for c in cands} == set(space.depth_choices)
    for c in cands:
        u = c.as_unit(space)
        assert len(u) == 8 and (u >= 0.0).all() and (u <= 1.0).all()


def test_optimizer_selects_nontrivial_comm_plan():
    """Acceptance: with the comm dimensions in the search space, a
    deadline goal on a comm-heavy workload must pick a non-trivial
    (strategy, ratio) — the dense default scheme cannot win once
    compression/hierarchy cut the dominant wire cost, even judged on
    compression-inflated time and dollars."""
    plat = ServerlessPlatform(seed=0)
    sched = TaskScheduler(plat, ObjectStore(), ParamStore(),
                          scheme="scatter_reduce",
                          space=ConfigSpace(max_workers=64,
                                            search_comm=True), seed=0)
    cfg, _t, _u, _n = sched.optimize(
        WORKLOADS["bert-medium"], 1024,
        Goal("min_cost_deadline", deadline_s=3600.0),
        epochs_remaining=4, samples=25_000)
    assert cfg.compress_ratio < 1.0 or cfg.comm not in ("", "scatter_reduce")
    # and the scheduler deploys what it searched: the engine/analytic
    # paths both price the selected spec
    spec = sched._comm_for(cfg)
    assert spec.ratio == cfg.compress_ratio
    est_sel = epoch_estimate(WORKLOADS["bert-medium"], spec, cfg, 1024,
                             ParamStore(), ObjectStore(), samples=25_000)
    est_dense = epoch_estimate(WORKLOADS["bert-medium"], "scatter_reduce",
                               cfg, 1024, ParamStore(), ObjectStore(),
                               samples=25_000)
    assert est_sel.wall_s < est_dense.wall_s


def test_scheduler_deploys_pipelined_comm_on_both_paths():
    """A config carrying a searched ``pipeline_depth`` must deploy the
    overlapped schedule on the analytic *and* the event path — and beat
    its sequential twin on a comm-heavy deployment."""
    walls = {}
    for engine in ("analytic", "event"):
        for depth in (1, 4):
            plat = ServerlessPlatform(seed=0)
            sched = TaskScheduler(plat, ObjectStore(), ParamStore(), seed=0,
                                  scheme="scatter_reduce",
                                  space=ConfigSpace(max_workers=64),
                                  engine=engine)
            cfg = Config(64, 4096, pipeline_depth=depth)
            spec = sched._comm_for(cfg)
            assert (spec == "scatter_reduce" if depth == 1
                    else spec.pipeline_depth == depth)
            res = sched.run([EpochPlan(512, W, samples=4_096)],
                            Goal("min_time"), adaptive=False,
                            fixed_config=cfg)
            walls[(engine, depth)] = res.wall_s
    assert walls[("analytic", 4)] < walls[("analytic", 1)]
    assert walls[("event", 4)] < walls[("event", 1)]
    # both paths agree on the overlapped epoch at zero variance
    assert walls[("event", 4)] == pytest.approx(walls[("analytic", 4)],
                                                rel=0.01)


def test_scheduler_deploys_searched_fleet_on_event_engine():
    """engine='event' + a config with small_frac must execute the epoch on
    the mixed fleet (per-worker billing at both memory sizes)."""
    plat = ServerlessPlatform(seed=0)
    sched = TaskScheduler(plat, ObjectStore(), ParamStore(), seed=0,
                          space=ConfigSpace(max_workers=64,
                                            search_fleet=True),
                          engine="event")
    res = sched.run([EpochPlan(1024, W, samples=10_000)], Goal("min_time"),
                    adaptive=False,
                    fixed_config=Config(16, 4096, small_frac=0.5))
    assert res.epochs_done == 1
    assert len({rec.worker_id for rec in plat.invocations}) == 16
    # had the fleet silently deployed homogeneous at 4096MB, the ledger
    # would bill every invocation second at 4096 — the mixed fleet bills
    # half the workers at 2048, so the GB-seconds must come in well under
    homog_gb = sum(4096 / 1024.0 * (rec.end - rec.start)
                   for rec in plat.invocations)
    assert plat.ledger.gb_seconds < 0.95 * homog_gb


# -- goals, budget stops, trace-kind validation, resumable runs --------------

def test_goal_validation_edge_cases():
    """Unknown kinds and missing/non-positive limits on constrained kinds
    fail at construction, not deep inside a run."""
    with pytest.raises(ValueError, match="unknown goal kind"):
        Goal("warp_speed")
    with pytest.raises(ValueError, match="requires deadline_s"):
        Goal("min_cost_deadline")
    with pytest.raises(ValueError, match="requires budget_usd"):
        Goal("min_time_budget")
    with pytest.raises(ValueError, match="requires"):
        Goal("deadline_budget", deadline_s=10.0)
    with pytest.raises(ValueError, match="positive"):
        Goal("min_time_budget", budget_usd=0.0)
    Goal("min_time")                      # unconstrained kinds need nothing


def test_goal_inflation_scales_time_and_cost():
    g = Goal("min_time_budget", budget_usd=10.0)
    obj, cons, limit = g.objective_and_constraint(100.0, 4.0, inflation=1.5)
    assert obj == pytest.approx(150.0)    # time objective inflates
    assert cons == pytest.approx(6.0)     # and so does the cost constraint
    assert limit == 10.0
    # the workflow kind: normalized binding constraint against 1.0
    gw = Goal("deadline_budget", deadline_s=200.0, budget_usd=5.0)
    obj, cons, limit = gw.objective_and_constraint(100.0, 4.0)
    assert obj == 100.0 and limit == 1.0
    assert cons == pytest.approx(max(100.0 / 200.0, 4.0 / 5.0))


def test_run_result_total_cost_accounting():
    from repro.core import RunResult
    res = RunResult(events=[], wall_s=10.0, cost_usd=3.0, profile_s=2.0,
                    profile_usd=0.5, epochs_done=1, config_history=[])
    assert res.total_cost == pytest.approx(3.5)
    assert res.stop_reason == ""          # no state attached


def test_trace_event_kind_validated():
    from repro.core import TraceEvent
    for kind in sorted(TraceEvent.KINDS):
        TraceEvent(0.0, 0, kind)          # every registered kind is legal
    assert "reoptimize_mid" in TraceEvent.KINDS
    with pytest.raises(ValueError, match="reoptimize_mdi"):
        TraceEvent(0.0, 0, "reoptimize_mdi")


def test_budget_stop_never_overspends():
    """Satellite regression: the symmetric budget stop breaks before the
    epoch that would push total cost past goal.budget_usd."""
    cfg = Config(workers=16, memory_mb=3072)
    est = epoch_estimate(W, "hier", cfg, 1024, ParamStore(), ObjectStore(),
                         samples=50_000)
    budget = est.cost_usd * 2.5           # room for 2 of the 5 epochs
    goal = Goal("min_time_budget", budget_usd=budget)
    sched, _ = make_sched()
    res = sched.run(plans([1024] * 5), goal, adaptive=False,
                    fixed_config=cfg, stop_at_budget=True)
    assert res.epochs_done == 2
    assert res.total_cost <= budget
    assert res.stop_reason == "budget"
    # without the stop, the same run overspends — the regression guard
    sched2, _ = make_sched()
    res2 = sched2.run(plans([1024] * 5), goal, adaptive=False,
                      fixed_config=cfg)
    assert res2.total_cost > budget


def test_budget_stop_on_event_engine_gates_ledger():
    """Event-path epochs bill as they run, so the budget stop gates on
    the forecast *before* launching — the shared ledger never exceeds
    the budget either."""
    cfg = Config(workers=8, memory_mb=2048)
    est = epoch_estimate(W, "hier", cfg, 1024, ParamStore(), ObjectStore(),
                         samples=20_000)
    budget = est.cost_usd * 1.5
    plat = ServerlessPlatform(seed=0)
    sched = TaskScheduler(plat, ObjectStore(), ParamStore(), seed=0,
                          engine="event")
    res = sched.run(plans([1024] * 3, samples=20_000),
                    Goal("min_time_budget", budget_usd=budget),
                    adaptive=False, fixed_config=cfg, stop_at_budget=True)
    assert res.epochs_done == 1
    assert res.stop_reason == "budget"
    assert plat.ledger.total_cost <= budget


def test_sliced_run_resumes_to_identical_result():
    """run(max_epochs=1) slices resumed back-to-back must reproduce the
    uninterrupted run bit for bit: totals, trace, config history, and the
    failure-injection RNG stream all carry through SchedulerState."""
    batches = [512, 512, 2048]
    g = Goal("min_time")
    sched_full, _ = make_sched(failure_rate=0.05, seed=4)
    full = sched_full.run(plans(batches), g)

    sched_sliced, _ = make_sched(failure_rate=0.05, seed=4)
    res = sched_sliced.run(plans(batches), g, max_epochs=1)
    assert not res.state.done
    while not res.state.done:
        res = sched_sliced.run(plans(batches), g, max_epochs=1,
                               resume=res.state)
    assert res.epochs_done == full.epochs_done == 3
    assert res.wall_s == pytest.approx(full.wall_s, rel=1e-12)
    assert res.cost_usd == pytest.approx(full.cost_usd, rel=1e-12)
    assert [e.kind for e in res.events] == [e.kind for e in full.events]
    assert [(e.t, e.cost_cum) for e in res.events] == \
        [(e.t, e.cost_cum) for e in full.events]
    assert res.config_history == full.config_history
    assert res.stop_reason == full.stop_reason == "completed"
    with pytest.raises(ValueError, match="finished"):
        sched_sliced.run(plans(batches), g, resume=res.state)
