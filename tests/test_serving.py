"""Serving layer: adaptive batching policy + real-model batched engine."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.serving import (Request, ServePolicy, ServingEngine,
                           optimize_policy, simulate)

FLOPS_PER_REQ = 2e9  # ~1B-param model, 1 token


def test_batching_amortizes_cost():
    """Bigger batches cut $/request (the BATCH [17] premise)."""
    costs = []
    for B in (1, 8, 32):
        st = simulate(ServePolicy(B, 0.2, 2048), arrival_rate=50.0,
                      flops_per_request=FLOPS_PER_REQ)
        costs.append(st.cost_per_1k)
    assert costs[0] > costs[1] > costs[2]


def test_batching_trades_latency_at_light_load():
    """At light load (no queueing) a long batching window costs latency;
    at heavy load batching REDUCES p99 by lifting throughput."""
    lat1 = simulate(ServePolicy(1, 0.01, 4096), arrival_rate=0.5,
                    flops_per_request=FLOPS_PER_REQ).p99_s
    lat32 = simulate(ServePolicy(32, 1.0, 4096), arrival_rate=0.5,
                     flops_per_request=FLOPS_PER_REQ).p99_s
    assert lat32 > lat1
    busy1 = simulate(ServePolicy(1, 0.01, 4096), arrival_rate=5.0,
                     flops_per_request=FLOPS_PER_REQ).p99_s
    busy32 = simulate(ServePolicy(32, 0.25, 4096), arrival_rate=5.0,
                      flops_per_request=FLOPS_PER_REQ).p99_s
    assert busy32 < busy1


def test_policy_optimizer_meets_slo():
    pol, st, log = optimize_policy(arrival_rate=30.0,
                                   flops_per_request=FLOPS_PER_REQ,
                                   slo_s=1.0)
    assert pol is not None
    assert st.p99_s <= 1.0
    # and it should actually batch (B=1 is strictly more expensive here)
    single = simulate(ServePolicy(1, 0.01, pol.memory_mb),
                      arrival_rate=30.0, flops_per_request=FLOPS_PER_REQ)
    assert st.cost_per_1k < single.cost_per_1k


def test_optimal_batch_grows_with_load():
    lo, _, _ = optimize_policy(arrival_rate=2.0,
                               flops_per_request=FLOPS_PER_REQ, slo_s=1.0)
    hi, _, _ = optimize_policy(arrival_rate=40.0,
                               flops_per_request=FLOPS_PER_REQ, slo_s=1.0)
    assert lo is not None and hi is not None
    assert hi.max_batch >= lo.max_batch
    assert hi.max_batch >= 8


def test_infeasible_slo_reported():
    pol, st, log = optimize_policy(arrival_rate=5.0,
                                   flops_per_request=1e13, slo_s=0.05)
    assert pol is None and log["evaluated"] > 0


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b"])
def test_engine_batching_invariance(arch):
    """Greedy decode of a request is identical alone vs inside a batch."""
    cfg = reduced(ARCHS[arch])
    eng = ServingEngine(cfg, seed=0)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=12).astype(np.int32)
               for _ in range(3)]
    reqs = [Request(i, p, 6) for i, p in enumerate(prompts)]
    batched = eng.serve_batch(reqs)
    singles = [eng.serve_batch([r])[0] for r in reqs]
    for b, s in zip(batched, singles):
        assert b.rid == s.rid
        np.testing.assert_array_equal(b.tokens, s.tokens)
