"""Serving layer: adaptive batching policy + real-model batched engine."""
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.serving import (Request, ServePolicy, ServingEngine,
                           optimize_policy, simulate)

FLOPS_PER_REQ = 2e9  # ~1B-param model, 1 token


def test_batching_amortizes_cost():
    """Bigger batches cut $/request (the BATCH [17] premise)."""
    costs = []
    for B in (1, 8, 32):
        st = simulate(ServePolicy(B, 0.2, 2048), arrival_rate=50.0,
                      flops_per_request=FLOPS_PER_REQ)
        costs.append(st.cost_per_1k)
    assert costs[0] > costs[1] > costs[2]


def test_batching_trades_latency_at_light_load():
    """At light load (no queueing) a long batching window costs latency;
    at heavy load batching REDUCES p99 by lifting throughput."""
    lat1 = simulate(ServePolicy(1, 0.01, 4096), arrival_rate=0.5,
                    flops_per_request=FLOPS_PER_REQ).p99_s
    lat32 = simulate(ServePolicy(32, 1.0, 4096), arrival_rate=0.5,
                     flops_per_request=FLOPS_PER_REQ).p99_s
    assert lat32 > lat1
    busy1 = simulate(ServePolicy(1, 0.01, 4096), arrival_rate=5.0,
                     flops_per_request=FLOPS_PER_REQ).p99_s
    busy32 = simulate(ServePolicy(32, 0.25, 4096), arrival_rate=5.0,
                      flops_per_request=FLOPS_PER_REQ).p99_s
    assert busy32 < busy1


def test_policy_optimizer_meets_slo():
    pol, st, log = optimize_policy(arrival_rate=30.0,
                                   flops_per_request=FLOPS_PER_REQ,
                                   slo_s=1.0)
    assert pol is not None
    assert st.p99_s <= 1.0
    # and it should actually batch (B=1 is strictly more expensive here)
    single = simulate(ServePolicy(1, 0.01, pol.memory_mb),
                      arrival_rate=30.0, flops_per_request=FLOPS_PER_REQ)
    assert st.cost_per_1k < single.cost_per_1k


def test_optimal_batch_grows_with_load():
    lo, _, _ = optimize_policy(arrival_rate=2.0,
                               flops_per_request=FLOPS_PER_REQ, slo_s=1.0)
    hi, _, _ = optimize_policy(arrival_rate=40.0,
                               flops_per_request=FLOPS_PER_REQ, slo_s=1.0)
    assert lo is not None and hi is not None
    assert hi.max_batch >= lo.max_batch
    assert hi.max_batch >= 8


def test_infeasible_slo_reported():
    pol, st, log = optimize_policy(arrival_rate=5.0,
                                   flops_per_request=1e13, slo_s=0.05)
    assert pol is None and log["evaluated"] > 0


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b"])
def test_engine_batching_invariance(arch):
    """Greedy decode of a request is identical alone vs inside a batch."""
    cfg = reduced(ARCHS[arch])
    eng = ServingEngine(cfg, seed=0)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=12).astype(np.int32)
               for _ in range(3)]
    reqs = [Request(i, p, 6) for i, p in enumerate(prompts)]
    batched = eng.serve_batch(reqs)
    singles = [eng.serve_batch([r])[0] for r in reqs]
    for b, s in zip(batched, singles):
        assert b.rid == s.rid
        np.testing.assert_array_equal(b.tokens, s.tokens)


# -- batcher fix pass (timeout anchor, final partial batch) ------------------

def test_timeout_anchored_on_arrival_under_overload():
    """The batching timeout clock starts at the oldest request's
    *arrival*: when the server is busy past that deadline, the batch
    launches the moment the server frees — never free-time + timeout."""
    from repro.serving.batcher import exec_time
    pol = ServePolicy(4, 0.5, 1024)
    st = simulate(pol, arrival_rate=20.0, flops_per_request=2e11,
                  horizon_s=20.0, seed=0, keep_records=True)
    assert st.records and len(st.records) > 3
    arr = None  # records carry indices; rebuild the stream for checks
    rng = np.random.RandomState(0)
    n = max(int(20.0 * 20.0), 1)
    arr = np.sort(rng.uniform(0.0, 20.0, size=n))
    overdue_immediate = 0
    for r in st.records:
        # launch-wait invariant: a batch never starts later than the
        # larger of (oldest arrival + timeout) and server-free time
        assert r.start <= max(arr[r.i] + pol.timeout_s, r.free) + 1e-9
        if r.free > arr[r.i] + pol.timeout_s:
            # overdue when the server freed: must go immediately (the
            # old bug re-anchored the timeout on r.free, adding 0.5 s)
            assert r.start <= max(r.free, arr[r.j - 1]) + 1e-9
            overdue_immediate += 1
    assert overdue_immediate > 0      # the overload regime was exercised


def test_final_partial_batch_never_waits_out_timeout():
    """A final partial batch that no future arrival can fill launches
    immediately instead of burning the full timeout window."""
    from repro.serving.batcher import exec_time
    pol = ServePolicy(8, 30.0, 2048)
    arr = np.array([1.0])
    st = simulate(pol, arrival_rate=1.0, flops_per_request=FLOPS_PER_REQ,
                  arrivals=arr, keep_records=True)
    assert st.batches == 1
    assert st.records[0].start == pytest.approx(1.0)
    assert st.p99_s == pytest.approx(exec_time(FLOPS_PER_REQ, 1, 2048))


def test_serving_slo_bench_skips_infeasible_policy():
    """The benchmark reports an infeasible SLO as a row, not a crash."""
    from benchmarks.serving_slo import policy_row
    row = policy_row(40.0, 0.05)
    assert row["policy"] == "infeasible"
    assert row["evaluated"] > 0 and row["feasible"] == 0


def test_serve_batch_rejects_mixed_prompt_lengths():
    cfg = reduced(ARCHS["qwen2.5-3b"])
    eng = ServingEngine(cfg, seed=0)
    rng = np.random.RandomState(0)
    p8 = rng.randint(0, cfg.vocab_size, size=8).astype(np.int32)
    p12 = rng.randint(0, cfg.vocab_size, size=12).astype(np.int32)
    with pytest.raises(ValueError, match="prompt length"):
        eng.serve_batch([Request(0, p8, 4), Request(1, p12, 4)])
    # equal lengths still serve
    out = eng.serve_batch([Request(0, p8, 4),
                           Request(1, p8[::-1].copy(), 4)])
    assert len(out) == 2


# -- ServingJob: serving as a first-class event-engine job -------------------

def _serving_job(pol, arr, **kw):
    from repro.serverless import ObjectStore, ParamStore, ServingJob
    kw.setdefault("param_store", ParamStore())
    kw.setdefault("object_store", ObjectStore())
    ps, os_ = kw.pop("param_store"), kw.pop("object_store")
    return ServingJob(pol, arr, FLOPS_PER_REQ, ps, os_, **kw)


def test_serving_job_matches_simulate_exactly():
    """Single instance, zero cold start, no model/code fetches, infinite
    keep-warm: the event-engine job IS the closed simulate() queue —
    bit-identical latency percentiles, batch count, and $/1k."""
    pol = ServePolicy(4, 0.15, 2048)
    rng = np.random.RandomState(3)
    arr = np.sort(rng.uniform(0.0, 60.0, size=600))
    sim = simulate(pol, arrival_rate=10.0, flops_per_request=FLOPS_PER_REQ,
                   arrivals=arr)
    res = _serving_job(pol, arr, max_instances=1, cold_start_s=0.0,
                       keep_warm_s=float("inf")).run()
    assert res.requests == sim.requests
    assert res.batches == sim.batches
    assert res.p50_s == pytest.approx(sim.p50_s, abs=1e-9)
    assert res.p99_s == pytest.approx(sim.p99_s, abs=1e-9)
    assert res.cost_per_1k == pytest.approx(sim.cost_per_1k, rel=1e-9)


def test_serving_job_autoscales_under_load():
    """With cold starts allowed, an overloaded stream scales out and the
    tail improves over the single-server queue."""
    pol = ServePolicy(4, 0.1, 2048)
    rng = np.random.RandomState(5)
    arr = np.sort(rng.uniform(0.0, 30.0, size=900))
    single = _serving_job(pol, arr, max_instances=1, cold_start_s=0.0,
                          keep_warm_s=float("inf")).run()
    fleet = _serving_job(pol, arr, max_instances=8, cold_start_s=0.5,
                         keep_warm_s=30.0).run()
    assert fleet.peak_instances > 1
    assert fleet.cold_starts >= fleet.peak_instances
    assert fleet.p99_s < single.p99_s


def test_serving_job_contends_with_training_on_shared_store():
    """Train + serve in one ContentionDomain on one ParamStore: serving
    p99 AND training wall both degrade vs isolated; with separate
    stores in the same domain, neither does."""
    from repro.serverless import (WORKLOADS, ContentionDomain, EventEngine,
                                  ObjectStore, ParamStore, ServingJob)
    w = WORKLOADS["bert-medium"]
    pol = ServePolicy(8, 0.1, 3072)
    rng = np.random.RandomState(11)
    arr = np.sort(rng.uniform(0.0, 60.0, size=1800))

    def train(ps, dom):
        return EventEngine(w, "ps", 32, 3072, 1024, ps, ObjectStore(),
                           samples=3000, seed=1, domain=dom,
                           trace_enabled=False)

    def serve(ps, dom, prio=1.0):
        return ServingJob(pol, arr, FLOPS_PER_REQ, ps, ObjectStore(),
                          domain=dom, model_bytes=w.param_count * 4.0,
                          code_bytes=20e6, cold_start_s=1.0,
                          keep_warm_s=30.0, max_instances=16,
                          refresh_every_s=1.0, link_priority=prio)

    rt_iso = train(ParamStore(), None).run()
    rs_iso = serve(ParamStore(), ContentionDomain()).run()

    def corun(shared, prio=1.0):
        dom = ContentionDomain()
        ps = ParamStore()
        t = train(ps, dom)
        s = serve(ps if shared else ParamStore(), dom, prio=prio)
        dom.run()
        return t.result(), s.result()

    rt_sh, rs_sh = corun(shared=True)
    rt_ct, rs_ct = corun(shared=False)
    # both directions degrade on the shared store...
    assert rs_sh.p99_s > rs_iso.p99_s * 1.02
    assert rt_sh.wall_s > rt_iso.wall_s * 1.001
    # ...and neither does in the separate-store control
    assert rs_ct.p99_s == pytest.approx(rs_iso.p99_s, rel=1e-6)
    assert rt_ct.wall_s == pytest.approx(rt_iso.wall_s, rel=1e-6)
    # link priority bounds the serving inflation
    _, rs_pr = corun(shared=True, prio=8.0)
    assert rs_pr.p99_s < rs_sh.p99_s


def test_shared_link_weighted_priority_shares():
    """Water-filling with per-flow priorities: uncapped flows split the
    aggregate in priority proportion; equal priorities keep the classic
    even split (the uniform fast path)."""
    from repro.serverless.events import _Transfer
    from repro.serverless.stores import SharedLink

    def mk(prios):
        link = SharedLink("t", aggregate_gbps=8.0, per_stream_gbps=100.0,
                          latency_s=0.0)
        trs = [_Transfer(link, 1e9, 0.0, lambda: None, False, prio=p)
               for p in prios]
        for tr in trs:
            link.add_flow(tr)
        rates = link.rates()
        return [rates[tr.fid] for tr in trs]

    r3, r1 = mk([3.0, 1.0])
    assert r3 == pytest.approx(6.0)
    assert r1 == pytest.approx(2.0)
    even = mk([2.0, 2.0])
    assert even == pytest.approx([4.0, 4.0])
